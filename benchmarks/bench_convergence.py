"""Paper Figs. 4/5: TopK (+QSGD) SGD convergence vs full dense SGD on a
small LM — end accuracy parity is the claim being reproduced."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.train.state import TrainConfig
from repro.train.train_step import build_train_step, init_state


def _run(mesh, sync: SyncConfig, steps=30):
    # leaf shapes sized so canonical cols/bucket divides dp=4 (the batched
    # sparse path requires m %% dp == 0; smaller leaves fall back to dense)
    cfg = ModelConfig(name="bench", family="dense", num_layers=2, d_model=512,
                      num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=512,
                      dtype=jnp.float32, param_dtype=jnp.float32, max_seq_len=64)
    model = build_model(cfg)
    tcfg = TrainConfig(sync=sync, optimizer=OptimizerConfig(),
                       schedule=ScheduleConfig(peak_lr=3e-3, warmup_steps=5,
                                               total_steps=100))
    step_fn, _ = build_train_step(model, tcfg, mesh)
    state, _ = init_state(model, tcfg, mesh)
    dcfg = DataConfig(global_batch=8, seq_len=32, vocab_size=256)
    key = jax.random.PRNGKey(0)
    losses = []
    with mesh:
        for i in range(steps):
            batch = jax.tree.map(jnp.asarray, synthetic_batch(dcfg, i))
            state, m = step_fn(state, batch, jax.random.fold_in(key, i))
            losses.append(float(m["loss"]))
    return losses


def run() -> list[tuple[str, float, str]]:
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=4, model=2)
    rows = []
    t0 = time.perf_counter()
    dense = _run(mesh, SyncConfig(mode="dense"))
    variants = {
        "fig4_dense_sgd": dense,
        "fig4_topk_12.5pct": _run(mesh, SyncConfig(
            mode="sparcml", k_per_bucket=16, bucket_size=128,
            algorithm="dsar_split_allgather", min_sparse_size=65536, impl="ref")),
        "fig4_topk_qsgd4bit": _run(mesh, SyncConfig(
            mode="sparcml", k_per_bucket=16, bucket_size=128, qsgd_bits=4, qsgd_bucket=128,
            algorithm="dsar_split_allgather", min_sparse_size=65536, impl="ref")),
        "fig4_topk_1.6pct": _run(mesh, SyncConfig(
            mode="sparcml", k_per_bucket=2, bucket_size=128,
            algorithm="ssar_split_allgather", min_sparse_size=65536, impl="ref")),
    }
    us = (time.perf_counter() - t0) * 1e6
    for name, losses in variants.items():
        gap = (losses[-1] - dense[-1]) / dense[-1]
        rows.append((name, us / len(variants),
                     f"loss0={losses[0]:.3f},loss_end={losses[-1]:.3f},"
                     f"gap_vs_dense={gap:+.2%}"))
    return rows
