"""Fault-tolerance cost (DESIGN.md §12): guard overhead + recovery latency.

Two claims behind the fault-tolerant runtime:

* ``guard_overhead`` — the guarded step (in-graph all-finite check over
  the grad leaves + the conditional no-op apply + the injection select)
  versus the identical unguarded step. The guard is a handful of
  reductions over already-materialized gradients, so it must be nearly
  free: acceptance <= 5%. ABBA-paired rounds, best-of-min per arm
  (methodology of ``bench_obs_health``).
* ``recovery_<class>`` — wall-clock cost of surviving one injected
  fault of each recoverable class under the async driver (collective
  raise, data-pipeline stall, non-finite escalation), measured as the
  faulted run's wall time minus the clean run's on the same compiled
  step and checkpoint wiring. Includes detection, backoff, CRC-verified
  restore, and the replayed steps — the end-to-end price of one
  recovery, not just the restore. Informational (wall-clock on a shared
  runner); the gated cell is the overhead row.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.core.compressor import SyncConfig

P_DATA = 4
STEPS = 8       # steps per timed block (overhead) / per driver run
ROUNDS = 4
CKPT_EVERY = 2


def bench_meta() -> dict:
    return {"p_data": P_DATA, "steps_per_block": STEPS, "rounds": ROUNDS,
            "ckpt_every": CKPT_EVERY}


def _configs():
    from repro.models.config import ModelConfig
    from repro.optim.optimizers import OptimizerConfig
    from repro.optim.schedule import ScheduleConfig
    from repro.train.state import TrainConfig

    cfg = ModelConfig(name="ft", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype=jnp.float32, param_dtype=jnp.float32,
                      max_seq_len=32)
    sync = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                      algorithm="dsar_split_allgather", min_sparse_size=1024,
                      impl="ref")
    tcfg = TrainConfig(sync=sync, optimizer=OptimizerConfig(),
                       schedule=ScheduleConfig(peak_lr=1e-3, warmup_steps=5,
                                               total_steps=100000),
                       zero1=False)
    return cfg, tcfg


def _build(guard: bool):
    from repro.compat import make_mesh
    from repro.models.model import build_model
    from repro.runtime import pipeline as rp
    from repro.train.train_step import init_state

    cfg, tcfg = _configs()
    model = build_model(cfg)
    mesh = make_mesh((P_DATA, 2), ("data", "model"))
    # staleness=0: the plain synchronous step, so the guarded driver runs
    # below can rewind to a checkpoint with no in-flight buffers to lose
    fn, _, _ = rp.build_pipelined_step(model, tcfg, mesh, staleness=0,
                                       telemetry=False, guard=guard,
                                       inject=guard)
    st, _ = init_state(model, tcfg, mesh)
    return mesh, model, tcfg, fn, st


def _guard_overhead():
    from repro.data.pipeline import DataConfig, synthetic_batch
    from repro.runtime.faults import FAULT_KEY

    dcfg = DataConfig(global_batch=8, seq_len=16, vocab_size=256)
    key = jax.random.PRNGKey(0)

    mesh, model, tcfg, fn_on, st_on = _build(guard=True)
    _, _, _, fn_off, st_off = _build(guard=False)
    n_leaves = len(jax.tree.leaves(st_on.params))
    clean_flag = jnp.zeros((n_leaves,), jnp.float32)
    states = {"on": st_on, "off": st_off}
    fns = {"on": fn_on, "off": fn_off}

    def block(tag, start):
        t0 = time.perf_counter()
        st = states[tag]
        for i in range(start, start + STEPS):
            batch = jax.tree.map(jnp.asarray, synthetic_batch(dcfg, i))
            if tag == "on":
                batch[FAULT_KEY] = clean_flag
            st, m = fns[tag](st, batch, jax.random.fold_in(key, i))
            jax.block_until_ready(m["loss"])
        states[tag] = st
        return (time.perf_counter() - t0) / STEPS * 1e6

    with mesh:
        block("on", 0), block("off", 0)           # compile + warm
        t_on, t_off = [], []
        for r in range(ROUNDS):                   # ABBA-paired rounds
            start = (r + 1) * STEPS
            if r % 2 == 0:
                a = block("on", start)
                b = block("off", start)
            else:
                b = block("off", start)
                a = block("on", start)
            t_on.append(a)
            t_off.append(b)
    us_on, us_off = min(t_on), min(t_off)
    overhead = us_on / us_off - 1.0
    rows = [("guard_overhead", us_on,
             f"off={us_off:.1f}us,overhead={overhead:+.1%},"
             f"le_5pct={overhead <= 0.05}")]
    return rows, (mesh, model, tcfg, fn_on)


def _driver_run(mesh, model, tcfg, fn, injector, *, recovery=None,
                timeout_s=60.0):
    from repro import obs as obs_mod
    from repro.data.pipeline import DataConfig, synthetic_batch
    from repro.runtime import driver as rt_driver
    from repro.train import checkpoint as ckpt
    from repro.train.train_step import init_state

    dcfg = DataConfig(global_batch=8, seq_len=16, vocab_size=256)
    key = jax.random.PRNGKey(0)
    obs = obs_mod.configure(metrics=True, set_as_default=False)
    ckpt_dir = tempfile.mkdtemp(prefix="bench_faults_ck_")
    try:
        def ckpt_fn(s):
            ckpt.save(ckpt_dir, s, dp_total=P_DATA,
                      opt_layout=ckpt.opt_layout_of(tcfg))

        def restore_fn():
            like, _ = init_state(model, tcfg, mesh)
            return ckpt.restore(ckpt_dir, like, dp_total=P_DATA,
                                step=ckpt.latest_valid_step(ckpt_dir),
                                verify=True)

        with mesh:
            state, _ = init_state(model, tcfg, mesh)
            injector.bind(n_leaves=len(jax.tree.leaves(state.params)))
            t0 = time.perf_counter()
            state, log = rt_driver.run_pipelined(
                fn, state, start_step=0, num_steps=STEPS,
                batch_fn=lambda s: synthetic_batch(dcfg, s),
                key_fn=lambda s: jax.random.fold_in(key, s),
                cfg=rt_driver.DriverConfig(depth=1, prefetch=1,
                                           prefetch_timeout_s=timeout_s),
                ckpt_every=CKPT_EVERY, ckpt_fn=ckpt_fn,
                restore_fn=restore_fn, obs=obs, recovery=recovery,
                injector=injector)
            wall = time.perf_counter() - t0
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    return wall, log


def _recovery_latency(built):
    from repro.runtime.faults import (FaultInjector, FaultPlan,
                                      RecoveryConfig)

    mesh, model, tcfg, fn = built
    fast = RecoveryConfig(backoff_base_s=0.001, backoff_max_s=0.005)
    # clean reference on the same compiled step + checkpoint cadence
    clean_wall, _ = _driver_run(mesh, model, tcfg, fn,
                                FaultInjector(FaultPlan()), recovery=fast)

    cases = {
        "collective": dict(
            injector=FaultInjector(FaultPlan.single("collective", 3)),
            timeout_s=60.0),
        # the stall must outlast the take() deadline to be detected; its
        # recovery price is dominated by that bounded wait, not the nap
        # (the sleeping producer is a daemon thread)
        "stall": dict(
            injector=FaultInjector(
                FaultPlan.single("stall", 2, duration_s=4.0)),
            timeout_s=0.3),
        "nonfinite": dict(
            injector=FaultInjector(
                FaultPlan.single("nonfinite", 3, mode="nan", repeat=2)),
            timeout_s=60.0),
    }
    rows = []
    for cls, kw in cases.items():
        rec = fast if cls != "nonfinite" else RecoveryConfig(
            backoff_base_s=0.001, backoff_max_s=0.005,
            max_consecutive_nonfinite=2)
        wall, log = _driver_run(mesh, model, tcfg, fn, kw["injector"],
                                recovery=rec, timeout_s=kw["timeout_s"])
        rows.append((f"recovery_{cls}", max(0.0, wall - clean_wall) * 1e6,
                     f"restarts={log.restarts},wall={wall:.2f}s,"
                     f"clean={clean_wall:.2f}s"))
    return rows


def run() -> list[tuple[str, float, str]]:
    rows, built = _guard_overhead()
    rows.extend(_recovery_latency(built))
    return rows
