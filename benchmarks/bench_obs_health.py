"""Observability + health-engine overhead (DESIGN.md §10.5-§10.7).

One paired measurement: the pipelined training step with the FULL
compression-health observability stack on — in-graph (4,) mass
telemetry, per-step ``record_bucket_telemetry`` into a live metrics
registry, and a windowed ``HealthMonitor.evaluate()`` at every would-be
drain barrier — versus everything off (``telemetry=False`` compiles the
in-graph stats out entirely; the registry is disabled so every host-side
record is a no-op). Acceptance: <= 15% overhead. The bound is wider
than bench_adapt's bare-telemetry 5% because this arm also pays the
host-side histogram folds and the rule sweep.

Methodology matches ``bench_adapt._telemetry_overhead``: ABBA-paired
rounds, best-of-min per arm (noise-robust on shared CI runners).
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressor import SyncConfig

P_DATA = 4
STEPS = 12
ROUNDS = 6
HEALTH_EVERY = 4   # steps between HealthMonitor sweeps (a drain cadence)


def bench_meta() -> dict:
    return {"p_data": P_DATA, "steps_per_block": STEPS, "rounds": ROUNDS,
            "health_every": HEALTH_EVERY}


def _build(telemetry: bool):
    from repro.compat import make_mesh
    from repro.models.config import ModelConfig
    from repro.models.model import build_model
    from repro.optim.optimizers import OptimizerConfig
    from repro.optim.schedule import ScheduleConfig
    from repro.runtime import pipeline as rp
    from repro.train.state import TrainConfig
    from repro.train.train_step import init_state

    cfg = ModelConfig(name="oh", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype=jnp.float32, param_dtype=jnp.float32,
                      max_seq_len=32)
    sync = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                      algorithm="dsar_split_allgather", min_sparse_size=1024,
                      impl="ref")
    tcfg = TrainConfig(sync=sync, optimizer=OptimizerConfig(),
                       schedule=ScheduleConfig(peak_lr=1e-3, warmup_steps=5,
                                               total_steps=100000),
                       zero1=False)
    model = build_model(cfg)
    mesh = make_mesh((P_DATA, 2), ("data", "model"))
    fn, _, plan = rp.build_pipelined_step(model, tcfg, mesh, staleness=1,
                                          telemetry=telemetry)
    st, _ = init_state(model, tcfg, mesh)
    st = rp.attach_inflight(st, plan, mesh)
    return mesh, fn, st


def _overhead() -> list[tuple[str, float, str]]:
    from repro.data.pipeline import DataConfig, synthetic_batch
    from repro.obs.health import HealthConfig, HealthMonitor
    from repro.obs.metrics import MetricsRegistry, record_bucket_telemetry

    dcfg = DataConfig(global_batch=8, seq_len=16, vocab_size=256)
    key = jax.random.PRNGKey(0)

    mesh_on, fn_on, st_on = _build(telemetry=True)
    _, fn_off, st_off = _build(telemetry=False)
    states = {"on": st_on, "off": st_off}
    fns = {"on": fn_on, "off": fn_off}
    reg_on = MetricsRegistry(enabled=True)
    reg_off = MetricsRegistry(enabled=False)
    regs = {"on": reg_on, "off": reg_off}
    # a small window so the rule sweep actually fires during the run
    monitors = {tag: HealthMonitor(regs[tag],
                                   HealthConfig(window=8, min_samples=4))
                for tag in ("on", "off")}
    n_events = 0

    def block(tag, start):
        nonlocal n_events
        reg, mon = regs[tag], monitors[tag]
        t0 = time.perf_counter()
        st = states[tag]
        for i in range(start, start + STEPS):
            batch = jax.tree.map(jnp.asarray, synthetic_batch(dcfg, i))
            ts = time.perf_counter()
            st, m = fns[tag](st, batch, jax.random.fold_in(key, i))
            jax.block_until_ready(m["loss"])
            reg.series("train/step_time_s").append(time.perf_counter() - ts)
            if "telemetry" in m:
                record_bucket_telemetry(reg, m["telemetry"])
            if (i + 1) % HEALTH_EVERY == 0:
                n_events += len(mon.evaluate())
        states[tag] = st
        return (time.perf_counter() - t0) / STEPS * 1e6

    with mesh_on:
        block("on", 0), block("off", 0)           # compile + warm
        t_on, t_off = [], []
        for r in range(ROUNDS):                   # ABBA-paired rounds
            start = (r + 1) * STEPS
            if r % 2 == 0:
                a = block("on", start)
                b = block("off", start)
            else:
                b = block("off", start)
                a = block("on", start)
            t_on.append(a)
            t_off.append(b)
    us_on = min(t_on)
    us_off = min(t_off)
    overhead = us_on / us_off - 1.0
    n_buckets = sum(1 for k in reg_on.metrics if k.startswith("bucket/"))
    return [("obs_health_overhead", us_on,
             f"off={us_off:.1f}us,overhead={overhead:+.1%},"
             f"le_15pct={overhead <= 0.15},hists={n_buckets},"
             f"health_events={n_events}")]


def run() -> list[tuple[str, float, str]]:
    return _overhead()
