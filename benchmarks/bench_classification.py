"""Paper Table 2 / §8.2: distributed sparse linear classification (the
MPI-OPT scenario). Gradients of linear models on trigram-sparse data are
naturally sparse; communication is lossless.

Reports: epoch time dense vs sparse aggregation on 8 host ranks, plus the
modeled communication-volume ratio at P=32 (the paper's Piz Daint scale).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core.allreduce import make_sparse_allreduce
from repro.data.sparse_datasets import make_url_like_dataset


def run() -> list[tuple[str, float, str]]:
    from repro.compat import make_mesh
    rows = []
    n_feat = 1 << 20
    idx, val, y = make_url_like_dataset(
        n_samples=1024, n_features=n_feat, nnz_per_sample=64)
    mesh = make_mesh((8,), ("data",))

    # per-rank minibatch gradient of logistic loss (naturally sparse)
    def local_grad(w, rank, step):
        sl = slice(rank * 16, rank * 16 + 16)
        ii, vv, yy = idx[sl], val[sl], y[sl]
        margins = (vv * np.asarray(w)[ii]).sum(1)
        coef = -yy / (1 + np.exp(yy * margins)) / len(yy)
        g = np.zeros(n_feat, np.float32)
        np.add.at(g, ii.ravel(), (coef[:, None] * vv).ravel())
        return g

    w = np.zeros(n_feat, np.float32)
    # measured: dense psum vs sparse allreduce of the 8 rank gradients
    for algo, name in (("dense", "dense_allreduce"),
                       ("ssar_split_allgather", "sparse_allreduce")):
        f = make_sparse_allreduce(mesh, "data", n_feat, k_per_bucket=8,
                                  bucket_size=512, algorithm=algo)
        grads = np.stack([local_grad(w, r, 0) for r in range(8)])
        out = f(jnp.asarray(grads).reshape(-1), None)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            out = f(jnp.asarray(grads).reshape(-1), None)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 3 * 1e6
        nnz = int((np.asarray(out) != 0).sum())
        rows.append((f"table2_{name}", us, f"P=8,N={n_feat},result_nnz={nnz}"))

    # modeled at paper scale: P=32, URL-like density
    k = 64 * 16  # per-rank gradient nnz (batch 16 x 64 feats)
    t_dense = cm.t_dense_allreduce(32, n_feat)
    t_sparse = cm.t_ssar_recursive_double(32, k, n_feat)[1]
    rows.append(("table2_model_P32", t_dense * 1e6,
                 f"dense={t_dense*1e3:.2f}ms,sparse={t_sparse*1e3:.3f}ms,"
                 f"speedup={t_dense/t_sparse:.1f}x"))
    return rows
