"""Adaptive re-planning (DESIGN.md §7) on a density-drifting workload.

Three views:

  (a) drift: a real fused-bucket reduction (auto-SPMD executor, 8 ranks)
      over a gradient stream whose cross-rank TopK overlap DRIFTS mid-run
      — an EF-warmup-like phase where every rank selects the same hot
      coordinates (post-reduction nnz ~ k, sparse wins) followed by a
      steady state of disjoint per-rank supports (nnz ~ P*k >= delta,
      dense representation forced). The adaptive controller consumes the
      executor's real telemetry and swaps plans; the total MODELED
      collective time (alpha-beta at the measured per-step nnz) is
      compared for static-worst / static-best / adaptive. Acceptance:
      >= 1 swap, adaptive beats static-worst, ends at static-best's
      steady-state cost, and stays within tolerance of static-best
      overall (it pays only the detection windows).
  (b) telemetry overhead: measured wall time of the pipelined step with
      the per-bucket stats emitted vs compiled out (<= 5% acceptance).
  (c) the one-shot alpha-beta calibrator's fitted NetworkParams.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import comm
from repro.core import cost_model as cm
from repro.core.compressor import SyncConfig
from repro.runtime.adapt import AdaptConfig, AdaptiveController

P_RANKS = 8
N = 1 << 20
PHASE_STEPS = 40          # per phase; drift happens at the boundary


def _drift_setup():
    from jax.sharding import PartitionSpec as P

    # No QSGD here: the 4-bit gather's stochastic rounding zeroes small
    # reduced values, which would confound the fill-in telemetry the
    # drift is meant to exercise (and hide the true union size).
    cfg = SyncConfig(mode="sparcml", k_per_bucket=16, bucket_size=128,
                     algorithm="auto", min_sparse_size=1024, impl="ref",
                     fusion_bucket_bytes=1 << 20)
    shapes = {"g": jax.ShapeDtypeStruct((N,), jnp.float32)}
    plan = comm.build_sync_plan(shapes, {"g": P()}, cfg, P_RANKS)
    return cfg, plan


def bench_meta() -> dict:
    """BENCH-header extras (benchmarks/run.py schema v2): the plan this
    module's drift run starts from, git-describe-ably."""
    _, plan = _drift_setup()
    return {"plan_signature": plan.signature(), "p_ranks": P_RANKS,
            "n_elems": N}


def _drift_grads(cfg, step: int, rng) -> jnp.ndarray:
    """(P, N) per-rank gradients. Phase A (step < PHASE_STEPS): every
    rank's TopK hits the SAME hot coordinates -> full overlap. Phase B:
    disjoint per-rank hot sets -> fill-in ~ P*k >= delta."""
    base = rng.standard_normal((P_RANKS, N)).astype(np.float32) * 0.01
    starts = np.arange(N // cfg.bucket_size)[:, None] * cfg.bucket_size
    per = cfg.k_per_bucket
    if step < PHASE_STEPS:
        # every rank's TopK hits the first `per` slots of every bucket
        cols = (starts + np.arange(per)[None, :]).reshape(-1)
        base[:, cols] += 5.0
    else:
        for r in range(P_RANKS):
            # rank r owns slots [r*per, (r+1)*per) of every TopK bucket
            cols = (starts + r * per + np.arange(per)[None, :]).reshape(-1)
            base[r, cols] += 5.0
    return jnp.asarray(base)


def _modeled_step_cost(plan, densities, net) -> float:
    return sum(cm.plan_bucket_times(plan, P_RANKS, net, densities))


def _run_drift() -> list[tuple[str, float, str]]:
    cfg, base_plan = _drift_setup()
    net = cm.DEFAULT_NET
    acfg = AdaptConfig(window=4, hysteresis=0.1, patience=2,
                       calibrate=False)
    ctrl = AdaptiveController(base_plan, net, acfg)
    rng = np.random.default_rng(0)
    residuals = {k: jnp.zeros(s.shape, s.dtype)
                 for k, s in base_plan.residual_shapes().items()}
    # Static dense reference, run in lockstep on the SAME grad trace:
    # on the auto-SPMD lowering every algorithm folds into the exact
    # sum, so the adaptive run must match it bit for bit even across
    # plan swaps onto the capacity-clamped portfolio (DESIGN.md §9).
    dense_plan = base_plan.replan(
        algorithms={b.name: "dense" for b in base_plan.buckets if b.sparse})
    dense_res = {k: jnp.zeros(s.shape, s.dtype)
                 for k, s in base_plan.residual_shapes().items()}
    key = jax.random.PRNGKey(0)

    jitted = {}

    def reduce_with(plan):
        sig = plan.signature()
        if sig not in jitted:
            jitted[sig] = jax.jit(partial(
                comm.reduce_buckets_spmd, plan, p_data=P_RANKS))
        return jitted[sig]

    steps = 2 * PHASE_STEPS
    per_step_nnz: list[dict] = []
    adaptive_cost = 0.0
    spmd_equals_dense = True
    plans_seen = {base_plan.signature(): base_plan}
    for step in range(steps):
        plan = ctrl.plan
        leaves = [_drift_grads(cfg, step, rng)]
        skey = jax.random.fold_in(key, step)
        reduced, residuals, telem = reduce_with(plan)(
            leaves, residuals, skey)
        red_ref, dense_res, _ = reduce_with(dense_plan)(
            leaves, dense_res, skey)
        spmd_equals_dense &= all(
            np.array_equal(np.asarray(reduced[name]),
                           np.asarray(red_ref[name]))
            for name in red_ref)
        row = {name: float(np.asarray(v)[0]) for name, v in telem.items()}
        per_step_nnz.append(row)
        adaptive_cost += _modeled_step_cost(plan, row, net)
        accepted = ctrl.observe_step(row)
        if accepted is not None:
            plans_seen[accepted.signature()] = accepted

    # Static references: every plan the run visited, held fixed. The
    # best/worst static plan is decided on the same measured trace.
    static = {
        sig: sum(_modeled_step_cost(p, row, net) for row in per_step_nnz)
        for sig, p in plans_seen.items()
    }
    best_sig = min(static, key=static.get)
    worst_sig = max(static, key=static.get)
    tail = per_step_nnz[-acfg.window:]
    adaptive_tail = np.mean([_modeled_step_cost(ctrl.plan, r, net)
                             for r in tail])
    # "ends at best": the steady-state cost matches the best ANY static
    # plan could achieve on the final-phase densities
    best_tail = min(np.mean([_modeled_step_cost(p, r, net) for r in tail])
                    for p in plans_seen.values())
    within_tail = bool(adaptive_tail <= best_tail * 1.05)
    within_total = bool(adaptive_cost <= static[best_sig] * 1.25)
    beats_worst = bool(adaptive_cost <= static[worst_sig])
    portfolio = ("ssar_balanced_split", "ssar_rearranged_rs")
    selects_portfolio = any(a in portfolio
                            for p in plans_seen.values()
                            for a in p.algorithms().values())
    # On a drift whose phases favor DIFFERENT algorithms, no static plan
    # is good everywhere — adaptive should beat the best static too,
    # paying only the detection windows.
    return [
        ("adapt_drift_static_worst", static[worst_sig] / steps * 1e6,
         f"plan={worst_sig.split(',')[0]},steps={steps}"),
        ("adapt_drift_static_best", static[best_sig] / steps * 1e6,
         f"plan={best_sig.split(',')[0]}"),
        ("adapt_drift_adaptive", adaptive_cost / steps * 1e6,
         f"swaps={ctrl.swaps},ge1_swap={ctrl.swaps >= 1},"
         f"tail_us={adaptive_tail*1e6:.2f},best_tail_us={best_tail*1e6:.2f},"
         f"ends_at_best={within_tail},within_total_tol={within_total},"
         f"beats_worst={beats_worst},selects_portfolio={selects_portfolio},"
         f"spmd_equals_dense={spmd_equals_dense}"),
    ]


def _emulated_parity() -> list[tuple[str, float, str]]:
    """Single-step probe of the psum-emulated lowering: a plan on each
    portfolio algorithm must reduce bit-identically to the static dense
    reference (the emulated executor reroutes every SSAR family to the
    exact DSAR path — DESIGN.md §4)."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map

    mesh = make_mesh((8,), ("data",))
    n = 1 << 15
    cfg = SyncConfig(mode="sparcml", k_per_bucket=16, bucket_size=128,
                     algorithm="dsar_split_allgather", min_sparse_size=1024,
                     impl="ref", fusion_bucket_bytes=1 << 16)
    shapes = {"g": jax.ShapeDtypeStruct((n,), jnp.float32)}
    base = comm.build_sync_plan(shapes, {"g": P()}, cfg, 8)
    sparse = [b.name for b in base.buckets if b.sparse]
    rng = np.random.default_rng(5)
    g = jnp.asarray(rng.standard_normal((8, n)).astype(np.float32))
    rid = jnp.arange(8, dtype=jnp.int32)
    key = jax.random.PRNGKey(0)

    def run(plan):
        res = plan.init_residuals()
        rspecs = {k: P("data", None, None) for k in res}

        def inner(gr, r, rid):
            out, _ = comm.execute_plan(
                plan, [gr[0]], r, key, data_axis="data", p_data=8,
                native=False, data_rank=rid[0])
            return out[0]

        f = shard_map(inner, mesh=mesh,
                      in_specs=(P("data", None), rspecs, P("data")),
                      out_specs=P(), check_vma=False)
        return np.asarray(f(g, res, rid))

    ref = run(base.replan(algorithms={nm: "dense" for nm in sparse}))
    flags = []
    for algo in ("ssar_balanced_split", "ssar_rearranged_rs"):
        out = run(base.replan(algorithms={nm: algo for nm in sparse}))
        flags.append(f"{algo}_equal={bool(np.array_equal(out, ref))}")
    return [("adapt_emulated_parity", 0.0, ",".join(flags))]


def _telemetry_overhead() -> list[tuple[str, float, str]]:
    from repro.compat import make_mesh
    from repro.data.pipeline import DataConfig, synthetic_batch
    from repro.models.config import ModelConfig
    from repro.models.model import build_model
    from repro.optim.optimizers import OptimizerConfig
    from repro.optim.schedule import ScheduleConfig
    from repro.runtime import pipeline as rp
    from repro.train.state import TrainConfig
    from repro.train.train_step import init_state

    cfg = ModelConfig(name="ta", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype=jnp.float32, param_dtype=jnp.float32,
                      max_seq_len=32)
    sync = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                      algorithm="dsar_split_allgather", min_sparse_size=1024,
                      impl="ref")
    tcfg = TrainConfig(sync=sync, optimizer=OptimizerConfig(),
                       schedule=ScheduleConfig(peak_lr=1e-3, warmup_steps=5,
                                               total_steps=100000),
                       zero1=False)
    dcfg = DataConfig(global_batch=8, seq_len=16, vocab_size=256)
    model = build_model(cfg)
    mesh = make_mesh((4, 2), ("data", "model"))
    steps, rounds = 12, 6
    key = jax.random.PRNGKey(0)

    with mesh:
        fns, states = {}, {}
        for tag, emit in (("with", True), ("without", False)):
            fn, _, plan = rp.build_pipelined_step(model, tcfg, mesh,
                                                  staleness=1,
                                                  telemetry=emit)
            st, _ = init_state(model, tcfg, mesh)
            fns[tag] = fn
            states[tag] = rp.attach_inflight(st, plan, mesh)

        def block(tag, start):
            t0 = time.perf_counter()
            st = states[tag]
            for i in range(start, start + steps):
                batch = jax.tree.map(jnp.asarray, synthetic_batch(dcfg, i))
                st, m = fns[tag](st, batch, jax.random.fold_in(key, i))
                jax.block_until_ready(m["loss"])
            states[tag] = st
            return (time.perf_counter() - t0) / steps * 1e6

        block("with", 0), block("without", 0)     # compile + warm
        t_with, t_without = [], []
        for r in range(rounds):                   # ABBA-paired rounds
            start = (r + 1) * steps
            if r % 2 == 0:
                a = block("with", start)
                b = block("without", start)
            else:
                b = block("without", start)
                a = block("with", start)
            t_with.append(a)
            t_without.append(b)
    us_with = min(t_with)                         # best-of: noise-robust
    us_without = min(t_without)
    overhead = us_with / us_without - 1.0
    return [("adapt_telemetry_overhead", us_with,
             f"without={us_without:.1f}us,overhead={overhead:+.1%},"
             f"le_5pct={overhead <= 0.05}")]


def _mode_recommendation() -> list[tuple[str, float, str]]:
    """Output-mode drift (DESIGN.md §11): sweep the post-reduction
    density from EF-warm (nnz ~ k) to fully filled-in and ask the
    controller for its replicated <-> scattered restart recommendation
    at every point. The mode is pinned per run (never a maybe_swap), so
    the property that matters is STICKINESS: along the monotone sweep
    the recommendation must switch at most once per direction, and at
    the crossover there must be a non-empty hysteresis band where BOTH
    incumbents keep their own layout — a workload hovering there never
    flaps across restarts."""
    from repro.core.cost_model import plan_bucket_times, t_param_allgather

    cfg, base = _drift_setup()
    net = cm.DEFAULT_NET
    acfg = AdaptConfig(window=4, hysteresis=0.1, patience=2,
                       calibrate=False)
    ctrl_r = AdaptiveController(base, net, acfg)
    scat = base.replan(output_mode="scattered")
    ctrl_s = AdaptiveController(scat, net, acfg)
    t_ag = sum(t_param_allgather(P_RANKS, b.n, net)
               for g in base.groups for b in g.buckets)

    def dens(frac):
        return {b.name: max(float(cfg.k_per_bucket), frac * b.cols)
                for grp in base.groups for b in grp.buckets}

    # the drift: EF-warm + compute-rich (allgather fully hidden) ->
    # filled-in + compute-poor (allgather fully exposed); the boundary
    # phase sits at the modeled indifference point — exposure chosen so
    # NEITHER layout beats the other by the hysteresis margin, which is
    # exactly the workload that must not flap across restarts
    mid = 0.3
    tr_mid = sum(plan_bucket_times(base, P_RANKS, net,
                                   densities=dens(mid)))
    tsx_mid = sum(plan_bucket_times(scat, P_RANKS, net,
                                    densities=dens(mid)))
    h = acfg.hysteresis
    lo = (1.0 - h) * tr_mid - tsx_mid     # below: scat incumbent flips
    hi = tr_mid / (1.0 - h) - tsx_mid     # above: rep incumbent flips
    e_mid = min(max((lo + hi) / 2.0, 0.0), t_ag)
    phases = ([(dens(0.0), t_ag)] * 8          # A: scattered clearly wins
              + [(dens(mid), t_ag - e_mid)] * 8   # B: indifference band
              + [(dens(1.0), 0.0)] * 8)        # C: replicated clearly wins
    recs_r = [ctrl_r.recommend_output_mode(d, ov) for d, ov in phases]
    recs_s = [ctrl_s.recommend_output_mode(d, ov) for d, ov in phases]
    flips_r = sum(a != b for a, b in zip(recs_r, recs_r[1:]))
    flips_s = sum(a != b for a, b in zip(recs_s, recs_s[1:]))
    covers_both = ("scattered" in recs_r and "replicated" in recs_r
                   and "scattered" in recs_s and "replicated" in recs_s)
    # the hysteresis band: phase-B points where each incumbent keeps
    # its own layout even though the other is (marginally) modeled ahead
    band = sum(r == "replicated" and s == "scattered"
               for r, s in zip(recs_r, recs_s))
    no_flap = flips_r <= 1 and flips_s <= 1
    return [(
        "adapt_mode_recommendation", e_mid * 1e6,
        f"indiff_exposure_us,no_flap={no_flap},flips={flips_r}/{flips_s},"
        f"hysteresis_band_pts={band},covers_both_modes={covers_both},"
        f"recs_at_phases={recs_r[0][:4]}/{recs_r[8][:4]}/{recs_r[16][:4]}")]


def _calibration() -> list[tuple[str, float, str]]:
    from repro.compat import make_mesh
    from repro.utils.calibrate import calibrate

    mesh = make_mesh((8,), ("data",))
    net = calibrate(mesh, sizes=(1 << 12, 1 << 15, 1 << 18), repeats=3)
    return [("adapt_calibrated_alpha", net.alpha * 1e6,
             f"link_GBps={net.link_bytes_per_s/1e9:.2f},"
             f"default_alpha_us={cm.DEFAULT_NET.alpha*1e6:.2f}")]


def run() -> list[tuple[str, float, str]]:
    return (_run_drift() + _emulated_parity() + _mode_recommendation()
            + _telemetry_overhead() + _calibration())
