"""Dry-run profiler: multiplier-weighted HBM/collective attribution per
computation + top ops — the 'profile' used by the §Perf hypothesis loop.

  PYTHONPATH=src python -m benchmarks.profile_cell --arch moonshot-v1-16b-a3b \
      --shape train_4k [--multi-pod] [--sync dense]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
from collections import defaultdict

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import lower_cell
from repro.utils import hlo_cost as hc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sync", default=None)
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    with mesh:
        lowered, meta = lower_cell(args.arch, args.shape, mesh, args.sync)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    comps, entry = hc.parse_module(hlo)
    mc = hc.total_cost(hlo)
    print(f"totals/chip: flops={mc.flops:.3e} hbm={mc.hbm_bytes:.3e} "
          f"coll={mc.coll_bytes:.3e}")
    print("coll by kind:", {k: f"{v:.2e}" for k, v in mc.coll_by_kind.items()})

    eff_h, eff_c = defaultdict(float), defaultdict(float)

    def walk(name, mult):
        c = comps.get(name)
        if c is None:
            return
        eff_h[name] += (c.hbm_bytes + sum(
            comps.get(ch, hc.CompCost()).boundary_bytes()
            for k, ch, _ in c.children if k == "fusion")) * mult
        eff_c[name] += sum(c.coll_by_kind.values()) * mult
        for kind, child, cond in c.children:
            m = mult * ((comps.get(cond, hc.CompCost()).max_const or 1)
                        if kind == "while" else 1)
            if kind in ("fusion", "call"):
                continue
            walk(child, m)

    walk(entry, 1.0)

    for label, eff in (("HBM", eff_h), ("COLLECTIVE", eff_c)):
        rows = sorted(eff.items(), key=lambda kv: -kv[1])[:4]
        print(f"--- weighted {label} by computation")
        for n, b in rows:
            if b:
                print(f"  {b:.3e}  {n[:70]}")
        if not rows or not rows[0][1]:
            continue
        heavy = rows[0][0]
        idx = hlo.find(heavy)
        cl = []
        for ln in hlo[idx:].splitlines()[1:]:
            if ln.strip() == "}":
                break
            m = hc._RESULT_RE.match(ln)
            km = hc._OP_KIND_RE.search(ln)
            kind = km.group(1) if km else "?"
            if m and not m.group(2) and kind not in hc._SKIP_HBM:
                want = (kind in hc._COLLECTIVES if label == "COLLECTIVE"
                        else True)
                if want:
                    cl.append((hc._nbytes(m.group(3), hc._dims(m.group(4))),
                               kind, ln.strip()[:95]))
        print(f"    top ops of {heavy[:45]}:")
        for b, kind, ln in sorted(cl, reverse=True)[: args.top]:
            print(f"    {b:.2e} [{kind}] {ln}")


if __name__ == "__main__":
    main()
