"""Kernel microbenchmarks: bucket_topk / qsgd / bucket_scatter wall time
(jnp reference path on CPU — interpret-mode Pallas timing is not
meaningful; TPU timing comes from the roofline model in §Roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.bucket_topk.ops import bucket_topk
from repro.kernels.bucket_scatter.ops import bucket_scatter
from repro.kernels.qsgd_pack.ops import qsgd_pack
from repro.kernels.qsgd_unpack.ops import qsgd_unpack


def _time(fn, *args, reps=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[tuple[str, float, str]]:
    key = jax.random.PRNGKey(0)
    nb, b = 2048, 512  # 1M elements
    x = jax.random.normal(key, (nb, b))
    rand = jax.random.bits(key, (nb, 1024), dtype=jnp.uint32)
    xq = jax.random.normal(key, (nb, 1024))
    rows = []
    us = _time(lambda a: bucket_topk(a, 4, impl="ref"), x)
    rows.append(("kernel_bucket_topk_1M_k4", us, f"{nb*b/us:.0f} elem/us"))
    us = _time(lambda a, r: qsgd_pack(a, r, 4, impl="ref"), xq, rand)
    rows.append(("kernel_qsgd_pack_2M_4bit", us, f"{nb*1024/us:.0f} elem/us"))
    p, s = qsgd_pack(xq, rand, 4, impl="ref")
    us = _time(lambda a, c: qsgd_unpack(a, c, 4, impl="ref"), p, s)
    rows.append(("kernel_qsgd_unpack_2M_4bit", us, f"{nb*1024/us:.0f} elem/us"))
    _, lidx, _ = bucket_topk(x, 4, impl="ref")
    val = jax.random.normal(key, (nb, 4))
    us = _time(lambda i, v: bucket_scatter(i, v, b, impl="ref"), lidx, val)
    rows.append(("kernel_bucket_scatter_1M", us, f"{nb*b/us:.0f} elem/us"))
    return rows
