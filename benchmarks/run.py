"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and, per module, writes a
machine-readable ``BENCH_<module>.json`` so the perf trajectory can be
tracked across PRs (CI uploads the JSON as artifacts and
``benchmarks/regress.py`` compares headline cells against the committed
baselines). Heavy modules can be filtered:

  PYTHONPATH=src python -m benchmarks.run [--only density,allreduce,...]
                                          [--json-dir DIR]
                                          [--trace] [--metrics-out PATH]

BENCH file format (schema v2, DESIGN.md §10): an object
``{"schema_version": 2, "meta": {...}, "rows": [...]}``. ``meta`` is the
run-identity header — device count, backend, jax/python versions, git
describe — plus whatever the module's optional ``bench_meta()`` hook
adds (e.g. the plan signature a serve bench ran under), so files are
comparable across PRs. ``rows`` is the old flat list (regress reads
both formats).

``--trace`` exports a Chrome-trace JSON per module
(``TRACE_<module>.json`` next to the BENCH files) through the same
``repro.obs`` layer every runtime uses; ``--metrics-out`` writes the
combined metrics/event JSONL of the whole invocation.
"""
from __future__ import annotations

import os

# The collective benchmarks (Fig. 3 / Table 2 / Fig. 4) need real
# multi-device shard_map execution: 8 host devices (NOT the 512-device
# dry-run flag, which stays local to launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import platform
import subprocess
import sys
import traceback

SCHEMA_VERSION = 2

MODULES = {
    "density": "benchmarks.bench_density",          # Fig. 1 / Fig. 7
    "allreduce": "benchmarks.bench_allreduce",      # Fig. 3
    "classification": "benchmarks.bench_classification",  # Table 2
    "convergence": "benchmarks.bench_convergence",  # Figs. 4/5
    "volume": "benchmarks.bench_volume",            # §8.3/8.4 bandwidth
    "kernels": "benchmarks.bench_kernels",          # kernel microbench
    "overlap": "benchmarks.bench_overlap",          # §4/§7 non-blocking
    "adapt": "benchmarks.bench_adapt",              # DESIGN.md §7 re-planning
    "bench_serve": "benchmarks.bench_serve",        # DESIGN.md §8 serving
    "zero": "benchmarks.bench_zero",                # DESIGN.md §11 ZeRO state
    "obs_health": "benchmarks.bench_obs_health",    # DESIGN.md §10.5-§10.7
    "faults": "benchmarks.bench_faults",            # DESIGN.md §12 recovery
}


def _git_describe() -> str:
    try:
        return subprocess.run(
            ["git", "describe", "--always", "--dirty"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def run_meta() -> dict:
    """The run-identity header shared by every BENCH_*.json of one
    invocation; per-module ``bench_meta()`` extras are merged on top."""
    import jax

    return {
        "device_count": jax.device_count(),
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
        "git": _git_describe(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--json-dir", type=str, default=".",
                    help="directory for the BENCH_<module>.json files")
    ap.add_argument("--trace", action="store_true",
                    help="export a Chrome-trace JSON per module "
                         "(TRACE_<module>.json in --json-dir)")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the combined metrics/event JSONL here")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        raise SystemExit(
            f"unknown benchmark modules {unknown}; choose from {list(MODULES)}")

    from repro import obs as obs_mod

    # one observability handle for the whole invocation — the SAME layer
    # (and the same registry) the runtimes under benchmark thread through
    obs = obs_mod.configure(trace=args.trace,
                            metrics=bool(args.metrics_out) or args.trace)
    meta = run_meta()
    # open the sink at run START so a crashed/killed invocation still
    # leaves a complete, parseable JSONL of everything up to that point
    sink = (obs.metrics.jsonl_sink(args.metrics_out, meta=meta)
            if args.metrics_out else None)

    print("name,us_per_call,derived")
    failed = []
    for name in names:
        modname = MODULES[name]
        try:
            mod = __import__(modname, fromlist=["run"])
            with obs.span(f"bench/{name}"):
                rows = list(mod.run())
            for row_name, us, derived in rows:
                print(f"{row_name},{us:.1f},{derived}")
                obs.metrics.event("bench/row", module=name, name=row_name,
                                  us_per_call=us, derived=derived)
            sys.stdout.flush()
            os.makedirs(args.json_dir, exist_ok=True)
            # file named after the bench MODULE (BENCH_bench_allreduce.json),
            # stable across any renaming of the CLI keys
            basename = modname.rsplit(".", 1)[-1]
            mod_meta = dict(meta)
            extra = getattr(mod, "bench_meta", None)
            if callable(extra):
                mod_meta.update(extra())
            with open(os.path.join(args.json_dir,
                                   f"BENCH_{basename}.json"), "w") as f:
                json.dump({
                    "schema_version": SCHEMA_VERSION,
                    "meta": mod_meta,
                    "rows": [{"name": r, "us_per_call": us, "derived": d}
                             for r, us, d in rows],
                }, f, indent=1)
            if args.trace:
                from repro.obs import validate_span_tree

                bad = validate_span_tree(obs.tracer.events)
                if bad:  # cheap artifact sanity check, not a hard fail
                    print(f"trace: {len(bad)} malformed span(s) after "
                          f"{name}", file=sys.stderr)
                obs.tracer.export(
                    os.path.join(args.json_dir, f"TRACE_{basename}.json"),
                    meta={**meta, "module": name})
        except Exception as e:  # pragma: no cover
            failed.append(name)
            print(f"{name},ERROR,{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc()
    if sink is not None:
        sink.close()
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
