"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and, per module, writes a
machine-readable ``BENCH_<module>.json`` (list of
``{name, us_per_call, derived}``) so the perf trajectory can be tracked
across PRs (CI uploads the JSON as artifacts). Heavy modules can be
filtered:
  PYTHONPATH=src python -m benchmarks.run [--only density,allreduce,...]
                                          [--json-dir DIR]
"""
from __future__ import annotations

import os

# The collective benchmarks (Fig. 3 / Table 2 / Fig. 4) need real
# multi-device shard_map execution: 8 host devices (NOT the 512-device
# dry-run flag, which stays local to launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import sys
import traceback

MODULES = {
    "density": "benchmarks.bench_density",          # Fig. 1 / Fig. 7
    "allreduce": "benchmarks.bench_allreduce",      # Fig. 3
    "classification": "benchmarks.bench_classification",  # Table 2
    "convergence": "benchmarks.bench_convergence",  # Figs. 4/5
    "volume": "benchmarks.bench_volume",            # §8.3/8.4 bandwidth
    "kernels": "benchmarks.bench_kernels",          # kernel microbench
    "overlap": "benchmarks.bench_overlap",          # §4/§7 non-blocking
    "adapt": "benchmarks.bench_adapt",              # DESIGN.md §7 re-planning
    "bench_serve": "benchmarks.bench_serve",        # DESIGN.md §8 serving
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--json-dir", type=str, default=".",
                    help="directory for the BENCH_<module>.json files")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        raise SystemExit(
            f"unknown benchmark modules {unknown}; choose from {list(MODULES)}")

    print("name,us_per_call,derived")
    failed = []
    for name in names:
        modname = MODULES[name]
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = list(mod.run())
            for row_name, us, derived in rows:
                print(f"{row_name},{us:.1f},{derived}")
            sys.stdout.flush()
            os.makedirs(args.json_dir, exist_ok=True)
            # file named after the bench MODULE (BENCH_bench_allreduce.json),
            # stable across any renaming of the CLI keys
            basename = modname.rsplit(".", 1)[-1]
            with open(os.path.join(args.json_dir,
                                   f"BENCH_{basename}.json"), "w") as f:
                json.dump(
                    [{"name": r, "us_per_call": us, "derived": d}
                     for r, us, d in rows], f, indent=1)
        except Exception as e:  # pragma: no cover
            failed.append(name)
            print(f"{name},ERROR,{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
