"""Paper Fig. 3: reduction time vs node count and vs density, per algorithm.

Two views:
  (a) alpha-beta model on TPU v5e constants (the deployable prediction),
  (b) measured wall time of the real shard_map collectives on 8 host
      devices (relative ordering check; absolute CPU numbers are not TPU).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core.allreduce import make_sparse_allreduce


def _modeled() -> list[tuple[str, float, str]]:
    rows = []
    n = 1 << 24  # 16M (paper Fig. 3 uses N=16M)
    for p in (8, 64, 256, 1024):
        k = int(0.00781 * n)  # d=0.781% per node (paper Fig. 3 left)
        t_rd = cm.t_ssar_recursive_double(p, k, n)[1]
        t_sa = cm.t_ssar_split_allgather(p, k, n)[1]
        t_ds = sum(cm.t_dsar_split_allgather(p, k, n, value_bits=4)) / 2
        t_dn = cm.t_dense_allreduce(p, n)
        best = cm.select_algorithm(p, k, n, value_bits=4)
        rows.append((
            f"fig3_model_P{p}", t_dn * 1e6,
            f"rec_dbl={t_rd*1e3:.2f}ms,split_ag={t_sa*1e3:.2f}ms,"
            f"dsar4bit={t_ds*1e3:.2f}ms,dense={t_dn*1e3:.2f}ms,auto={best}",
        ))
    return rows


def _measured() -> list[tuple[str, float, str]]:
    from jax.sharding import AxisType
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    n, b = 1 << 18, 512
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, n))
    rows = []
    for algo in ("ssar_recursive_double", "ssar_split_allgather",
                 "dsar_split_allgather", "dense"):
        for k in (1, 8):
            f = make_sparse_allreduce(mesh, "data", n, k, b, algorithm=algo)
            out = f(x.reshape(-1), None)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            reps = 5
            for _ in range(reps):
                out = f(x.reshape(-1), None)
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / reps * 1e6
            rows.append((f"fig3_measured_{algo}_k{k}", us,
                         f"N={n},P=8,density={k/b:.3%}"))
    return rows


def run() -> list[tuple[str, float, str]]:
    return _modeled() + _measured()
