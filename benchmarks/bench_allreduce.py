"""Paper Fig. 3: reduction time vs node count and vs density, per algorithm.

Two views:
  (a) alpha-beta model on TPU v5e constants (the deployable prediction),
  (b) measured wall time of the real shard_map collectives on 8 host
      devices (relative ordering check; absolute CPU numbers are not TPU).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core.allreduce import make_sparse_allreduce


def _modeled() -> list[tuple[str, float, str]]:
    rows = []
    n = 1 << 24  # 16M (paper Fig. 3 uses N=16M)
    for p in (8, 64, 256, 1024):
        k = int(0.00781 * n)  # d=0.781% per node (paper Fig. 3 left)
        t_rd = cm.t_ssar_recursive_double(p, k, n)[1]
        t_sa = cm.t_ssar_split_allgather(p, k, n)[1]
        t_ds = sum(cm.t_dsar_split_allgather(p, k, n, value_bits=4)) / 2
        t_dn = cm.t_dense_allreduce(p, n)
        best = cm.select_algorithm(p, k, n, value_bits=4)
        rows.append((
            f"fig3_model_P{p}", t_dn * 1e6,
            f"rec_dbl={t_rd*1e3:.2f}ms,split_ag={t_sa*1e3:.2f}ms,"
            f"dsar4bit={t_ds*1e3:.2f}ms,dense={t_dn*1e3:.2f}ms,auto={best}",
        ))
    return rows


def _measured() -> list[tuple[str, float, str]]:
    from repro.compat import make_mesh
    mesh = make_mesh((8,), ("data",))
    n, b = 1 << 18, 512
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, n))
    rows = []
    for algo in ("ssar_recursive_double", "ssar_split_allgather",
                 "dsar_split_allgather", "dense"):
        for k in (1, 8):
            f = make_sparse_allreduce(mesh, "data", n, k, b, algorithm=algo)
            out = f(x.reshape(-1), None)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            reps = 5
            for _ in range(reps):
                out = f(x.reshape(-1), None)
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / reps * 1e6
            rows.append((f"fig3_measured_{algo}_k{k}", us,
                         f"N={n},P=8,density={k/b:.3%}"))
    return rows


def _fused_vs_per_leaf() -> list[tuple[str, float, str]]:
    """The fusion claim on real collectives (8 host devices): syncing a
    many-leaf gradient pytree through ONE planned sparse collective per
    fusion bucket vs the per-leaf pipeline (one TopK + DSAR per leaf).
    Same compression (k/512 DSAR), same numerics class — the delta is the
    per-collective latency paid O(num_leaves) vs O(num_buckets) times."""
    from jax.sharding import PartitionSpec as P

    from repro import comm
    from repro.compat import make_mesh, shard_map
    from repro.core import compressor as comp
    from repro.core.compressor import SyncConfig

    mesh = make_mesh((8,), ("data",))
    n_leaves, leaf_n = 32, 8192
    cfg = SyncConfig(mode="sparcml", k_per_bucket=4, bucket_size=512,
                     algorithm="dsar_split_allgather", min_sparse_size=1,
                     impl="ref", fusion_bucket_bytes=4 << 20)
    shapes = {f"w{i}": jax.ShapeDtypeStruct((leaf_n,), jnp.float32)
              for i in range(n_leaves)}
    specs = {k: P() for k in shapes}
    key = jax.random.PRNGKey(0)
    grads_r = {k: jax.random.normal(jax.random.fold_in(key, i), (8, leaf_n))
               for i, k in enumerate(shapes)}

    plan = comm.build_sync_plan(shapes, specs, cfg, 8)
    res_fused = plan.init_residuals()
    res_leaf = comp.init_residuals(shapes, specs, cfg, 8)

    def fused(gr, r):
        g = jax.tree.map(lambda x: x[0], gr)
        leaves, tree = jax.tree.flatten(g)
        out, new_r = comm.execute_plan(plan, leaves, r, key,
                                       data_axis="data", p_data=8)
        return tree.unflatten(out), new_r

    def per_leaf(gr, r):
        g = jax.tree.map(lambda x: x[0], gr)
        return comp.sync_grads_inside(g, r, key, cfg, specs,
                                      data_axis="data", p_data=8)

    g_specs = {k: P("data", None) for k in shapes}
    o_specs = {k: P() for k in shapes}
    rf_specs = {k: P("data", None, None) for k in res_fused}
    rl_specs = {k: P("data", None, None) for k in shapes}
    f_fused = jax.jit(shard_map(fused, mesh=mesh, in_specs=(g_specs, rf_specs),
                                out_specs=(o_specs, rf_specs), check_vma=False))
    f_leaf = jax.jit(shard_map(per_leaf, mesh=mesh,
                               in_specs=(g_specs, rl_specs),
                               out_specs=(o_specs, rl_specs), check_vma=False))

    def timed(f, r):
        out = f(grads_r, r)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        reps = 10
        for _ in range(reps):
            out = f(grads_r, r)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps * 1e6

    us_fused = timed(f_fused, res_fused)
    us_leaf = timed(f_leaf, res_leaf)
    return [
        ("fused_multi_leaf", us_fused,
         f"leaves={n_leaves},buckets={plan.num_sparse_buckets},"
         f"per_leaf={us_leaf:.0f}us,speedup={us_leaf / us_fused:.2f}x,"
         f"fused_le_per_leaf={us_fused <= us_leaf}"),
    ]


def _portfolio() -> list[tuple[str, float, str]]:
    """Full algorithm-portfolio sweep (DESIGN.md §9): every registered
    algorithm x density grid at the acceptance-cell geometry (P=8
    emulated devices, N=2^18). Emits per-algorithm rows (modeled time,
    modeled wire bytes, measured wall time of the real shard_map
    collectives) plus per-density win flags of the two capacity-clamped
    portfolio algorithms vs BOTH classic SSAR variants."""
    from repro.compat import make_mesh

    mesh = make_mesh((8,), ("data",))
    p, n, b = 8, 1 << 18, 512
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (8, n))
    rows = []
    for dens in (0.001, 0.01, 0.05):
        kpb = max(1, int(dens * b))
        k = kpb * (n // b)            # realizable per-bucket geometry
        stats = {}
        for algo in cm.ALL_ALGORITHMS:
            t_model = cm.bucket_time(algo, p, k, n)
            wire = cm.bucket_wire_bytes(algo, p, k, n)
            f = make_sparse_allreduce(mesh, "data", n, kpb, b,
                                      algorithm=algo)
            out = f(x.reshape(-1), None)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            reps = 5
            for _ in range(reps):
                out = f(x.reshape(-1), None)
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / reps * 1e6
            stats[algo] = (t_model, wire, us)
            rows.append((f"portfolio_{algo}_d{dens:g}", us,
                         f"P={p},N={n},k={k},model_us={t_model*1e6:.2f},"
                         f"wire_bytes={wire:.0f}"))
        classic = ("ssar_recursive_double", "ssar_split_allgather")
        for new in ("ssar_balanced_split", "ssar_rearranged_rs"):
            model_win = all(stats[new][0] < stats[c][0] for c in classic)
            wire_win = all(stats[new][1] < stats[c][1] for c in classic)
            measured_win = all(stats[new][2] < stats[c][2] for c in classic)
            rows.append((f"portfolio_win_{new}_d{dens:g}", stats[new][2],
                         f"model_win={model_win},wire_win={wire_win},"
                         f"measured_win={measured_win},"
                         f"auto={cm.select_algorithm(p, k, n)}"))
    return rows


def run() -> list[tuple[str, float, str]]:
    return _modeled() + _measured() + _fused_vs_per_leaf() + _portfolio()
