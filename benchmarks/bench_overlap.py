"""Non-blocking runtime (DESIGN.md §6): pipelined stale-gradient training
vs the synchronous step at 8 emulated host devices.

Two views:
  (a) overlap-aware alpha-beta model on TPU v5e constants: per-bucket
      drain times from the actual SyncPlan, exposed fraction under a
      sweep of compute/comm ratios;
  (b) measured wall time: the synchronous loop (dispatch one step, block
      on its loss — Trainer.run semantics) vs the pipelined runtime
      (K-step scanned superstep, staleness=1, async driver with depth-2
      dispatch and background data prefetch). The acceptance claim is
      pipelined mean step time <= synchronous mean step time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.core import cost_model as cm
from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.train.state import TrainConfig
from repro.train.train_step import build_train_step, init_state


def _bench_setup():
    # Deliberately small: on the 2-core emulated-device host, the
    # overlap win the runtime can realize is the per-DISPATCH cost of an
    # 8-device program (launch + rendezvous, ~tens of ms) amortized over
    # the superstep, so the step must not be compute-swamped. Real
    # accelerators overlap the collectives themselves — that is view (a).
    cfg = ModelConfig(name="ob", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype=jnp.float32, param_dtype=jnp.float32,
                      max_seq_len=32)
    sync = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                      algorithm="dsar_split_allgather", min_sparse_size=1024,
                      impl="ref")
    tcfg = TrainConfig(
        sync=sync, optimizer=OptimizerConfig(),
        schedule=ScheduleConfig(peak_lr=1e-3, warmup_steps=5,
                                total_steps=100000),
        zero1=False)
    dcfg = DataConfig(global_batch=8, seq_len=16, vocab_size=256)
    return build_model(cfg), tcfg, dcfg


def _modeled() -> list[tuple[str, float, str]]:
    from repro import comm
    from repro.models.specs import param_specs

    model, tcfg, _ = _bench_setup()
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    rows = []
    for p in (8, 64):
        plan = comm.build_sync_plan(
            pshapes, param_specs(pshapes, model.cfg, None), tcfg.sync, p)
        tb = cm.plan_bucket_times(plan, p)
        t_comm = sum(tb)
        for ratio in (0.5, 1.0, 2.0):
            tc = ratio * t_comm
            t_sync = cm.t_step_overlapped(tc, tb, staleness=0)
            t_pipe = cm.t_step_overlapped(tc, tb, staleness=1)
            hidden = 1.0 - sum(cm.exposed_bucket_times(tb, tc)) / t_comm
            rows.append((
                f"overlap_model_P{p}_r{ratio}", t_pipe * 1e6,
                f"sync={t_sync*1e6:.2f}us,buckets={plan.num_buckets},"
                f"hidden={hidden:.0%},speedup={t_sync/t_pipe:.2f}x",
            ))
    return rows


def _measured() -> list[tuple[str, float, str]]:
    from repro.runtime import driver as rd
    from repro.runtime import pipeline as rp

    # 4x2 = 8 emulated host devices; a real model axis, so both loops
    # take the auto-SPMD lowering — the production path of this backend
    # (DESIGN.md §4.2) and the one the integration tests train through.
    mesh = make_mesh((4, 2), ("data", "model"))
    model, tcfg, dcfg = _bench_setup()
    steps, k_super, rounds = 16, 4, 8
    key = jax.random.PRNGKey(0)
    batch_fn = lambda s: synthetic_batch(dcfg, s)
    key_fn = lambda s: jax.random.fold_in(key, s)

    with mesh:
        step_fn, _ = build_train_step(model, tcfg, mesh)
        state, _ = init_state(model, tcfg, mesh)
        # unrolled superstep: the emulated-CPU host pays heavy scan-carry
        # copies, straight-line K steps alias freely (DESIGN.md §6.1).
        # telemetry=False: measure the same non-instrumented step the
        # non-adaptive Trainer.run_pipelined runs, so the CI perf trail
        # tracks the product path (bench_adapt owns the overhead A/B).
        sfn, _, plan = rp.build_superstep(model, tcfg, mesh, staleness=1,
                                          steps=k_super, unroll=True,
                                          telemetry=False)
        pstate, _ = init_state(model, tcfg, mesh)
        pstate = rp.attach_inflight(pstate, plan, mesh)

        def sync_block(state, start):
            # synchronous reference: block on every step's loss
            t0 = time.perf_counter()
            for i in range(start, start + steps):
                batch = jax.tree.map(jnp.asarray, batch_fn(i))
                state, m = step_fn(state, batch, key_fn(i))
                jax.block_until_ready(m["loss"])
            return state, (time.perf_counter() - t0) / steps * 1e6

        def pipe_block(pstate, start):
            t0 = time.perf_counter()
            pstate, _ = rd.run_pipelined(
                sfn, pstate, start_step=start, num_steps=start + steps,
                batch_fn=batch_fn, key_fn=key_fn,
                cfg=rd.DriverConfig(depth=2, prefetch=2,
                                    steps_per_unit=k_super))
            return pstate, (time.perf_counter() - t0) / steps * 1e6

        # compile + warm both paths outside the timed windows
        state, _ = sync_block(state, 0)
        pstate, _ = pipe_block(pstate, 0)

        # ABBA-paired rounds (alternating order cancels slow host drift
        # out of the means). The headline estimator is the MEAN step time
        # — the acceptance quantity, and the one that charges the
        # synchronous loop for its real cost here: blocking once per step
        # exposes every scheduler-jitter spike, while the pipelined
        # driver blocks once per retired unit and rides them out.
        t_sync, t_pipe = [], []
        for r in range(rounds):
            start = (r + 1) * steps
            if r % 2 == 0:
                state, a = sync_block(state, start)
                pstate, b = pipe_block(pstate, start)
            else:
                pstate, b = pipe_block(pstate, start)
                state, a = sync_block(state, start)
            t_sync.append(a)
            t_pipe.append(b)
        us_sync = sum(t_sync) / rounds
        us_pipe = sum(t_pipe) / rounds

    fmt = lambda ts: "/".join(f"{t/1e3:.0f}" for t in ts)
    return [
        ("overlap_sync_step", us_sync,
         f"devices=8,dp=4,steps={steps},rounds={rounds},"
         f"rounds_ms={fmt(t_sync)},blocking-per-step"),
        ("overlap_pipelined_step", us_pipe,
         f"devices=8,dp=4,steps={steps},rounds={rounds},staleness=1,"
         f"superstep={k_super},unrolled,depth=2,"
         f"rounds_ms={fmt(t_pipe)},"
         f"sync={us_sync:.0f}us,speedup={us_sync/us_pipe:.2f}x,"
         f"pipelined_le_sync={us_pipe <= us_sync}"),
    ]


def run() -> list[tuple[str, float, str]]:
    return _modeled() + _measured()
