"""Paper Fig. 1 + Fig. 7 / App. B: density of the reduced result vs node
count and per-node density — closed form vs Monte Carlo."""
from __future__ import annotations

import time

from repro.core.density import expected_nnz, monte_carlo_nnz, reduced_density


def run() -> list[tuple[str, float, str]]:
    rows = []
    n = 1 << 22  # ~4.2M, ResNet20-scale flat gradient (Fig. 1 setting)
    t0 = time.perf_counter()
    for dens_pct in (0.1, 1.0, 5.0, 10.0):
        k = int(n * dens_pct / 100)
        series = [100 * reduced_density(k, n, p) for p in (2, 8, 32, 128, 512)]
        rows.append((
            f"fig1_density_k{dens_pct}pct",
            (time.perf_counter() - t0) * 1e6,
            "P=[2,8,32,128,512]->" + ",".join(f"{d:.1f}%" for d in series),
        ))
    # Fig. 7: fill-in factor at N=512
    mc = monte_carlo_nnz(8, 512, 32, trials=32)
    cf = expected_nnz(8, 512, 32)
    rows.append((
        "fig7_fill_in_N512_k8_P32",
        (time.perf_counter() - t0) * 1e6,
        f"closed_form={cf:.1f},monte_carlo={mc:.1f},err={abs(mc-cf)/cf:.3f}",
    ))
    return rows
