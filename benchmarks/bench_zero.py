"""ZeRO-sharded training state (DESIGN.md §11): what the scattered
output mode buys at 8 emulated host devices.

Two views:
  (a) per-device state memory (bytes, from launch.dryrun's breakdown —
      the same accounting ``--dryrun`` prints): replicated-full vs
      zero1 (sharded moments, replicated exchange) vs scattered
      (sharded moments ON the owner chunks, no gradient allgather),
      plus the per-rank gradient-exchange wire bytes of the scattered
      vs replicated plans;
  (b) measured wall time per training step, scattered vs replicated,
      on the 4x2 auto-SPMD lowering the integration tests train
      through. On an emulated-CPU host the collectives are memcpys, so
      this is a no-regression guard for the step as a whole, not a
      bandwidth claim — view (a) carries the wire/memory claim.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import comm
from repro.compat import make_mesh
from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.launch.dryrun import state_memory_breakdown
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.train.state import TrainConfig
from repro.train.train_step import build_train_step, init_state, state_shapes

P_BENCH = 8


def _model():
    # big enough that every transformer group goes sparse at dp=8 and
    # the optimizer state dominates params 2:1 (adam m+v) — the regime
    # the ZeRO split targets
    return build_model(ModelConfig(
        name="bz", family="dense", num_layers=1, d_model=256, num_heads=4,
        num_kv_heads=2, d_ff=512, vocab_size=512, dtype=jnp.float32,
        param_dtype=jnp.float32, max_seq_len=64))


def _tcfg(mode: str, zero1: bool = True) -> TrainConfig:
    return TrainConfig(
        sync=SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                        algorithm="ssar_balanced_split", min_sparse_size=1024,
                        impl="ref", output_mode=mode),
        optimizer=OptimizerConfig(),
        schedule=ScheduleConfig(peak_lr=1e-3, warmup_steps=5,
                                total_steps=100000),
        zero1=zero1)


def bench_meta() -> dict:
    model = _model()
    mesh = make_mesh((P_BENCH, 1), ("data", "model"))
    _, _, plan = state_shapes(model, _tcfg("scattered"), mesh,
                              return_plan=True)
    return {"zero_plan": plan.signature(), "zero_p": P_BENCH}


def _memory_rows() -> list[tuple[str, float, str]]:
    model = _model()
    mesh = make_mesh((P_BENCH, 1), ("data", "model"))
    views = {
        "full": _tcfg("replicated", zero1=False),
        "zero1": _tcfg("replicated", zero1=True),
        "scattered": _tcfg("scattered", zero1=True),
    }
    bd = {k: state_memory_breakdown(model, t, mesh) for k, t in views.items()}
    rows = []
    for k, m in bd.items():
        opt = m["opt_mu"] + m["opt_nu"]
        opt_full = bd["full"]["opt_mu"] + bd["full"]["opt_nu"]
        rows.append((
            f"zero_state_{k}_P{P_BENCH}", float(m["total"]),
            f"bytes/device,opt={opt},opt_vs_full={opt / opt_full:.3f},"
            f"params={m['params']}"))
    # per-rank wire bytes of the gradient exchange (cost-model registry,
    # the quantity the acceptance bound compares)
    _, _, plan_r = state_shapes(model, views["zero1"], mesh,
                                return_plan=True)
    _, _, plan_s = state_shapes(model, views["scattered"], mesh,
                                return_plan=True)
    wr, ws = plan_r.wire_bytes(), plan_s.wire_bytes()
    rows.append((f"zero_wire_replicated_P{P_BENCH}", float(wr),
                 "bytes/rank/step,grad exchange"))
    rows.append((
        f"zero_wire_scattered_P{P_BENCH}", float(ws),
        f"bytes/rank/step,vs_replicated={ws / wr:.3f},"
        f"param_ag={plan_s.param_allgather_bytes():.0f}"))
    return rows


def _measured_rows() -> list[tuple[str, float, str]]:
    mesh = make_mesh((4, 2), ("data", "model"))
    model = _model()
    dcfg = DataConfig(global_batch=8, seq_len=32, vocab_size=512)
    steps, rounds = 8, 4
    key = jax.random.PRNGKey(0)

    def build(mode):
        tcfg = _tcfg(mode)
        step_fn, _ = build_train_step(model, tcfg, mesh)
        state, _ = init_state(model, tcfg, mesh)
        return step_fn, state

    with mesh:
        runs = {m: build(m) for m in ("replicated", "scattered")}
        times = {m: [] for m in runs}

        def block(mode, start):
            step_fn, state = runs[mode]
            t0 = time.perf_counter()
            for i in range(start, start + steps):
                batch = jax.tree.map(jnp.asarray, synthetic_batch(dcfg, i))
                state, met = step_fn(state, batch, jax.random.fold_in(key, i))
                jax.block_until_ready(met["loss"])
            runs[mode] = (step_fn, state)
            return (time.perf_counter() - t0) / steps * 1e6

        for m in runs:                      # compile + warm, untimed
            block(m, 0)
        order = ("replicated", "scattered")
        for r in range(rounds):             # ABBA-paired rounds
            for m in (order if r % 2 == 0 else order[::-1]):
                times[m].append(block(m, (r + 1) * steps))

    mean = {m: sum(v) / len(v) for m, v in times.items()}
    return [
        ("zero_step_replicated", mean["replicated"], f"P=8,steps={steps}"),
        ("zero_step_scattered", mean["scattered"],
         f"P=8,vs_replicated={mean['scattered'] / mean['replicated']:.2f}x"),
    ]


def run() -> list[tuple[str, float, str]]:
    return _memory_rows() + _measured_rows()


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
