"""Serving benchmark (DESIGN.md §8): continuous vs static batching under
a Poisson arrival trace, and sparse vs dense expert dispatch across the
occupancy range.

Workload: a burst of short requests plus two long ones fills all slots,
then retirements drain the batch while a late Poisson trickle arrives —
the occupancy sweep that makes both claims measurable:

  (a) continuous batching sustains higher tok/s than static batching:
      the static engine decodes every batch to its LONGEST request (and
      waits for whole batches), the scheduler retires early and back-
      fills slots from the arrival queue;
  (b) the adaptive engine demotes the MoE dispatch to the row-stream
      wire as occupancy drains (>= 1 telemetry-driven swap) and back up
      under the late burst, cutting modeled wire bytes at low occupancy
      while emitting EXACTLY the dense reference's tokens.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.serve import (
    ContinuousServeEngine,
    Request,
    ServeEngine,
    poisson_trace,
)

SLOTS = 16
CACHE = 64
D_MODEL = 128


def _setup():
    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = ModelConfig(name="serve-bench", family="moe", num_layers=2,
                      d_model=D_MODEL, num_heads=8, num_kv_heads=4, d_ff=256,
                      vocab_size=512, dtype=jnp.float32,
                      param_dtype=jnp.float32, max_seq_len=128,
                      num_experts=4, experts_per_token=2, moe_d_ff=128,
                      capacity_factor=4.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return mesh, model, params


def bench_meta() -> dict:
    """BENCH-header extras (benchmarks/run.py schema v2): the serve plan
    the continuous engine starts from (mesh (4,2): 2 model shards)."""
    from repro.comm.plan import build_serve_plan

    plan = build_serve_plan(2, SLOTS, D_MODEL, algorithm="dense")
    return {"serve_plan_signature": plan.signature(),
            "slots": SLOTS, "cache_len": CACHE}


def _workload():
    """One long request rides EACH static group: the static engine
    decodes every group to its longest member, while the scheduler runs
    both long requests CONCURRENTLY and back-fills retired slots."""
    rng = np.random.default_rng(0)
    lens = [4, 8, 12]     # few distinct ragged lengths: few admit compiles
    reqs = []
    # burst: 15 short + 1 long request at t=0 (fills all 16 slots; the
    # static engine's first group decodes 40 steps for everyone)
    for i in range(15):
        reqs.append(Request(rid=i, prompt=rng.integers(0, 512, int(rng.choice(lens))),
                            max_new_tokens=int(rng.integers(6, 11)), arrival=0.0))
    reqs.append(Request(rid=15, prompt=rng.integers(0, 512, 8),
                        max_new_tokens=40, arrival=0.0))
    # late Poisson trickle into the draining batch, with the second long
    # request at its head (static: a whole second 36-step group)
    reqs.append(Request(rid=16, prompt=rng.integers(0, 512, 6),
                        max_new_tokens=36, arrival=14.0))
    late = poisson_trace(9, rate=0.4, seed=1, start=14.5)
    for j in range(9):
        reqs.append(Request(rid=17 + j,
                            prompt=rng.integers(0, 512, int(rng.choice(lens))),
                            max_new_tokens=int(rng.integers(6, 11)),
                            arrival=float(late[j])))
    return reqs


def _run_static(eng, reqs):
    """Static batching baseline: groups of up to SLOTS requests in
    arrival order; each group prefills rectangular (right-padded ragged
    prompts) and decodes to the LONGEST max_new_tokens in the group —
    the per-request waste continuous batching eliminates. Useful tokens
    = what each request actually asked for."""
    t0 = time.perf_counter()
    useful = steps = 0
    order = sorted(reqs, key=lambda r: (r.arrival, r.rid))
    for g in range(0, len(order), SLOTS):
        group = order[g:g + SLOTS]
        lmax = max(r.prompt.size for r in group)
        # fixed-shape batch: a partial last group still decodes SLOTS
        # rows (the static engine has one compiled shape)
        prompts = np.zeros((SLOTS, lmax), np.int32)
        for i, r in enumerate(group):
            prompts[i, :r.prompt.size] = r.prompt
        m = max(r.max_new_tokens for r in group)
        eng.generate(prompts, max_new_tokens=m)
        useful += sum(r.max_new_tokens for r in group)
        steps += m
    dt = time.perf_counter() - t0
    return useful, steps, dt


def run():
    mesh, model, params = _setup()
    reqs = _workload()

    # warm-up pass (compiles: decode steps for every plan signature, the
    # per-length prefill scans, the static engine's jitted step), then
    # the measured steady-state pass on the same engines
    static_eng = ServeEngine(model, mesh, params, cache_len=CACHE,
                             batch_size=SLOTS)
    _run_static(static_eng, reqs)
    useful_s, steps_s, dt_s = _run_static(static_eng, reqs)
    tps_s = useful_s / dt_s

    dense = ContinuousServeEngine(model, mesh, params, cache_len=CACHE,
                                  batch_size=SLOTS, dispatch="dense")
    adap = ContinuousServeEngine(model, mesh, params, cache_len=CACHE,
                                 batch_size=SLOTS, dispatch="adaptive")
    dense.run(reqs), adap.run(reqs)
    rd = dense.run(reqs)
    ra = adap.run(reqs)
    tps_c = ra.tok_per_s

    # (b) dispatch: exact equality, drain swap, low-occupancy wire
    outputs_equal = all(
        np.array_equal(rd.outputs[r.rid], ra.outputs[r.rid]) for r in reqs)
    telem_swaps = [s for s in ra.swap_log if s["reason"] == "telemetry"]
    drain_swaps = [s for s in telem_swaps if "stream_gather" in s["signature"]]
    lo_d = [r["wire_bytes"] for r in rd.step_log if r["active"] <= SLOTS // 4]
    lo_a = [r["wire_bytes"] for r in ra.step_log if r["active"] <= SLOTS // 4]
    lo_cut = (1.0 - np.mean(lo_a) / np.mean(lo_d)) if lo_d and lo_a else 0.0

    return [
        ("serve_static_batch", dt_s / useful_s * 1e6,
         f"tok_per_s={tps_s:.1f},decode_steps={steps_s},tokens={useful_s}"),
        ("serve_continuous", ra.wall_s / ra.tokens * 1e6,
         f"tok_per_s={tps_c:.1f},decode_steps={ra.decode_steps},"
         f"tokens={ra.tokens},continuous_wins={tps_c > tps_s}"),
        ("serve_dispatch_adaptive", ra.wire_bytes / max(1, ra.decode_steps),
         f"wire_total_B={ra.wire_bytes:.0f},dense_wire_B={rd.wire_bytes:.0f},"
         f"low_occupancy_wire_cut={lo_cut:.1%},"
         f"swaps={len(ra.swap_log)},ge1_drain_swap={len(drain_swaps) >= 1},"
         f"outputs_equal_dense={outputs_equal}"),
        # latency distributions in DECODE-STEP units (deterministic on
        # the fixed trace; multiply by wall_s/decode_steps for seconds)
        ("serve_latency", ra.latency["e2e"]["p99"],
         f"ttft_p50={ra.latency['ttft']['p50']:.1f},"
         f"ttft_p99={ra.latency['ttft']['p99']:.1f},"
         f"tpot_p50={ra.latency['tpot']['p50']:.2f},"
         f"queue_p99={ra.latency['queue_delay']['p99']:.1f},"
         f"e2e_p99={ra.latency['e2e']['p99']:.1f}"),
    ]
