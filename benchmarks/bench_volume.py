"""Paper §8.3/§8.4 bandwidth observations ("80 MB -> <0.5 MB per step"):
bytes-on-wire per rank per step, dense vs SparCML, per architecture."""
from __future__ import annotations

import time

import jax

from repro import configs as cfgreg
from repro.core.compressor import SyncConfig, wire_bytes_per_step
from repro.models.model import build_model


def run() -> list[tuple[str, float, str]]:
    rows = []
    t0 = time.perf_counter()
    for arch in ("mamba2-370m", "qwen3-4b", "internlm2-20b",
                 "moonshot-v1-16b-a3b", "zamba2-2.7b"):
        cfg = cfgreg.get_config(arch)
        model = build_model(cfg)
        pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        sync = SyncConfig(mode="sparcml", k_per_bucket=4, bucket_size=512,
                          qsgd_bits=4)
        rep = wire_bytes_per_step(pshapes, sync, p=16)
        rows.append((
            f"volume_{arch}", (time.perf_counter() - t0) * 1e6,
            f"dense={rep['dense_bytes']/1e6:.1f}MB,"
            f"sparcml={rep['sparcml_bytes']/1e6:.1f}MB,"
            f"ratio={rep['ratio']:.1f}x",
        ))
    # the paper's ATIS observation: 20M params, 80MB fp32 -> <0.5MB
    n = 20_000_000
    shapes = {"w": jax.ShapeDtypeStruct((n,), jax.numpy.float32)}
    atis = wire_bytes_per_step(
        shapes, SyncConfig(mode="sparcml", k_per_bucket=2, bucket_size=512,
                           qsgd_bits=None), p=8)
    # paper sends only the sparse items (SSAR, result stays sparse):
    sparse_only = n * (2 / 512) * 8  # idx+val per selected item
    rows.append(("volume_atis_20M_k2_512",
                 (time.perf_counter() - t0) * 1e6,
                 f"dense={atis['dense_bytes']/1e6:.1f}MB,"
                 f"ssar_payload={sparse_only/1e6:.2f}MB (paper: <0.5MB)"))
    return rows
