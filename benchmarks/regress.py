"""bench-regress: compare fresh BENCH_*.json against committed baselines.

Usage (the CI ``bench-regress`` step):

  PYTHONPATH=src python -m benchmarks.regress --fresh bench-out \
      [--baselines benchmarks/baselines] [--tol 0.25] [--update]

Headline cells (ISSUE 7 satellite): the cross-PR perf trail distilled to
what the paper claims —

  adapt µs/step        BENCH_bench_adapt.json / adapt_drift_adaptive
                       us_per_call (modeled cost at measured telemetry;
                       deterministic) — lower is better
  serve tok/s          BENCH_bench_serve.json / serve_continuous derived
                       tok_per_s (wall-clock) — higher is better
  portfolio wire bytes BENCH_bench_allreduce.json / portfolio_*_d* rows'
                       derived wire_bytes (modeled; deterministic) —
                       lower is better

A cell regressing by more than ``--tol`` (fractional, default 0.25)
fails the run with exit code 1. Missing files or rows only warn: the CI
smoke job runs a module subset, and a renamed row should not brick CI
silently-forever (the warning is the signal to refresh baselines).
``--update`` copies the fresh files over the baselines instead of
comparing (run it locally after an intentional perf change and commit
the result). Both BENCH schemas load: v1 (flat row list) and v2
({schema_version, meta, rows}).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

DEFAULT_TOL = 0.25

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")


def load_rows(path: str) -> dict[str, dict]:
    """name -> row for either BENCH schema (v1 list, v2 object)."""
    with open(path) as f:
        doc = json.load(f)
    rows = doc["rows"] if isinstance(doc, dict) else doc
    return {r["name"]: r for r in rows}


def parse_derived(derived: str) -> dict[str, str]:
    """'k=v,k2=v2' derived strings -> dict (values stay strings)."""
    out = {}
    for part in str(derived).split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k.strip()] = v.strip()
    return out


def _cell_us(row: dict) -> float:
    return float(row["us_per_call"])


def _cell_derived(row: dict, field: str) -> float:
    return float(parse_derived(row.get("derived", ""))[field])


def headline_cells(fresh_dir: str, baseline_dir: str) -> list[dict]:
    """Resolve every headline cell present in BOTH trees. Each cell:
    {label, fresh, baseline, higher_better}."""
    cells = []

    def both(fname):
        fp = os.path.join(fresh_dir, fname)
        bp = os.path.join(baseline_dir, fname)
        if not os.path.exists(fp) or not os.path.exists(bp):
            print(f"regress: skipping {fname} "
                  f"(fresh={os.path.exists(fp)}, "
                  f"baseline={os.path.exists(bp)})", file=sys.stderr)
            return None
        return load_rows(fp), load_rows(bp)

    pair = both("BENCH_bench_adapt.json")
    if pair:
        fresh, base = pair
        name = "adapt_drift_adaptive"
        if name in fresh and name in base:
            cells.append({"label": f"{name}.us_per_call",
                          "fresh": _cell_us(fresh[name]),
                          "baseline": _cell_us(base[name]),
                          "higher_better": False})
        else:
            print(f"regress: row {name!r} missing", file=sys.stderr)

    pair = both("BENCH_bench_serve.json")
    if pair:
        fresh, base = pair
        name = "serve_continuous"
        try:
            cells.append({"label": f"{name}.tok_per_s",
                          "fresh": _cell_derived(fresh[name], "tok_per_s"),
                          "baseline": _cell_derived(base[name], "tok_per_s"),
                          "higher_better": True})
        except KeyError:
            print(f"regress: {name!r} tok_per_s missing", file=sys.stderr)

    pair = both("BENCH_bench_allreduce.json")
    if pair:
        fresh, base = pair
        shared = [n for n in base
                  if n.startswith("portfolio_") and "win" not in n
                  and n in fresh]
        for name in shared:
            try:
                cells.append({"label": f"{name}.wire_bytes",
                              "fresh": _cell_derived(fresh[name],
                                                     "wire_bytes"),
                              "baseline": _cell_derived(base[name],
                                                        "wire_bytes"),
                              "higher_better": False})
            except KeyError:
                print(f"regress: {name!r} wire_bytes missing",
                      file=sys.stderr)
        if not shared:
            print("regress: no shared portfolio_* rows", file=sys.stderr)

    pair = both("BENCH_bench_zero.json")
    if pair:
        fresh, base = pair
        # the two ZeRO acceptance quantities: per-device state bytes of
        # the scattered layout (memory claim) and its per-rank gradient
        # wire bytes (exchange claim) — both analytic, so near-zero
        # run-to-run noise; the step-time rows stay informational (the
        # emulated-CPU host is too jittery to gate on)
        for name in ("zero_state_scattered_P8", "zero_wire_scattered_P8"):
            if name in fresh and name in base:
                cells.append({"label": f"{name}.us_per_call",
                              "fresh": _cell_us(fresh[name]),
                              "baseline": _cell_us(base[name]),
                              "higher_better": False})
            else:
                print(f"regress: row {name!r} missing", file=sys.stderr)
    return cells


def compare(cells: list[dict], tol: float) -> list[dict]:
    """Returns the regressed cells (worse than baseline by > tol)."""
    bad = []
    for c in cells:
        base, fresh = c["baseline"], c["fresh"]
        if base == 0:
            continue
        # fractional regression, sign-normalized so positive == worse
        reg = (base - fresh) / base if c["higher_better"] \
            else (fresh - base) / base
        c["regression"] = reg
        if reg > tol:
            bad.append(c)
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", type=str, required=True,
                    help="directory with freshly produced BENCH_*.json")
    ap.add_argument("--baselines", type=str, default=BASELINE_DIR)
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help="max fractional regression per headline cell")
    ap.add_argument("--update", action="store_true",
                    help="copy fresh BENCH files over the baselines "
                         "instead of comparing")
    args = ap.parse_args()

    if args.update:
        os.makedirs(args.baselines, exist_ok=True)
        import glob

        for src in sorted(glob.glob(os.path.join(args.fresh,
                                                 "BENCH_*.json"))):
            dst = os.path.join(args.baselines, os.path.basename(src))
            shutil.copy(src, dst)
            print(f"regress: updated {dst}")
        return

    cells = headline_cells(args.fresh, args.baselines)
    if not cells:
        print("regress: no comparable headline cells found", file=sys.stderr)
        return
    bad = compare(cells, args.tol)
    w = max(len(c["label"]) for c in cells)
    for c in cells:
        mark = "REGRESSED" if c in bad else "ok"
        print(f"  {c['label']:<{w}}  baseline={c['baseline']:<12.4g} "
              f"fresh={c['fresh']:<12.4g} "
              f"delta={c.get('regression', 0.0):+7.1%}  {mark}")
    if bad:
        raise SystemExit(
            f"bench-regress: {len(bad)} headline cell(s) regressed beyond "
            f"{args.tol:.0%} — intentional? refresh with --update and "
            f"commit benchmarks/baselines/")


if __name__ == "__main__":
    main()
