"""bench-regress: compare fresh BENCH_*.json against committed baselines.

Usage (the CI ``bench-regress`` step):

  PYTHONPATH=src python -m benchmarks.regress --fresh bench-out \
      [--baselines benchmarks/baselines] [--tol 0.25] [--update]

Headline cells (ISSUE 7 satellite): the cross-PR perf trail distilled to
what the paper claims —

  adapt µs/step        BENCH_bench_adapt.json / adapt_drift_adaptive
                       us_per_call (modeled cost at measured telemetry;
                       deterministic) — lower is better
  serve tok/s          BENCH_bench_serve.json / serve_continuous derived
                       tok_per_s (wall-clock) — higher is better
  portfolio wire bytes BENCH_bench_allreduce.json / portfolio_*_d* rows'
                       derived wire_bytes (modeled; deterministic) —
                       lower is better

Tolerances are PER CELL: a flat band is simultaneously too loose for
the analytic cells (wire/state bytes are deterministic — a 25% wire
regression is a real algorithmic change, not noise) and too tight for
the wall-clock ones (shared CI runners jitter timing well past 25%).
Each cell gets its band from, in priority order: the baseline file's
``meta.tolerances[label]`` (committed alongside the numbers so an
intentional band change reviews like any perf change), a built-in
per-kind default (``CELL_TOL``), then ``--tol``. A cell regressing
beyond its band fails the run with exit code 1. Missing files or rows
only warn: the CI smoke job runs a module subset, and a renamed row
should not brick CI silently-forever (the warning is the signal to
refresh baselines). ``--update`` copies the fresh files over the
baselines instead of comparing, PRESERVING any ``meta.tolerances``
already committed (run it locally after an intentional perf change and
commit the result). Both BENCH schemas load: v1 (flat row list) and
v2 ({schema_version, meta, rows}).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

DEFAULT_TOL = 0.25

# Built-in per-kind bands (overridable per baseline file via
# meta.tolerances): analytic cells tight, wall-clock cells wide.
CELL_TOL = {
    "adapt_drift_adaptive.us_per_call": 0.25,   # modeled cost, mild jitter
    "serve_continuous.tok_per_s": 0.35,         # wall-clock throughput
    "obs_health_overhead.us_per_call": 0.50,    # wall-clock step timing
    "guard_overhead.us_per_call": 0.50,         # wall-clock step timing
    "zero_state_scattered_P8.us_per_call": 0.02,   # analytic bytes
    "zero_wire_scattered_P8.us_per_call": 0.05,    # analytic bytes
}
WIRE_BYTES_TOL = 0.05   # portfolio_*.wire_bytes: modeled, deterministic

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")


def load_doc(path: str):
    with open(path) as f:
        return json.load(f)


def load_rows(path: str) -> dict[str, dict]:
    """name -> row for either BENCH schema (v1 list, v2 object)."""
    doc = load_doc(path)
    rows = doc["rows"] if isinstance(doc, dict) else doc
    return {r["name"]: r for r in rows}


def load_tolerances(path: str) -> dict[str, float]:
    """The committed per-cell bands of a baseline file (v2 meta only)."""
    doc = load_doc(path)
    if isinstance(doc, dict):
        tols = doc.get("meta", {}).get("tolerances", {})
        return {str(k): float(v) for k, v in tols.items()}
    return {}


def cell_tol(label: str, overrides: dict[str, float]) -> float | None:
    if label in overrides:
        return overrides[label]
    if label in CELL_TOL:
        return CELL_TOL[label]
    if label.endswith(".wire_bytes"):
        return WIRE_BYTES_TOL
    return None


def parse_derived(derived: str) -> dict[str, str]:
    """'k=v,k2=v2' derived strings -> dict (values stay strings)."""
    out = {}
    for part in str(derived).split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k.strip()] = v.strip()
    return out


def _cell_us(row: dict) -> float:
    return float(row["us_per_call"])


def _cell_derived(row: dict, field: str) -> float:
    return float(parse_derived(row.get("derived", ""))[field])


def headline_cells(fresh_dir: str, baseline_dir: str) -> list[dict]:
    """Resolve every headline cell present in BOTH trees. Each cell:
    {label, fresh, baseline, higher_better[, tol]}."""
    cells = []

    def both(fname):
        fp = os.path.join(fresh_dir, fname)
        bp = os.path.join(baseline_dir, fname)
        if not os.path.exists(fp) or not os.path.exists(bp):
            print(f"regress: skipping {fname} "
                  f"(fresh={os.path.exists(fp)}, "
                  f"baseline={os.path.exists(bp)})", file=sys.stderr)
            return None
        return load_rows(fp), load_rows(bp), load_tolerances(bp)

    def add(label, fresh_v, base_v, higher_better, overrides):
        c = {"label": label, "fresh": fresh_v, "baseline": base_v,
             "higher_better": higher_better}
        t = cell_tol(label, overrides)
        if t is not None:
            c["tol"] = t
        cells.append(c)

    pair = both("BENCH_bench_adapt.json")
    if pair:
        fresh, base, tols = pair
        name = "adapt_drift_adaptive"
        if name in fresh and name in base:
            add(f"{name}.us_per_call", _cell_us(fresh[name]),
                _cell_us(base[name]), False, tols)
        else:
            print(f"regress: row {name!r} missing", file=sys.stderr)

    pair = both("BENCH_bench_serve.json")
    if pair:
        fresh, base, tols = pair
        name = "serve_continuous"
        try:
            add(f"{name}.tok_per_s", _cell_derived(fresh[name], "tok_per_s"),
                _cell_derived(base[name], "tok_per_s"), True, tols)
        except KeyError:
            print(f"regress: {name!r} tok_per_s missing", file=sys.stderr)

    pair = both("BENCH_bench_allreduce.json")
    if pair:
        fresh, base, tols = pair
        shared = [n for n in base
                  if n.startswith("portfolio_") and "win" not in n
                  and n in fresh]
        for name in shared:
            try:
                add(f"{name}.wire_bytes",
                    _cell_derived(fresh[name], "wire_bytes"),
                    _cell_derived(base[name], "wire_bytes"), False, tols)
            except KeyError:
                print(f"regress: {name!r} wire_bytes missing",
                      file=sys.stderr)
        if not shared:
            print("regress: no shared portfolio_* rows", file=sys.stderr)

    pair = both("BENCH_bench_zero.json")
    if pair:
        fresh, base, tols = pair
        # the two ZeRO acceptance quantities: per-device state bytes of
        # the scattered layout (memory claim) and its per-rank gradient
        # wire bytes (exchange claim) — both analytic, so near-zero
        # run-to-run noise; the step-time rows stay informational (the
        # emulated-CPU host is too jittery to gate on)
        for name in ("zero_state_scattered_P8", "zero_wire_scattered_P8"):
            if name in fresh and name in base:
                add(f"{name}.us_per_call", _cell_us(fresh[name]),
                    _cell_us(base[name]), False, tols)
            else:
                print(f"regress: row {name!r} missing", file=sys.stderr)

    pair = both("BENCH_bench_obs_health.json")
    if pair:
        fresh, base, tols = pair
        name = "obs_health_overhead"
        if name in fresh and name in base:
            add(f"{name}.us_per_call", _cell_us(fresh[name]),
                _cell_us(base[name]), False, tols)
        else:
            print(f"regress: row {name!r} missing", file=sys.stderr)

    pair = both("BENCH_bench_faults.json")
    if pair:
        fresh, base, tols = pair
        # the gated cell is the guarded-step overhead; the per-class
        # recovery_<cls> rows stay informational (one-shot wall-clock
        # deltas on a shared runner are far too jittery to gate on)
        name = "guard_overhead"
        if name in fresh and name in base:
            add(f"{name}.us_per_call", _cell_us(fresh[name]),
                _cell_us(base[name]), False, tols)
        else:
            print(f"regress: row {name!r} missing", file=sys.stderr)
    return cells


def compare(cells: list[dict], tol: float) -> list[dict]:
    """Returns the regressed cells (worse than baseline by more than
    their band: the cell's own ``tol`` when present, else ``tol``)."""
    bad = []
    for c in cells:
        base, fresh = c["baseline"], c["fresh"]
        if base == 0:
            continue
        # fractional regression, sign-normalized so positive == worse
        reg = (base - fresh) / base if c["higher_better"] \
            else (fresh - base) / base
        c["regression"] = reg
        if reg > c.get("tol", tol):
            bad.append(c)
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", type=str, required=True,
                    help="directory with freshly produced BENCH_*.json")
    ap.add_argument("--baselines", type=str, default=BASELINE_DIR)
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help="fallback fractional band for cells with no "
                         "per-cell tolerance")
    ap.add_argument("--update", action="store_true",
                    help="copy fresh BENCH files over the baselines "
                         "instead of comparing (meta.tolerances of an "
                         "existing baseline is preserved)")
    args = ap.parse_args()

    if args.update:
        os.makedirs(args.baselines, exist_ok=True)
        import glob

        for src in sorted(glob.glob(os.path.join(args.fresh,
                                                 "BENCH_*.json"))):
            dst = os.path.join(args.baselines, os.path.basename(src))
            tols = load_tolerances(dst) if os.path.exists(dst) else {}
            doc = load_doc(src)
            if tols and isinstance(doc, dict):
                doc.setdefault("meta", {})["tolerances"] = tols
                with open(dst, "w") as f:
                    json.dump(doc, f, indent=1)
            else:
                shutil.copy(src, dst)
            print(f"regress: updated {dst}")
        return

    cells = headline_cells(args.fresh, args.baselines)
    if not cells:
        print("regress: no comparable headline cells found", file=sys.stderr)
        return
    bad = compare(cells, args.tol)
    w = max(len(c["label"]) for c in cells)
    for c in cells:
        mark = "REGRESSED" if c in bad else "ok"
        print(f"  {c['label']:<{w}}  baseline={c['baseline']:<12.4g} "
              f"fresh={c['fresh']:<12.4g} "
              f"delta={c.get('regression', 0.0):+7.1%} "
              f"tol={c.get('tol', args.tol):.0%}  {mark}")
    if bad:
        raise SystemExit(
            f"bench-regress: {len(bad)} headline cell(s) regressed beyond "
            f"their band — intentional? refresh with --update and "
            f"commit benchmarks/baselines/")


if __name__ == "__main__":
    main()
