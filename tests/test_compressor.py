"""Gradient-sync layer: canonical layout round-trips (hypothesis), Alg. 2
semantics inside shard_map, EF invariant, hierarchical pod reduction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import compressor as comp
from repro.core import topk as topk_mod
from repro.core.compressor import SyncConfig


@settings(max_examples=30, deadline=None)
@given(
    shape=st.sampled_from([(64,), (128, 8), (4, 32, 16), (8, 16, 4, 4)]),
    model_ax=st.integers(-1, 3),
    seed=st.integers(0, 2**16),
)
def test_canonical_roundtrip(shape, model_ax, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    if model_ax < 0 or model_ax >= len(shape):
        spec = P()
    else:
        spec = P(*([None] * model_ax + ["model"]))
    c = comp.to_canonical(x, spec, bucket_size=128)
    rows, cols = comp.canonical_shape(shape, spec, 128)
    assert c.shape == (rows, cols) and cols % 128 == 0
    back = comp.from_canonical(c, shape, spec)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_sync_matches_oracle_and_ef_invariant(mesh4x2):
    cfg = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=512,
                     algorithm="dsar_split_allgather", min_sparse_size=1024,
                     impl="ref")
    # w canonical: model axis (8) leading, 8192 cols -> m=16 buckets/row
    # (divisible by dp=4, required by the batched split phase)
    shapes = {"w": jax.ShapeDtypeStruct((8192, 8), jnp.float32),
              "b": jax.ShapeDtypeStruct((128,), jnp.float32)}
    specs = {"w": P(None, "model"), "b": P()}
    res = comp.init_residuals(shapes, specs, cfg, dp_total=4)
    rspecs = comp.residual_specs(shapes, specs, cfg, 4, dp_axes=("data",))
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (4, 8192, 8)),
             "b": jax.random.normal(key, (4, 128))}

    def step(g, r, k):
        g = jax.tree.map(lambda x: x[0], g)
        return comp.sync_grads_inside(g, r, k, cfg, specs,
                                      data_axis="data", p_data=4)

    f = shard_map(
        step, mesh=mesh4x2,
        in_specs=({"w": P("data", None, "model"), "b": P("data", None)},
                  rspecs, P()),
        out_specs=({"w": P(None, "model"), "b": P()}, rspecs),
        check_vma=False)
    out, new_res = f(grads, res, key)

    # oracle: per-rank canonical (8, 8192) bucketed topk, mean over ranks
    dens = []
    for rnk in range(4):
        canon = jnp.asarray(np.asarray(grads["w"][rnk]).T)  # (8, 8192)
        u, _ = topk_mod.compress2d(canon, 8, 512)
        dens.append(np.asarray(u.densify()))
    oracle = np.stack(dens).sum(0) / 4.0
    got = np.asarray(out["w"]).T
    np.testing.assert_allclose(got, oracle, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"]),
                               np.asarray(grads["b"]).mean(0), rtol=1e-5)
    # EF invariant: residual + selected == original grad (rank 0)
    recon = dens[0] + np.asarray(new_res["w"][0])
    np.testing.assert_allclose(recon, np.asarray(grads["w"][0]).T,
                               rtol=1e-5, atol=1e-6)


def test_hierarchical_pod_reduction(mesh2x2x2):
    """Multi-pod: sparse AR over 'data' within pod + psum over 'pod'."""
    cfg = SyncConfig(mode="sparcml", k_per_bucket=256, bucket_size=512,
                     algorithm="dsar_split_allgather", min_sparse_size=512,
                     impl="ref")
    n = 2048
    shapes = {"w": jax.ShapeDtypeStruct((n,), jnp.float32)}
    specs = {"w": P()}
    res = comp.init_residuals(shapes, specs, cfg, dp_total=4)
    rspecs = comp.residual_specs(shapes, specs, cfg, 4,
                                 dp_axes=("pod", "data"))
    key = jax.random.PRNGKey(1)
    grads = {"w": jax.random.normal(key, (4, n))}

    def step(g, r, k):
        g = jax.tree.map(lambda x: x[0], g)
        return comp.sync_grads_inside(
            g, r, k, cfg, specs, data_axis="data", p_data=2,
            pod_axis="pod", p_pod=2)

    f = shard_map(
        step, mesh=mesh2x2x2,
        in_specs=({"w": P(("pod", "data"), None)}, rspecs, P()),
        out_specs=({"w": P()}, rspecs), check_vma=False)
    out, _ = f(grads, res, key)
    # oracle: mean over all 4 replicas of the bucket-topk'd grads
    dens = [np.asarray(topk_mod.compress2d(
        grads["w"][r].reshape(1, -1), 256, 512)[0].densify()).reshape(-1)
        for r in range(4)]
    np.testing.assert_allclose(np.asarray(out["w"]), np.stack(dens).mean(0),
                               rtol=1e-5, atol=1e-6)


def test_sync_auto_dense_resolution_keeps_error_feedback(mesh8):
    """Regression: algorithm='auto' resolving a residual-bearing leaf's
    bucket to 'dense' (high density -> fill-in past the delta threshold)
    must keep the legacy semantics — compress + EF + allreduce of the
    densified stream — not KeyError on the missing bucket residual."""
    from repro import comm

    cfg = SyncConfig(mode="sparcml", algorithm="auto", k_per_bucket=256,
                     bucket_size=512, min_sparse_size=65536, impl="ref")
    n = 1 << 17
    shapes = {"w": jax.ShapeDtypeStruct((n,), jnp.float32)}
    specs = {"w": P()}
    plan = comm.build_per_leaf_plan(shapes, specs, cfg, 8)
    assert plan.buckets[0].algorithm == "dense"    # the premise
    res = comp.init_residuals(shapes, specs, cfg, dp_total=8)
    assert res["w"] is not None
    rspecs = comp.residual_specs(shapes, specs, cfg, 8, dp_axes=("data",))
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (8, n))}

    def step(g, r, k):
        g = jax.tree.map(lambda x: x[0], g)
        return comp.sync_grads_inside(g, r, k, cfg, specs,
                                      data_axis="data", p_data=8)

    f = shard_map(step, mesh=mesh8,
                  in_specs=({"w": P("data", None)}, rspecs, P()),
                  out_specs=({"w": P()}, rspecs), check_vma=False)
    out, new_res = f(grads, res, key)
    dens = [np.asarray(topk_mod.compress2d(
        grads["w"][r].reshape(1, -1), 256, 512)[0].densify()).reshape(-1)
        for r in range(8)]
    np.testing.assert_allclose(np.asarray(out["w"]), np.stack(dens).mean(0),
                               rtol=1e-5, atol=1e-6)
    assert np.abs(np.asarray(new_res["w"])).sum() > 0   # EF actually ran


def test_wire_bytes_report():
    shapes = {"w": jax.ShapeDtypeStruct((1 << 20,), jnp.float32)}
    cfg = SyncConfig(mode="sparcml", k_per_bucket=4, bucket_size=512, qsgd_bits=4)
    rep = comp.wire_bytes_per_step(shapes, cfg, p=16)
    assert rep["ratio"] > 4  # compressed well below dense
    dense_cfg = SyncConfig(mode="dense")
    assert comp.wire_bytes_per_step(shapes, dense_cfg, p=16)["ratio"] == 1.0
