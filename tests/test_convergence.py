"""Paper-claim validation (EXPERIMENTS.md index):

* Thm 4.1 / Fig. 4: Quantized TopK SGD converges, tracking dense SGD.
* §8.2 / Table 2: naturally-sparse linear classification with lossless
  sparse communication converges identically to dense.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topk as topk_mod
from repro.core.qsgd import QSGDConfig, quantize, dequantize


def test_quantized_topk_sgd_converges_logreg():
    """Alg. 2 on a convex problem: loss -> near-dense optimum."""
    rng = np.random.default_rng(0)
    n, d = 512, 2048
    w_true = np.zeros(d); w_true[:32] = rng.standard_normal(32)
    X = rng.standard_normal((n, d)).astype(np.float32) / np.sqrt(d)
    y = (X @ w_true > 0).astype(np.float32) * 2 - 1
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    def loss(w):
        return jnp.mean(jnp.log1p(jnp.exp(-yj * (Xj @ w))))

    gfn = jax.grad(loss)
    key = jax.random.PRNGKey(0)

    def run(compressed: bool, steps=200, lr=20.0):
        w = jnp.zeros(d)
        err = jnp.zeros(d)
        hist = []
        for t in range(steps):
            g = gfn(w)
            if compressed:
                acc = err + lr * g
                u, err = topk_mod.compress(acc, 8, 512, impl="ref")  # 1.6%
                upd = u.densify()
                rand = jax.random.bits(jax.random.fold_in(key, t), (d,),
                                       dtype=jnp.uint32)
                q = QSGDConfig(bits=4)
                p, s = quantize(upd, q, rand)
                upd = dequantize(p, s, q, d)
                w = w - upd
            else:
                w = w - lr * g
            hist.append(float(loss(w)))
        return hist

    dense = run(False)
    sparse = run(True)
    assert sparse[-1] < 0.25, f"Quantized TopK did not converge: {sparse[-1]}"
    assert sparse[-1] < dense[0] * 0.5
    # compressed tracks dense closely (paper Fig. 4)
    assert abs(sparse[-1] - dense[-1]) < 0.05
    # ergodic decrease (Thm 4.1 flavor): tail avg well below head avg
    assert np.mean(sparse[-10:]) < np.mean(sparse[:10]) * 0.5


def test_error_feedback_matters():
    """Anisotropic quadratic: coords with small curvature lose every
    per-bucket top-k race; without EF they starve, with EF their error
    accumulates until transmitted (the point of Alg. 2's residual)."""
    rng = np.random.default_rng(1)
    d = 4096
    target = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    scale_vec = np.full(d, 0.05, np.float32)
    scale_vec[::64] = 1.0  # 8 loud coords per 512-bucket dominate selection
    a = jnp.asarray(scale_vec)

    def loss(w):
        return 0.5 * jnp.mean(a * (w - target) ** 2)

    gfn = jax.grad(loss)

    def run(ef: bool, steps=120, lr=0.3 * d):
        w = jnp.zeros(d)
        err = jnp.zeros(d)
        for _ in range(steps):
            acc = err + lr * gfn(w)
            u, new_err = topk_mod.compress(acc, 2, 512, impl="ref")  # 0.4%
            err = new_err if ef else jnp.zeros(d)
            w = w - u.densify()
        return float(loss(w))

    with_ef = run(True)
    without_ef = run(False)
    assert with_ef < without_ef * 0.8, (with_ef, without_ef)


def test_lossless_sparse_classification():
    """§8.2: gradients of linear models on trigram-sparse data ARE sparse;
    sparse aggregation is lossless -> identical trajectory to dense."""
    from repro.data.sparse_datasets import make_url_like_dataset
    from repro.core import sparse_stream as ss

    idx, val, y = make_url_like_dataset(n_samples=256, n_features=1 << 16,
                                        nnz_per_sample=32)
    n_feat = 1 << 16
    w_dense = np.zeros(n_feat, np.float32)
    w_sparse = np.zeros(n_feat, np.float32)
    lr = 0.1
    for i in range(256):
        margin = float((val[i] * w_dense[idx[i]]).sum())
        coef = -y[i] / (1 + np.exp(y[i] * margin))
        # dense grad update
        g = np.zeros(n_feat, np.float32)
        np.add.at(g, idx[i], coef * val[i])
        w_dense -= lr * g
        # sparse stream update (the natural-sparsity path)
        s = ss.SparseStream(jnp.asarray(idx[i]), jnp.asarray(coef * val[i]),
                            jnp.asarray(len(idx[i])))
        w_sparse -= lr * np.asarray(ss.densify(s, n_feat))
    np.testing.assert_allclose(w_sparse, w_dense, rtol=1e-5, atol=1e-7)
    # gradients really are sparse (paper's premise)
    assert len(np.unique(idx)) < n_feat * 0.15
