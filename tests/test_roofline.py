"""HLO cost parser + roofline model validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import cost_analysis, shard_map
from repro.utils.hlo_cost import total_cost
from repro.utils.roofline import Roofline, model_flops_train


def test_loop_free_flops_match_cost_analysis():
    @jax.jit
    def f(a, b, c):
        return (a @ b) @ c

    comp = f.lower(jax.ShapeDtypeStruct((256, 512), jnp.float32),
                   jax.ShapeDtypeStruct((512, 1024), jnp.float32),
                   jax.ShapeDtypeStruct((1024, 128), jnp.float32)).compile()
    mc = total_cost(comp.as_text())
    np.testing.assert_allclose(mc.flops, cost_analysis(comp)["flops"], rtol=1e-6)


def test_scan_trip_count_multiplies():
    @jax.jit
    def g(x, ws):
        def body(h, w):
            return h @ w, None
        return jax.lax.scan(body, x, ws)[0]

    comp = g.lower(jax.ShapeDtypeStruct((256, 256), jnp.float32),
                   jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)).compile()
    mc = total_cost(comp.as_text())
    np.testing.assert_allclose(mc.flops, 10 * 2 * 256 ** 3, rtol=1e-6)
    assert any(t == 10 for _, t in mc.trip_counts)
    # XLA's own analysis counts the body once — we must exceed it
    assert mc.flops > cost_analysis(comp)["flops"] * 5


def test_collective_bytes_psum(mesh4x2):
    def h(x):
        return jax.lax.psum(x, "data")

    m = jax.jit(shard_map(h, mesh=mesh4x2, in_specs=P("data"),
                          out_specs=P(), check_vma=False))
    comp = m.lower(jax.ShapeDtypeStruct((64, 128), jnp.float32)).compile()
    mc = total_cost(comp.as_text())
    # all-reduce of a (16,128) f32 shard = 8192B -> ring 2*(3/4)*8192
    np.testing.assert_allclose(mc.coll_by_kind["all-reduce"], 12288.0, rtol=1e-6)


def test_nested_scan_multiplies():
    @jax.jit
    def g(x, ws):
        def outer(h, _):
            def inner(h2, w):
                return h2 @ w, None
            return jax.lax.scan(inner, h, ws)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    comp = g.lower(jax.ShapeDtypeStruct((128, 128), jnp.float32),
                   jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)).compile()
    mc = total_cost(comp.as_text())
    np.testing.assert_allclose(mc.flops, 15 * 2 * 128 ** 3, rtol=1e-6)


def test_roofline_terms_and_dominance():
    r = Roofline(flops=197e12 * 256, hbm_bytes=819e9 * 256 * 2,
                 coll_bytes_per_chip=50e9, chips=256,
                 model_flops=197e12 * 256 * 0.5)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.dominant == "memory"
    assert r.bound == 2.0
    assert abs(r.serial_bound - 3.5) < 1e-9
    assert abs(r.useful_flops_ratio - 0.5) < 1e-9
    assert abs(r.mfu_bound - 0.25) < 1e-9


def test_model_flops():
    assert model_flops_train(1e9, 1e6) == 6e15
