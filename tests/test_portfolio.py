"""Sparse-allreduce portfolio (DESIGN.md §9): registry cost/wire
properties, the two capacity-clamped algorithms (balanced
split-and-gather, rearranged reduce-scatter) vs the dense reference on
all three lowerings, the global-residual mass-conservation rule, and
replan/controller/checkpoint carry of the new algorithm names."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.compat import shard_map
from repro.core import cost_model as cm
from repro.core.compressor import SyncConfig
from repro.core.density import expected_nnz
from repro.core.sparse_stream import delta_threshold
from repro.runtime import adapt as rt_adapt

KEY = jax.random.PRNGKey(0)
NEW_ALGOS = ("ssar_balanced_split", "ssar_rearranged_rs")
N, BUCKET, KPB = 8192, 128, 8


# --------------------------------------------------------------------------
# registry properties: every algorithm goes through the one dispatch
# --------------------------------------------------------------------------

def test_registry_covers_new_algorithms():
    for a in NEW_ALGOS:
        assert a in cm.ALL_ALGORITHMS
        assert cm.algorithm_output_cap(a, 8, 1600, 1 << 18) is not None
    for a in ("ssar_recursive_double", "ssar_split_allgather",
              "dsar_split_allgather", "dense"):
        assert cm.algorithm_output_cap(a, 8, 1600, 1 << 18) is None


@pytest.mark.parametrize("algo", cm.ALL_ALGORITHMS)
def test_wire_bytes_monotone_in_reduced_nnz(algo):
    p, k, n = 8, 1600, 1 << 18
    grid = [1.0, 16.0, 256.0, 4096.0, 65536.0, float(n)]
    wires = [cm.bucket_wire_bytes(algo, p, k, n, nnz=z) for z in grid]
    assert all(w >= 0 for w in wires)
    assert all(b >= a - 1e-9 for a, b in zip(wires, wires[1:]))


@pytest.mark.parametrize("case", [
    (8, 128, 1 << 15, None),          # latency-bound small data
    (8, 1600, 1 << 18, None),         # moderate density, the headline cell
    (1024, 1 << 17, 1 << 20, None),   # heavy fill-in past delta
    (8, 2048, 1 << 15, 20000.0),      # measured nnz over delta
    (8, 1600, 1 << 18, 200.0),        # measured nnz tiny
])
def test_select_algorithm_picks_modeled_argmin(case):
    """select_algorithm = argmin of bucket_time over the eligible set
    (dense only past delta; uncapped sparse representations only under
    it; capped ones survive iff their output bound stays under delta)."""
    p, k, n, nnz = case
    net = cm.DEFAULT_NET
    delta = delta_threshold(n, net.isize)
    exp_k = nnz if nnz is not None else expected_nnz(k, n, p)
    fill_dense = exp_k >= delta
    eligible = {}
    for name, entry in cm.ALGORITHM_REGISTRY.items():
        cap = cm.algorithm_output_cap(name, p, k, n)
        if name == "dense" and not fill_dense:
            continue
        if (entry.sparse_result and fill_dense
                and (cap is None or cap >= delta)):
            continue
        eligible[name] = cm.bucket_time(name, p, k, n, net,
                                        reduced_nnz=nnz)
    choice = cm.select_algorithm(p, k, n, net, reduced_nnz=nnz)
    assert eligible and choice == min(eligible, key=eligible.get)


def test_capped_algorithms_survive_delta_switchover():
    """Even at full measured fill-in the clamped portfolio stays
    eligible: its result cannot densify past the output bound."""
    p, k, n = 8, 2048, 1 << 15
    delta = delta_threshold(n)
    assert cm.algorithm_output_cap("ssar_balanced_split", p, k, n) < delta
    choice = cm.select_algorithm(p, k, n, reduced_nnz=float(n))
    cap = cm.algorithm_output_cap(choice, p, k, n)
    assert choice == "dense" or (cap is not None and cap < delta)


def test_headline_cell_portfolio_beats_classic_ssar():
    """The acceptance cell: P=8, moderate density — both new algorithms
    model cheaper (time AND wire) than both classic SSAR variants."""
    p, n = 8, 1 << 18
    k = int(0.05 * n)   # ~5% per-node density
    for new in NEW_ALGOS:
        for old in ("ssar_recursive_double", "ssar_split_allgather"):
            assert (cm.bucket_time(new, p, k, n)
                    < cm.bucket_time(old, p, k, n))
            assert (cm.bucket_wire_bytes(new, p, k, n)
                    < cm.bucket_wire_bytes(old, p, k, n))


# --------------------------------------------------------------------------
# parse_stream_cap input validation
# --------------------------------------------------------------------------

def test_parse_stream_cap_valid():
    assert cm.parse_stream_cap("stream_gather@64") == 64
    assert cm.parse_stream_cap("stream_gather@1") == 1


@pytest.mark.parametrize("tag", [
    "stream_gather", "stream_gather@", "stream_gather@x",
    "stream_gather@3.5", "dense@4", "stream_gather@0", "stream_gather@-3",
])
def test_parse_stream_cap_malformed(tag):
    with pytest.raises(ValueError, match="stream"):
        cm.parse_stream_cap(tag)


# --------------------------------------------------------------------------
# execution parity on the three lowerings
# --------------------------------------------------------------------------

def _portfolio_plan(algo, n=N, dp=8):
    cfg = SyncConfig(mode="sparcml", k_per_bucket=KPB, bucket_size=BUCKET,
                     algorithm="dsar_split_allgather", min_sparse_size=1024,
                     impl="ref", fusion_bucket_bytes=1 << 14)
    shapes = {"a": jax.ShapeDtypeStruct((n,), jnp.float32)}
    plan = comm.build_sync_plan(shapes, {"a": P()}, cfg, dp)
    sparse = [b.name for b in plan.buckets if b.sparse]
    assert sparse, plan.describe()
    return plan.replan(algorithms={nm: algo for nm in sparse})


def _overlap_grads(step, n=N):
    """Per-rank grads whose TopK supports coincide exactly (paper extreme
    case 2): every capacity in both portfolio algorithms is slack, so
    the native protocols are exact."""
    rng = np.random.default_rng(101 + step)
    g = rng.standard_normal((8, n)).astype(np.float32) * 0.01
    hot = (np.arange(n // BUCKET)[:, None] * BUCKET
           + np.arange(KPB)[None, :]).reshape(-1)
    g[:, hot] += 10.0
    return jnp.asarray(g)


def _run_manual(mesh8, plan, grads_list, native):
    res = plan.init_residuals()
    rspecs = {k: P("data", None, None) for k in res}
    rid = jnp.arange(8, dtype=jnp.int32)

    def inner(g, r, rid):
        out, new_res = comm.execute_plan(
            plan, [g[0]], r, KEY, data_axis="data", p_data=8,
            native=native, data_rank=rid[0])
        return out[0], new_res

    f = shard_map(inner, mesh=mesh8,
                  in_specs=(P("data", None), rspecs, P("data")),
                  out_specs=(P(), rspecs), check_vma=False)
    outs = []
    for g in grads_list:
        o, res = f(g, res, rid)
        outs.append(np.asarray(o))
    return outs, {k: np.asarray(v) for k, v in res.items()}


def _run_spmd(plan, grads_list):
    res = plan.init_residuals()
    outs = []
    for g in grads_list:
        synced, res = comm.execute_plan_spmd(plan, [g], res, KEY, p_data=8)
        outs.append(np.asarray(synced[0]))
    return outs, {k: np.asarray(v) for k, v in res.items()}


@pytest.mark.parametrize("algo", NEW_ALGOS)
def test_parity_all_lowerings_full_overlap(mesh8, algo):
    """Two EF steps: the native protocol matches the dense reference when
    no capacity binds, and the emulated/spmd lowerings are bit-identical
    to their dense-reference counterparts (the executor-parity
    invariant the existing algorithms already honor)."""
    plan = _portfolio_plan(algo)
    dense_plan = _portfolio_plan("dense")
    grads = [_overlap_grads(s) for s in range(2)]

    ref, ref_res = _run_manual(mesh8, dense_plan, grads, native=True)

    out_n, res_n = _run_manual(mesh8, plan, grads, native=True)
    for o, r in zip(out_n, ref):
        np.testing.assert_allclose(o, r, rtol=1e-5, atol=1e-6)
    for k in ref_res:   # caps slack -> fold == 0 -> EF state identical
        np.testing.assert_allclose(res_n[k], ref_res[k],
                                   rtol=1e-5, atol=1e-6)

    out_e, _ = _run_manual(mesh8, plan, grads, native=False)
    ref_e, _ = _run_manual(mesh8, dense_plan, grads, native=False)
    for o, r in zip(out_e, ref_e):
        np.testing.assert_array_equal(o, r)

    out_s, _ = _run_spmd(plan, grads)
    ref_s, _ = _run_spmd(dense_plan, grads)
    for o, r in zip(out_s, ref_s):
        np.testing.assert_array_equal(o, r)
    for o, r in zip(out_s, ref):
        np.testing.assert_allclose(o, r, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("algo", NEW_ALGOS)
def test_global_residual_conserves_mass(mesh8, algo):
    """Random (low-overlap) data makes the capacity clamps bind; the
    clamped mass must land in the EF residual, not vanish: per bucket,
    replicas * reduced + sum_r residual_r == sum_r grad_r exactly as for
    the unclamped algorithms (the global-residual rule)."""
    plan = _portfolio_plan(algo)
    rng = np.random.default_rng(7)
    g = jnp.asarray(rng.standard_normal((8, N)).astype(np.float32))
    res = plan.init_residuals()
    rspecs = {k: P("data", None, None) for k in res}
    out_specs = ({b.name: P() for b in plan.buckets}, rspecs)

    def inner(gr, r):
        reduced, new_res, _ = comm.reduce_buckets(
            plan, [gr[0]], r, KEY, data_axis="data", p_data=8)
        return reduced, new_res

    f = shard_map(inner, mesh=mesh8, in_specs=(P("data", None), rspecs),
                  out_specs=out_specs, check_vma=False)
    reduced, new_res = f(g, res)

    gnp = np.asarray(g)
    clamped_any = False
    for grp in plan.groups:
        for b in grp.buckets:
            seg = gnp[:, b.col_start:b.col_start + b.cols]
            exact = seg.sum(axis=0)
            got = (np.asarray(reduced[b.name])[0] * 8
                   + np.asarray(new_res[b.name])[:, 0, :].sum(axis=0))
            np.testing.assert_allclose(got, exact, rtol=1e-4, atol=1e-5)
            # non-vacuity: the clamp must actually have bound — the
            # reduced union is strictly smaller than the per-rank TopK
            # support union of the exact protocol
            per_bucket = np.abs(seg).reshape(8, -1, BUCKET)
            thresh = np.sort(per_bucket, axis=2)[:, :, -KPB][:, :, None]
            union = int((per_bucket >= thresh).any(axis=0).sum())
            out_nnz = int(np.count_nonzero(np.asarray(reduced[b.name])))
            k_total = b.cols // BUCKET * KPB
            cap = cm.algorithm_output_cap(b.algorithm, 8, k_total, b.n)
            assert out_nnz <= cap
            if out_nnz < union:
                clamped_any = True
    assert clamped_any, "caps never bound; the test is vacuous"


@pytest.mark.parametrize("algo", NEW_ALGOS)
def test_standalone_allreduce_exact_under_full_overlap(mesh8, algo):
    """make_sparse_allreduce wrapper: full index overlap -> result has
    exactly k nonzeros of value P (same contract as split_allgather)."""
    from repro.core.allreduce import make_sparse_allreduce

    k = 8
    xs = np.zeros((8, N), np.float32)
    xs[:, : BUCKET * k : BUCKET] = 1.0
    f = make_sparse_allreduce(mesh8, "data", N, k, BUCKET, algorithm=algo)
    out = np.asarray(f(jnp.asarray(xs).reshape(-1), None))
    nz = np.nonzero(out)[0]
    assert len(nz) == k and np.allclose(out[nz], 8.0)


# --------------------------------------------------------------------------
# plan / controller carry
# --------------------------------------------------------------------------

def _toy_plan(n=1 << 15, algorithm="ssar_split_allgather", dp=8):
    cfg = SyncConfig(mode="sparcml", k_per_bucket=KPB, bucket_size=BUCKET,
                     algorithm=algorithm, min_sparse_size=1024, impl="ref",
                     fusion_bucket_bytes=1 << 14)
    shapes = {"a": jax.ShapeDtypeStruct((n,), jnp.float32)}
    return comm.build_sync_plan(shapes, {"a": P()}, cfg, dp)


@pytest.mark.parametrize("algo", NEW_ALGOS)
def test_replan_signature_checkpoint_carry(algo):
    plan = _toy_plan()
    sparse = [b.name for b in plan.buckets if b.sparse]
    adapted = plan.replan(algorithms={nm: algo for nm in sparse})
    assert set(adapted.algorithms().values()) >= {algo}
    assert adapted.signature() != plan.signature()
    assert set(adapted.residual_shapes()) == set(plan.residual_shapes())
    assert adapted.wire_bytes() > 0
    # checkpoint resume: re-applying the saved algorithm map reproduces
    # the adapted plan exactly (signature match = compiled-step cache hit)
    resumed = plan.replan(algorithms=dict(adapted.algorithms()))
    assert resumed.signature() == adapted.signature()


def test_controller_replans_onto_portfolio_algorithm():
    """The bench_adapt acceptance path: measured fill-in crosses delta on
    an uncapped SSAR plan and the forced switchover lands on a
    capacity-clamped portfolio algorithm (modeled cheapest there)."""
    plan = _toy_plan(algorithm="ssar_split_allgather")
    b = next(bb for bb in plan.buckets if bb.sparse)
    ctrl = rt_adapt.AdaptiveController(
        plan, cm.DEFAULT_NET,
        rt_adapt.AdaptConfig(window=1, patience=1, calibrate=False))
    over = {b.name: float(delta_threshold(b.n) + 1)}
    accepted = None
    for _ in range(3):
        accepted = ctrl.observe_step(over) or accepted
    assert accepted is not None
    assert dict(accepted.algorithms())[b.name] in NEW_ALGOS


def test_controller_allow_restricts_portfolio():
    """AdaptConfig.allow narrows the replan candidates: with the
    portfolio excluded the delta crossing falls back to DSAR/dense."""
    legacy = ("ssar_recursive_double", "ssar_split_allgather",
              "dsar_split_allgather", "dense")
    plan = _toy_plan(algorithm="ssar_split_allgather")
    b = next(bb for bb in plan.buckets if bb.sparse)
    ctrl = rt_adapt.AdaptiveController(
        plan, cm.DEFAULT_NET,
        rt_adapt.AdaptConfig(window=1, patience=1, calibrate=False,
                             allow=legacy))
    over = {b.name: float(delta_threshold(b.n) + 1)}
    accepted = None
    for _ in range(3):
        accepted = ctrl.observe_step(over) or accepted
    assert accepted is not None
    assert dict(accepted.algorithms())[b.name] in (
        "dsar_split_allgather", "dense")


def test_capped_plan_not_force_switched_past_delta():
    """A plan already ON a capped algorithm does not get delta-forced
    off it: the output bound keeps the result sparse whatever the
    measured fill-in (the adapt-guard the output_cap exists for)."""
    plan = _toy_plan(algorithm="ssar_split_allgather")
    sparse = [b.name for b in plan.buckets if b.sparse]
    plan = plan.replan(algorithms={nm: "ssar_rearranged_rs"
                                   for nm in sparse})
    b = next(bb for bb in plan.buckets if bb.sparse)
    ctrl = rt_adapt.AdaptiveController(
        plan, cm.DEFAULT_NET,
        rt_adapt.AdaptConfig(window=1, patience=1, hysteresis=0.99,
                             calibrate=False))
    over = {b.name: float(delta_threshold(b.n) + 1)}
    for _ in range(4):
        accepted = ctrl.observe_step(over)
        assert accepted is None, dict(accepted.algorithms())
    assert ctrl.swaps == 0
