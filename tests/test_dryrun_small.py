"""Miniature dry-run: lower+compile the production code path on an 8-device
mesh for representative archs (full 16x16/2x16x16 runs live in
launch/dryrun.py; this keeps the invariant under pytest)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgreg
from repro.configs._common import make_train_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.train.train_step import build_train_step, state_shapes


def small_mesh(multi_pod=False):
    if multi_pod:
        return make_host_mesh(data=2, model=2, pod=2)
    return make_host_mesh(data=4, model=2)


@pytest.mark.parametrize("multi_pod", [False, True])
@pytest.mark.parametrize("sync", ["dense", "sparcml"])
def test_train_cell_lowers_and_compiles(multi_pod, sync):
    mesh = small_mesh(multi_pod)
    cfg = cfgreg.smoke_config("qwen3-4b")
    model = build_model(cfg)
    tcfg = make_train_config(sync_mode=sync, fsdp=(sync == "dense"))
    with mesh:
        step_fn, (shapes, _) = build_train_step(model, tcfg, mesh)
        b = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        lowered = step_fn.lower(shapes, b, key)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
        # the paper's collectives must appear in sparcml mode (lowering
        # depends on the backend path — DESIGN.md §4)
        hlo = compiled.as_text()
        if sync == "sparcml":
            from repro.train.train_step import sparcml_uses_manual_collectives
            if sparcml_uses_manual_collectives(mesh):
                assert "all-to-all" in hlo, "DSAR split phase missing"
                assert "all-gather" in hlo, "DSAR gather phase missing"
            else:
                # auto-SPMD fallback: XLA inserts the dp reductions
                assert "all-reduce" in hlo, "dp-axis reduction missing"


@pytest.mark.parametrize("arch", ["mamba2-370m", "zamba2-2.7b", "dbrx-132b"])
def test_decode_cell_lowers(arch):
    mesh = small_mesh()
    from repro.serve.engine import build_serve_step
    cfg = cfgreg.smoke_config(arch)
    model = build_model(cfg)
    with mesh:
        dec_fn, _ = build_serve_step(model, mesh, batch_size=8, cache_len=64)
        pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        st = jax.eval_shape(lambda: model.init_decode_state(8, 64, prefix_len=63))
        toks = jax.ShapeDtypeStruct((8, 1), jnp.int32)
        dec_fn.lower(pshapes, st, toks).compile()


def test_input_specs_are_abstract():
    from repro.launch.dryrun import input_specs
    spec = input_specs("qwen3-4b", "train_4k")
    assert spec["tokens"].shape == (256, 4096)
    assert spec["tokens"].dtype == jnp.int32
    spec2 = input_specs("hubert-xlarge", "prefill_32k")
    assert spec2["frames"].shape == (32, 32768, 512)
    spec3 = input_specs("llama-3.2-vision-11b", "train_4k")
    assert spec3["image_embeds"].shape == (256, 1600, 1280)
