"""Test harness: 8 host devices for the distributed unit tests.

(The 512-device flag is reserved for launch/dryrun.py per its contract;
8 is enough for every collective test here and keeps smoke tests fast.)

Meshes are built via repro.compat.make_mesh (routed through
repro.launch.mesh) so the suite collects on JAX builds without
jax.sharding.AxisType.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

try:
    import hypothesis  # noqa: F401
except ImportError:  # container image has no hypothesis; use the stub
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.compat import make_mesh  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def mesh8():
    return make_mesh((8,), ("data",))


@pytest.fixture(scope="session")
def mesh4x2():
    return make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="session")
def mesh2x2x2():
    return make_mesh((2, 2, 2), ("pod", "data", "model"))
