"""Test harness: 8 host devices for the distributed unit tests.

(The 512-device flag is reserved for launch/dryrun.py per its contract;
8 is enough for every collective test here and keeps smoke tests fast.)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import pytest  # noqa: E402
from jax.sharding import AxisType  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def mesh8():
    return jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))


@pytest.fixture(scope="session")
def mesh4x2():
    return jax.make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def mesh2x2x2():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)
