"""Fault tolerance: atomic checkpoints, restart-after-failure replay,
elastic remesh of replica-dependent state."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.train import checkpoint as ckpt
from repro.train.state import TrainConfig
from repro.train.trainer import Trainer
from repro.train.train_step import dp_total_of


def tiny_cfg():
    return ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                       dtype=jnp.float32, param_dtype=jnp.float32,
                       max_seq_len=64)


def make_trainer(mesh, tmpdir, sync_mode="sparcml"):
    sync = (SyncConfig(mode="sparcml", k_per_bucket=64, bucket_size=512,
                       algorithm="dsar_split_allgather", min_sparse_size=4096,
                       impl="ref")
            if sync_mode == "sparcml" else SyncConfig(mode="dense"))
    tcfg = TrainConfig(sync=sync, optimizer=OptimizerConfig(),
                       schedule=ScheduleConfig(peak_lr=3e-3, warmup_steps=5,
                                               total_steps=200))
    return Trainer(build_model(tiny_cfg()), tcfg, mesh,
                   DataConfig(global_batch=8, seq_len=32, vocab_size=256),
                   ckpt_dir=str(tmpdir), ckpt_every=5)


def test_save_restore_roundtrip(mesh4x2, tmp_path):
    tr = make_trainer(mesh4x2, tmp_path)
    tr.run(7)
    state = tr.state
    restored = ckpt.restore(str(tmp_path), state, dp_total=dp_total_of(mesh4x2))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_resumes_from_latest(mesh4x2, tmp_path):
    tr = make_trainer(mesh4x2, tmp_path)
    tr.run(12)
    # simulate a fresh process
    tr2 = make_trainer(mesh4x2, tmp_path)
    start = tr2.init_or_resume()
    assert start == 12
    tr2.run(15)
    assert int(tr2.state.step) == 15


def test_injected_failure_recovers(mesh4x2, tmp_path):
    tr = make_trainer(mesh4x2, tmp_path)
    log = tr.run(20, fail_at=13)
    assert log.restarts >= 1
    assert int(tr.state.step) == 20
    # deterministic data replay: loss trajectory still converged
    assert log.losses[-1] < log.losses[0]


def test_elastic_remesh(mesh4x2, mesh2x2x2, tmp_path):
    """Checkpoint at dp=4 (4x2 mesh), resume on dp=4 across 2 pods (2x2x2)."""
    tr = make_trainer(mesh4x2, tmp_path)
    tr.run(10)
    tr2 = make_trainer(mesh2x2x2, tmp_path)
    start = tr2.resume_elastic(mesh2x2x2)
    assert start == 10
    tr2.run(14)
    assert int(tr2.state.step) == 14


def test_atomic_no_partial_checkpoints(mesh4x2, tmp_path):
    tr = make_trainer(mesh4x2, tmp_path)
    tr.run(6)
    for d in os.listdir(tmp_path):
        assert not d.endswith(".tmp"), "partial checkpoint leaked"


def test_checkpoint_gc_keeps_last(mesh4x2, tmp_path):
    tr = make_trainer(mesh4x2, tmp_path)
    tr.run(26)  # checkpoints at 5,10,15,20,25(+final 26)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) <= 3


def test_straggler_watchdog_logs(mesh4x2, tmp_path, monkeypatch):
    from statistics import median

    tr = make_trainer(mesh4x2, tmp_path)
    tr.init_or_resume()
    tr.run(6)  # warm up: compile + collect a baseline step-time median
    baseline = median(tr.log.step_times[1:])  # drop the compile step
    # wrap the step fn with a delay safely above straggler_factor x median
    orig = tr.step_fn

    def slow(state, batch, key):
        import time
        if int(state.step) == 8:
            time.sleep(max(5 * baseline, 0.5))
        return orig(state, batch, key)

    tr.step_fn = slow
    log = tr.run(12)
    assert any(s == 8 for s, *_ in log.straggler_events)
