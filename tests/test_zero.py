"""ZeRO-sharded training state (DESIGN.md §11): scattered output mode.

Covers the PR's acceptance surface:
  * SyncPlan.wire_bytes per-rank vs aggregate conventions, and the
    scattered-mode wire win over the replicated ssar_* exchanges;
  * scattered-vs-replicated training parity on the auto-SPMD and
    manual lowerings (>= 2 EF steps each);
  * emulated-lowering owner chunks == column slices of the replicated
    reduce (exact), with residual carry;
  * shard mass conservation when the portfolio capacity caps bind;
  * checkpoint interop in BOTH directions (zero_scattered <->
    zero1_leaf), in memory and through the Trainer's on-disk restore;
  * the pipelined scattered step's param allgather stays O(num_buckets).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.compat import make_mesh, shard_map
from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.train import checkpoint as ckpt
from repro.train.state import TrainConfig
from repro.train.train_step import (
    build_train_step,
    init_state,
    sparcml_uses_manual_collectives,
    state_shapes,
)

KEY = jax.random.PRNGKey(0)
N, BUCKET, KPB = 8192, 128, 8


def _sync(mode, algorithm="dsar_split_allgather", k=KPB, **kw):
    base = dict(mode="sparcml", k_per_bucket=k, bucket_size=BUCKET,
                algorithm=algorithm, min_sparse_size=1024, impl="ref",
                fusion_bucket_bytes=1 << 18, output_mode=mode)
    base.update(kw)
    return SyncConfig(**base)


def _tcfg(mode, algorithm="dsar_split_allgather"):
    return TrainConfig(sync=_sync(mode, algorithm),
                       optimizer=OptimizerConfig(),
                       schedule=ScheduleConfig(peak_lr=3e-3, warmup_steps=2,
                                               total_steps=100),
                       zero1=True)


def _model_cfg():
    """Sized so the sparse path engages at dp=4 and dp=8 (canonical
    cols per bucket divide both)."""
    return ModelConfig(name="tz", family="dense", num_layers=2, d_model=512,
                       num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=512,
                       dtype=jnp.float32, param_dtype=jnp.float32,
                       max_seq_len=64)


def _flat_plan(mode, algorithm, k=KPB, dp=8, n=N):
    cfg = _sync(mode, algorithm, k=k, fusion_bucket_bytes=1 << 14)
    shapes = {"a": jax.ShapeDtypeStruct((n,), jnp.float32)}
    plan = comm.build_sync_plan(shapes, {"a": P()}, cfg, dp)
    sparse = [b.name for b in plan.buckets if b.sparse]
    assert sparse, plan.describe()
    return plan.replan(algorithms={nm: algorithm for nm in sparse})


def _run_steps(mesh, tcfg, n_steps=4, seed_offset=0):
    model = build_model(_model_cfg())
    step_fn, _ = build_train_step(model, tcfg, mesh)
    state, _ = init_state(model, tcfg, mesh)
    dcfg = DataConfig(global_batch=8, seq_len=32, vocab_size=512)
    losses = []
    with mesh:
        for i in range(n_steps):
            batch = jax.tree.map(jnp.asarray,
                                 synthetic_batch(dcfg, i + seed_offset))
            state, m = step_fn(state, batch, jax.random.fold_in(KEY, i))
            losses.append(float(m["loss"]))
    return losses, state


# --------------------------------------------------------------------------
# wire accounting (satellite: per-rank vs aggregate convention)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["ssar_balanced_split",
                                  "ssar_rearranged_rs",
                                  "dsar_split_allgather"])
def test_wire_bytes_per_rank_vs_aggregate(algo):
    """wire_bytes() is PER RANK per step; aggregate=True is exactly p
    times that — both for the gradient exchange and the param
    allgather. Pins the convention so callers can't mix the two."""
    p = 8
    for mode in ("replicated", "scattered"):
        plan = _flat_plan(mode, algo, dp=p)
        per_rank = plan.wire_bytes()
        agg = plan.wire_bytes(aggregate=True)
        assert per_rank > 0
        assert agg == pytest.approx(p * per_rank, rel=1e-12)
        pg = plan.param_allgather_bytes()
        pg_agg = plan.param_allgather_bytes(aggregate=True)
        if mode == "replicated":
            assert pg == 0.0 and pg_agg == 0.0
        else:
            # every bucket ships its (P-1)/P foreign fp32 columns
            want = sum((p - 1) / p * b.n * 4 for b in plan.buckets)
            assert pg == pytest.approx(want)
            assert pg_agg == pytest.approx(p * pg, rel=1e-12)


@pytest.mark.parametrize("algo", ["ssar_balanced_split",
                                  "ssar_rearranged_rs"])
def test_scattered_wire_below_replicated_at_low_density(algo):
    """The tentpole wire claim: at d <= 1% the scattered gradient
    exchange is STRICTLY below the replicated ssar_* exchange (the
    skipped gather is the saving; the dense param allgather is
    accounted separately because it overlaps the next forward)."""
    k = 1                           # 1/128 per bucket < 1% density
    rep = _flat_plan("replicated", algo, k=k)
    sc = _flat_plan("scattered", algo, k=k)
    assert sc.wire_bytes() < rep.wire_bytes(), (
        algo, sc.wire_bytes(), rep.wire_bytes())
    assert sc.param_allgather_bytes() > 0


def test_scattered_plan_geometry_and_replan():
    plan = _flat_plan("scattered", "ssar_balanced_split")
    assert plan.scattered
    assert plan.signature().startswith("out=scattered|")
    for g in plan.groups:
        for b in g.buckets:
            assert plan.owned_cols(b) * plan.dp_total == b.cols
    # replanning (density drift, algorithm swap) must PRESERVE the
    # output mode — the state layout is pinned to it (DESIGN.md §11)
    re = plan.replan(algorithms={b.name: "ssar_rearranged_rs"
                                 for b in plan.buckets if b.sparse})
    assert re.scattered and re.signature().startswith("out=scattered|")


# --------------------------------------------------------------------------
# per-device state memory (satellite: dryrun breakdown)
# --------------------------------------------------------------------------

def test_state_memory_breakdown_scattered_shards_opt(mesh4x2):
    from repro.launch.dryrun import state_memory_breakdown

    model = build_model(_model_cfg())
    full = TrainConfig(sync=_sync("replicated"), optimizer=OptimizerConfig(),
                       schedule=ScheduleConfig(peak_lr=3e-3, warmup_steps=2,
                                               total_steps=100), zero1=False)
    scat = _tcfg("scattered")
    m_full = state_memory_breakdown(model, full, mesh4x2)
    m_scat = state_memory_breakdown(model, scat, mesh4x2)
    p = 4  # dp_total on mesh4x2
    assert m_full["params"] == m_scat["params"]
    # moments shard 1/P per device (bucket padding adds a little)
    assert m_scat["opt_mu"] <= m_full["opt_mu"] / p * 1.10
    assert m_scat["opt_nu"] <= m_full["opt_nu"] / p * 1.10
    assert m_scat["total"] < m_full["total"]
    assert m_scat["ef_residual"] > 0       # EF state is accounted
    for k in ("params", "opt_mu", "opt_nu", "ef_residual", "inflight",
              "total"):
        assert k in m_scat


# --------------------------------------------------------------------------
# training parity: scattered == replicated on every lowering
# --------------------------------------------------------------------------

def test_scattered_spmd_matches_replicated(mesh4x2):
    """Auto-SPMD lowering (mesh4x2 falls back on CPU), 4 steps — at
    least 2 with the EF residual warm. The scattered step rebuilds the
    synced leaves and reuses the replicated clip, so training tracks
    the replicated run to fp-fusion noise."""
    lr_, sr = _run_steps(mesh4x2, _tcfg("replicated"))
    ls_, ss = _run_steps(mesh4x2, _tcfg("scattered"))
    np.testing.assert_allclose(lr_, ls_, rtol=1e-5)
    # residuals exist and are warm (EF actually engaged)
    assert ss.residuals and any(
        float(jnp.abs(v).sum()) > 0 for v in jax.tree.leaves(ss.residuals))
    for a, b in zip(jax.tree.leaves(sr.params), jax.tree.leaves(ss.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_scattered_manual_matches_replicated():
    """Native manual lowering ((8,1) mesh): the reduce stops at the
    owner shard and the only gather left is the per-bucket dense param
    allgather. Grad norm comes from a per-shard psum (different fp
    summation order), so parity is allclose, not bitwise."""
    mesh = make_mesh((8, 1), ("data", "model"))
    assert sparcml_uses_manual_collectives(mesh)
    lr_, sr = _run_steps(mesh, _tcfg("replicated", "ssar_balanced_split"))
    ls_, ss = _run_steps(mesh, _tcfg("scattered", "ssar_balanced_split"))
    np.testing.assert_allclose(lr_, ls_, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(sr.params), jax.tree.leaves(ss.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-2)


def test_scattered_emulated_chunks_match_replicated_slices(mesh8):
    """Emulated lowering (psum-only CollectiveContext): each reduced
    value is my (1, rows, cols/p) owned chunk and must equal the OWN
    column slice of the replicated reduce EXACTLY, with identical
    residual carry, over 2 EF steps."""
    rng = np.random.default_rng(3)
    grads = [jnp.asarray(rng.standard_normal((8, N)).astype(np.float32))
             for _ in range(2)]
    rep = _flat_plan("replicated", "ssar_rearranged_rs")
    sc = _flat_plan("scattered", "ssar_rearranged_rs")

    def run(plan, scattered):
        res = plan.init_residuals()
        rspecs = {k: P("data", None, None) for k in res}
        rid = jnp.arange(8, dtype=jnp.int32)
        out_specs = ({b.name: (P("data", None, None) if scattered else P())
                      for b in plan.buckets}, rspecs)

        def inner(g, r, rid):
            reduced, new_res, _ = comm.reduce_buckets(
                plan, [g[0]], r, KEY, data_axis="data", p_data=8,
                native=False, data_rank=rid[0])
            return reduced, new_res

        f = shard_map(inner, mesh=make_mesh((8,), ("data",)),
                      in_specs=(P("data", None), rspecs, P("data")),
                      out_specs=out_specs, check_vma=False)
        outs = []
        for g in grads:
            reduced, res = f(g, res, rid)
            outs.append({k: np.asarray(v) for k, v in reduced.items()})
        return outs, {k: np.asarray(v) for k, v in res.items()}

    out_r, res_r = run(rep, scattered=False)
    out_s, res_s = run(sc, scattered=True)
    for step in range(2):
        for g in sc.groups:
            for b in g.buckets:
                full = out_r[step][b.name]            # (rows, cols)
                chunks = out_s[step][b.name]          # (p, rows, w)
                w = sc.owned_cols(b)
                for r in range(8):
                    np.testing.assert_array_equal(
                        chunks[r], full[:, r * w:(r + 1) * w])
    for k in res_r:
        np.testing.assert_array_equal(res_r[k], res_s[k])


def test_shard_mass_conservation_under_caps(mesh8):
    """Random low-overlap grads make the balanced-split capacity clamp
    BIND. The owner shards must still conserve mass: per bucket,
    replicas * concat(shards) + sum_r residual_r == sum_r grad_r (the
    clamped-off mass lands in the owning rank's fold, never vanishes)."""
    plan = _flat_plan("scattered", "ssar_balanced_split")
    rng = np.random.default_rng(7)
    g = jnp.asarray(rng.standard_normal((8, N)).astype(np.float32))
    res = plan.init_residuals()
    rspecs = {k: P("data", None, None) for k in res}
    out_specs = ({b.name: P("data", None, None) for b in plan.buckets},
                 rspecs)

    rid = jnp.arange(8, dtype=jnp.int32)

    def inner(gr, r, rid):
        reduced, new_res, _ = comm.reduce_buckets(
            plan, [gr[0]], r, KEY, data_axis="data", p_data=8, native=False,
            data_rank=rid[0])
        return reduced, new_res

    f = shard_map(inner, mesh=mesh8,
                  in_specs=(P("data", None), rspecs, P("data")),
                  out_specs=out_specs, check_vma=False)
    reduced, new_res = f(g, res, rid)

    gnp = np.asarray(g)
    clamped_any = False
    for grp in plan.groups:
        for b in grp.buckets:
            seg = gnp[:, b.col_start:b.col_start + b.cols]
            exact = seg.sum(axis=0)                       # (cols,)
            chunks = np.asarray(reduced[b.name])          # (p, rows, w)
            merged = np.concatenate([chunks[r][0] for r in range(8)])
            r_sum = np.asarray(new_res[b.name])[:, 0, :].sum(axis=0)
            recon = 8.0 * merged + r_sum                  # mean=True scale
            np.testing.assert_allclose(recon, exact, rtol=1e-4, atol=1e-4)
            if not np.allclose(8.0 * merged, exact, atol=1e-6):
                clamped_any = True
    assert clamped_any, "caps never bound — test exercises nothing"


# --------------------------------------------------------------------------
# checkpoint interop: zero_scattered <-> zero1_leaf, both directions
# --------------------------------------------------------------------------

def _convert_state(state, plan, source, target):
    return ckpt.convert_opt_layout(state, plan, source=source, target=target)


def _resume_steps(mesh, tcfg, state, start, n_steps):
    model = build_model(_model_cfg())
    step_fn, _ = build_train_step(model, tcfg, mesh)
    dcfg = DataConfig(global_batch=8, seq_len=32, vocab_size=512)
    with mesh:
        for i in range(start, start + n_steps):
            batch = jax.tree.map(jnp.asarray, synthetic_batch(dcfg, i))
            state, _ = step_fn(state, batch, jax.random.fold_in(KEY, i))
    return state


@pytest.mark.parametrize("direction", ["scattered_to_replicated",
                                       "replicated_to_scattered"])
def test_checkpoint_interop_continues_training(mesh4x2, direction):
    """2 steps under one layout -> convert -> 2 more under the other
    == 4 straight steps under the target layout. The conversion is
    value-exact (pinned bitwise in the trainer test below); the
    tolerance here absorbs the lowering fp noise of the first two
    steps, which EF top-k selection can amplify on a few coordinates."""
    src_mode, dst_mode = (("scattered", "replicated")
                          if direction == "scattered_to_replicated"
                          else ("replicated", "scattered"))
    src_layout = ("zero_scattered" if src_mode == "scattered"
                  else "zero1_leaf")
    dst_layout = ("zero_scattered" if dst_mode == "scattered"
                  else "zero1_leaf")
    model = build_model(_model_cfg())
    _, _, plan = state_shapes(model, _tcfg(src_mode), mesh4x2,
                              return_plan=True)

    _, mid = _run_steps(mesh4x2, _tcfg(src_mode), n_steps=2)
    mid = _convert_state(mid, plan, src_layout, dst_layout)
    end = _resume_steps(mesh4x2, _tcfg(dst_mode), mid, start=2, n_steps=2)
    _, ref = _run_steps(mesh4x2, _tcfg(dst_mode), n_steps=4)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(end.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-2, atol=5e-3)


def test_trainer_restores_other_layout_from_disk(mesh4x2, tmp_path):
    """On-disk interop through the Trainer: a checkpoint written under
    scattered (meta stamped zero_scattered) resumes under a replicated
    config — the moments come back converted, value-exact."""
    from repro.train.trainer import Trainer

    _, st = _run_steps(mesh4x2, _tcfg("scattered"), n_steps=2)
    ckpt.save(str(tmp_path), st, dp_total=4,
              opt_layout="zero_scattered")
    meta = ckpt.load_meta(str(tmp_path))
    assert meta["opt_layout"] == "zero_scattered"

    model = build_model(_model_cfg())
    dcfg = DataConfig(global_batch=8, seq_len=32, vocab_size=512)
    tr = Trainer(model, _tcfg("replicated"), mesh4x2, dcfg,
                 ckpt_dir=str(tmp_path))
    start = tr.init_or_resume()
    assert start == 2
    # structure matches the replicated (zero1_leaf) template...
    shapes, _, plan = state_shapes(model, _tcfg("replicated"), mesh4x2,
                                   return_plan=True)
    got = jax.tree_util.tree_structure(tr.state.opt)
    want = jax.tree_util.tree_structure(
        jax.tree.map(lambda s: 0, shapes.opt,
                     is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)))
    assert got == want
    # ...and the values are the converted scattered moments, exactly
    conv = _convert_state(st, plan, "zero_scattered", "zero1_leaf")
    for a, b in zip(jax.tree.leaves(conv.opt["mu"]),
                    jax.tree.leaves(tr.state.opt["mu"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rejects_full_to_sharded():
    model = build_model(_model_cfg())
    mesh = make_mesh((4, 2), ("data", "model"))
    _, _, plan = state_shapes(model, _tcfg("scattered"), mesh,
                              return_plan=True)
    state, _ = init_state(model, _tcfg("scattered"), mesh)
    with pytest.raises(ValueError, match="only"):
        ckpt.convert_opt_layout(state, plan, source="full",
                                target="zero_scattered")


# --------------------------------------------------------------------------
# pipelined scattered step: param allgather is O(num_buckets)
# --------------------------------------------------------------------------

def _count_prims(jaxpr, names: set) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in names:
            total += 1
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                total += _count_prims(sub, names)
    return total


try:  # moved out of jax.core in newer JAX
    from jax.extend.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr
except ImportError:
    from jax.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr


def _subjaxprs(v):
    out = []
    if isinstance(v, _ClosedJaxpr):
        out.append(v.jaxpr)
    elif isinstance(v, _Jaxpr):
        out.append(v)
    elif isinstance(v, (list, tuple)):
        for x in v:
            out.extend(_subjaxprs(x))
    return out


def test_pipelined_scattered_allgather_is_per_bucket():
    """The collective-count acceptance: on the native lowering the
    scattered pipelined step issues exactly ONE all_gather per fusion
    bucket (the dense param allgather) — not one per leaf — and fewer
    than the replicated zero1 step (whose DSAR gather phase + per-leaf
    param gathers both survive)."""
    from repro.runtime.pipeline import build_pipelined_step

    mesh = make_mesh((8, 1), ("data", "model"))
    assert sparcml_uses_manual_collectives(mesh)
    model = build_model(_model_cfg())

    def trace(mode):
        tcfg = _tcfg(mode)
        with mesh:
            jitted, (shapes, _), plan = build_pipelined_step(
                model, tcfg, mesh, staleness=1, telemetry=False)
            b = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
            key = jax.ShapeDtypeStruct((2,), jnp.uint32)
            jaxpr = jax.make_jaxpr(jitted)(shapes, b, key).jaxpr
        return _count_prims(jaxpr, {"all_gather"}), plan

    n_scat, plan = trace("scattered")
    n_rep, _ = trace("replicated")
    n_leaves = plan.num_leaves
    assert n_scat == plan.num_buckets, (n_scat, plan.describe())
    assert plan.num_buckets < n_leaves  # fusion actually fuses here
    assert n_scat < n_rep, (n_scat, n_rep)
