"""Observability layer (DESIGN.md §10): tracer, metrics registry, drift
auditor, and their integration with the pipelined driver and the serve
engine.

The two load-bearing invariants:

* obs OFF is free: the driver's loop is byte-identical, every span a
  shared no-op context manager;
* obs ON adds NO sync points: retire's ``block_until_ready`` stays the
  only one (counted under a monkeypatch), the span tree is well-formed
  Chrome-trace JSON, and the derived device phases tile each retire
  interval exactly.
"""
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs as obs_mod
from repro.compat import make_mesh
from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.obs import (
    DriftAuditor,
    MetricsRegistry,
    NULL_TRACER,
    Observability,
    Tracer,
    attribute_step_phases,
    audit_sync_plan,
    record_bucket_telemetry,
    validate_span_tree,
)
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.runtime import driver as rt_driver
from repro.runtime import pipeline as rt_pipeline
from repro.serve import ContinuousServeEngine, Request
from repro.train.state import TrainConfig
from repro.train.train_step import init_state

MODEL_CFG = ModelConfig(name="obs", family="dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                        dtype=jnp.float32, param_dtype=jnp.float32,
                        max_seq_len=64)
SYNC = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                  algorithm="dsar_split_allgather", min_sparse_size=1024,
                  impl="ref", fusion_bucket_bytes=1 << 18)
TCFG = TrainConfig(sync=SYNC, optimizer=OptimizerConfig(),
                   schedule=ScheduleConfig(peak_lr=3e-3, warmup_steps=5,
                                           total_steps=100),
                   zero1=True)
DCFG = DataConfig(global_batch=8, seq_len=32, vocab_size=256)
KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# Tracer units
# --------------------------------------------------------------------------

def test_null_tracer_is_shared_noop():
    from repro.obs.trace import _NULL_SPAN

    assert not NULL_TRACER.enabled
    # the hot-path contract: a disabled span() is the SAME object every
    # call (no allocation), and recording is a no-op
    assert NULL_TRACER.span("a") is NULL_TRACER.span("b") is _NULL_SPAN
    NULL_TRACER.instant("x")
    NULL_TRACER.complete("x", "c", 0.0, 1.0)
    assert NULL_TRACER.events == []


def test_span_tree_nesting_and_export(tmp_path):
    tr = Tracer()
    with tr.span("outer", step=1):
        with tr.span("inner/a"):
            pass
        with tr.span("inner/b"):
            pass
    tr.instant("marker")
    tr.counter("occupancy", active=3)
    assert validate_span_tree(tr.events) == []
    names = [e["name"] for e in tr.events if e["ph"] == "X"]
    # spans record on exit, so children precede the parent in the list
    assert names == ["inner/a", "inner/b", "outer"]
    path = tr.export(str(tmp_path / "t.json"), meta={"run": "test"})
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["run"] == "test"
    assert len(doc["traceEvents"]) == len(tr.events)
    outer = next(e for e in doc["traceEvents"] if e["name"] == "outer")
    assert outer["args"]["step"] == 1 and outer["dur"] >= 0


def test_validate_span_tree_catches_partial_overlap():
    evs = [
        {"name": "a", "ph": "X", "ts": 0.0, "dur": 100.0, "pid": 1, "tid": 1},
        {"name": "b", "ph": "X", "ts": 50.0, "dur": 100.0, "pid": 1, "tid": 1},
    ]
    bad = validate_span_tree(evs)
    assert len(bad) == 1 and "partially overlaps" in bad[0]
    # same intervals on DIFFERENT tracks: fine
    evs[1]["tid"] = 2
    assert validate_span_tree(evs) == []


# --------------------------------------------------------------------------
# Metrics registry units
# --------------------------------------------------------------------------

def test_registry_kinds_and_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2)
    reg.gauge("g").set(1.5)
    for v in [1.0, 2.0, 3.0, 4.0]:
        reg.histogram("h").observe(v)
    reg.series("s").append((1, "x"))
    reg.event("ev/one", step=3, signature="sig")
    # get-or-create returns the same object; kind conflicts raise
    assert reg.counter("c").value == 3
    with pytest.raises(TypeError):
        reg.gauge("c")

    path = reg.dump_jsonl(str(tmp_path / "m.jsonl"), meta={"who": "test"})
    lines = [json.loads(ln) for ln in open(path)]
    head = lines[0]
    assert head["kind"] == "header" and head["schema_version"] == 2
    assert head["meta"]["who"] == "test"
    by = {(ln["kind"], ln.get("name")): ln for ln in lines[1:]}
    assert by[("counter", "c")]["value"] == 3
    assert by[("gauge", "g")]["value"] == 1.5
    h = by[("histogram", "h")]
    assert h["count"] == 4 and h["min"] == 1.0 and h["max"] == 4.0
    assert by[("series", "s")]["values"] == [[1, "x"]]
    evs = [ln for ln in lines if ln["kind"] == "event"]
    assert evs[0]["event"] == "ev/one" and evs[0]["step"] == 3
    assert "summary" not in reg.summary()  # smoke: renders without raising


def test_disabled_registry_series_still_back_logs():
    """DriverLog's public fields are Series views — they must work (as
    plain lists) even when the registry is disabled, while events stay
    off."""
    reg = MetricsRegistry(enabled=False)
    data = reg.series("train/loss").data
    data.append(1.0)
    assert reg.series("train/loss").data == [1.0]
    reg.event("nope", x=1)
    assert reg.events == []


def test_record_bucket_telemetry_shapes():
    reg = MetricsRegistry()
    telem = {"b0": np.array([[3, 96.0], [5, 160.0]]),
             "scalar": np.array([1.0])}  # wrong shape: ignored
    record_bucket_telemetry(reg, telem)
    assert reg.histogram("bucket/b0/nnz").values == [3.0, 5.0]
    assert reg.histogram("bucket/b0/wire_bytes").values == [96.0, 160.0]
    assert "bucket/scalar/nnz" not in reg.metrics


def test_histogram_percentiles():
    reg = MetricsRegistry()
    reg.histogram("h").observe_many(np.arange(1, 101, dtype=np.float64))
    s = reg.histogram("h").snapshot()
    assert s["p50"] == pytest.approx(50.5)
    assert s["p99"] == pytest.approx(99.01)
    assert reg.histogram("h").percentile(90) == pytest.approx(90.1)


def test_histogram_percentiles_empty_and_single_sample():
    reg = MetricsRegistry()
    h = reg.histogram("empty")
    # empty: NaN percentile (never a crash), count-0 snapshot, renderable
    assert np.isnan(h.percentile(99))
    assert h.snapshot() == {"count": 0}
    assert h.brief() == "empty"
    # single sample: every percentile IS that sample
    one = reg.histogram("one")
    one.observe(7.5)
    s = one.snapshot()
    assert s["count"] == 1
    for q in ("p50", "p90", "p99", "min", "max", "mean"):
        assert s[q] == 7.5
    assert one.percentile(0) == one.percentile(100) == 7.5


def test_series_view_survives_registry_disabled_mid_run():
    """DriverLog holds Series ``.data`` views for the run's lifetime; a
    registry toggled off mid-run must keep those views alive (same list,
    appends land) while the event log goes quiet."""
    reg = MetricsRegistry()
    view = reg.series("train/loss").data
    view.append(1.0)
    reg.event("before", x=1)
    reg.enabled = False
    # same backing object, not a fresh one
    assert reg.series("train/loss") is reg.series("train/loss")
    assert reg.series("train/loss").data is view
    view.append(2.0)
    reg.series("train/loss").append(3.0)
    assert view == [1.0, 2.0, 3.0]
    reg.event("after", x=2)   # dropped: registry is off
    assert [e["event"] for e in reg.events] == ["before"]
    # re-enable: the history was never lost
    reg.enabled = True
    reg.event("resumed")
    assert len(reg.events) == 2 and reg.series("train/loss").data is view


def test_validate_span_tree_out_of_order_events():
    """Spans record on EXIT, so the event list is naturally child-first
    and may interleave arbitrarily across tracks — the validator must
    sort per track, not trust input order."""
    nested = [
        {"name": "root", "ph": "X", "ts": 0.0, "dur": 100.0,
         "pid": 1, "tid": 1},
        {"name": "mid", "ph": "X", "ts": 10.0, "dur": 50.0,
         "pid": 1, "tid": 1},
        {"name": "leaf", "ph": "X", "ts": 20.0, "dur": 10.0,
         "pid": 1, "tid": 1},
        {"name": "tail", "ph": "X", "ts": 70.0, "dur": 20.0,
         "pid": 1, "tid": 1},
    ]
    # every permutation of a well-formed tree validates clean
    import itertools

    for perm in itertools.permutations(nested):
        assert validate_span_tree(list(perm)) == []
    # an overlap is caught regardless of where it sits in the list
    bad_ev = {"name": "ovl", "ph": "X", "ts": 45.0, "dur": 20.0,
              "pid": 1, "tid": 1}
    for pos in range(len(nested) + 1):
        evs = nested[:pos] + [bad_ev] + nested[pos:]
        bad = validate_span_tree(evs)
        assert len(bad) == 1 and "ovl" in bad[0]
    # same-ts siblings: longer span is the parent (tiebreak), zero-dur
    # markers nest anywhere, non-X events are ignored
    twins = [
        {"name": "p", "ph": "X", "ts": 0.0, "dur": 100.0, "pid": 1, "tid": 1},
        {"name": "c", "ph": "X", "ts": 0.0, "dur": 40.0, "pid": 1, "tid": 1},
        {"name": "dot", "ph": "X", "ts": 99.9, "dur": 0.0, "pid": 1, "tid": 1},
        {"name": "i", "ph": "i", "ts": 1e9, "pid": 1, "tid": 1},
    ]
    assert validate_span_tree(twins) == []


# --------------------------------------------------------------------------
# Drift auditor units
# --------------------------------------------------------------------------

def test_drift_auditor_flags_drifted_algorithm():
    aud = DriftAuditor(flag_ratio=3.0)
    for i in range(3):
        aud.record("good_alg", f"b{i}", 1e-3, 1.1e-3)
        aud.record("bad_alg", f"b{i}", 1e-3, 1e-2)   # 10x drift
    stats = aud.per_algorithm()
    assert not stats["good_alg"]["flagged"]
    assert stats["bad_alg"]["flagged"]
    assert stats["bad_alg"]["median_ratio"] == pytest.approx(10.0)
    assert aud.flagged_algorithms() == ["bad_alg"]
    # overall hint: median over all 6 samples
    assert aud.net_scale_hint() == pytest.approx(np.median([1.1] * 3 + [10.0] * 3))
    rep = aud.report()
    assert rep["samples"] == 6 and rep["flagged"] == ["bad_alg"]
    # emit mirrors into the registry as events + gauge
    reg = MetricsRegistry()
    aud.emit(reg)
    assert len(reg.events_named("audit/algorithm_residual")) == 2
    assert reg.gauge("audit/net_scale_hint").value is not None
    assert "bad_alg" in aud.summary() and "DRIFT" in aud.summary()


def test_attribute_step_phases_tile_interval():
    dt = 0.010
    for staleness in (0, 1):
        phases = attribute_step_phases(dt, [0.002, 0.001],
                                       names=["b0", "b1"],
                                       staleness=staleness)
        assert phases[0]["name"] == "compute"
        # phases tile [0, dt] exactly: contiguous offsets, total == dt
        off = 0.0
        for ph in phases:
            assert ph["offset_s"] == pytest.approx(off, abs=1e-12)
            off += ph["dur_s"]
        assert off == pytest.approx(dt, rel=1e-9)
    # staleness=0 (sequential): exposed comm == full bucket times
    ph0 = attribute_step_phases(dt, [0.002, 0.001], staleness=0)
    comm = [p for p in ph0 if p["name"].startswith("comm/")]
    assert sum(p["dur_s"] for p in comm) == pytest.approx(0.003)
    # an interval smaller than the modeled drain still tiles (all comm)
    tiny = attribute_step_phases(0.001, [0.002, 0.001], staleness=0)
    assert sum(p["dur_s"] for p in tiny) == pytest.approx(0.001)
    assert attribute_step_phases(0.0, [0.001]) == []


# --------------------------------------------------------------------------
# Driver integration: no extra syncs, well-formed trace, bounded overhead
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh8x1():
    return make_mesh((8, 1), ("data", "model"))


@pytest.fixture(scope="module")
def pipelined(mesh8x1):
    model = build_model(MODEL_CFG)
    with mesh8x1:
        fn, _, plan = rt_pipeline.build_superstep(
            model, TCFG, mesh8x1, staleness=1, steps=2)
    return model, fn, plan


def _drive(mesh, model, fn, plan, n=8, obs=None, phase_attr=None):
    with mesh:
        state, _ = init_state(model, TCFG, mesh)
        state = rt_pipeline.attach_inflight(state, plan, mesh)
        state, log = rt_driver.run_pipelined(
            fn, state, start_step=0, num_steps=n,
            batch_fn=lambda s: synthetic_batch(DCFG, s),
            key_fn=lambda s: jax.random.fold_in(KEY, s),
            cfg=rt_driver.DriverConfig(depth=2, prefetch=2,
                                       steps_per_unit=2),
            obs=obs, phase_attr=phase_attr)
    return state, log


def test_driver_obs_adds_no_sync_points(mesh8x1, pipelined, monkeypatch):
    """Retire's ``block_until_ready`` is the ONLY sync point — the same
    count with observability off and fully on (trace+metrics+derived
    phases)."""
    model, fn, plan = pipelined
    real = jax.block_until_ready
    counts = {"n": 0}

    def counting(x):
        counts["n"] += 1
        return real(x)

    def run(obs, phase_attr=None):
        counts["n"] = 0
        monkeypatch.setattr(jax, "block_until_ready", counting)
        try:
            _drive(mesh8x1, model, fn, plan, n=8, obs=obs,
                   phase_attr=phase_attr)
        finally:
            monkeypatch.setattr(jax, "block_until_ready", real)
        return counts["n"]

    off = run(obs_mod.Observability())            # all-off handle
    on = run(obs_mod.configure(trace=True, metrics=True,
                               set_as_default=False),
             phase_attr=lambda dt: attribute_step_phases(
                 dt, [dt * 0.05, dt * 0.03], names=["b0", "b1"]))
    assert off == on == 4        # one retire per 2-step unit, 8 steps


def test_driver_trace_well_formed_and_metrics_backed(mesh8x1, pipelined,
                                                     tmp_path):
    model, fn, plan = pipelined
    obs = obs_mod.configure(trace=True, metrics=True, set_as_default=False)
    # staleness=0 (sequential model): comm is always exposed, so every
    # retire interval gets compute + both bucket phases (under the
    # staleness=1 model, buckets this small hide entirely under compute)
    phase_attr = lambda dt: attribute_step_phases(   # noqa: E731
        dt, [dt * 0.05, dt * 0.03], names=["b0", "b1"], staleness=0)
    n = 8
    state, log = _drive(mesh8x1, model, fn, plan, n=n, obs=obs,
                        phase_attr=phase_attr)
    assert int(state.step) == n

    # the DriverLog's public lists ARE registry series views
    assert log.losses is obs.metrics.series("train/loss").data
    assert len(log.losses) == n == len(log.step_times)
    assert obs.metrics.histogram("driver/retire_wall_s").snapshot()["count"] == 4

    # well-formed span tree with the driver's host spans present...
    assert validate_span_tree(obs.tracer.events) == []
    names = {e["name"] for e in obs.tracer.events if e["ph"] == "X"}
    assert {"driver/dispatch", "driver/retire"} <= names
    # ...and the derived device phases on their own track, tiling each
    # retire interval (compute + both buckets per unit)
    derived = [e for e in obs.tracer.events
               if e.get("tid") == "device-phases"]
    assert {e["name"] for e in derived} == {"compute", "comm/b0", "comm/b1"}
    assert len(derived) == 3 * 4
    assert all(e["cat"] == "device.derived" for e in derived)

    # the export is loadable Chrome-trace JSON
    doc = json.load(open(obs.tracer.export(str(tmp_path / "t.json"))))
    assert len(doc["traceEvents"]) == len(obs.tracer.events)


def test_driver_obs_overhead_bounded(mesh8x1, pipelined):
    """Tracing budget: <=5% per-step overhead target at 8 emulated
    devices. Measured as best-of-2 ABBA-paired run totals; the assert
    allows extra headroom for shared-runner noise, and still catches any
    accidental per-span sync or allocation storm."""
    model, fn, plan = pipelined
    phase_attr = lambda dt: attribute_step_phases(   # noqa: E731
        dt, [dt * 0.05, dt * 0.03], names=["b0", "b1"])

    def timed(obs, pa):
        t0 = time.perf_counter()
        _drive(mesh8x1, model, fn, plan, n=8, obs=obs, phase_attr=pa)
        return time.perf_counter() - t0

    def on():
        return timed(obs_mod.configure(trace=True, metrics=True,
                                       set_as_default=False), phase_attr)

    def off():
        return timed(obs_mod.Observability(), None)

    t_off = min(off(), off())
    t_on = min(on(), on())
    t_off = min(t_off, off())   # ABBA(A): bracket drift both ways
    assert t_on <= 1.15 * t_off, (t_on, t_off)


def test_record_step_straggler_watchdog():
    reg = MetricsRegistry()
    log = rt_driver.DriverLog(registry=reg)
    for i in range(10):
        rt_driver.record_step(log, i, 0.01, 1.0, straggler_factor=3.0)
    assert log.straggler_events == []
    rt_driver.record_step(log, 10, 1.0, 1.0, straggler_factor=3.0)
    assert len(log.straggler_events) == 1
    step, dt, med = log.straggler_events[0]
    assert step == 10 and dt == 1.0 and med == pytest.approx(0.01)
    assert reg.counter("driver/stragglers").value == 1
    assert reg.gauge("driver/straggler_median_s").value == pytest.approx(0.01)
    assert len(reg.events_named("driver/straggler")) == 1
    # restarts round-trips through its backing counter
    log.restarts += 1
    assert log.restarts == 1 == reg.counter("driver/restarts").value


def test_driverlog_standalone_works_like_plain_lists():
    log = rt_driver.DriverLog()
    log.losses.append(2.5)
    log.step_times.append(0.1)
    log.plan_swaps.append((3, "sig"))
    assert log.losses[-1] == 2.5 and log.plan_swaps[0][1] == "sig"
    assert log.restarts == 0


# --------------------------------------------------------------------------
# Drift audit over a real plan
# --------------------------------------------------------------------------

def test_audit_sync_plan_probes_buckets(mesh8x1, pipelined):
    model, fn, plan = pipelined
    reg = MetricsRegistry()
    aud = audit_sync_plan(plan, mesh8x1, axis_name="data",
                          reps=1, registry=reg)
    assert len(aud) >= 1
    stats = aud.per_algorithm()
    for st in stats.values():
        assert st["predicted_total_s"] > 0
        assert st["measured_total_s"] > 0
        assert np.isfinite(st["median_ratio"])
    # the join was mirrored into the registry
    assert len(reg.events_named("audit/algorithm_residual")) == len(stats)


# --------------------------------------------------------------------------
# Serve latency percentiles
# --------------------------------------------------------------------------

def test_serve_latency_percentiles_deterministic():
    """Latency stats are in decode-step units on the scheduler's
    deterministic clock: two identical runs on the same fixed trace give
    IDENTICAL percentile dicts, and ttft == queue_delay (the prefill
    argmax IS the first token, landed at admission)."""
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype=jnp.float32, param_dtype=jnp.float32,
                      max_seq_len=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 256, L),
                    max_new_tokens=m, arrival=a)
            for i, (L, m, a) in enumerate(
                [(3, 6, 0), (7, 4, 0), (5, 8, 1), (4, 7, 3), (6, 6, 8)])]

    def run():
        eng = ContinuousServeEngine(model, mesh, params, cache_len=32,
                                    batch_size=4)
        return eng.run(reqs)

    r1, r2 = run(), run()
    assert r1.latency and r1.latency == r2.latency
    for metric in ("queue_delay", "ttft", "tpot", "e2e"):
        assert set(r1.latency[metric]) == {"p50", "p90", "p99", "mean"}
    assert r1.latency["ttft"] == r1.latency["queue_delay"]
    # e2e >= queue delay for every percentile (decode takes steps)
    assert r1.latency["e2e"]["p99"] >= r1.latency["queue_delay"]["p99"]


def test_serve_obs_records_lifecycle(tmp_path):
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype=jnp.float32, param_dtype=jnp.float32,
                      max_seq_len=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 256, 4),
                    max_new_tokens=4, arrival=float(i // 2))
            for i in range(4)]
    obs = obs_mod.configure(trace=True, metrics=True, set_as_default=False)
    eng = ContinuousServeEngine(model, mesh, params, cache_len=32,
                                batch_size=2, obs=obs)
    res = eng.run(reqs)
    assert len(res.outputs) == 4
    assert validate_span_tree(obs.tracer.events) == []
    names = {e["name"] for e in obs.tracer.events if e["ph"] == "X"}
    assert {"serve/admit", "serve/decode_step"} <= names
    for h in ("serve/occupancy", "serve/queue_depth",
              "serve/ttft_steps", "serve/e2e_steps"):
        assert obs.metrics.histogram(h).snapshot()["count"] > 0
    assert obs.metrics.gauge("serve/tok_per_s").value > 0
    out = obs.export(trace_path=str(tmp_path / "t.json"),
                     metrics_path=str(tmp_path / "m.jsonl"))
    assert os.path.exists(out["trace"]) and os.path.exists(out["metrics"])


# --------------------------------------------------------------------------
# bench-regress compare logic
# --------------------------------------------------------------------------

def _regress():
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import regress
    return regress


def test_regress_loads_both_schemas_and_compares(tmp_path):
    regress = _regress()
    fresh, base = tmp_path / "fresh", tmp_path / "base"
    fresh.mkdir(), base.mkdir()
    # baseline in the OLD v1 flat-list format; fresh in v2
    (base / "BENCH_bench_adapt.json").write_text(json.dumps(
        [{"name": "adapt_drift_adaptive", "us_per_call": 100.0,
          "derived": ""}]))
    (fresh / "BENCH_bench_adapt.json").write_text(json.dumps(
        {"schema_version": 2, "meta": {},
         "rows": [{"name": "adapt_drift_adaptive", "us_per_call": 110.0,
                   "derived": ""}]}))
    (base / "BENCH_bench_serve.json").write_text(json.dumps(
        [{"name": "serve_continuous", "us_per_call": 1.0,
          "derived": "tok_per_s=100.0,decode_steps=50"}]))
    (fresh / "BENCH_bench_serve.json").write_text(json.dumps(
        [{"name": "serve_continuous", "us_per_call": 1.0,
          "derived": "tok_per_s=60.0,decode_steps=50"}]))

    cells = regress.headline_cells(str(fresh), str(base))
    by = {c["label"]: c for c in cells}
    # per-cell bands attached from the built-in table
    assert by["adapt_drift_adaptive.us_per_call"]["tol"] == 0.25
    assert by["serve_continuous.tok_per_s"]["tol"] == 0.35
    # adapt: 10% slower (lower-better) — inside its 25% band
    # serve: 40% fewer tok/s (higher-better) — beyond its 35% band,
    # even under a flat fallback wide enough to let it pass
    bad = regress.compare(cells, tol=0.5)
    assert by["adapt_drift_adaptive.us_per_call"] not in bad
    assert by["serve_continuous.tok_per_s"] in bad
    assert by["serve_continuous.tok_per_s"]["regression"] == pytest.approx(0.4)
    # cells without their own band fall back to the flat tol
    for c in cells:
        c.pop("tol", None)
    assert regress.compare(cells, tol=0.5) == []
    assert by["serve_continuous.tok_per_s"] in regress.compare(cells,
                                                               tol=0.25)


def test_regress_per_cell_tolerance_from_baseline_meta(tmp_path):
    """A band committed in the baseline file's meta.tolerances overrides
    the built-in table, and --update-style merges preserve it."""
    regress = _regress()
    fresh, base = tmp_path / "fresh", tmp_path / "base"
    fresh.mkdir(), base.mkdir()
    (base / "BENCH_bench_serve.json").write_text(json.dumps(
        {"schema_version": 2,
         "meta": {"tolerances": {"serve_continuous.tok_per_s": 0.6}},
         "rows": [{"name": "serve_continuous", "us_per_call": 1.0,
                   "derived": "tok_per_s=100.0"}]}))
    (fresh / "BENCH_bench_serve.json").write_text(json.dumps(
        [{"name": "serve_continuous", "us_per_call": 1.0,
          "derived": "tok_per_s=60.0"}]))
    cells = regress.headline_cells(str(fresh), str(base))
    assert cells[0]["tol"] == 0.6
    # 40% regression sits inside the committed 60% band
    assert regress.compare(cells, tol=0.25) == []
    # wire_bytes cells default to the tight analytic band
    assert regress.cell_tol("portfolio_x_d01.wire_bytes", {}) == \
        regress.WIRE_BYTES_TOL


def test_regress_parse_derived_and_improvements():
    regress = _regress()
    d = regress.parse_derived("tok_per_s=61.4,continuous_wins=True,n=3")
    assert d == {"tok_per_s": "61.4", "continuous_wins": "True", "n": "3"}
    # improvements never fail, in either direction convention
    cells = [
        {"label": "lower", "fresh": 50.0, "baseline": 100.0,
         "higher_better": False},
        {"label": "higher", "fresh": 200.0, "baseline": 100.0,
         "higher_better": True},
    ]
    assert regress.compare(cells, tol=0.25) == []
    assert cells[0]["regression"] == pytest.approx(-0.5)
