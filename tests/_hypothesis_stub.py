"""Minimal deterministic stand-in for the `hypothesis` package.

The container image does not ship hypothesis and nothing may be pip
installed, so conftest registers this module under ``sys.modules
['hypothesis']`` when the real package is absent. It covers exactly the
surface the suite uses — ``given``, ``settings``, ``strategies.
sampled_from/integers/booleans`` — by running each property test over a
fixed number of pseudo-random draws seeded from the test name, so runs
are reproducible and failures are replayable.
"""
from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


class strategies:
    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: rng.choice(options))

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**named_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_stub_max_examples", None)
            n = n if n is not None else getattr(wrapper, "_stub_max_examples", 20)
            rng = random.Random(fn.__qualname__)
            for case in range(n):
                draws = {k: s.draw(rng) for k, s in named_strategies.items()}
                try:
                    fn(*args, **draws, **kwargs)
                except Exception as e:  # replayable: seed is the test name
                    raise AssertionError(
                        f"property case {case} failed with draws {draws}"
                    ) from e

        # Strategy-bound params must not look like pytest fixtures.
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items()
                if name not in named_strategies]
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper

    return deco
