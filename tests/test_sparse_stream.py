"""Sparse stream (paper §5.1) properties: merge = dense sum, densify,
delta threshold, capacity bounds — with hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import sparse_stream as ss


def _random_stream(seed, n, k):
    rng = np.random.default_rng(seed)
    idx = np.sort(rng.choice(n, size=k, replace=False)).astype(np.int32)
    val = rng.standard_normal(k).astype(np.float32)
    pad = np.full(16, ss.SENTINEL, np.int32)
    return ss.SparseStream(
        idx=jnp.concatenate([jnp.asarray(idx), jnp.asarray(pad)]),
        val=jnp.concatenate([jnp.asarray(val), jnp.zeros(16)]),
        nnz=jnp.asarray(k, jnp.int32),
    ), idx, val


@settings(max_examples=30, deadline=None)
@given(
    n=st.sampled_from([64, 256, 1024]),
    k1=st.integers(1, 32),
    k2=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
def test_merge_equals_dense_sum(n, k1, k2, seed):
    k1, k2 = min(k1, n // 2), min(k2, n // 2)
    s1, i1, v1 = _random_stream(seed, n, k1)
    s2, i2, v2 = _random_stream(seed + 1, n, k2)
    merged = ss.merge(s1, s2, cap_out=k1 + k2 + 32)
    dense = np.zeros(n, np.float32)
    np.add.at(dense, i1, v1)
    np.add.at(dense, i2, v2)
    np.testing.assert_allclose(np.asarray(ss.densify(merged, n)), dense,
                               rtol=1e-6, atol=1e-6)
    # merged stream is sorted with padding at the back
    mi = np.asarray(merged.idx)
    nnz = int(merged.nnz)
    assert np.all(np.diff(mi[:nnz]) > 0)
    assert np.all(mi[nnz:] == ss.SENTINEL)
    assert nnz == len(np.union1d(i1, i2))


def test_merge_cancellation_keeps_index():
    """Paper: 'we ignore cancellation of indices during the summation'."""
    a = ss.SparseStream(jnp.array([3], jnp.int32), jnp.array([1.0]), jnp.asarray(1))
    b = ss.SparseStream(jnp.array([3], jnp.int32), jnp.array([-1.0]), jnp.asarray(1))
    m = ss.merge(a, b, 4)
    assert int(m.nnz) == 1 and int(m.idx[0]) == 3 and float(m.val[0]) == 0.0


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([256, 4096]), k=st.integers(1, 64), seed=st.integers(0, 2**16))
def test_from_mask_densify_roundtrip(n, k, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    mask = np.zeros(n, bool)
    mask[rng.choice(n, size=min(k, n), replace=False)] = True
    s = ss.from_mask(jnp.asarray(x), jnp.asarray(mask), cap=n)
    np.testing.assert_allclose(np.asarray(ss.densify(s, n)),
                               np.where(mask, x, 0), rtol=1e-6)


def test_delta_threshold_matches_paper_formula():
    # delta = N*isize/(c+isize); fp32 values, 4-byte indices -> N/2
    assert ss.delta_threshold(1 << 20, isize=4) == (1 << 20) // 2
    # fp64 values: 8/(4+8) = 2/3 N
    assert ss.delta_threshold(1200, isize=8) == 800


def test_from_dense_topk():
    x = jnp.asarray(np.array([0.1, -5.0, 0.0, 3.0, -0.2], np.float32))
    s = ss.from_dense_topk(x, 2)
    assert set(np.asarray(s.idx).tolist()) == {1, 3}


# -- capacity-overflow behavior (merge / concat) -----------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.sampled_from([256, 1024]), k1=st.integers(8, 64),
       k2=st.integers(8, 64), cap=st.integers(1, 48),
       seed=st.integers(0, 2**16))
def test_merge_overflow_keeps_smallest_indices(n, k1, k2, cap, seed):
    """cap_out below the union size: merge keeps the cap_out SMALLEST
    indices (streams are index-sorted), sums them exactly, saturates nnz
    at the capacity, and pads the rest with SENTINEL."""
    k1, k2 = min(k1, n // 4), min(k2, n // 4)
    s1, i1, v1 = _random_stream(seed, n, k1)
    s2, i2, v2 = _random_stream(seed + 1, n, k2)
    union = np.union1d(i1, i2)
    m = ss.merge(s1, s2, cap_out=cap)
    keep = min(cap, len(union))
    assert int(m.nnz) == keep
    mi, mv = np.asarray(m.idx), np.asarray(m.val)
    np.testing.assert_array_equal(mi[:keep], union[:keep])
    assert np.all(mi[keep:] == ss.SENTINEL)
    dense = np.zeros(n, np.float32)
    np.add.at(dense, i1, v1)
    np.add.at(dense, i2, v2)
    np.testing.assert_allclose(mv[:keep], dense[union[:keep]],
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n_parts=st.integers(2, 4), k=st.integers(4, 16),
       cap=st.integers(1, 40), seed=st.integers(0, 2**16))
def test_concat_overflow_clamps_nnz(n_parts, k, cap, seed):
    """concat with disjoint ranges: under capacity pressure the smallest
    indices survive and nnz saturates at cap_out (it must never report
    more items than the stream can hold)."""
    rng = np.random.default_rng(seed)
    streams, all_idx = [], []
    for part in range(n_parts):
        base = part * 1000
        idx = base + np.sort(rng.choice(1000, size=k, replace=False))
        val = rng.standard_normal(k).astype(np.float32)
        streams.append(ss.SparseStream(
            jnp.asarray(idx.astype(np.int32)), jnp.asarray(val),
            jnp.asarray(k, jnp.int32)))
        all_idx.append(idx)
    total = n_parts * k
    out = ss.concat(streams, cap_out=cap)
    # shrinks to cap; a cap above the concat length is a no-op slice
    # (callers grow capacity explicitly via pad_to)
    assert out.capacity == min(cap, total)
    assert int(out.nnz) == min(total, cap)      # clamped, never overstated
    expect = np.concatenate(all_idx)
    np.testing.assert_array_equal(np.asarray(out.idx)[:min(total, cap)],
                                  np.sort(expect)[:cap][:min(total, cap)])
    # no-cap concat keeps everything and the true count
    full = ss.concat(streams)
    assert int(full.nnz) == total


# -- delta threshold <-> cost-model switchover consistency -------------------

@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from([1 << 12, 1 << 16, 1 << 20]),
       p=st.sampled_from([2, 8, 64]),
       frac=st.integers(1, 100))
def test_delta_threshold_is_the_cost_model_switchover(n, p, frac):
    """The cost model's sparse->dense switchover happens EXACTLY at
    delta = N*isize/(c+isize) (paper §5.1 / §5.3.3) when the measured
    fill-in is supplied: any reduced_nnz under delta keeps the sparse
    end-representation available, any at/over delta removes it."""
    from repro.core.cost_model import select_algorithm

    delta = ss.delta_threshold(n, isize=4)
    nnz = max(1, delta * frac // 50)            # sweeps both sides of delta
    choice = select_algorithm(
        p, k=max(1, n // 100), n=n, reduced_nnz=float(nnz),
        allow=("ssar_split_allgather", "dense"))
    if nnz >= delta:
        assert choice == "dense"
    else:
        assert choice == "ssar_split_allgather"
