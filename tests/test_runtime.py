"""Non-blocking runtime (DESIGN.md §6): staleness semantics and the
async driver.

* staleness=0 pipelined path == the synchronous executor on all three
  lowerings (manual native, manual psum-emulated, auto-SPMD);
* staleness=1 (one-step-stale gradients + error feedback) still descends
  on the convergence harness;
* the jaxpr collective count per pipelined step stays O(num_buckets)
  (also inside the scanned superstep);
* the scanned K-step superstep is exactly K sequential pipelined steps;
* the double-buffered driver changes scheduling, never numerics, and its
  checkpoints round-trip through the synchronous state shape.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro.compat import make_mesh
from repro.core import cost_model as cm
from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.runtime import driver as rt_driver
from repro.runtime import pipeline as rt_pipeline
from repro.train.state import TrainConfig
from repro.train.train_step import build_train_step, init_state

from test_comm_plan import _count_prims


MODEL_CFG = ModelConfig(name="rt", family="dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                        dtype=jnp.float32, param_dtype=jnp.float32,
                        max_seq_len=64)
SYNC = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                  algorithm="dsar_split_allgather", min_sparse_size=1024,
                  impl="ref", fusion_bucket_bytes=1 << 18)
TCFG = TrainConfig(sync=SYNC, optimizer=OptimizerConfig(),
                   schedule=ScheduleConfig(peak_lr=3e-3, warmup_steps=5,
                                           total_steps=100),
                   zero1=True)
DCFG = DataConfig(global_batch=8, seq_len=32, vocab_size=256)
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def mesh8x1():
    # dp-only (trivial model axis): the manual/native lowering executes
    # everywhere, so all three lowerings can be forced and compared.
    return make_mesh((8, 1), ("data", "model"))


@pytest.fixture(scope="module")
def model():
    return build_model(MODEL_CFG)


def _batch(i):
    return jax.tree.map(jnp.asarray, synthetic_batch(DCFG, i))


def _run(step_fn, state, n, start=0):
    losses = []
    for i in range(start, start + n):
        state, m = step_fn(state, _batch(i), jax.random.fold_in(KEY, i))
        losses.append(float(m["loss"]))
    return state, losses


def _assert_state_close(a, b, rtol=2e-4, atol=1e-5):
    # cross-lowering fp32 comparisons: different reduction orders diverge
    # by a few ulp per step (same tolerance class as executor parity)
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)
    for x, y in zip(jax.tree.leaves(a.opt), jax.tree.leaves(b.opt)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)
    for name in a.residuals:
        np.testing.assert_allclose(np.asarray(a.residuals[name]),
                                   np.asarray(b.residuals[name]),
                                   rtol=rtol, atol=atol)


# --------------------------------------------------------------------------
# (a) staleness=0 == synchronous executor, all three lowerings
# --------------------------------------------------------------------------

@pytest.mark.parametrize("lowering", ["manual", "emulated", "spmd"])
def test_staleness0_matches_synchronous(mesh8x1, model, lowering):
    with mesh8x1:
        sync_fn, _ = build_train_step(model, TCFG, mesh8x1)
        pipe_fn, _, plan = rt_pipeline.build_pipelined_step(
            model, TCFG, mesh8x1, staleness=0, lowering=lowering)
        assert plan.num_sparse_buckets >= 1
        s_sync, _ = init_state(model, TCFG, mesh8x1)
        s_pipe, _ = init_state(model, TCFG, mesh8x1)
        s_sync, l_sync = _run(sync_fn, s_sync, 3)
        s_pipe, l_pipe = _run(pipe_fn, s_pipe, 3)
    np.testing.assert_allclose(l_sync, l_pipe, rtol=1e-5)
    assert s_pipe.inflight is None
    _assert_state_close(s_sync, s_pipe)


# --------------------------------------------------------------------------
# (b) staleness=1 still descends (convergence harness)
# --------------------------------------------------------------------------

def test_staleness1_descends(mesh8x1, model):
    with mesh8x1:
        pipe_fn, _, plan = rt_pipeline.build_pipelined_step(
            model, TCFG, mesh8x1, staleness=1)
        state, _ = init_state(model, TCFG, mesh8x1)
        state = rt_pipeline.attach_inflight(state, plan, mesh8x1)
        state, losses = _run(pipe_fn, state, 30)
    assert losses[-1] < losses[0] - 0.4, losses
    # the in-flight state really is live (holds the last reduction, and
    # is stamped valid so the next apply runs at full lr)
    assert state.inflight is not None
    assert float(state.inflight[rt_pipeline.VALID_KEY]) == 1.0
    assert any(float(jnp.abs(v).sum()) > 0
               for k, v in state.inflight.items()
               if k != rt_pipeline.VALID_KEY)


# --------------------------------------------------------------------------
# (c) collective count per pipelined step stays O(num_buckets)
# --------------------------------------------------------------------------

def test_pipelined_step_collective_count(mesh8x1, model):
    with mesh8x1:
        pipe_fn, (shapes, _), plan = rt_pipeline.build_pipelined_step(
            model, TCFG, mesh8x1, staleness=1, lowering="manual")
        b = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        jaxpr = jax.make_jaxpr(pipe_fn)(shapes, b, key).jaxpr
        n_a2a = _count_prims(jaxpr, {"all_to_all"})
        n_leaves = len(jax.tree.leaves(shapes.params))
        assert 1 <= n_a2a == plan.num_sparse_buckets < n_leaves, (
            n_a2a, plan.describe())

        # the scanned superstep traces its body ONCE: per-step count is
        # unchanged under K-step pipelining
        sup_fn, _, _ = rt_pipeline.build_superstep(
            model, TCFG, mesh8x1, staleness=1, steps=3, lowering="manual")
        bs = {"tokens": jax.ShapeDtypeStruct((3, 8, 32), jnp.int32),
              "labels": jax.ShapeDtypeStruct((3, 8, 32), jnp.int32)}
        keys = jax.ShapeDtypeStruct((3, 2), jnp.uint32)
        sup_jaxpr = jax.make_jaxpr(sup_fn)(shapes, bs, keys).jaxpr
        assert _count_prims(sup_jaxpr, {"all_to_all"}) == plan.num_sparse_buckets


# --------------------------------------------------------------------------
# superstep scan == sequential pipelined steps
# --------------------------------------------------------------------------

def test_superstep_matches_sequential(mesh8x1, model):
    k_steps = 3
    with mesh8x1:
        sup_fn, _, plan = rt_pipeline.build_superstep(
            model, TCFG, mesh8x1, staleness=1, steps=k_steps, donate=False)
        step_fn, _, _ = rt_pipeline.build_pipelined_step(
            model, TCFG, mesh8x1, staleness=1, donate=False)
        sa, _ = init_state(model, TCFG, mesh8x1)
        sb, _ = init_state(model, TCFG, mesh8x1)
        sa = rt_pipeline.attach_inflight(sa, plan, mesh8x1)
        sb = rt_pipeline.attach_inflight(sb, plan, mesh8x1)
        batches = [_batch(i) for i in range(k_steps)]
        keys = [jax.random.fold_in(KEY, i) for i in range(k_steps)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
        sa, ms = sup_fn(sa, stacked, jnp.stack(keys))
        seq_losses = []
        for i in range(k_steps):
            sb, mb = step_fn(sb, batches[i], keys[i])
            seq_losses.append(float(mb["loss"]))
    np.testing.assert_allclose(np.asarray(ms["loss"]), seq_losses, rtol=1e-5)
    _assert_state_close(sa, sb)
    for name in sa.inflight:
        np.testing.assert_allclose(np.asarray(sa.inflight[name]),
                                   np.asarray(sb.inflight[name]),
                                   rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# async driver: scheduling only, never numerics; checkpoint sync points
# --------------------------------------------------------------------------

def test_driver_matches_sequential(mesh8x1, model):
    n = 8
    with mesh8x1:
        fn, _, plan = rt_pipeline.build_superstep(
            model, TCFG, mesh8x1, staleness=1, steps=2)
        ref_fn, _, _ = rt_pipeline.build_pipelined_step(
            model, TCFG, mesh8x1, staleness=1, donate=False)
        state, _ = init_state(model, TCFG, mesh8x1)
        state = rt_pipeline.attach_inflight(state, plan, mesh8x1)
        state, log = rt_driver.run_pipelined(
            fn, state, start_step=0, num_steps=n,
            batch_fn=lambda s: synthetic_batch(DCFG, s),
            key_fn=lambda s: jax.random.fold_in(KEY, s),
            cfg=rt_driver.DriverConfig(depth=2, prefetch=2,
                                       steps_per_unit=2))
        ref, _ = init_state(model, TCFG, mesh8x1)
        ref = rt_pipeline.attach_inflight(ref, plan, mesh8x1)
        ref, ref_losses = _run(ref_fn, ref, n)
    assert len(log.losses) == n == len(log.step_times)
    assert int(state.step) == n
    np.testing.assert_allclose(log.losses, ref_losses, rtol=1e-5)
    _assert_state_close(state, ref)


def test_trainer_run_pipelined_checkpoints_interoperate(tmp_path):
    """Trainer.run_pipelined writes synchronous-shaped checkpoints (the
    in-flight buffers are stripped at the drain barrier), so a fresh
    Trainer resumes from them — in either loop."""
    from repro.train.trainer import Trainer

    mesh = make_mesh((8, 1), ("data", "model"))
    model = build_model(MODEL_CFG)
    ckpt_dir = str(tmp_path / "ckpt")
    tr = Trainer(model, TCFG, mesh, DCFG, ckpt_dir=ckpt_dir, ckpt_every=4)
    log = tr.run_pipelined(8, staleness=1, superstep=2, depth=2)
    assert len(log.losses) == 8
    assert int(tr.state.step) == 8
    assert tr.state.inflight is not None      # live pipelined state

    # fresh trainer resumes from the stripped checkpoint...
    tr2 = Trainer(model, TCFG, mesh, DCFG, ckpt_dir=ckpt_dir, ckpt_every=4)
    assert tr2.init_or_resume() == 8
    assert tr2.state.inflight is None
    # ...and both loops can continue from it
    tr2.run_pipelined(10, staleness=1, superstep=2)
    assert int(tr2.state.step) == 10
    tr2.run(12)
    assert int(tr2.state.step) == 12


# --------------------------------------------------------------------------
# overlap-aware cost model
# --------------------------------------------------------------------------

def test_overlap_cost_model_exposure():
    tb = [1.0, 2.0, 3.0]
    # no compute to hide under: everything exposed
    assert cm.exposed_bucket_times(tb, 0.0) == tb
    # infinite compute: fully hidden
    assert cm.exposed_bucket_times(tb, 100.0) == [0.0, 0.0, 0.0]
    # partial: the straddling bucket pays only its tail
    assert cm.exposed_bucket_times(tb, 2.5) == [0.0, 0.5, 3.0]
    assert sum(cm.exposed_bucket_times(tb, 2.5)) == pytest.approx(
        max(0.0, sum(tb) - 2.5))
    # pipelined step model: never slower than synchronous, equals
    # max(compute, comm) at staleness 1
    for tc in (0.0, 2.5, 10.0):
        t_sync = cm.t_step_overlapped(tc, tb, staleness=0)
        t_pipe = cm.t_step_overlapped(tc, tb, staleness=1)
        assert t_pipe <= t_sync
        assert t_pipe == pytest.approx(max(tc, sum(tb)) + 0.0)
    assert cm.t_step_overlapped(2.5, tb, staleness=0) == pytest.approx(8.5)


def test_plan_bucket_times_cover_every_bucket():
    from jax.sharding import PartitionSpec as P

    shapes = {"a": jax.ShapeDtypeStruct((1 << 15,), jnp.float32),
              "b": jax.ShapeDtypeStruct((64,), jnp.float32)}
    specs = {"a": P(), "b": P()}
    plan = comm.build_sync_plan(shapes, specs, SYNC, 8)
    tb = cm.plan_bucket_times(plan)
    assert len(tb) == plan.num_buckets
    assert all(t > 0 for t in tb)
