"""Fault-tolerant runtime (DESIGN.md §12): chaos matrix + recovery.

* the chaos harness is deterministic and one-shot (a rewound replay runs
  clean — the property every bit-equal recovery assertion leans on);
* the guarded step skips the apply on non-finite grads with EF residuals,
  optimizer state and in-flight buffers preserved BIT-EXACTLY;
* the driver's retry/backoff supervisor bounds restores per fault class
  and escalates to a clean abort (parseable blackbox) when spent;
* recovery is bit-reproducible: after a skip or a checkpoint rewind the
  retired losses and final state equal the uninjected run's exactly;
* checkpoint integrity: CRC32 per array, corrupt saves are detected and
  the restore falls back to the newest VALID step;
* the serve engine retries pre-dispatch faults (token-identical output),
  aborts cleanly on post-dispatch-unsafe ones, and sheds load gracefully
  (bounded queue + TTFT deadline) with full accounting.
"""
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs as obs_mod
from repro.compat import make_mesh
from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.obs.health import HealthMonitor
from repro.obs.metrics import MetricsRegistry
from repro.obs.recorder import FlightRecorder
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.runtime import driver as rt_driver
from repro.runtime import pipeline as rt_pipeline
from repro.runtime.adapt import AdaptConfig, AdaptiveController
from repro.runtime.faults import (
    FAULT_KEY,
    FaultInjectionError,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    NonFiniteEscalation,
    PrefetchStalled,
    RecoveryConfig,
    RetryBudgetExhausted,
    RetrySupervisor,
    classify_fault,
)
from repro.serve.scheduler import ContinuousScheduler, Request, ServeConfig
from repro.serve.sparse_decode import ContinuousServeEngine
from repro.train import checkpoint as ckpt
from repro.train.state import TrainConfig
from repro.train.train_step import init_state

MODEL_CFG = ModelConfig(name="ft", family="dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                        dtype=jnp.float32, param_dtype=jnp.float32,
                        max_seq_len=64)
SYNC = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                  algorithm="dsar_split_allgather", min_sparse_size=1024,
                  impl="ref", fusion_bucket_bytes=1 << 18)
TCFG = TrainConfig(sync=SYNC, optimizer=OptimizerConfig(),
                   schedule=ScheduleConfig(peak_lr=3e-3, warmup_steps=5,
                                           total_steps=100),
                   zero1=True)
DCFG = DataConfig(global_batch=8, seq_len=32, vocab_size=256)
KEY = jax.random.PRNGKey(0)
N = 8          # driver-run length of every matrix entry
CKPT_EVERY = 2
# fast supervisor for tests: real backoff policy, negligible sleeps
FAST_RECOVERY = RecoveryConfig(backoff_base_s=0.001, backoff_max_s=0.005)


@pytest.fixture(scope="module")
def mesh8x1():
    return make_mesh((8, 1), ("data", "model"))


@pytest.fixture(scope="module")
def model():
    return build_model(MODEL_CFG)


@pytest.fixture(scope="module")
def guarded_fn(mesh8x1, model):
    """One guarded+injectable pipelined step (staleness=0: no in-flight
    buffers, so checkpoint rewinds are loss-free and bit-reproducible),
    shared by the whole driver matrix."""
    with mesh8x1:
        fn, _, plan = rt_pipeline.build_pipelined_step(
            model, TCFG, mesh8x1, staleness=0, guard=True, inject=True,
            telemetry=False)
    return fn, plan


def _obs_with_metrics(recorder_path=None):
    ob = obs_mod.configure(metrics=True, set_as_default=False)
    if recorder_path is not None:
        ob.recorder = FlightRecorder(str(recorder_path), obs=ob)
    return ob


def _drive(fn, mesh, model, *, injector, obs, ckpt_dir=None, recovery=None,
           num_steps=N, timeout_s=60.0):
    """Run the shared guarded step under the async driver with the
    standard checkpoint wiring (CRC-verified fallback restore)."""
    ckpt_fn = restore_fn = None
    if ckpt_dir is not None:
        def ckpt_fn(s):
            ckpt.save(str(ckpt_dir), s, dp_total=8,
                      opt_layout=ckpt.opt_layout_of(TCFG))

        def restore_fn():
            like, _ = init_state(model, TCFG, mesh)
            return ckpt.restore(str(ckpt_dir), like, dp_total=8,
                                step=ckpt.latest_valid_step(str(ckpt_dir)),
                                verify=True)

    with mesh:
        state, _ = init_state(model, TCFG, mesh)
        # the driver binds the registry; the grad-leaf count is the
        # caller's to provide (the Trainer does the same)
        injector.bind(n_leaves=len(jax.tree.leaves(state.params)))
        state, log = rt_driver.run_pipelined(
            fn, state, start_step=0, num_steps=num_steps,
            batch_fn=lambda s: synthetic_batch(DCFG, s),
            key_fn=lambda s: jax.random.fold_in(KEY, s),
            cfg=rt_driver.DriverConfig(depth=1, prefetch=1,
                                       prefetch_timeout_s=timeout_s),
            ckpt_every=CKPT_EVERY if ckpt_dir else None,
            ckpt_fn=ckpt_fn, restore_fn=restore_fn,
            obs=obs, recovery=recovery, injector=injector)
    return state, log


def _state_leaves(state):
    return {
        "params": [np.asarray(x) for x in jax.tree.leaves(state.params)],
        "opt": [np.asarray(x) for x in jax.tree.leaves(state.opt)],
        "residuals": {k: np.asarray(v) for k, v in state.residuals.items()},
    }


def _assert_leaves_equal(a, b):
    for x, y in zip(a["params"], b["params"]):
        np.testing.assert_array_equal(x, y)
    for x, y in zip(a["opt"], b["opt"]):
        np.testing.assert_array_equal(x, y)
    for k in a["residuals"]:
        np.testing.assert_array_equal(a["residuals"][k], b["residuals"][k])


@pytest.fixture(scope="module")
def clean_run(guarded_fn, mesh8x1, model, tmp_path_factory):
    """The uninjected reference: same compiled step, same checkpoint
    wiring, an EMPTY fault plan (hooks execute, nothing fires) — every
    bit-equality claim in the matrix compares against this."""
    fn, _ = guarded_fn
    state, log = _drive(fn, mesh8x1, model,
                        injector=FaultInjector(FaultPlan()),
                        obs=_obs_with_metrics(),
                        ckpt_dir=tmp_path_factory.mktemp("clean_ck"))
    return {"losses": [float(x) for x in log.losses],
            "state": _state_leaves(state)}


# --------------------------------------------------------------------------
# unit: plans, classification, supervisor, scheduler shedding
# --------------------------------------------------------------------------

def test_fault_spec_and_chaos_plan_deterministic():
    with pytest.raises(ValueError):
        FaultSpec(kind="nope", step=1)
    with pytest.raises(ValueError):
        FaultSpec(kind="nonfinite", step=1, mode="weird")
    with pytest.raises(ValueError):
        FaultSpec(kind="stall", step=1, repeat=0)
    a = FaultPlan.chaos(7, 64, ckpt_every=8)
    b = FaultPlan.chaos(7, 64, ckpt_every=8)
    assert a == b                       # same seed -> identical schedule
    assert a != FaultPlan.chaos(8, 64, ckpt_every=8)
    kinds = [s.kind for s in a.specs]
    for k in ("nonfinite", "straggler", "stall", "collective"):
        assert k in kinds
    assert "ckpt_corrupt" in kinds      # the ckpt_every pair rode along
    assert all(2 <= s.step <= 62 for s in a.specs)
    assert len(a.by_kind("stall")) == 1


def test_classify_fault_taxonomy():
    assert classify_fault(NonFiniteEscalation("x")) == "nonfinite"
    assert classify_fault(PrefetchStalled("x")) == "stall"
    assert classify_fault(ckpt.CheckpointCorrupt("x")) == "ckpt_corrupt"
    assert classify_fault(OSError("x")) == "ckpt_corrupt"
    assert classify_fault(FaultInjectionError("x")) == "collective"
    assert classify_fault(KeyboardInterrupt()) == "sigterm"
    assert classify_fault(RuntimeError("?")) == "collective"  # default


def test_retry_supervisor_budget_and_backoff():
    reg = MetricsRegistry(enabled=True)
    cfg = RecoveryConfig(budgets={"collective": 2, "default": 1},
                         backoff_base_s=0.1, backoff_max_s=0.3, jitter=0.5)
    sup = RetrySupervisor(cfg, registry=reg)
    d1 = sup.on_failure(FaultInjectionError("a"), step=3)
    d2 = sup.on_failure(FaultInjectionError("b"), step=4)
    # exponential in the attempt count, jitter-bounded
    assert 0.1 <= d1 <= 0.1 * 1.5 and 0.2 <= d2 <= 0.2 * 1.5
    with pytest.raises(RetryBudgetExhausted) as ei:
        sup.on_failure(FaultInjectionError("c"), step=5)
    assert isinstance(ei.value.__cause__, FaultInjectionError)
    # distinct classes draw on distinct budgets
    sup.on_failure(PrefetchStalled("s"), step=6)
    assert reg.counter("recovery/retries").value == 3
    assert reg.counter("recovery/retries_collective").value == 2
    assert reg.counter("recovery/retries_stall").value == 1
    assert reg.counter("recovery/aborts").value == 1
    assert len(reg.events_named("recovery/retry")) == 3
    assert len(reg.events_named("recovery/abort")) == 1
    # backoff is capped at backoff_max_s x (1 + jitter)
    for _ in range(10):
        sup.attempts["stall"] += 1
    assert sup.backoff_s("stall") <= 0.3 * 1.5


def test_injector_one_shot_and_batch_wrap():
    reg = MetricsRegistry(enabled=True)
    plan = FaultPlan(specs=(
        FaultSpec(kind="nonfinite", step=2, mode="inf", leaves=(0, 2),
                  repeat=2),
        FaultSpec(kind="stall", step=1, duration_s=0.0),
    ))
    inj = FaultInjector(plan).bind(n_leaves=4, registry=reg)
    assert inj.grad_flag(0).tolist() == [0, 0, 0, 0]
    assert inj.grad_flag(2).tolist() == [2, 0, 2, 0]   # inf -> flag 2
    assert inj.grad_flag(3).tolist() == [2, 0, 2, 0]   # repeat covers 3
    assert inj.grad_flag(4).tolist() == [0, 0, 0, 0]   # exhausted
    assert inj.grad_flag(2).tolist() == [0, 0, 0, 0]   # one-shot: spent
    wrapped = inj.wrap_batch_fn(lambda s: {"tokens": np.zeros(2)})
    b = wrapped(1)
    assert FAULT_KEY in b and b[FAULT_KEY].shape == (4,)
    assert inj.fired_total == 3        # 2 nonfinite repeats + 1 stall
    assert reg.counter("faults/injected_nonfinite").value == 2
    assert reg.counter("faults/injected_stall").value == 1


def test_refund_undispatched_nonfinite_refires_after_rewind():
    # poison consumed at PRODUCTION (prefetch) for a step that never
    # dispatched dies with the queue on restore — refund re-arms it;
    # poison below the frontier was dispatched and stays spent
    plan = FaultPlan(specs=(FaultSpec(kind="nonfinite", step=6),
                            FaultSpec(kind="nonfinite", step=2),
                            FaultSpec(kind="stall", step=6,
                                      duration_s=0.0)))
    inj = FaultInjector(plan).bind(n_leaves=2)
    for s in range(8):                       # prefetch produced 0..7
        inj.grad_flag(s)
        inj._take("stall", s)
    assert inj.fired_total == 3
    # failure while dispatch frontier was at 4: steps >= 4 undispatched
    assert inj.refund_undispatched(4) == 1   # nonfinite@6 only, NOT stall
    assert inj.grad_flag(2).tolist() == [0, 0]       # dispatched: spent
    assert inj.grad_flag(6).tolist() == [1, 1]       # replay re-injects
    assert inj.refund_undispatched(8) == 0   # all below frontier: spent


def test_before_dispatch_covers_superstep_range():
    # a K-step superstep dispatches ONCE for steps [s, s+K): specs at
    # non-boundary steps (21 with K=4 dispatching at 20) must still fire
    plan = FaultPlan(specs=(FaultSpec(kind="collective", step=21),
                            FaultSpec(kind="collective", step=25)))
    inj = FaultInjector(plan)
    inj.before_dispatch(16, 4)                     # covers 16..19: clean
    with pytest.raises(FaultInjectionError, match="step 21"):
        inj.before_dispatch(20, 4)
    inj.before_dispatch(20, 4)                     # one-shot: replay clean
    with pytest.raises(FaultInjectionError, match="step 25"):
        inj.before_dispatch(25)                    # default unit width 1
    assert inj.fired_total == 2


def test_scheduler_shed_accounting():
    def reqs(n, arrival=0.0):
        return [Request(rid=i, prompt=np.ones(3, np.int32),
                        max_new_tokens=4, arrival=arrival) for i in range(n)]

    s = ContinuousScheduler(2, reqs(6))
    s.clock = 5.0
    assert s.shed_overdue(3.0) == [0, 1, 2, 3, 4, 5]
    assert all(s.lifecycle[r]["shed"] == 5.0 for r in range(6))
    assert s.done and not s.completed
    assert s.latency_stats()["rids"].size == 0     # shed != retired

    s2 = ContinuousScheduler(2, reqs(6))
    assert s2.shed_overflow(2) == [2, 3, 4, 5]     # newest beyond bound
    assert [r.rid for r in s2.waiting] == [0, 1]
    assert s2.shed == {2: "queue_full", 3: "queue_full",
                       4: "queue_full", 5: "queue_full"}
    # future arrivals never count against the bound
    s3 = ContinuousScheduler(2, reqs(2) + reqs(4, arrival=99.0)[2:])
    assert s3.shed_overflow(1) == [1]


def test_serve_config_shed_deadline_defaults_to_ttft():
    # slo_* alone is a MONITORING declaration, never an admission
    # policy: shedding stays off until a degradation knob is touched
    assert ServeConfig().effective_shed_deadline() is None
    assert ServeConfig(slo_ttft_p99=4.0).effective_shed_deadline() is None
    # once enabled via queue_limit, the deadline defaults to the TTFT
    # target (TTFT == queue delay in this scheduler)
    assert ServeConfig(slo_ttft_p99=4.0,
                       queue_limit=8).effective_shed_deadline() == 4.0
    assert ServeConfig(queue_limit=8).effective_shed_deadline() is None
    # an explicit shed_deadline enables deadline shedding on its own
    assert ServeConfig(shed_deadline=9.0).effective_shed_deadline() == 9.0
    assert ServeConfig(slo_ttft_p99=4.0,
                       shed_deadline=9.0).effective_shed_deadline() == 9.0


def test_health_rule_nonfinite_fires_on_new_trips():
    reg = MetricsRegistry(enabled=True)
    mon = HealthMonitor(reg)
    assert mon.evaluate() == []
    reg.counter("guard/nonfinite_trips").inc(2)
    evs = mon.evaluate()
    assert [(e.severity, e.rule, e.subject) for e in evs] == \
        [("critical", "nonfinite", "grads")]
    assert evs[0].value == 2.0
    assert reg.events_named("health/nonfinite")    # mirrored to registry
    assert mon.evaluate() == []                    # no NEW trips
    reg.counter("guard/nonfinite_trips").inc()
    assert mon.evaluate()[0].value == 1.0


def test_controller_fault_demotion_holds_dense(guarded_fn):
    _, plan = guarded_fn
    reg = MetricsRegistry(enabled=True)
    ctrl = AdaptiveController(plan, cfg=AdaptConfig(demote_hold=2),
                              obs=obs_mod.Observability(metrics=reg))
    forced = ctrl.demote()
    assert forced is not None
    assert set(forced.algorithms().values()) == {"dense"}
    assert forced.version > plan.version
    assert reg.events_named("adapt/fault_demotion")
    assert all(h == 2 for h in ctrl._demoted.values())
    # already dense: the hold refreshes but nothing is re-forced
    assert ctrl.demote() is None
    assert all(h == 2 for h in ctrl._demoted.values())


# --------------------------------------------------------------------------
# checkpoint integrity (§12.4)
# --------------------------------------------------------------------------

def test_checkpoint_crc_detects_corruption_and_falls_back(
        mesh8x1, model, tmp_path):
    d = str(tmp_path / "ck")
    with mesh8x1:
        state, _ = init_state(model, TCFG, mesh8x1)
    ckpt.save(d, state, dp_total=8, opt_layout=ckpt.opt_layout_of(TCFG))
    s1 = state._replace(step=state.step + 1)
    ckpt.save(d, s1, dp_total=8, opt_layout=ckpt.opt_layout_of(TCFG))
    assert ckpt.verify_checkpoint(d, 0) and ckpt.verify_checkpoint(d, 1)
    assert ckpt.latest_valid_step(d) == 1

    inj = FaultInjector(FaultPlan.single("ckpt_corrupt", 1))
    path = inj.corrupt_checkpoint(d, 1)
    assert path is not None and path.endswith("arrays.npz")
    assert not ckpt.verify_checkpoint(d, 1)
    assert ckpt.verify_checkpoint(d, 0)
    assert ckpt.latest_valid_step(d) == 0          # newest VALID wins
    with pytest.raises(ckpt.CheckpointCorrupt):
        ckpt.restore(d, state, dp_total=8, step=1, verify=True)
    restored = ckpt.restore(d, state, dp_total=8, step=0, verify=True)
    assert int(restored.step) == 0


# --------------------------------------------------------------------------
# driver matrix: {nonfinite, straggler, stall, collective, ckpt, sigterm}
# --------------------------------------------------------------------------

def test_driver_nonfinite_skip_preserves_prefix(guarded_fn, mesh8x1, model,
                                                clean_run):
    """A single poisoned step is SKIPPED: losses through the faulted step
    are bit-equal to the clean run (the forward never sees the poison),
    and divergence starts only where the clean run applied the gradient
    the guard discarded."""
    fn, _ = guarded_fn
    obs = _obs_with_metrics()
    inj = FaultInjector(FaultPlan.single("nonfinite", 3))
    state, log = _drive(fn, mesh8x1, model, injector=inj, obs=obs)
    assert int(state.step) == N
    clean = clean_run["losses"]
    assert list(log.losses[:4]) == clean[:4]       # bit-equal incl. step 3
    assert list(log.losses[4:]) != clean[4:]       # skipped apply diverges
    assert all(np.isfinite(x) for x in log.losses)
    assert obs.metrics.counter("guard/nonfinite_trips").value == 1
    assert obs.metrics.counter("faults/injected_nonfinite").value == 1
    evs = obs.metrics.events_named("health/nonfinite")
    assert len(evs) == 1 and evs[0]["step"] == 3


def test_driver_nonfinite_escalates_to_bit_equal_rewind(
        guarded_fn, mesh8x1, model, clean_run, tmp_path):
    """N consecutive trips rewind to the last-good checkpoint; the
    replay runs clean (one-shot injection), so the retired tail and the
    FINAL STATE are bit-equal to the uninjected run."""
    fn, _ = guarded_fn
    obs = _obs_with_metrics()
    inj = FaultInjector(FaultPlan(
        specs=(FaultSpec(kind="nonfinite", step=4, repeat=2),)))
    rec_cfg = RecoveryConfig(max_consecutive_nonfinite=2,
                             backoff_base_s=0.001, backoff_max_s=0.005)
    state, log = _drive(fn, mesh8x1, model, injector=inj, obs=obs,
                        ckpt_dir=tmp_path / "ck", recovery=rec_cfg)
    assert int(state.step) == N
    assert log.restarts == 1
    _assert_leaves_equal(_state_leaves(state), clean_run["state"])
    # replayed tail (steps 4..7 after the rewind) bit-equal clean losses
    assert list(log.losses[-4:]) == clean_run["losses"][4:]
    assert obs.metrics.counter("guard/nonfinite_trips").value == 2
    assert obs.metrics.counter("recovery/retries_nonfinite").value == 1
    assert obs.metrics.events_named("recovery/retry")
    assert obs.metrics.events_named("driver/restart")


def test_driver_collective_retry_and_budget_abort(
        guarded_fn, mesh8x1, model, clean_run, tmp_path):
    fn, _ = guarded_fn
    # recoverable: one raise, budget 3 -> restore + clean replay
    obs = _obs_with_metrics()
    inj = FaultInjector(FaultPlan.single("collective", 3))
    state, log = _drive(fn, mesh8x1, model, injector=inj, obs=obs,
                        ckpt_dir=tmp_path / "ok", recovery=FAST_RECOVERY)
    assert int(state.step) == N and log.restarts == 1
    _assert_leaves_equal(_state_leaves(state), clean_run["state"])
    assert list(log.losses[-5:]) == clean_run["losses"][3:]
    assert obs.metrics.counter("recovery/retries_collective").value == 1

    # exhausted budget: clean abort AFTER the blackbox dump
    bb = tmp_path / "bb.json"
    obs2 = _obs_with_metrics(recorder_path=bb)
    inj2 = FaultInjector(FaultPlan.single("collective", 3))
    zero = RecoveryConfig(budgets={"collective": 0, "default": 0},
                          backoff_base_s=0.001)
    with pytest.raises(RetryBudgetExhausted) as ei:
        _drive(fn, mesh8x1, model, injector=inj2, obs=obs2,
               ckpt_dir=tmp_path / "abort", recovery=zero)
    assert isinstance(ei.value.__cause__, FaultInjectionError)
    doc = json.load(open(bb))
    assert doc["kind"] == "blackbox"
    assert doc["reason"] == "exception:FaultInjectionError"
    assert obs2.metrics.counter("recovery/aborts").value == 1


def test_driver_stall_bounded_timeout_recovers(
        guarded_fn, mesh8x1, model, clean_run, tmp_path):
    """A stalled data pipeline trips the bounded queue.get timeout
    instead of hanging the dispatch loop forever; the stall budget
    restores and the replay completes bit-equal."""
    fn, _ = guarded_fn
    obs = _obs_with_metrics()
    # The stall must outlast (driver reaches take(2)) + the take timeout
    # to be detected — real step times here are ~1s, so a short stall
    # finishes inside the poll window and the run sails through. 6s vs a
    # 0.4s timeout makes detection deterministic; the sleeping producer
    # is a daemon thread, so the restart does not wait out the full nap.
    inj = FaultInjector(FaultPlan.single("stall", 2, duration_s=6.0))
    state, log = _drive(fn, mesh8x1, model, injector=inj, obs=obs,
                        ckpt_dir=tmp_path / "ck", recovery=FAST_RECOVERY,
                        timeout_s=0.4)
    assert int(state.step) == N and log.restarts == 1
    _assert_leaves_equal(_state_leaves(state), clean_run["state"])
    assert obs.metrics.counter("faults/injected_stall").value == 1
    assert obs.metrics.counter("recovery/retries_stall").value == 1


def test_driver_prefetch_thread_exception_propagates(
        guarded_fn, mesh8x1, model, clean_run, tmp_path):
    """A batch_fn crash inside the prefetch thread surfaces on the
    driver thread as PrefetchStalled (cause attached), lands in the
    blackbox notes, and recovers on the stall budget."""
    fn, _ = guarded_fn
    bb = tmp_path / "bb.json"
    obs = _obs_with_metrics(recorder_path=bb)
    boom = {"armed": True}

    def flaky_batch(s):
        if s == 3 and boom.pop("armed", False):
            raise ValueError("synthetic pipeline crash")
        return synthetic_batch(DCFG, s)

    inj = FaultInjector(FaultPlan())
    with mesh8x1:
        state, _ = init_state(model, TCFG, mesh8x1)
        inj.bind(n_leaves=len(jax.tree.leaves(state.params)))

        def restore_fn():
            like, _ = init_state(model, TCFG, mesh8x1)
            return ckpt.restore(str(tmp_path / "ck"), like, dp_total=8,
                                step=ckpt.latest_valid_step(
                                    str(tmp_path / "ck")), verify=True)

        state, log = rt_driver.run_pipelined(
            fn, state, start_step=0, num_steps=N,
            batch_fn=flaky_batch,
            key_fn=lambda s: jax.random.fold_in(KEY, s),
            cfg=rt_driver.DriverConfig(depth=1, prefetch=1),
            ckpt_every=CKPT_EVERY,
            ckpt_fn=lambda s: ckpt.save(str(tmp_path / "ck"), s, dp_total=8,
                                        opt_layout=ckpt.opt_layout_of(TCFG)),
            restore_fn=restore_fn, obs=obs, recovery=FAST_RECOVERY,
            injector=inj)
    assert int(state.step) == N and log.restarts == 1
    _assert_leaves_equal(_state_leaves(state), clean_run["state"])
    assert obs.metrics.counter("recovery/retries_stall").value == 1
    doc = json.load(open(bb))
    notes = [n for n in doc["notes"] if n.get("note") == "driver/prefetch_error"
             or n.get("kind") == "driver/prefetch_error"
             or "prefetch_error" in str(n)]
    assert notes, doc["notes"]
    assert "ValueError" in json.dumps(notes)


def test_driver_straggler_injection_is_wall_time_only(
        guarded_fn, mesh8x1, model, clean_run):
    fn, _ = guarded_fn
    obs = _obs_with_metrics()
    inj = FaultInjector(FaultPlan.single("straggler", 5, duration_s=0.05))
    state, log = _drive(fn, mesh8x1, model, injector=inj, obs=obs)
    assert int(state.step) == N
    assert list(log.losses) == clean_run["losses"]     # numerics untouched
    _assert_leaves_equal(_state_leaves(state), clean_run["state"])
    assert obs.metrics.counter("faults/injected_straggler").value == 1
    assert inj.fired_total == 1


def test_driver_sigterm_clean_abort_with_blackbox(
        guarded_fn, mesh8x1, model, tmp_path):
    """SIGTERM mid-superstep: the recorder's chained handler dumps the
    blackbox, then the previous handler aborts the run. The driver's
    recovery path (Exception-only) must NOT swallow it."""
    fn, _ = guarded_fn
    bb = tmp_path / "bb.json"
    obs = _obs_with_metrics(recorder_path=bb)

    def die(signum, frame):
        raise KeyboardInterrupt("SIGTERM")

    prev = signal.signal(signal.SIGTERM, die)
    try:
        obs.recorder.install_signal_handlers(("SIGTERM",))
        inj = FaultInjector(FaultPlan.single("sigterm", 2))
        with pytest.raises(KeyboardInterrupt):
            _drive(fn, mesh8x1, model, injector=inj, obs=obs,
                   num_steps=4)
        doc = json.load(open(bb))
        assert doc["reason"] == "signal:SIGTERM"
    finally:
        obs.recorder.uninstall_signal_handlers()
        signal.signal(signal.SIGTERM, prev)


# --------------------------------------------------------------------------
# guarded step: EF residual / optimizer / inflight preservation (§12.2)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("lowering", ["manual", "spmd"])
def test_guard_trip_preserves_state_bit_exact(mesh8x1, model, lowering):
    """On a tripped step the apply is a no-op: params, optimizer moments,
    EF residuals and the in-flight reduction are BIT-EQUAL to the
    pre-step state (only the step counter advances), on both the manual
    (cross-rank pmin) and auto-SPMD lowerings."""
    with mesh8x1:
        fn, _, plan = rt_pipeline.build_pipelined_step(
            model, TCFG, mesh8x1, staleness=1, lowering=lowering,
            guard=True, inject=True, donate=False, telemetry=False)
        state, _ = init_state(model, TCFG, mesh8x1)
        state = rt_pipeline.attach_inflight(state, plan, mesh8x1)
        n_leaves = len(jax.tree.leaves(state.params))

        def step(state, i, flag_val):
            batch = jax.tree.map(jnp.asarray, synthetic_batch(DCFG, i))
            batch[FAULT_KEY] = jnp.full((n_leaves,), flag_val, jnp.float32)
            return fn(state, batch, jax.random.fold_in(KEY, i))

        state, _ = step(state, 0, 0.0)             # warm: inflight nonzero
        pre = _state_leaves(state)
        pre_inflight = [np.asarray(x) for x in jax.tree.leaves(state.inflight)]
        tripped, m = step(state, 1, 1.0)           # NaN every leaf
        assert float(m["nonfinite"]) == 1.0
        post = _state_leaves(tripped)
        _assert_leaves_equal(post, pre)            # bit-exact no-op
        for x, y in zip(jax.tree.leaves(tripped.inflight), pre_inflight):
            np.testing.assert_array_equal(np.asarray(x), y)
        assert int(tripped.step) == int(state.step) + 1
        clean, m2 = step(tripped, 2, 0.0)          # recovery step applies
        assert float(m2["nonfinite"]) == 0.0
        assert all(np.isfinite(x).all() for x in
                   jax.tree.leaves(jax.tree.map(np.asarray, clean.params)))


# --------------------------------------------------------------------------
# trainer integration: corrupt save -> CRC fallback mid-run
# --------------------------------------------------------------------------

def test_trainer_chaos_ckpt_corrupt_falls_back_and_completes(
        mesh8x1, model, tmp_path):
    from repro.train.trainer import Trainer

    plan = FaultPlan(specs=(FaultSpec(kind="ckpt_corrupt", step=4),
                            FaultSpec(kind="collective", step=5)))
    inj = FaultInjector(plan)
    obs = _obs_with_metrics()
    tr = Trainer(model, TCFG, mesh8x1, DCFG, ckpt_dir=str(tmp_path / "ck"),
                 ckpt_every=2, obs=obs)
    log = tr.run_pipelined(N, staleness=0, superstep=1, depth=1, prefetch=1,
                           guard=True, injector=inj, recovery=FAST_RECOVERY)
    assert int(tr.state.step) == N
    assert log.restarts == 1
    m = obs.metrics
    assert m.counter("faults/injected_ckpt_corrupt").value == 1
    assert m.counter("faults/injected_collective").value == 1
    assert m.counter("recovery/ckpt_fallbacks").value == 1
    assert m.counter("recovery/retries_collective").value == 1
    fb = m.events_named("recovery/ckpt_fallback")
    assert fb and fb[0]["corrupt_step"] == 4 and fb[0]["step"] == 2


# --------------------------------------------------------------------------
# serve matrix: chaos ticks + graceful degradation
# --------------------------------------------------------------------------

def _serve_requests():
    rng = np.random.default_rng(3)
    return [Request(rid=i, prompt=rng.integers(0, 256, L),
                    max_new_tokens=m, arrival=a)
            for i, (L, m, a) in enumerate(
                [(3, 6, 0), (5, 5, 0), (4, 6, 1), (6, 4, 2), (3, 5, 4)])]


@pytest.fixture(scope="module")
def mesh4x2():
    return make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="module")
def serve_eng(mesh4x2, model):
    params = model.init(jax.random.PRNGKey(0))
    return ContinuousServeEngine(model, mesh4x2, params, cache_len=32,
                                 batch_size=4, dispatch="dense")


@pytest.fixture(scope="module")
def serve_clean(serve_eng):
    res = serve_eng.run(_serve_requests())
    return {rid: t.tolist() for rid, t in res.outputs.items()}


def _same_outputs(got, want_lists):
    assert set(got) == set(want_lists)
    for rid in got:
        assert got[rid].tolist() == want_lists[rid], rid


def test_serve_collective_tick_retries_token_identical(serve_eng,
                                                       serve_clean):
    obs = _obs_with_metrics()
    serve_eng.obs = obs
    serve_eng.injector = FaultInjector(FaultPlan.single("collective", 2))
    try:
        res = serve_eng.run(_serve_requests())
    finally:
        serve_eng.injector = None
    _same_outputs(res.outputs, serve_clean)        # token-identical
    assert obs.metrics.counter("serve/retries").value == 1
    assert obs.metrics.counter("faults/injected_collective").value == 1
    assert obs.metrics.events_named("recovery/serve_retry")


def test_serve_latency_faults_token_identical(serve_eng, serve_clean):
    inj = FaultInjector(FaultPlan(specs=(
        FaultSpec(kind="straggler", step=1, duration_s=0.03),
        FaultSpec(kind="stall", step=3, duration_s=0.03))))
    serve_eng.obs = obs_mod.Observability()
    serve_eng.injector = inj
    try:
        res = serve_eng.run(_serve_requests())
    finally:
        serve_eng.injector = None
    _same_outputs(res.outputs, serve_clean)
    assert inj.fired_total == 2


def test_serve_nonfinite_tick_aborts_with_blackbox(serve_eng, tmp_path):
    """Decode state is donated: a post-dispatch-unsafe fault cannot be
    retried in place — the engine aborts cleanly, blackbox first."""
    bb = tmp_path / "bb.json"
    obs = _obs_with_metrics(recorder_path=bb)
    serve_eng.obs = obs
    serve_eng.injector = FaultInjector(FaultPlan.single("nonfinite", 2))
    try:
        with pytest.raises(NonFiniteEscalation):
            serve_eng.run(_serve_requests())
    finally:
        serve_eng.injector = None
    doc = json.load(open(bb))
    assert doc["reason"] == "exception:NonFiniteEscalation"


def test_serve_sigterm_tick_aborts(serve_eng, tmp_path):
    bb = tmp_path / "bb.json"
    obs = _obs_with_metrics(recorder_path=bb)

    def die(signum, frame):
        raise KeyboardInterrupt("SIGTERM")

    prev = signal.signal(signal.SIGTERM, die)
    serve_eng.obs = obs
    serve_eng.injector = FaultInjector(FaultPlan.single("sigterm", 1))
    try:
        obs.recorder.install_signal_handlers(("SIGTERM",))
        with pytest.raises(KeyboardInterrupt):
            serve_eng.run(_serve_requests())
    finally:
        serve_eng.injector = None
        obs.recorder.uninstall_signal_handlers()
        signal.signal(signal.SIGTERM, prev)
    assert json.load(open(bb))["reason"] == "signal:SIGTERM"


def test_serve_shedding_bounded_queue_and_deadline(serve_eng, serve_clean):
    """Overload: 12 simultaneous arrivals into 4 slots with queue_limit=3
    and a 2-step TTFT deadline. Served requests are token-identical to
    the unloaded run; everything else is shed with full accounting."""
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(0, 256, 3 + i % 3),
                    max_new_tokens=6, arrival=0.0) for i in range(12)]
    obs = _obs_with_metrics()
    serve_eng.obs = obs
    serve_eng.serve_cfg = ServeConfig(slo_ttft_p99=2.0, queue_limit=3)
    try:
        res = serve_eng.run(reqs)
        ref = serve_eng.run(reqs[:4])      # unloaded: the served four
    finally:
        serve_eng.serve_cfg = None
        serve_eng.obs = obs_mod.Observability()
    # slots absorb the first 4; queue keeps 3 more; 5 shed immediately,
    # and the 3 queued ones outlive the 2-step TTFT deadline -> shed too
    assert set(res.outputs) == {0, 1, 2, 3}
    assert set(res.shed) == set(range(4, 12))
    assert sorted(res.shed.values()).count("queue_full") == 5
    assert sorted(res.shed.values()).count("deadline") == 3
    assert not (set(res.outputs) & set(res.shed))
    for rid in res.outputs:                # non-shed: token-identical
        assert res.outputs[rid].tolist() == ref.outputs[rid].tolist()
    m = obs.metrics
    assert m.counter("serve/shed_requests").value == 8
    assert m.counter("serve/shed_queue_full").value == 5
    assert m.counter("serve/shed_deadline").value == 3
    assert len(m.events_named("serve/shed")) == 8
    backpressure = [e for e in res.health if e.rule == "serve_shed"]
    assert backpressure and backpressure[0].severity == "warn"
    assert backpressure[0].value == 8.0
    # shed lifecycles never enter the latency distributions
    assert sorted(res.latency) == ["e2e", "queue_delay", "tpot", "ttft"]


# --------------------------------------------------------------------------
# recovery-timeline report section
# --------------------------------------------------------------------------

def test_report_renders_recovery_timeline(tmp_path):
    from repro.obs.report import load_metrics_jsonl, render

    reg = MetricsRegistry(enabled=True)
    reg.counter("faults/injected_nonfinite").inc()
    reg.counter("guard/nonfinite_trips").inc(2)
    reg.counter("recovery/retries_stall").inc()
    reg.counter("serve/shed_requests").inc(3)
    reg.event("faults/injected", fault="nonfinite", step=4)
    reg.event("health/nonfinite", severity="critical", subject="grads",
              step=4, message="non-finite grads: apply skipped")
    reg.event("recovery/retry", cls="stall", step=5, attempt=1,
              delay_s=0.01, error="PrefetchStalled")
    reg.event("recovery/ckpt_fallback", step=2, corrupt_step=4)
    reg.event("serve/shed", rid=7, reason="deadline", step=3.0)
    reg.event("adapt/fault_demotion", buckets=["b0"], hold=4,
              signature="b0=dense")
    path = reg.dump_jsonl(str(tmp_path / "m.jsonl"))
    out = render(path)
    assert "-- recovery timeline --" in out
    for needle in ("faults/injected", "health/nonfinite", "recovery/retry",
                   "recovery/ckpt_fallback", "serve/shed",
                   "adapt/fault_demotion", "guard/nonfinite_trips=2",
                   "serve/shed_requests=3"):
        assert needle in out, needle
    # torn tail still renders (the writer crashed mid-line)
    with open(path, "a") as f:
        f.write('{"kind": "event", "event": "recovery/retr')
    doc = load_metrics_jsonl(path)
    assert len(doc["events"]) == 6
    assert "-- recovery timeline --" in render(path)
