"""Serve subsystem (DESIGN.md §8): continuous-batching scheduler,
slot decode engine, and the plan-driven sparse expert dispatch.

The two load-bearing invariants:

* continuous batching is INVISIBLE to a request: its tokens equal a
  per-request ``ServeEngine.generate`` greedy decode, token for token,
  whatever slots/arrivals/retirements happen around it;
* the sparse (row-stream) dispatch wire is EXACT: bit-identical to the
  dense psum reference on every lowering, as long as occupancy stays
  under the stream capacity (which the engine's guard enforces).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from functools import partial
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.comm import (
    CollectiveContext,
    build_serve_plan,
    exchange_activation,
    exchange_activation_spmd,
)
from repro.core import sparse_stream as ss
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.models.moe import ServeDispatch, moe_apply, moe_apply_serve
from repro.runtime.adapt import AdaptConfig, AdaptiveController
from repro.serve import (
    ContinuousScheduler,
    ContinuousServeEngine,
    Request,
    ServeEngine,
    poisson_trace,
    truncate_at_eos,
)


# --------------------------------------------------------------------------
# Row streams + exchange parity
# --------------------------------------------------------------------------

def _row_sparse(p, t, d, nnz_rows, seed=0):
    rng = np.random.default_rng(seed)
    parts = np.zeros((p, t, d), np.float32)
    for s in range(p):
        for r in rng.choice(t, nnz_rows, replace=False):
            parts[s, r] = rng.standard_normal(d)
    return jnp.asarray(parts)


def test_row_stream_roundtrip_exact():
    x = np.asarray(_row_sparse(1, 16, 8, 3)[0])
    xj = jnp.asarray(x)
    st = ss.from_row_mask(xj, jnp.any(xj != 0, axis=1), cap=4)
    assert int(st.nnz) == 3
    back = ss.densify_rows(st, 16)
    np.testing.assert_array_equal(np.asarray(back), x)


def test_row_stream_overflow_clamps():
    # over capacity the round-trip is lossy — this is WHY the engine's
    # occupancy guard exists; the nnz count saturates at cap
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 4))
                    .astype(np.float32))
    st = ss.from_row_mask(x, jnp.ones((8,), bool), cap=4)
    assert int(st.nnz) == 4
    back = ss.densify_rows(st, 8)
    assert not np.array_equal(np.asarray(back), np.asarray(x))
    # the kept rows are the lowest indices, intact
    np.testing.assert_array_equal(np.asarray(back[:4]), np.asarray(x[:4]))


@pytest.mark.parametrize("p", [2, 8])
def test_exchange_spmd_sparse_equals_dense(p):
    parts = _row_sparse(p, 16, 8, 3)
    dense = exchange_activation_spmd(parts, "dense")
    sparse = exchange_activation_spmd(parts, "stream_gather@4")
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sparse))


@pytest.mark.parametrize("p", [2, 8])
def test_exchange_manual_lowerings(p):
    """Manual lowerings of the activation exchange (DESIGN.md §8.2):

    * native: the real stream all-gather; its per-shard densify + sum is
      the same summation structure as the SPMD dense reference — bitwise
      equal at any p;
    * emulated (psum-only): the stream round-trip feeds the SAME psum as
      the dense path — bitwise equal to THAT reference at any p (psum's
      own reduction order may differ from the stacked sum's above p=2).
    """
    t, d = 16, 8
    parts = _row_sparse(p, t, d, 3)
    mesh = make_mesh((p,), ("model",))
    ref = np.asarray(exchange_activation_spmd(parts, "dense"))

    def native(x):
        coll = CollectiveContext("model", p, native=True)
        return exchange_activation(x[0], "stream_gather@4", coll=coll)[None]

    fn = shard_map(native, mesh=mesh, in_specs=P("model"),
                   out_specs=P("model"), axis_names={"model"})
    with mesh:
        out_native = np.asarray(jax.jit(fn)(parts))
    for s in range(p):
        np.testing.assert_array_equal(out_native[s], ref)

    def emul(x, rid, algorithm):
        coll = CollectiveContext("model", p, native=False, rank=rid[0])
        return exchange_activation(x[0], algorithm, coll=coll)[None]

    outs = {}
    for algorithm in ("dense", "stream_gather@4"):
        fe = shard_map(partial(emul, algorithm=algorithm), mesh=mesh,
                       in_specs=(P("model"), P("model")),
                       out_specs=P("model"), axis_names={"model"})
        with mesh:
            outs[algorithm] = np.asarray(
                jax.jit(fe)(parts, jnp.arange(p, dtype=jnp.int32)))
    np.testing.assert_array_equal(outs["stream_gather@4"], outs["dense"])
    np.testing.assert_allclose(outs["dense"][0], ref, rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# Serve-time MoE dispatch
# --------------------------------------------------------------------------

def _moe_cfg(**kw):
    base = dict(name="t", family="moe", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=256, dtype=jnp.float32,
                param_dtype=jnp.float32, max_seq_len=64, num_experts=4,
                experts_per_token=2, moe_d_ff=64, capacity_factor=4.0)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def moe_model():
    cfg = _moe_cfg()
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def test_moe_serve_dispatch_masking_and_parity(moe_model):
    model, params = moe_model
    cfg = model.cfg
    lp = jax.tree.map(lambda a: a[0], params["blocks"])["moe"]
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((8, cfg.d_model)).astype(np.float32))
    act = jnp.zeros((8,), bool).at[0].set(True).at[3].set(True)

    def md(algorithm):
        return ServeDispatch(
            active=act,
            exchange=lambda parts: exchange_activation_spmd(parts, algorithm),
            p_shards=2)

    y_dense = moe_apply_serve(lp, cfg, x, md("dense"))
    y_sparse = moe_apply_serve(lp, cfg, x, md("stream_gather@4"))
    # sparse dispatch is bit-identical to the dense reference
    np.testing.assert_array_equal(np.asarray(y_dense), np.asarray(y_sparse))
    # inactive slots contribute and receive nothing through dispatch
    inactive = np.asarray(y_dense)[np.asarray(~act)]
    np.testing.assert_array_equal(inactive, np.zeros_like(inactive))
    # an active token's output is what a batch of just-itself computes
    y_solo = moe_apply(lp, cfg, x[0:1])
    np.testing.assert_allclose(np.asarray(y_dense)[0], np.asarray(y_solo)[0],
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# Scheduler unit tests
# --------------------------------------------------------------------------

def test_scheduler_lifecycle_and_fifo():
    reqs = [Request(rid=i, prompt=np.array([1, 2]), max_new_tokens=3,
                    arrival=a) for i, a in enumerate([0, 0, 5, 0])]
    sched = ContinuousScheduler(2, reqs, eos_id=99)
    admits = sched.admit_ready()
    assert [(i, r.rid) for i, r in admits] == [(0, 0), (1, 1)]  # FIFO
    for i, r in admits:
        sched.install(i, r, first_token=7)
    assert sched.active_count == 2 and not sched.admit_ready()
    # early EOS retires and frees the slot
    assert sched.record(0, 99) is True
    assert sched.completed[0].tolist() == [7, 99]
    # rid 3 (arrival 0) is admitted before rid 2 (arrival 5)
    admits = sched.admit_ready()
    assert [(i, r.rid) for i, r in admits] == [(0, 3)]
    sched.install(0, admits[0][1], first_token=1)
    # max_new_tokens retirement
    sched.record(1, 1)
    assert sched.record(1, 2) is True            # 3 tokens incl. install
    assert sched.completed[1].tolist() == [7, 1, 2]
    # idle skip jumps to the next arrival
    sched.record(0, 1), sched.record(0, 2)
    assert sched.active_count == 0 and sched.waiting
    sched.skip_to_next_arrival()
    assert sched.clock == 5.0
    assert [(i, r.rid) for i, r in sched.admit_ready()] == [(0, 2)]


def test_poisson_trace_deterministic():
    a = poisson_trace(16, rate=0.5, seed=7)
    b = poisson_trace(16, rate=0.5, seed=7)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) > 0).all() and a.shape == (16,)
    assert not np.array_equal(a, poisson_trace(16, rate=0.5, seed=8))


def test_truncate_at_eos():
    t = np.array([3, 9, 4, 9, 5])
    assert truncate_at_eos(t, 9).tolist() == [3, 9]
    assert truncate_at_eos(t, 77).tolist() == t.tolist()
    assert truncate_at_eos(t, None).tolist() == t.tolist()


# --------------------------------------------------------------------------
# Continuous batching == per-request decode (token for token)
# --------------------------------------------------------------------------

def _requests(rng, specs):
    return [Request(rid=i, prompt=rng.integers(0, 256, L),
                    max_new_tokens=m, arrival=a)
            for i, (L, m, a) in enumerate(specs)]


def _references(model, mesh, params, reqs, cache_len, eos_id=None):
    eng = ServeEngine(model, mesh, params, cache_len=cache_len, batch_size=1)
    out = {}
    for r in reqs:
        toks = eng.generate(r.prompt[None], max_new_tokens=r.max_new_tokens)[0]
        out[r.rid] = truncate_at_eos(toks, eos_id)
    return out


def _assert_outputs_equal(got: dict, want: dict):
    assert set(got) == set(want)
    for rid in want:
        assert got[rid].tolist() == want[rid].tolist(), rid


def test_continuous_matches_per_request_dense_ragged_eos(mesh4x2):
    """Ragged prompts, staggered arrivals, early EOS: every request's
    continuous-batching output equals its own B=1 greedy decode."""
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype=jnp.float32, param_dtype=jnp.float32,
                      max_seq_len=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = _requests(rng, [(3, 6, 0), (7, 4, 0), (5, 8, 0), (10, 5, 1),
                           (4, 7, 3), (6, 6, 8), (1, 4, 9)])
    plain = _references(model, mesh4x2, params, reqs, cache_len=32)
    # an EOS id that actually fires mid-stream for request 0
    eos = int(plain[0][2])
    want = {rid: truncate_at_eos(t, eos) for rid, t in plain.items()}
    eng = ContinuousServeEngine(model, mesh4x2, params, cache_len=32,
                                batch_size=4, eos_id=eos)
    res = eng.run(reqs)
    _assert_outputs_equal(res.outputs, want)
    assert res.tokens == sum(len(t) for t in want.values())


@pytest.fixture(scope="module")
def moe_serving(moe_model):
    """One MoE drain-shaped workload + its per-request references."""
    model, params = moe_model
    mesh = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(1)
    # burst fills the slots (high occupancy), then short requests retire
    # and two long ones drain at low occupancy for many steps
    reqs = _requests(rng, [(4, 6, 0), (6, 5, 0), (3, 6, 0), (5, 4, 0),
                           (7, 5, 0), (4, 5, 0), (5, 22, 0), (6, 20, 1)])
    refs = _references(model, mesh, params, reqs, cache_len=32)
    return model, params, mesh, reqs, refs


def test_continuous_moe_dense_matches_per_request(moe_serving):
    model, params, mesh, reqs, refs = moe_serving
    eng = ContinuousServeEngine(model, mesh, params, cache_len=32,
                                batch_size=8, dispatch="dense")
    res = eng.run(reqs)
    _assert_outputs_equal(res.outputs, refs)


def test_continuous_moe_adaptive_exact_and_swaps(moe_serving):
    """The adaptive engine must (a) emit EXACTLY the dense reference's
    tokens, (b) log a telemetry-driven dense->stream swap during the
    drain, (c) put fewer modeled bytes on the wire than dense mode."""
    model, params, mesh, reqs, refs = moe_serving
    dense = ContinuousServeEngine(model, mesh, params, cache_len=32,
                                  batch_size=8, dispatch="dense")
    rd = dense.run(reqs)
    adap = ContinuousServeEngine(model, mesh, params, cache_len=32,
                                 batch_size=8, dispatch="adaptive")
    ra = adap.run(reqs)
    _assert_outputs_equal(ra.outputs, refs)
    _assert_outputs_equal(ra.outputs, rd.outputs)
    telem_swaps = [s for s in ra.swap_log if s["reason"] == "telemetry"]
    assert telem_swaps and "stream_gather" in telem_swaps[0]["signature"]
    assert ra.wire_bytes < rd.wire_bytes
    # the plan actually went sparse at low occupancy
    sparse_steps = [r for r in ra.step_log if "stream_gather" in r["signature"]]
    assert sparse_steps
    assert max(r["active"] for r in sparse_steps) <= 4


def test_occupancy_guard_forces_dense(moe_model):
    """A late burst that outgrows the stream capacity must force-demote
    to dense BEFORE any token is computed under an over-capacity stream
    — and the output must stay exact."""
    model, params = moe_model
    mesh = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(2)
    reqs = _requests(rng, [(4, 18, 0), (5, 18, 0)] +
                     [(4, 8, 12 + i * 0.01) for i in range(6)])
    refs = _references(model, mesh, params, reqs, cache_len=32)
    adap = ContinuousServeEngine(model, mesh, params, cache_len=32,
                                 batch_size=8, dispatch="adaptive")
    res = adap.run(reqs)
    _assert_outputs_equal(res.outputs, refs)
    reasons = [s["reason"] for s in res.swap_log]
    assert "telemetry" in reasons          # drained to the stream first
    assert "occupancy-guard" in reasons    # burst forced it back to dense
    guard = [s for s in res.swap_log if s["reason"] == "occupancy-guard"][0]
    assert guard["signature"] == "act0=dense"


# --------------------------------------------------------------------------
# ServePlan + controller
# --------------------------------------------------------------------------

def test_serve_plan_selection_and_signature():
    plan = build_serve_plan(2, 16, 128, algorithm="dense")
    assert plan.signature() == "act0=dense"
    low = plan.replan({"act0": 2.0})
    assert low.signature() == "act0=stream_gather@4"
    assert low.version == plan.version + 1
    assert low.wire_bytes() < plan.wire_bytes()
    # high occupancy: cap would reach the token count -> dense
    high = low.replan({"act0": 14.0})
    assert high.signature() == "act0=dense"
    # capacity crossing is a forced switch, hysteresis may not veto it
    assert low.switch_forced("act0", "stream_gather@4", "dense", 4.0)
    assert not low.switch_forced("act0", "stream_gather@4", "dense", 3.0)
    assert not plan.switch_forced("act0", "dense", "stream_gather@4", 99.0)
    # explicit algorithm overrides (checkpoint-resume style)
    forced = plan.replan(algorithms={"act0": "stream_gather@8"})
    assert forced.signature() == "act0=stream_gather@8"
    assert forced.buckets[0].cap == 8


def test_adaptive_controller_drives_serve_plan():
    plan = build_serve_plan(2, 16, 128, algorithm="dense")
    ctrl = AdaptiveController(plan, cfg=AdaptConfig(
        window=2, patience=1, calibrate=False, pod_sparse=False))
    accepted = None
    for _ in range(4):
        accepted = ctrl.observe_step({"act0": 2.0}) or accepted
    assert accepted is not None
    assert accepted.signature() == "act0=stream_gather@4"
    # occupancy crossing the cap forces the way back up (no veto)
    back = None
    for _ in range(4):
        back = ctrl.observe_step({"act0": 14.0}) or back
    assert back is not None and back.signature() == "act0=dense"
    assert ctrl.swaps == 2


# --------------------------------------------------------------------------
# Per-slot positions in attention decode
# --------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 6])
def test_vector_pos_attention_matches_scalar(window):
    from repro.models import layers as L
    from repro.models.layers import KVCache

    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype=jnp.float32, param_dtype=jnp.float32,
                      max_seq_len=32, sliding_window=window)
    p = jax.tree.map(lambda a: a[0],
                     build_model(cfg).init(jax.random.PRNGKey(0))["blocks"])
    rng = np.random.default_rng(0)
    b, w = 4, 6 if window else 12
    x = jnp.asarray(rng.standard_normal((b, 1, 32)).astype(np.float32))
    kv = KVCache(
        jnp.asarray(rng.standard_normal((b, w, 2, 8)).astype(np.float32)),
        jnp.asarray(rng.standard_normal((b, w, 2, 8)).astype(np.float32)))
    pos = jnp.asarray([0, 3, 7, 11], jnp.int32)
    o_vec, kc_vec = L.attention_decode(p["attn"], cfg, x, kv, pos)
    for i in range(b):
        kv1 = KVCache(kv.k[i:i + 1], kv.v[i:i + 1])
        o_s, kc_s = L.attention_decode(p["attn"], cfg, x[i:i + 1], kv1,
                                       jnp.asarray(int(pos[i]), jnp.int32))
        np.testing.assert_array_equal(np.asarray(o_vec[i]), np.asarray(o_s[0]))
        np.testing.assert_array_equal(np.asarray(kc_vec.k[i]),
                                      np.asarray(kc_s.k[0]))
