"""Sparse allreduce algorithms (paper §5.3) vs dense-sum oracle on 8 devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topk as topk_mod
from repro.core.allreduce import make_sparse_allreduce
from repro.core.qsgd import QSGDConfig

N, K, B = 8192, 4, 512


@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(42)
    x = jax.random.normal(key, (8, N))
    rows = [np.asarray(topk_mod.compress(x[i], K, B, impl="ref")[0].densify())
            for i in range(8)]
    return x, np.stack(rows).sum(0)


ALGOS = ["ssar_recursive_double", "ssar_split_allgather",
         "dsar_split_allgather", "dense", "auto"]


@pytest.mark.parametrize("algo", ALGOS)
def test_sparse_allreduce_exact(mesh8, data, algo):
    x, oracle = data
    f = make_sparse_allreduce(mesh8, "data", N, K, B, algorithm=algo)
    out = np.asarray(f(x.reshape(-1), None))
    np.testing.assert_allclose(out, oracle, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("bits", [4, 8])
def test_dsar_qsgd_bounded_error(mesh8, data, bits):
    x, oracle = data
    key = jax.random.PRNGKey(7)
    rand = jax.random.bits(key, (8, N), dtype=jnp.uint32)
    f = make_sparse_allreduce(mesh8, "data", N, K, B,
                              algorithm="dsar_split_allgather",
                              qsgd=QSGDConfig(bits=bits))
    out = np.asarray(f(x.reshape(-1), rand.reshape(-1)))
    mask = np.abs(oracle) > 0
    rel = np.abs(out - oracle)[mask].mean() / np.abs(oracle)[mask].mean()
    assert rel < (0.5 if bits == 4 else 0.06)


def test_no_overlap_equals_allgather_semantics(mesh8):
    """Paper extreme case (1): disjoint indices -> result has k*P nonzeros."""
    k = 8
    xs = np.zeros((8, N), np.float32)
    for r in range(8):
        # rank r's top-k live in bucket positions unique to r
        for j in range(k):
            xs[r, j * B + r] = float(r + 1)
    f = make_sparse_allreduce(mesh8, "data", N, k, B,
                              algorithm="ssar_recursive_double")
    out = np.asarray(f(jnp.asarray(xs).reshape(-1), None))
    assert (out != 0).sum() == 8 * k


def test_full_overlap_equals_dense_k(mesh8):
    """Paper extreme case (2): identical indices -> result has k nonzeros."""
    k = 8
    xs = np.zeros((8, N), np.float32)
    xs[:, : B * k : B] = 1.0  # same k positions on every rank
    f = make_sparse_allreduce(mesh8, "data", N, k, B,
                              algorithm="ssar_split_allgather")
    out = np.asarray(f(jnp.asarray(xs).reshape(-1), None))
    nz = np.nonzero(out)[0]
    assert len(nz) == k and np.allclose(out[nz], 8.0)
