"""Compression-health observability (DESIGN.md §10.5-§10.7): in-graph
mass telemetry, the windowed health rule engine, the flight recorder,
and the report CLI.

The acceptance criteria pinned here:

* mass telemetry — per-bucket coverage + EF norm agree with an eager
  reference on all THREE lowerings (manual-native, emulated, auto-SPMD)
  and compile out entirely under ``telemetry=False`` (jaxpr-asserted,
  not just DCE'd);
* health engine — a synthetic EF-blowup registry and a synthetic serve
  SLO-violation trace each produce the expected severity-ranked events
  DETERMINISTICALLY;
* flight recorder — a killed driver run leaves a parseable
  ``blackbox.json`` holding the last steps; signal and watchdog
  triggers dump too;
* report CLI — renders the artifacts of a run without jax.
"""
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import obs as obs_mod
from repro.compat import make_mesh, shard_map
from repro import comm
from repro.core.compressor import SyncConfig
from repro.obs import FlightRecorder, MetricsRegistry
from repro.obs.health import (
    HealthConfig,
    HealthEvent,
    HealthMonitor,
    rank_events,
)

KEY = jax.random.PRNGKey(0)
P_DATA = 8


def _plan(algorithm="dsar_split_allgather", n=3000):
    cfg = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                     algorithm=algorithm, min_sparse_size=1024, impl="ref",
                     fusion_bucket_bytes=1 << 14)
    shapes = {"a": jax.ShapeDtypeStruct((n,), jnp.float32),
              "b": jax.ShapeDtypeStruct((77,), jnp.float32)}
    return comm.build_sync_plan(shapes, {"a": P(), "b": P()}, cfg, P_DATA)


def _grads(n=3000, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((P_DATA, n)).astype(np.float32))


def _leaves_r(g):
    """(R, 3000) grads -> per-leaf (R, *leaf) stacks (leaf b rides as a
    deterministic non-zero tail so every bucket carries signal)."""
    tail = jnp.tile(jnp.arange(77, dtype=jnp.float32)[None] * 0.1,
                    (P_DATA, 1))
    return [g, tail]


def _ef_names(plan):
    return [b.name for grp in plan.groups for b in grp.buckets
            if b.has_residual]


def _acc_ref(plan, leaves_r, residuals):
    """The global accumulator each EF bucket compressed, rebuilt with
    the executor's own packing: {name: (R, rows, cols) res + seg}."""
    from repro.comm.buckets import pack_group

    accs = {}
    for grp in plan.groups:
        bufs = np.stack([
            np.asarray(pack_group(grp, [np.asarray(lv)[r] for lv in leaves_r],
                                  plan.cfg.bucket_size))
            for r in range(P_DATA)])                    # (R, rows, cols)
        for b in grp.buckets:
            if b.has_residual:
                seg = bufs[:, :, b.col_start:b.col_start + b.cols]
                accs[b.name] = (np.asarray(residuals[b.name], np.float64)
                                .reshape(seg.shape) + seg)
    return accs


# --------------------------------------------------------------------------
# mass telemetry: eager reference on all three lowerings
# --------------------------------------------------------------------------

def _check_mass(telem, new_res, *, acc=None):
    """Shared reference: reported ef_norm must equal the norm of the
    RETURNED residuals (valid for every algorithm — clamp folds are
    added before the telemetry read), and, when ``acc`` (the global
    (R, rows, cols) pre-compression accumulator per bucket) is given,
    coverage must equal ‖acc - r'‖²/‖acc‖² (fold-free algorithms only).
    """
    for name, t in telem.items():
        t = np.asarray(t)
        assert t.shape == (4,)
        nnz, wire, coverage, ef_norm = t
        r = np.asarray(new_res[name], dtype=np.float64)
        assert ef_norm == pytest.approx(np.sqrt((r ** 2).sum()), rel=1e-5)
        assert 0.0 <= coverage <= 1.0 + 1e-6
        assert nnz >= 0 and wire > 0
        if acc is not None:
            a = np.asarray(acc[name], dtype=np.float64)
            u = a - r.reshape(a.shape)
            ref = (u ** 2).sum() / max((a ** 2).sum(), 1e-30)
            assert coverage == pytest.approx(ref, rel=1e-5)


def test_mass_telemetry_spmd_matches_eager_reference():
    plan = _plan()
    g = _grads()
    res = plan.init_residuals()
    # two steps so the second's accumulator carries real residual mass
    for step in range(2):
        leaves = _leaves_r(g)
        accs = _acc_ref(plan, leaves, res)
        reduced, res, telem = comm.reduce_buckets_spmd(
            plan, leaves, res, jax.random.fold_in(KEY, step), p_data=P_DATA)
        assert set(telem) == set(_ef_names(plan)) == set(accs)
        _check_mass(telem, res, acc=accs)
        g = g * 0.5


def _run_manual(plan, g, native, key=KEY):
    """shard_map harness over the manual executor; returns the gathered
    (R, rows, cols) residuals and the replicated telemetry vectors."""
    mesh = make_mesh((P_DATA,), ("data",))
    res = plan.init_residuals()
    rspecs = {k: P("data", None, None) for k in res}
    tspecs = {k: P() for k in _ef_names(plan)}
    rid = jnp.arange(P_DATA, dtype=jnp.int32)
    leaves = _leaves_r(g)

    def inner(ga, gb, r, rid):
        _, new_res, telem = comm.reduce_buckets(
            plan, [ga[0], gb[0]], r, key, data_axis="data",
            p_data=P_DATA, native=native, data_rank=rid[0])
        return new_res, telem

    f = shard_map(inner, mesh=mesh,
                  in_specs=(P("data", None), P("data", None), rspecs,
                            P("data")),
                  out_specs=(rspecs, tspecs), check_vma=False)
    new_res, telem = f(leaves[0], leaves[1], res, rid)
    accs = _acc_ref(plan, leaves, res)
    return ({k: np.asarray(v) for k, v in new_res.items()},
            {k: np.asarray(v) for k, v in telem.items()}, accs)


@pytest.mark.parametrize("native", [True, False],
                         ids=["manual", "emulated"])
@pytest.mark.parametrize("algorithm", ["dsar_split_allgather",
                                       "ssar_balanced_split"])
def test_mass_telemetry_manual_lowerings(native, algorithm):
    plan = _plan(algorithm=algorithm)
    new_res, telem, accs = _run_manual(plan, _grads(seed=3), native)
    assert set(telem) == set(_ef_names(plan))
    # ef_norm reference holds for all algorithms (fold precedes the
    # telemetry read); the coverage identity only for fold-free DSAR
    _check_mass(telem, new_res,
                acc=accs if algorithm == "dsar_split_allgather" else None)


def test_mass_telemetry_manual_emulated_agree():
    """The (4,) vectors themselves must agree across the two manual
    lowerings of the SAME plan (the executor-parity invariant extends to
    telemetry: emulated reroutes SSAR->DSAR but reduces the same sum)."""
    plan = _plan()
    g = _grads(seed=11)
    res_n, tel_n, _ = _run_manual(plan, g, True)
    res_e, tel_e, _ = _run_manual(plan, g, False)
    for name in tel_n:
        np.testing.assert_allclose(tel_n[name], tel_e[name], rtol=1e-5)
    for name in res_n:
        np.testing.assert_allclose(res_n[name], res_e[name], rtol=1e-5)


# --------------------------------------------------------------------------
# compile-out: telemetry=False leaves NO trace in the jaxpr
# --------------------------------------------------------------------------

def test_telemetry_compiles_out_spmd_jaxpr():
    plan = _plan()
    res = plan.init_residuals()

    def fn(telemetry):
        def step(leaves, res, key):
            return comm.reduce_buckets_spmd(plan, leaves, res, key,
                                            p_data=P_DATA,
                                            telemetry=telemetry)
        return step

    leaves = _leaves_r(_grads())
    jx_on = jax.make_jaxpr(fn(True))(leaves, res, KEY)
    jx_off = jax.make_jaxpr(fn(False))(leaves, res, KEY)
    _, _, telem_off = fn(False)(leaves, res, KEY)
    assert telem_off == {}
    # absent from the jaxpr, not merely unused: strictly fewer equations
    assert len(jx_off.jaxpr.eqns) < len(jx_on.jaxpr.eqns)
    # sqrt only appears in the ef_norm read
    assert "sqrt" in str(jx_on) and "sqrt" not in str(jx_off)


def test_telemetry_compiles_out_manual_psum_count():
    """Manual lowering: telemetry ON adds exactly ONE psum per EF bucket
    (the (3,) mass vector); OFF traces the identical collective set as
    the telemetry-free executor always did."""
    plan = _plan()
    res = plan.init_residuals()
    mesh = make_mesh((P_DATA,), ("data",))
    rspecs = {k: P("data", None, None) for k in res}
    rid = jnp.arange(P_DATA, dtype=jnp.int32)

    def traced(telemetry):
        def inner(gr, r, rid):
            reduced, new_res, _ = comm.reduce_buckets(
                plan, [gr[0], jnp.zeros((77,), jnp.float32)], r, KEY,
                data_axis="data", p_data=P_DATA, native=False,
                data_rank=rid[0], telemetry=telemetry)
            return reduced, new_res

        f = shard_map(inner, mesh=mesh,
                      in_specs=(P("data", None), rspecs, P("data")),
                      out_specs=({b.name: P() for b in plan.buckets},
                                 rspecs), check_vma=False)
        return str(jax.make_jaxpr(f)(_grads(), res, rid))

    on, off = traced(True), traced(False)
    n_ef = len(_ef_names(plan))
    assert n_ef >= 1
    assert on.count("psum") == off.count("psum") + n_ef
    assert "sqrt" in on and "sqrt" not in off


# --------------------------------------------------------------------------
# health engine: deterministic ranked verdicts on synthetic traces
# --------------------------------------------------------------------------

def _ef_blowup_registry():
    """Synthetic EF blowup: bucket g0b0's residual norm grows
    geometrically while its coverage decays under the floor; g0b1 stays
    healthy; step times spike in the recent window."""
    reg = MetricsRegistry()
    for i in range(32):
        reg.histogram("bucket/g0b0/ef_norm").observe(
            1.0 * (1.3 ** i))                       # geometric growth
        reg.histogram("bucket/g0b0/mass_coverage").observe(
            max(0.05, 0.9 - 0.05 * i))              # decays to 0.05
        reg.histogram("bucket/g0b1/ef_norm").observe(
            1.0 + 0.01 * (i % 3))                   # hovers
        reg.histogram("bucket/g0b1/mass_coverage").observe(0.95)
        reg.series("train/step_time_s").append(
            0.01 if i < 24 else 0.11)               # 11x spike at the end
    return reg


def test_health_ef_blowup_ranked_deterministically():
    cfg = HealthConfig(window=8, min_samples=4)
    ev1 = HealthMonitor(_ef_blowup_registry(), cfg).evaluate()
    ev2 = HealthMonitor(_ef_blowup_registry(), cfg).evaluate()
    assert ev1 == ev2                                # deterministic
    key = [(e.severity, e.rule, e.subject) for e in ev1]
    # 1.3^8 ~ 8.2x growth >= 2*critical_factor -> critical; coverage
    # median 0.05 < 0.5/2 -> critical; step p99 11x -> critical.
    assert key == [
        ("critical", "coverage_floor", "g0b0"),
        ("critical", "ef_growth", "g0b0"),
        ("critical", "step_time_p99", "train/step_time_s"),
    ]
    for e in ev1:
        assert e.value > e.threshold or e.rule == "coverage_floor"
    # healthy bucket stayed silent
    assert not any(e.subject == "g0b1" for e in ev1)


def test_health_events_mirrored_and_advisory():
    reg = _ef_blowup_registry()
    mon = HealthMonitor(reg, HealthConfig(window=8, min_samples=4))
    events = mon.evaluate()
    mirrored = [e for e in reg.events
                if str(e["event"]).startswith("health/")]
    assert len(mirrored) == len(events)
    assert {e["severity"] for e in mirrored} == {"critical"}
    adv = mon.advisory()
    assert adv["critical_buckets"] == ["g0b0"]
    assert adv["worst"] == "critical" and adv["n_events"] == len(events)
    # empty registries stay silent, advisory empty
    quiet = HealthMonitor(MetricsRegistry())
    assert quiet.evaluate() == []
    assert quiet.advisory() == {"critical_buckets": [], "worst": None,
                                "n_events": 0}
    assert "no findings" in quiet.summary()
    assert "g0b0" in mon.summary()


def test_health_underfilled_windows_stay_silent():
    reg = MetricsRegistry()
    for _ in range(7):   # < 2*min_samples
        reg.histogram("bucket/b0/ef_norm").observe(100.0)
        reg.histogram("bucket/b0/mass_coverage").observe(0.01)
    mon = HealthMonitor(reg, HealthConfig(window=8, min_samples=4))
    rules = {e.rule for e in mon.evaluate()}
    assert "ef_growth" not in rules   # needs both windows filled
    # coverage only needs min_samples -> it MAY fire; ef growth cannot


def test_health_serve_slo_and_drift_rules():
    from repro.obs import DriftAuditor

    reg = MetricsRegistry()
    # ttft p99 ~ 30 vs target 10 (beyond 2x -> critical); tpot ~ 1.5 vs
    # 1.2 (warn); e2e within target (silent)
    reg.histogram("serve/ttft_steps").observe_many([30.0] * 20)
    reg.histogram("serve/tpot_steps").observe_many([1.5] * 20)
    reg.histogram("serve/e2e_steps").observe_many([40.0] * 20)
    aud = DriftAuditor(flag_ratio=3.0)
    for i in range(3):
        aud.record("warn_alg", f"b{i}", 1e-3, 4e-3)    # 4x: warn
        aud.record("crit_alg", f"b{i}", 1e-3, 1e-2)    # 10x > 9: critical
    mon = HealthMonitor(reg, serve_slo={"ttft": 10.0, "tpot": 1.2,
                                        "e2e": 100.0}, audit=aud)
    ev1 = mon.evaluate()
    key = [(e.severity, e.rule, e.subject) for e in ev1]
    assert key == [
        ("critical", "drift_flag", "crit_alg"),
        ("critical", "serve_slo", "ttft"),
        ("warn", "drift_flag", "warn_alg"),
        ("warn", "serve_slo", "tpot"),
    ]
    # identical inputs -> identical list (ranking is total)
    mon2 = HealthMonitor(reg, serve_slo={"ttft": 10.0, "tpot": 1.2,
                                         "e2e": 100.0}, audit=aud)
    assert mon2.evaluate() == ev1


def test_rank_events_total_order():
    evs = [HealthEvent("info", "b_rule", "x", "", 1.0, 1.0),
           HealthEvent("critical", "z_rule", "b", "", 1.0, 1.0),
           HealthEvent("critical", "a_rule", "z", "", 1.0, 1.0),
           HealthEvent("warn", "a_rule", "a", "", 1.0, 1.0),
           HealthEvent("critical", "a_rule", "a", "", 1.0, 1.0)]
    ranked = rank_events(evs)
    assert [(e.severity, e.rule, e.subject) for e in ranked] == [
        ("critical", "a_rule", "a"), ("critical", "a_rule", "z"),
        ("critical", "z_rule", "b"), ("warn", "a_rule", "a"),
        ("info", "b_rule", "x")]


# --------------------------------------------------------------------------
# flight recorder
# --------------------------------------------------------------------------

def test_recorder_ring_bounded_and_atomic_dump(tmp_path):
    obs = obs_mod.configure(trace=True, metrics=True, set_as_default=False)
    rec = FlightRecorder(str(tmp_path / "blackbox.json"), capacity=16,
                         obs=obs)
    obs.metrics.series("train/loss").append(1.0)
    with obs.span("unit"):
        obs.metrics.event("step/ev", step=1)
    for i in range(100):
        rec.note("driver/retire", step=i, loss=float(i))
    assert len(rec.notes) == 16                        # bounded
    assert rec.notes[0]["step"] == 84
    path = rec.dump("test")
    doc = json.load(open(path))
    assert doc["kind"] == "blackbox" and doc["reason"] == "test"
    assert [n["step"] for n in doc["notes"]] == list(range(84, 100))
    assert doc["series_tail"]["train/loss"] == [1.0]
    assert any(e["event"] == "step/ev" for e in doc["event_tail"])
    assert any(e.get("name") == "unit" for e in doc["trace_tail"])
    # repeated dumps refresh the same file, no temp litter
    rec.dump("again")
    assert rec.dumps == 2 and rec.last_reason == "again"
    assert [p for p in os.listdir(tmp_path) if p.startswith(".")] == []


def test_recorder_signal_trigger_chains(tmp_path):
    rec = FlightRecorder(str(tmp_path / "bb.json"), obs=obs_mod.Observability())
    seen = []
    prev = signal.signal(signal.SIGUSR1, lambda n, f: seen.append(n))
    try:
        installed = rec.install_signal_handlers(("SIGUSR1", "SIGNOPE"))
        assert installed == ["SIGUSR1"]
        rec.note("before", step=1)
        os.kill(os.getpid(), signal.SIGUSR1)
        doc = json.load(open(tmp_path / "bb.json"))
        assert doc["reason"] == "signal:SIGUSR1"
        assert doc["notes"][0]["step"] == 1
        assert seen == [signal.SIGUSR1]               # chained through
    finally:
        rec.uninstall_signal_handlers()
        signal.signal(signal.SIGUSR1, prev)


def test_killed_driver_leaves_parseable_blackbox(tmp_path):
    """A step_fn that dies mid-run with NO restore_fn must still leave a
    blackbox.json holding the steps retired before the failure."""
    from repro.runtime import driver as rt_driver

    obs = obs_mod.configure(trace=False, metrics=True, set_as_default=False,
                            recorder=str(tmp_path / "blackbox.json"))
    boom_at = 6

    def step_fn(state, batch, key):
        step = int(state["step"])
        if step >= boom_at:
            raise RuntimeError("injected device fault")
        return ({"step": jnp.asarray(step + 1)},
                {"loss": jnp.asarray(1.0 / (step + 1))})

    with pytest.raises(RuntimeError, match="injected device fault"):
        rt_driver.run_pipelined(
            step_fn, {"step": jnp.asarray(0)}, start_step=0, num_steps=16,
            batch_fn=lambda s: {"x": np.zeros(1)},
            key_fn=lambda s: jax.random.fold_in(KEY, s),
            cfg=rt_driver.DriverConfig(depth=2, prefetch=1), obs=obs)
    doc = json.load(open(tmp_path / "blackbox.json"))
    assert doc["reason"] == "exception:RuntimeError"
    retires = [n for n in doc["notes"] if n["kind"] == "driver/retire"]
    assert retires and retires[-1]["step"] >= boom_at - 2
    assert [n["step"] for n in retires] == sorted(n["step"] for n in retires)
    assert doc["series_tail"]["train/loss"]          # losses made it out


def test_driver_watchdog_dumps_blackbox(tmp_path, monkeypatch):
    from repro.runtime import driver as rt_driver

    obs = obs_mod.configure(metrics=True, set_as_default=False,
                            recorder=str(tmp_path / "bb.json"))
    slow = {}

    def step_fn(state, batch, key):
        step = int(state["step"])
        if step == 10:
            slow["hit"] = True
        return ({"step": jnp.asarray(step + 1)}, {"loss": jnp.asarray(1.0)})

    real = jax.block_until_ready

    def maybe_slow(x):
        import time as _t
        if slow.pop("hit", False):
            _t.sleep(0.3)
        return real(x)

    monkeypatch.setattr(jax, "block_until_ready", maybe_slow)
    rt_driver.run_pipelined(
        step_fn, {"step": jnp.asarray(0)}, start_step=0, num_steps=16,
        batch_fn=lambda s: {"x": np.zeros(1)},
        key_fn=lambda s: jax.random.fold_in(KEY, s),
        cfg=rt_driver.DriverConfig(depth=1, prefetch=1),
        straggler_factor=3.0, obs=obs)
    assert obs.recorder.dumps >= 1
    assert obs.recorder.last_reason == "watchdog"
    assert json.load(open(tmp_path / "bb.json"))["reason"] == "watchdog"


def test_jsonl_sink_flushes_on_exception(tmp_path):
    reg = MetricsRegistry()
    path = tmp_path / "m.jsonl"
    with pytest.raises(ValueError):
        with reg.jsonl_sink(str(path), meta={"run": "t"}):
            reg.counter("steps").inc(3)
            raise ValueError("die mid-run")
    lines = [json.loads(ln) for ln in open(path)]
    assert lines[0]["kind"] == "header" and lines[0]["meta"]["run"] == "t"
    assert any(ln.get("name") == "steps" and ln["value"] == 3
               for ln in lines)
    # close is idempotent; atexit was deregistered
    sink = reg.jsonl_sink(str(path))
    assert sink.close() == sink.close() == str(path)


# --------------------------------------------------------------------------
# serve SLO integration + report CLI
# --------------------------------------------------------------------------

def _serve_run(tmp_path, obs):
    from repro.models.model import build_model
    from repro.models.config import ModelConfig
    from repro.serve import ContinuousServeEngine, Request, ServeConfig

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype=jnp.float32, param_dtype=jnp.float32,
                      max_seq_len=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 256, 4),
                    max_new_tokens=6, arrival=float(i)) for i in range(4)]
    # impossible ttft target (sub-step) -> guaranteed critical miss;
    # loose e2e target -> silent
    scfg = ServeConfig(slo_ttft_p99=0.01, slo_e2e_p99=1e6)
    eng = ContinuousServeEngine(model, mesh, params, cache_len=32,
                                batch_size=2, obs=obs, serve_cfg=scfg)
    return eng.run(reqs)


def test_serve_slo_violation_events_deterministic(tmp_path):
    obs = obs_mod.configure(metrics=True, set_as_default=False)
    res = _serve_run(tmp_path, obs)
    assert res.health, "sub-step ttft SLO must be missed"
    worst = res.health[0]
    assert (worst.severity, worst.rule, worst.subject) == \
        ("critical", "serve_slo", "ttft")
    assert not any(e.subject == "e2e" for e in res.health)
    targets = obs.metrics.events_named("serve/slo_targets")
    assert len(targets) == 1 and targets[0]["ttft"] == 0.01
    # a second identical run produces the identical verdict list
    obs2 = obs_mod.configure(metrics=True, set_as_default=False)
    res2 = _serve_run(tmp_path, obs2)
    assert [(e.severity, e.rule, e.subject) for e in res2.health] == \
        [(e.severity, e.rule, e.subject) for e in res.health]


def test_report_cli_renders_run_artifacts(tmp_path, capsys):
    from repro.obs import report

    obs = obs_mod.configure(trace=True, metrics=True, set_as_default=False,
                            recorder=str(tmp_path / "bb.json"))
    res = _serve_run(tmp_path, obs)
    assert res.tokens > 0
    # bucket telemetry rows so the spectra table has content
    obs.metrics.histogram("bucket/g0b0/nnz").observe_many([8, 9, 10])
    obs.metrics.histogram("bucket/g0b0/mass_coverage").observe_many(
        [0.8, 0.9])
    obs.metrics.histogram("bucket/g0b0/ef_norm").observe_many([1.0, 1.1])
    obs.recorder.note("serve/step", step=1)
    obs.recorder.dump("test")
    out = obs.export(trace_path=str(tmp_path / "t.json"),
                     metrics_path=str(tmp_path / "m.jsonl"))
    rc = report.main([out["metrics"], "--trace", out["trace"],
                      "--blackbox", str(tmp_path / "bb.json")])
    assert rc == 0
    text = capsys.readouterr().out
    assert "per-bucket density/mass spectra" in text
    assert "g0b0" in text
    assert "health timeline" in text and "serve_slo" in text
    assert "serve SLO attainment" in text
    assert "ttft" in text and "NO" in text     # the missed SLO row
    assert "e2e" in text and "yes" in text     # the attained one
    assert "span tree OK" in text
    assert "reason='test'" in text


def test_report_tolerates_truncated_jsonl(tmp_path):
    from repro.obs import report

    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.event("health/ef_growth", severity="warn", subject="b0",
              message="m")
    path = reg.dump_jsonl(str(tmp_path / "m.jsonl"))
    with open(path, "a") as f:
        f.write('{"kind": "event", "event": "torn-mid-wr')   # torn tail
    text = report.render(path)
    assert "ef_growth" in text and "b0" in text
    # header missing entirely -> a clear error, not a traceback
    (tmp_path / "junk.jsonl").write_text('{"kind": "counter", "name": "x"}\n')
    with pytest.raises(ValueError, match="header"):
        report.render(str(tmp_path / "junk.jsonl"))
