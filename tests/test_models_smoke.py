"""Per-architecture smoke tests (deliverable f): REDUCED config of the same
family, one forward + one train step on CPU, asserting shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgreg
from repro.models.model import build_model

ARCHS = [cfgreg.EXTERNAL_NAMES[a] for a in cfgreg.ARCH_IDS]


def _smoke_batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.num_image_tokens, cfg.vision_dim))
    if cfg.family == "encoder":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = cfgreg.smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _smoke_batch(cfg, key)
    logits = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf in logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_one_train_step(arch):
    cfg = cfgreg.smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = _smoke_batch(cfg, key)
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    # a normalized SGD step reduces the loss for SOME small step — the
    # guaranteed-descent property. Backtrack instead of a single fixed
    # 0.1: MoE routers are only piecewise smooth and 0.1 overshoots on
    # moonshot's init (grads verified descending at 0.03 and below).
    descended = False
    for scale in (0.1, 0.03, 0.01):
        step = scale / (float(gnorm) + 1e-9)
        new_params = jax.tree.map(lambda p, g: p - step * g.astype(p.dtype),
                                  params, grads)
        loss2 = model.loss(new_params, batch)
        assert bool(jnp.isfinite(loss2))
        if float(loss2) < float(loss):
            descended = True
            break
    assert descended, f"{arch}: no backtracked descent step reduced loss"


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert-xlarge"])
def test_smoke_prefill_decode(arch):
    cfg = cfgreg.smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    batch = _smoke_batch(cfg, key)
    logits, state = model.prefill(params, batch, cache_len=32)
    assert logits.shape == (2, cfg.padded_vocab)
    logits2, state2 = model.decode_step(params, state, batch["tokens"][:, :1])
    assert logits2.shape == (2, cfg.padded_vocab)
    assert int(state2.pos) == 17
    assert bool(jnp.isfinite(logits2).all())


def test_full_configs_match_assignment():
    """The exact assigned numbers (not smoke-reduced)."""
    expect = {
        "llama-3.2-vision-11b": dict(num_layers=40, d_model=4096, num_heads=32,
                                     num_kv_heads=8, d_ff=14336, vocab_size=128256),
        "mamba2-370m": dict(num_layers=48, d_model=1024, vocab_size=50280,
                            ssm_state=128),
        "minicpm-2b": dict(num_layers=40, d_model=2304, num_heads=36,
                           num_kv_heads=36, d_ff=5760, vocab_size=122753),
        "qwen3-4b": dict(num_layers=36, d_model=2560, num_heads=32,
                         num_kv_heads=8, d_ff=9728, vocab_size=151936,
                         qk_norm=True),
        "llama3-405b": dict(num_layers=126, d_model=16384, num_heads=128,
                            num_kv_heads=8, d_ff=53248, vocab_size=128256),
        "internlm2-20b": dict(num_layers=48, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab_size=92544),
        "dbrx-132b": dict(num_layers=40, d_model=6144, num_heads=48,
                          num_kv_heads=8, vocab_size=100352, num_experts=16,
                          experts_per_token=4, moe_d_ff=10752),
        "moonshot-v1-16b-a3b": dict(num_layers=48, d_model=2048, num_heads=16,
                                    num_kv_heads=16, vocab_size=163840,
                                    num_experts=64, experts_per_token=6,
                                    moe_d_ff=1408),
        "zamba2-2.7b": dict(num_layers=54, d_model=2560, num_heads=32,
                            num_kv_heads=32, d_ff=10240, vocab_size=32000,
                            ssm_state=64, attn_every=6),
        "hubert-xlarge": dict(num_layers=48, d_model=1280, num_heads=16,
                              num_kv_heads=16, d_ff=5120, vocab_size=504),
    }
    for arch, fields in expect.items():
        cfg = cfgreg.get_config(arch)
        for f, v in fields.items():
            assert getattr(cfg, f) == v, f"{arch}.{f}: {getattr(cfg, f)} != {v}"


def test_param_counts_plausible():
    """Analytic param counts within the advertised scale."""
    bounds = {
        "llama3-405b": (3.8e11, 4.3e11),
        "dbrx-132b": (1.2e11, 1.45e11),
        "internlm2-20b": (1.7e10, 2.3e10),
        # NOTE: the assigned numbers (48L x 64e x d_ff=1408) give ~29B total;
        # the "16b" tag matches the real Moonlight's 27 layers, but the
        # assignment's explicit config is authoritative here.
        "moonshot-v1-16b-a3b": (2.5e10, 3.2e10),
        "qwen3-4b": (3e9, 5e9),
        "minicpm-2b": (2e9, 3.3e9),
        "zamba2-2.7b": (2e9, 3.4e9),
        "mamba2-370m": (3e8, 4.8e8),
        "hubert-xlarge": (8e8, 1.3e9),
        "llama-3.2-vision-11b": (8.5e9, 1.2e10),
    }
    for arch, (lo, hi) in bounds.items():
        n = cfgreg.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_applicability_table():
    app = {a: cfgreg.applicable_shapes(a) for a in ARCHS}
    # encoder: no decode shapes
    assert not app["hubert-xlarge"]["decode_32k"][0]
    assert not app["hubert-xlarge"]["long_500k"][0]
    # subquadratic archs run long_500k
    assert app["mamba2-370m"]["long_500k"][0]
    assert app["zamba2-2.7b"]["long_500k"][0]
    # pure attention archs skip long_500k
    for a in ["qwen3-4b", "llama3-405b", "dbrx-132b", "minicpm-2b",
              "internlm2-20b", "moonshot-v1-16b-a3b", "llama-3.2-vision-11b"]:
        assert not app[a]["long_500k"][0]
    # total applicable cells = 31 of 40
    n_ok = sum(ok for by in app.values() for ok, _ in by.values())
    assert n_ok == 31
