"""End-to-end training integration: dense vs sparcml modes, decode
consistency, zero1 vs replicated optimizer equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.train.state import TrainConfig
from repro.train.train_step import build_train_step, init_state


def tiny_cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=256, dtype=jnp.float32,
                param_dtype=jnp.float32, max_seq_len=64)
    base.update(kw)
    return ModelConfig(**base)


def sparse_cfg(**kw):
    """Leaves sized so the batched sparse path engages at dp=4 with
    bucket_size=128 (canonical cols/bucket must divide dp)."""
    base = dict(name="ts", family="dense", num_layers=2, d_model=512,
                num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=512,
                dtype=jnp.float32, param_dtype=jnp.float32, max_seq_len=64)
    base.update(kw)
    return ModelConfig(**base)


def run_steps(mesh, tcfg, n=25, cfg=None):
    model = build_model(cfg or tiny_cfg())
    step_fn, _ = build_train_step(model, tcfg, mesh)
    state, _ = init_state(model, tcfg, mesh)
    dcfg = DataConfig(global_batch=8, seq_len=32, vocab_size=256)
    key = jax.random.PRNGKey(0)
    losses = []
    with mesh:
        for i in range(n):
            batch = jax.tree.map(jnp.asarray, synthetic_batch(dcfg, i))
            state, m = step_fn(state, batch, jax.random.fold_in(key, i))
            losses.append(float(m["loss"]))
    return losses, state


SCHED = ScheduleConfig(peak_lr=3e-3, warmup_steps=5, total_steps=100)


def test_dense_fsdp_training_converges(mesh4x2):
    losses, _ = run_steps(mesh4x2, TrainConfig(
        sync=SyncConfig(mode="dense"), optimizer=OptimizerConfig(),
        schedule=SCHED, microbatches=2, fsdp=True))
    assert losses[-1] < losses[0] - 0.5


# the in-train batched pipeline implements DSAR (the paper's DNN-training
# algorithm); SSAR variants are exercised via the standalone library tests
# (test_allreduce.py). Parametrize over compression strength instead.
@pytest.mark.parametrize("k,qsgd", [
    (16, None),   # 12.5% density
    (16, 8),      # + 8-bit QSGD second phase
    (16, 4),      # + 4-bit (paper default)
    (2, None),    # 1.6% density
])
def test_sparcml_training_converges(mesh4x2, k, qsgd):
    sync = SyncConfig(mode="sparcml", k_per_bucket=k, bucket_size=128,
                      algorithm="dsar_split_allgather", qsgd_bits=qsgd,
                      qsgd_bucket=128, min_sparse_size=65536, impl="ref")
    tcfg = TrainConfig(sync=sync, optimizer=OptimizerConfig(), schedule=SCHED,
                       microbatches=2, zero1=True)
    cfg = sparse_cfg()
    # regression guard: the sparse path must actually engage (leaves too
    # small / indivisible silently fall back to dense — that is NOT what
    # this test is for)
    from repro.models.model import build_model
    from repro.train.train_step import state_shapes
    model = build_model(cfg)
    shapes, _ = state_shapes(model, tcfg, mesh4x2)
    n_sparse = sum(x is not None for x in jax.tree.leaves(
        shapes.residuals, is_leaf=lambda x: x is None))
    assert n_sparse >= 4, "sparse path not engaged for any leaf"
    losses, _ = run_steps(mesh4x2, tcfg, cfg=cfg)
    assert losses[-1] < losses[0] - 0.5, losses


def test_sparcml_matches_dense_closely(mesh4x2):
    """Paper Figs. 4/5: compressed training tracks dense training."""
    sparse, _ = run_steps(mesh4x2, TrainConfig(
        sync=SyncConfig(mode="sparcml", k_per_bucket=16, bucket_size=128,
                        algorithm="dsar_split_allgather",
                        min_sparse_size=65536, impl="ref"),
        optimizer=OptimizerConfig(), schedule=SCHED, microbatches=1),
        cfg=sparse_cfg())
    dense, _ = run_steps(mesh4x2, TrainConfig(
        sync=SyncConfig(mode="dense"), optimizer=OptimizerConfig(),
        schedule=SCHED, microbatches=1), cfg=sparse_cfg())
    # 25 steps is a SHORT horizon: compressed SGD lags transiently while
    # the EF residual warms up (paper Fig. 5 shows the same early-phase
    # divergence closing by convergence; full-convergence parity is
    # asserted in test_convergence.py). 20% bounds the transient.
    assert abs(dense[-1] - sparse[-1]) / dense[-1] < 0.20
    assert sparse[-1] != dense[-1]  # the sparse path actually ran


def test_multipod_training(mesh2x2x2):
    sync = SyncConfig(mode="sparcml", k_per_bucket=64, bucket_size=512,
                      algorithm="dsar_split_allgather", min_sparse_size=4096,
                      impl="ref")
    losses, _ = run_steps(mesh2x2x2, TrainConfig(
        sync=sync, optimizer=OptimizerConfig(), schedule=SCHED, zero1=True))
    assert losses[-1] < losses[0] - 0.4


def test_zero1_equals_replicated_adam(mesh4x2):
    """ZeRO-1 chunked update must be bitwise-equivalent math to the
    replicated AdamW (same grads -> same params)."""
    sync = SyncConfig(mode="sparcml", k_per_bucket=512, bucket_size=512,
                      algorithm="dsar_split_allgather", min_sparse_size=1 << 30,
                      impl="ref")  # k=all + dense path only -> exact mean grads
    t1 = TrainConfig(sync=sync, optimizer=OptimizerConfig(grad_clip=0),
                     schedule=ScheduleConfig(kind="constant", peak_lr=1e-2,
                                             warmup_steps=0),
                     zero1=True)
    t2 = TrainConfig(sync=sync, optimizer=OptimizerConfig(grad_clip=0),
                     schedule=ScheduleConfig(kind="constant", peak_lr=1e-2,
                                             warmup_steps=0),
                     zero1=False)
    l1, s1 = run_steps(mesh4x2, t1, n=5)
    l2, s2 = run_steps(mesh4x2, t2, n=5)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_schedules():
    from repro.optim.schedule import make_schedule
    for kind in ["cosine", "linear", "wsd", "constant"]:
        sched = make_schedule(ScheduleConfig(kind=kind, peak_lr=1.0,
                                             warmup_steps=10, total_steps=100))
        assert float(sched(0)) == 0.0 or kind == "constant" or float(sched(0)) <= 0.11
        assert abs(float(sched(10)) - 1.0) < 1e-6
        assert float(sched(99)) <= 1.0
    wsd = make_schedule(ScheduleConfig(kind="wsd", peak_lr=1.0, warmup_steps=10,
                                       total_steps=100, wsd_decay_frac=0.2))
    assert abs(float(wsd(79)) - 1.0) < 1e-6   # stable phase
    assert float(wsd(99)) < 0.3               # decay phase
