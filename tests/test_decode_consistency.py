"""Serving correctness: prefill+decode logits == teacher-forced forward,
including the sliding-window ring cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.model import build_model


def tiny(family, **kw):
    base = dict(name="t", family=family, num_layers=4, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=256, dtype=jnp.float32,
                param_dtype=jnp.float32, max_seq_len=64, ssm_chunk=4,
                remat=False)
    base.update(kw)
    return ModelConfig(**base)


CASES = {
    "dense": tiny("dense", qk_norm=True),
    "moe": tiny("moe", num_experts=4, experts_per_token=2, moe_d_ff=64,
                capacity_factor=4.0),
    "ssm": tiny("ssm", ssm_state=16, ssm_head_dim=16),
    "hybrid": tiny("hybrid", ssm_state=16, ssm_head_dim=16, attn_every=2),
    "vlm": tiny("vlm", cross_attn_every=2, num_image_tokens=8, vision_dim=48),
}


@pytest.mark.parametrize("fam", list(CASES))
def test_decode_matches_forward(fam):
    cfg = CASES[fam]
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    p = model.init(key)
    B, S = 2, 12
    batch = {"tokens": jax.random.randint(key, (B, S), 0, 256)}
    if fam == "vlm":
        batch["image_embeds"] = jax.random.normal(key, (B, 8, 48))
    full = model.forward(p, batch)
    pre = {k: (v[:, :8] if k == "tokens" else v) for k, v in batch.items()}
    lg, st = model.prefill(p, pre, cache_len=16)
    errs = [float(jnp.abs(lg - full[:, 7]).max())]
    for t in range(8, S):
        lg, st = model.decode_step(p, st, batch["tokens"][:, t:t + 1])
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 1e-4, errs


def test_sliding_window_ring_cache():
    """Windowed decode == full-cache decode restricted to the window."""
    cfg_w = tiny("dense", sliding_window=6)
    model = build_model(cfg_w)
    key = jax.random.PRNGKey(5)
    p = model.init(key)
    B, S = 2, 14
    toks = jax.random.randint(key, (B, S), 0, 256)
    full = model.forward(p, {"tokens": toks})  # windowed mask applied
    lg, st = model.prefill(p, {"tokens": toks[:, :4]}, cache_len=32)
    errs = [float(jnp.abs(lg - full[:, 3]).max())]
    for t in range(4, S):
        lg, st = model.decode_step(p, st, toks[:, t:t + 1])
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 1e-4, errs
    # ring cache stays at window width
    assert st.kv.k.shape[2] == 6


def test_serve_engine_generates(mesh4x2):
    from repro.serve.engine import ServeEngine
    cfg = CASES["dense"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, mesh4x2, params, cache_len=64)
    prompts = np.random.default_rng(0).integers(0, 256, (4, 8)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=5)
    assert out.shape == (4, 5)
    assert out.dtype == np.int32
    # greedy decode is deterministic
    out2 = eng.generate(prompts, max_new_tokens=5)
    np.testing.assert_array_equal(out, out2)
