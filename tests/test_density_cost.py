"""Paper analytics: expected fill-in (App. B) and alpha-beta bounds (§5.3)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import density, cost_model
from repro.core.sparse_stream import delta_threshold


@settings(max_examples=30, deadline=None)
@given(n=st.sampled_from([512, 4096]), k=st.integers(1, 128),
       p=st.sampled_from([2, 8, 64, 1024]))
def test_closed_form_matches_inclusion_exclusion(n, k, p):
    k = min(k, n)
    a = density.expected_nnz(k, n, p)
    b = density.expected_nnz_inclusion_exclusion(k, n, min(p, 128))
    if p <= 128:
        np.testing.assert_allclose(a, b, rtol=1e-6)
    assert 0 <= a <= n + 1e-9
    assert a <= k * p + 1e-9  # union bound


def test_monte_carlo_agrees():
    k, n, p = 16, 512, 8
    mc = density.monte_carlo_nnz(k, n, p, trials=64)
    cf = density.expected_nnz(k, n, p)
    assert abs(mc - cf) / cf < 0.05


def test_fig1_density_growth_monotone():
    """Fig. 1: reduced density grows with node count, saturates at 1."""
    dens = [density.reduced_density(int(0.05 * 4096), 4096, p)
            for p in [1, 2, 4, 8, 16, 32, 64, 128]]
    assert all(np.diff(dens) >= -1e-12)
    assert dens[-1] > 0.9  # 5% per node goes dense at large P (paper's point)


def test_fig7_fill_in_factor():
    # E[K]/k at N=512 as in Fig. 7: bounded by min(P, N/k)
    for p in [2, 8, 32]:
        f = density.fill_in_factor(8, 512, p)
        assert 1 <= f <= min(p, 512 / 8) + 1e-9


# -- alpha-beta cost model ---------------------------------------------------

def test_bound_orderings():
    p, k, n = 64, 1024, 1 << 20
    lo, exp, hi = cost_model.t_ssar_recursive_double(p, k, n)
    assert lo <= exp <= hi
    lo2, exp2, hi2 = cost_model.t_ssar_split_allgather(p, k, n)
    assert lo2 <= exp2 <= hi2
    dlo, dhi = cost_model.t_dsar_split_allgather(p, k, n)
    assert dlo <= dhi


def test_recursive_double_wins_small_data():
    """§5.3.1: latency-dominated regime favors recursive doubling."""
    p, n = 64, 1 << 22
    k = 64  # tiny payload
    assert cost_model.select_algorithm(p, k, n) == "ssar_recursive_double"


def test_dense_or_dsar_wins_when_fill_in_dense():
    """§5.3.3: when E[K] >= delta, a fill-tracking sparse
    end-representation can't win. Among the CLASSIC algorithms that
    leaves DSAR/dense; the capacity-clamped portfolio (DESIGN.md §9) is
    exempt — its output bound can stay under delta."""
    p, n = 1024, 1 << 20
    k = n // 8  # heavy per-node density -> dense result
    legacy = ("ssar_recursive_double", "ssar_split_allgather",
              "dsar_split_allgather", "dense")
    choice = cost_model.select_algorithm(p, k, n, allow=legacy)
    assert choice in ("dsar_split_allgather", "dense")
    # unrestricted, the switchover may land on a clamped portfolio
    # algorithm instead — but never on an UNCAPPED sparse representation
    full = cost_model.select_algorithm(p, k, n)
    cap = cost_model.algorithm_output_cap(full, p, k, n)
    delta = delta_threshold(n)
    assert (full in ("dsar_split_allgather", "dense")
            or (cap is not None and cap < delta))


def test_lemma52_speedup_cap():
    """Lemma 5.2: sparse speedup capped at 2/kappa once result is dense."""
    n = 1 << 20
    cap = cost_model.dsar_speedup_cap(n, isize=4)
    kappa = delta_threshold(n, 4) / n  # = 0.5 for fp32
    assert abs(cap - 2 / kappa) < 1e-9
    assert abs(cap - 4.0) < 1e-9  # paper: kappa=0.5 -> max 4x


def test_quantized_dsar_cheaper_than_fp32_dsar():
    """§6: 4-bit second phase cuts the DSAR bandwidth term."""
    p, k, n = 64, 4096, 1 << 20
    _, hi32 = cost_model.t_dsar_split_allgather(p, k, n, value_bits=32)
    _, hi4 = cost_model.t_dsar_split_allgather(p, k, n, value_bits=4)
    assert hi4 < hi32


def test_dense_rabenseifner_formula():
    p, n = 16, 1 << 20
    net = cost_model.DEFAULT_NET
    t = cost_model.t_dense_allreduce(p, n, net)
    expect = 2 * 4 * net.alpha + 2 * 15 / 16 * n * net.beta_d
    np.testing.assert_allclose(t, expect, rtol=1e-12)
