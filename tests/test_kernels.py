"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
with hypothesis sweeps over shapes/dtypes/k."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.bucket_topk.ops import bucket_topk
from repro.kernels.bucket_scatter.ops import bucket_scatter
from repro.kernels.qsgd_pack.ops import qsgd_pack
from repro.kernels.qsgd_unpack.ops import qsgd_unpack
from repro.kernels.qsgd_pack.ref import levels


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# --------------------------------------------------------------------------
# bucket_topk
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    nb=st.sampled_from([1, 3, 16]),
    b=st.sampled_from([128, 256, 512]),
    k=st.sampled_from([1, 4, 8]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 2**16),
)
def test_bucket_topk_matches_ref(nb, b, k, dtype, seed):
    x = _rand(jax.random.PRNGKey(seed), (nb, b), jnp.dtype(dtype))
    v1, i1, r1 = bucket_topk(x, k, impl="ref")
    v2, i2, r2 = bucket_topk(x, k, impl="pallas")
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1, np.float32),
                               np.asarray(v2, np.float32), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(r1, np.float32),
                               np.asarray(r2, np.float32), rtol=1e-5)


def test_bucket_topk_selects_largest_and_residual_is_complement():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 512))
    k = 16
    val, lidx, res = bucket_topk(x, k, impl="pallas")
    # selected entries zeroed in residual, untouched elsewhere
    sel = np.zeros((8, 512), bool)
    np.put_along_axis(sel, np.asarray(lidx), True, axis=1)
    xr = np.asarray(x)
    assert np.all(np.asarray(res)[sel] == 0)
    np.testing.assert_array_equal(np.asarray(res)[~sel], xr[~sel])
    # top-k by magnitude: min selected |v| >= max unselected |v| per bucket
    mag_sel = np.abs(np.take_along_axis(xr, np.asarray(lidx), axis=1)).min(1)
    mag_uns = np.where(sel, 0, np.abs(xr)).max(1)
    assert np.all(mag_sel >= mag_uns - 1e-7)
    # reconstruction: residual + densified selection == x
    dense = bucket_scatter(lidx, val, 512, impl="ref")
    np.testing.assert_allclose(np.asarray(dense) + np.asarray(res), xr, rtol=1e-6)


def test_bucket_topk_indices_sorted():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 256))
    _, lidx, _ = bucket_topk(x, 8, impl="pallas")
    li = np.asarray(lidx)
    assert np.all(np.diff(li, axis=1) > 0)


# --------------------------------------------------------------------------
# qsgd pack/unpack
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    nb=st.sampled_from([1, 4, 16]),
    bq=st.sampled_from([128, 512, 1024]),
    bits=st.sampled_from([2, 4, 8]),
    scale_mode=st.sampled_from(["l2", "max"]),
    seed=st.integers(0, 2**16),
)
def test_qsgd_pack_matches_ref(nb, bq, bits, scale_mode, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (nb, bq))
    rand = jax.random.bits(key, (nb, bq), dtype=jnp.uint32)
    p1, s1 = qsgd_pack(x, rand, bits, scale_mode, impl="ref")
    p2, s2 = qsgd_pack(x, rand, bits, scale_mode, impl="pallas")
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    u1 = qsgd_unpack(p1, s1, bits, impl="ref")
    u2 = qsgd_unpack(p1, s1, bits, impl="pallas")
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u2), rtol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_qsgd_error_bounded_by_scale_over_levels(bits):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (32, 1024))
    rand = jax.random.bits(key, (32, 1024), dtype=jnp.uint32)
    p, s = qsgd_pack(x, rand, bits, "l2", impl="ref")
    xh = qsgd_unpack(p, s, bits, impl="ref")
    err = np.abs(np.asarray(xh) - np.asarray(x))
    bound = np.asarray(s) / levels(bits) + 1e-6
    assert np.all(err <= bound)


def test_qsgd_unbiased():
    """E[Q(x)] == x across stochastic-rounding draws (QSGD property)."""
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, 512))
    acc = np.zeros((1, 512))
    n = 400
    for i in range(n):
        rand = jax.random.bits(jax.random.fold_in(key, i), (1, 512), dtype=jnp.uint32)
        p, s = qsgd_pack(x, rand, 4, "l2", impl="ref")
        acc += np.asarray(qsgd_unpack(p, s, 4, impl="ref"))
    mean = acc / n
    scale = float(np.asarray(s)[0, 0])
    # std of the mean ~ scale/levels/sqrt(n)
    tol = 4 * scale / levels(4) / np.sqrt(n)
    np.testing.assert_allclose(mean, np.asarray(x), atol=tol)


def test_qsgd_zero_bucket():
    x = jnp.zeros((2, 512))
    rand = jnp.zeros((2, 512), jnp.uint32)
    p, s = qsgd_pack(x, rand, 4, impl="ref")
    xh = qsgd_unpack(p, s, 4, impl="ref")
    assert float(jnp.abs(xh).max()) == 0.0


# --------------------------------------------------------------------------
# bucket_scatter
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    nb=st.sampled_from([1, 8]),
    b=st.sampled_from([128, 512]),
    k=st.sampled_from([1, 8, 32]),
    dups=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_bucket_scatter_matches_ref(nb, b, k, dups, seed):
    key = jax.random.PRNGKey(seed)
    hi = b // 2 if dups else b  # force duplicates half the time
    lidx = jax.random.randint(key, (nb, k), 0, hi, dtype=jnp.int32)
    val = jax.random.normal(key, (nb, k))
    d1 = bucket_scatter(lidx, val, b, impl="ref")
    d2 = bucket_scatter(lidx, val, b, impl="pallas")
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6, atol=1e-6)


def test_bucket_scatter_drops_oob_sentinel():
    lidx = jnp.array([[0, 5, 1000]], jnp.int32)  # 1000 >= B: sentinel
    val = jnp.array([[1.0, 2.0, 3.0]])
    d = bucket_scatter(lidx, val, 16, impl="pallas")
    assert float(d[0, 0]) == 1.0 and float(d[0, 5]) == 2.0
    assert float(jnp.abs(d).sum()) == 3.0  # the 3.0 was dropped
