"""Adaptive re-planning (DESIGN.md §7): telemetry correctness, replan
layout-invariance, controller hysteresis/patience/delta rules, the
driver's swap-at-drain-barrier protocol, pod-sparse exchange parity,
checkpoint plan-signature round-trip, and the calibrator fit."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.compat import make_mesh, shard_map
from repro.core import cost_model as cm
from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.runtime import adapt as rt_adapt
from repro.runtime import driver as rt_driver
from repro.runtime import pipeline as rt_pipeline
from repro.train.state import TrainConfig

from test_comm_plan import _count_prims

MODEL_CFG = ModelConfig(name="ad", family="dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                        dtype=jnp.float32, param_dtype=jnp.float32,
                        max_seq_len=64)
SYNC = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                  algorithm="dsar_split_allgather", min_sparse_size=1024,
                  impl="ref", fusion_bucket_bytes=1 << 18)
TCFG = TrainConfig(sync=SYNC, optimizer=OptimizerConfig(),
                   schedule=ScheduleConfig(peak_lr=3e-3, warmup_steps=5,
                                           total_steps=100),
                   zero1=True)
DCFG = DataConfig(global_batch=8, seq_len=32, vocab_size=256)
KEY = jax.random.PRNGKey(0)
NO_CAL = rt_adapt.AdaptConfig(calibrate=False)


def _toy_plan(dp=8, algorithm="dsar_split_allgather", n=3000):
    cfg = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                     algorithm=algorithm, min_sparse_size=1024, impl="ref",
                     fusion_bucket_bytes=1 << 14)
    shapes = {"a": jax.ShapeDtypeStruct((n,), jnp.float32),
              "b": jax.ShapeDtypeStruct((77,), jnp.float32)}
    specs = {"a": P(), "b": P()}
    return cfg, comm.build_sync_plan(shapes, specs, cfg, dp)


# --------------------------------------------------------------------------
# replan: versioning, signatures, layout invariance
# --------------------------------------------------------------------------

def test_replan_layout_invariant_and_versioned():
    _, plan = _toy_plan()
    assert plan.version == 0
    sparse_names = [b.name for b in plan.buckets if b.sparse]
    assert sparse_names
    # demote every sparse bucket's wire representation to dense
    demoted = plan.replan(algorithms={n: "dense" for n in sparse_names})
    assert demoted.version == 1
    assert demoted.signature() != plan.signature()
    # ...but the residual layout (and thus TrainState) is untouched
    assert set(demoted.residual_shapes()) == set(plan.residual_shapes())
    assert demoted.num_sparse_buckets == 0
    for b in demoted.buckets:
        if b.name in sparse_names:
            assert b.has_residual and not b.sparse
    # inflight layout is bucket-universal and identical too
    assert set(demoted.inflight_shapes()) == set(plan.inflight_shapes())
    # a second replan can promote them back
    back = demoted.replan(algorithms={n: "ssar_recursive_double"
                                      for n in sparse_names})
    assert back.version == 2
    assert [b.algorithm for b in back.buckets if b.name in sparse_names] == \
        ["ssar_recursive_double"] * len(sparse_names)


def test_replan_raw_dense_buckets_never_promote():
    # min_sparse_size above the tail bucket's n -> a genuine raw-dense
    # bucket with no EF state
    cfg = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                     algorithm="dsar_split_allgather", min_sparse_size=2048,
                     impl="ref", fusion_bucket_bytes=1 << 14)
    shapes = {"a": jax.ShapeDtypeStruct((4096,), jnp.float32),
              "b": jax.ShapeDtypeStruct((512,), jnp.float32)}
    plan = comm.build_sync_plan(shapes, {"a": P(), "b": P()}, cfg, 8)
    raw = [b.name for b in plan.buckets if not b.has_residual]
    assert raw, plan.describe()
    promoted = plan.replan(algorithms={n: "ssar_recursive_double"
                                       for n in raw})
    for b in promoted.buckets:
        if b.name in raw:
            assert b.algorithm == "dense" and not b.has_residual


def test_replan_measured_density_follows_delta():
    """Measured fill-in over delta forces the dense end-representation;
    far under delta the sparse representations come back."""
    from repro.core.sparse_stream import delta_threshold

    _, plan = _toy_plan(algorithm="ssar_split_allgather")
    b = next(b for b in plan.buckets if b.sparse)
    k = next(plan.bucket_k(g, bb) for g in plan.groups for bb in g.buckets
             if bb.name == b.name)
    dense_plan = plan.replan({b.name: float(delta_threshold(b.n))})
    algo = dict(dense_plan.algorithms())[b.name]
    # past delta only dense-width or capacity-clamped (DESIGN.md §9)
    # representations remain; an uncapped SSAR must be gone
    cap = cm.algorithm_output_cap(algo, 8, k, b.n)
    assert (algo in ("dsar_split_allgather", "dense")
            or (cap is not None and cap < delta_threshold(b.n)))
    sparse_plan = plan.replan({b.name: 8.0})
    assert dict(sparse_plan.algorithms())[b.name].startswith("ssar")


# --------------------------------------------------------------------------
# telemetry: in-graph nnz is the true post-reduction count
# --------------------------------------------------------------------------

def test_spmd_telemetry_counts_true_union():
    cfg, plan = _toy_plan(n=4096)
    sparse_b = [b for b in plan.buckets if b.sparse]
    assert sparse_b
    rng = np.random.default_rng(0)
    # disjoint hot slots per rank -> union is exactly 8 * k_per_bucket
    # per TopK bucket of the covered range
    grads = []
    for name, n in (("a", 4096), ("b", 77)):
        g = rng.standard_normal((8, n)).astype(np.float32) * 0.01
        grads.append(g)
    a = grads[0]
    starts = np.arange(4096 // cfg.bucket_size)[:, None] * cfg.bucket_size
    for r in range(8):
        cols = (starts + r * cfg.k_per_bucket
                + np.arange(cfg.k_per_bucket)[None, :]).reshape(-1)
        a[r, cols] += 10.0
    leaves = [jnp.asarray(g) for g in grads]
    res = plan.init_residuals()
    _, _, telem = comm.reduce_buckets_spmd(plan, leaves, res, KEY, p_data=8)
    # telemetry covers exactly the EF (re-plannable) buckets
    assert set(telem) == {b.name for b in plan.buckets if b.has_residual}
    # every bucket reports [nnz, wire]; check the covered 'a' range
    total_sparse_nnz = sum(float(np.asarray(telem[b.name])[0])
                           for b in sparse_b)
    expect = 4096 // cfg.bucket_size * cfg.k_per_bucket * 8
    # padding tail of 'b' rides the same group; allow its contribution
    assert expect <= total_sparse_nnz <= expect + 77
    for b in plan.buckets:
        assert float(np.asarray(telem[b.name])[1]) > 0  # wire bytes


# --------------------------------------------------------------------------
# controller: hysteresis, patience, flap damping
# --------------------------------------------------------------------------

def _controller(plan, **kw):
    defaults = dict(window=2, hysteresis=0.2, patience=2, calibrate=False)
    defaults.update(kw)
    return rt_adapt.AdaptiveController(plan, cm.DEFAULT_NET,
                                       rt_adapt.AdaptConfig(**defaults))


def test_controller_patience_and_swap():
    _, plan = _toy_plan(n=1 << 15)
    ctrl = _controller(plan)
    b = next(b for b in plan.buckets if b.sparse)
    low = {b.name: 16.0}     # tiny measured fill: latency-bound -> SSAR rd
    # window=2, patience=2: three windows before the plan may swap
    assert ctrl.observe_step(low) is None
    assert ctrl.observe_step(low) is None      # window 1 full: pending
    assert ctrl.observe_step(low) is None
    accepted = ctrl.observe_step(low)          # window 2 agrees: accept
    assert accepted is not None and ctrl.swaps == 1
    assert dict(accepted.algorithms())[b.name] == "ssar_recursive_double"
    assert accepted.version == 1   # one accepted swap = one version step
    # steady telemetry at the new optimum: no further swaps
    for _ in range(6):
        assert ctrl.observe_step(low) is None
    assert ctrl.swaps == 1


def test_controller_hysteresis_blocks_marginal_wins():
    """A proposed switch whose modeled win is under the hysteresis
    threshold is vetoed (no flapping on near-ties)."""
    _, plan = _toy_plan(n=1 << 15)
    ctrl = _controller(plan, hysteresis=0.99, patience=1)
    b = next(b for b in plan.buckets if b.sparse)
    low = {b.name: 16.0}
    for _ in range(8):
        assert ctrl.observe_step(low) is None  # 99% win required: vetoed
    assert ctrl.swaps == 0


def test_controller_delta_forced_switch_bypasses_hysteresis():
    from repro.core.sparse_stream import delta_threshold

    _, plan = _toy_plan(n=1 << 15, algorithm="ssar_split_allgather")
    ctrl = _controller(plan, hysteresis=0.99, patience=1)
    b = next(b for b in plan.buckets if b.sparse)
    k = next(plan.bucket_k(g, bb) for g in plan.groups for bb in g.buckets
             if bb.name == b.name)
    over = {b.name: float(delta_threshold(b.n) + 1)}
    accepted = None
    for _ in range(4):
        accepted = ctrl.observe_step(over) or accepted
    assert accepted is not None, "delta switchover must not be vetoed"
    # the forced switch lands on a representation that cannot densify:
    # dense/DSAR or a capacity-clamped portfolio algorithm — never an
    # uncapped SSAR
    algo = dict(accepted.algorithms())[b.name]
    cap = cm.algorithm_output_cap(algo, 8, k, b.n)
    assert (not algo.startswith("ssar")
            or (cap is not None and cap < delta_threshold(b.n)))


# --------------------------------------------------------------------------
# driver swap protocol + collective counts after a swap
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh8x1():
    return make_mesh((8, 1), ("data", "model"))


@pytest.fixture(scope="module")
def model():
    return build_model(MODEL_CFG)


def test_driver_swaps_plan_at_drain_barrier(mesh8x1, model):
    """A forced replan mid-run: the driver drains, swaps the compiled
    superstep, training continues, numerics stay valid (loss finite,
    step count exact) and the swap is logged."""
    from repro.train import train_step as ts
    from repro.train.train_step import init_state

    with mesh8x1:
        _, _, base_plan = ts.state_shapes(model, TCFG, mesh8x1,
                                          return_plan=True)
        runtime = rt_adapt.AdaptiveRuntime(
            model, TCFG, mesh8x1, plan=base_plan, cfg=NO_CAL,
            staleness=1, superstep=2)
        sparse_names = [b.name for b in base_plan.buckets if b.sparse]
        new_plan = base_plan.replan(
            algorithms={n: "ssar_recursive_double" for n in sparse_names})
        runtime._swap_to = new_plan          # force: swap on next check
        state, _ = init_state(model, TCFG, mesh8x1)
        state = rt_pipeline.attach_inflight(state, base_plan, mesh8x1)
        state, log = rt_driver.run_pipelined(
            runtime.current_fn(), state, start_step=0, num_steps=8,
            batch_fn=lambda s: synthetic_batch(DCFG, s),
            key_fn=lambda s: jax.random.fold_in(KEY, s),
            cfg=rt_driver.DriverConfig(depth=2, prefetch=2,
                                       steps_per_unit=2),
            adapt=runtime)
    assert len(log.plan_swaps) == 1
    assert log.plan_swaps[0][1] == new_plan.signature()
    assert int(state.step) == 8 and len(log.losses) == 8
    assert all(np.isfinite(log.losses))
    # the swapped-in fn came from the signature-keyed cache
    assert new_plan.signature() in runtime._cache


def test_collective_count_stays_bucket_bounded_after_swap(mesh8x1, model):
    """Per-step collective count stays O(num_buckets) under a replanned
    mixed-algorithm plan (the acceptance bound: <= buckets * (2 log2 P
    + 4) data-axis collectives; DSAR buckets keep exactly one a2a)."""
    from repro.train import train_step as ts

    with mesh8x1:
        _, _, base_plan = ts.state_shapes(model, TCFG, mesh8x1,
                                          return_plan=True)
        # flat sparse buckets swap to recursive doubling, batched (rows>1)
        # buckets stay DSAR — a genuinely mixed post-swap plan
        algos = {b.name: ("ssar_recursive_double" if g.rows == 1
                          else "dsar_split_allgather")
                 for g in base_plan.groups for b in g.buckets if b.sparse}
        assert len(algos) >= 2
        assert any(a == "ssar_recursive_double" for a in algos.values())
        swapped = base_plan.replan(algorithms=algos)
        assert "ssar_recursive_double" in swapped.algorithms().values()
        fn, (shapes, _), plan = rt_pipeline.build_pipelined_step(
            model, TCFG, mesh8x1, staleness=1, lowering="manual",
            plan=swapped)
        b = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        jaxpr = jax.make_jaxpr(fn)(shapes, b, key).jaxpr
    # count from the RESOLVED plan: replan forces batched (rows>1)
    # buckets back to DSAR whatever the override asked for
    n_dsar = sum(1 for bk in plan.buckets
                 if bk.algorithm == "dsar_split_allgather")
    assert _count_prims(jaxpr, {"all_to_all"}) == n_dsar
    total = _count_prims(jaxpr, {"all_to_all", "all_gather", "ppermute"})
    p = 8
    assert total <= plan.num_buckets * (2 * math.log2(p) + 4)
    n_leaves = len(jax.tree.leaves(shapes.params))
    assert plan.num_buckets < n_leaves


def test_adaptive_trainer_converges_like_static(tmp_path, mesh8x1, model):
    """Acceptance: the adaptive run's losses match the static pipelined
    run (allclose-or-better final loss). Without QSGD every wire
    representation reduces to the same values, so even a mid-run swap
    cannot perturb the trajectory."""
    from repro.train.trainer import Trainer

    n = 12
    tr_s = Trainer(model, TCFG, mesh8x1, DCFG)
    log_s = tr_s.run_pipelined(n, staleness=1, superstep=2)
    tr_a = Trainer(model, TCFG, mesh8x1, DCFG)
    log_a = tr_a.run_pipelined(
        n, staleness=1, superstep=2,
        adapt=rt_adapt.AdaptConfig(window=3, patience=1, calibrate=False))
    assert len(log_a.losses) == n == len(log_s.losses)
    assert (np.allclose(log_a.losses, log_s.losses, rtol=2e-4, atol=1e-5)
            or log_a.losses[-1] <= log_s.losses[-1] + 1e-5)


# --------------------------------------------------------------------------
# checkpoint: plan signature round-trip; resume onto the adapted plan
# --------------------------------------------------------------------------

def test_checkpoint_resumes_adapted_plan(tmp_path, mesh8x1, model):
    from repro.train import checkpoint as ckpt
    from repro.train import train_step as ts
    from repro.train.trainer import Trainer

    ckpt_dir = str(tmp_path / "ck")
    tr = Trainer(model, TCFG, mesh8x1, DCFG, ckpt_dir=ckpt_dir,
                 ckpt_every=4)
    tr.run_pipelined(4, staleness=1, superstep=2, adapt=NO_CAL)
    with mesh8x1:
        _, _, base_plan = ts.state_shapes(model, TCFG, mesh8x1,
                                          return_plan=True)
    sparse_names = [b.name for b in base_plan.buckets if b.sparse]
    adapted = base_plan.replan(
        algorithms={n: "ssar_recursive_double" for n in sparse_names})
    # simulate a mid-adaptation checkpoint: same arrays, adapted meta
    ckpt.save(ckpt_dir, tr.state._replace(inflight=None), dp_total=8,
              extra_meta={"plan_signature": adapted.signature(),
                          "plan_version": adapted.version,
                          "plan_algorithms": adapted.algorithms(),
                          "plan_pod_sparse": adapted.pod_sparse_flags()})
    meta = ckpt.load_meta(ckpt_dir)
    assert meta["plan_signature"] == adapted.signature()

    tr2 = Trainer(model, TCFG, mesh8x1, DCFG, ckpt_dir=ckpt_dir,
                  ckpt_every=4)
    log2 = tr2.run_pipelined(
        8, staleness=1, superstep=2,
        adapt=rt_adapt.AdaptConfig(window=64, calibrate=False))
    # the run RESUMED on the adapted plan (no swap needed: window=64
    # guarantees the controller stayed silent)
    assert tr2.last_adapt_runtime is not None
    assert (tr2.last_adapt_runtime.current_plan.signature()
            == adapted.signature())
    assert log2.plan_swaps == []
    assert int(tr2.state.step) == 8
    # and the follow-up checkpoint still carries the adapted signature
    assert ckpt.load_meta(ckpt_dir)["plan_signature"] == adapted.signature()


# --------------------------------------------------------------------------
# pod_sparse exchange: exactness under a real pod axis
# --------------------------------------------------------------------------

def test_pod_sparse_exchange_matches_dense_psum():
    mesh = make_mesh((2, 4), ("pod", "data"))
    cfg, plan = _toy_plan(dp=8, n=4096)
    sparse_names = [b.name for b in plan.buckets if b.sparse]
    ps_plan = plan.replan(algorithms=plan.algorithms(),
                          pod_sparse={n: True for n in sparse_names})
    assert any(b.pod_sparse for b in ps_plan.buckets)
    rng = np.random.default_rng(3)
    grads_r = {"a": jnp.asarray(rng.standard_normal((8, 4096)).astype(np.float32)),
               "b": jnp.asarray(rng.standard_normal((8, 77)).astype(np.float32))}
    res = plan.init_residuals()
    rspecs = {k: P(("pod", "data"), None, None) for k in res}

    def run(p):
        def inner(gr, r):
            g = jax.tree.map(lambda x: x[0], gr)
            leaves, tree = jax.tree.flatten(g)
            out, _ = comm.execute_plan(
                plan=p, leaves=leaves, residuals=r, key=KEY,
                data_axis="data", p_data=4, pod_axis="pod", p_pod=2)
            return tree.unflatten(out)

        f = shard_map(inner, mesh=mesh,
                      in_specs=({k: P(("pod", "data"), None)
                                 for k in grads_r}, rspecs),
                      out_specs={k: P() for k in grads_r},
                      check_vma=False)
        return f(grads_r, res)

    base_out = run(plan)
    ps_out = run(ps_plan)
    for k in grads_r:
        np.testing.assert_allclose(np.asarray(base_out[k]),
                                   np.asarray(ps_out[k]),
                                   rtol=1e-6, atol=1e-7)


# --------------------------------------------------------------------------
# calibrator
# --------------------------------------------------------------------------

def test_calibrator_fit_recovers_known_params():
    from repro.utils.calibrate import fit_network_params

    true = cm.NetworkParams(alpha=2e-6, link_bytes_per_s=10e9)
    p = 8
    sizes = [1 << 12, 1 << 15, 1 << 18, 1 << 20]
    times = [2 * math.log2(p) * true.alpha
             + 2 * (p - 1) / p * s / true.link_bytes_per_s for s in sizes]
    fit = fit_network_params(sizes, times, p=p)
    np.testing.assert_allclose(fit.alpha, true.alpha, rtol=1e-6)
    np.testing.assert_allclose(fit.link_bytes_per_s,
                               true.link_bytes_per_s, rtol=1e-6)


def test_calibrator_rejects_degenerate_fit():
    from repro.utils.calibrate import fit_network_params

    # decreasing times with size: negative bandwidth -> fall back
    fit = fit_network_params([1e3, 1e6], [1e-3, 1e-6], p=8)
    assert fit is cm.DEFAULT_NET


def test_calibrate_measures_on_mesh(mesh8x1):
    from repro.utils.calibrate import calibrate

    net = calibrate(mesh8x1, sizes=(1 << 10, 1 << 14), repeats=1)
    assert net.alpha > 0 and net.link_bytes_per_s > 0
