"""Fusion-bucket plan layer: pack/unpack round-trips (property tests over
leaf mixes incl. model-sharded leaves and padded tails), plan invariants,
manual/emulated/auto-SPMD executor parity, and the headline scaling claim:
the number of data-axis collectives per step is O(num_buckets), NOT
O(num_leaves) — asserted by counting collectives in the jaxpr."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.compat import shard_map
from repro.core import topk as topk_mod
from repro.core.compressor import SyncConfig


def _leaf_mix(seed, n_leaves, model_frac=0.3):
    """A reproducible mixed tree: flat leaves of odd sizes (padded tails)
    plus model-sharded 2-D leaves."""
    rng = np.random.default_rng(seed)
    shapes, specs = {}, {}
    for i in range(n_leaves):
        if rng.random() < model_frac:
            rows = int(rng.choice([8, 16]))
            cols = int(rng.integers(1, 40)) * 16
            shapes[f"w{i}"] = jax.ShapeDtypeStruct((cols, rows), jnp.float32)
            specs[f"w{i}"] = P(None, "model")
        else:
            n = int(rng.integers(3, 2000))        # deliberately ragged
            shapes[f"b{i}"] = jax.ShapeDtypeStruct((n,), jnp.float32)
            specs[f"b{i}"] = P()
    return shapes, specs


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), n_leaves=st.integers(2, 12),
       dp=st.sampled_from([2, 4]), bucket=st.sampled_from([64, 128]))
def test_pack_unpack_roundtrip(seed, n_leaves, dp, bucket):
    cfg = SyncConfig(mode="sparcml", bucket_size=bucket, min_sparse_size=1,
                     fusion_bucket_bytes=1 << 14)
    shapes, specs = _leaf_mix(seed, n_leaves)
    plan = comm.build_sync_plan(shapes, specs, cfg, dp)
    rng = np.random.default_rng(seed + 1)
    tree = {k: jnp.asarray(rng.standard_normal(s.shape).astype(np.float32))
            for k, s in shapes.items()}
    leaves = jax.tree.leaves(tree)
    # every leaf is covered exactly once (small leaves are fused, not
    # dropped to a side path)
    assert plan.covered_leaf_ids() == set(range(len(leaves)))
    for g in plan.groups:
        buf = comm.pack_group(g, leaves, cfg.bucket_size)
        assert buf.shape == (g.rows, g.cols)
        # bucket boundaries tile the group exactly, quantum-aligned
        q = comm.plan._col_quantum(cfg, dp)
        assert sum(b.cols for b in g.buckets) == g.cols
        assert all(b.cols % q == 0 for b in g.buckets)
        for leaf_id, back in comm.unpack_group(g, buf, leaves):
            np.testing.assert_array_equal(np.asarray(back),
                                          np.asarray(leaves[leaf_id]))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_bucket_count_matches_ceil_bound(seed):
    """<= ceil(total_canonical_bytes / fusion_bucket_bytes) + one partial
    bucket per group (flat leaves share ONE group, so the flat bucket
    count meets the ceil bound exactly)."""
    cfg = SyncConfig(mode="sparcml", bucket_size=512, min_sparse_size=1,
                     fusion_bucket_bytes=1 << 16)
    rng = np.random.default_rng(seed)
    shapes = {f"b{i}": jax.ShapeDtypeStruct((int(rng.integers(100, 30000)),),
                                            jnp.float32)
              for i in range(10)}
    specs = {k: P() for k in shapes}
    plan = comm.build_sync_plan(shapes, specs, cfg, 4)
    assert len(plan.groups) == 1
    g = plan.groups[0]
    cap_cols = comm.plan._bucket_capacity_cols(cfg, 4, 1)
    assert len(g.buckets) == math.ceil(g.cols / cap_cols)


def test_per_leaf_plan_matches_legacy_routing():
    cfg = SyncConfig(mode="sparcml", bucket_size=512, min_sparse_size=65536)
    shapes = {"big": jax.ShapeDtypeStruct((1 << 17,), jnp.float32),
              "small": jax.ShapeDtypeStruct((128,), jnp.float32)}
    specs = {"big": P(), "small": P()}
    plan = comm.build_per_leaf_plan(shapes, specs, cfg, 4)
    assert plan.num_buckets == 1          # only the big leaf qualifies
    fused = comm.build_sync_plan(shapes, specs, cfg, 4)
    assert fused.covered_leaf_ids() == {0, 1}   # fusion covers both


# --------------------------------------------------------------------------
# Executor parity: manual(native) == manual(emulated) == auto-SPMD
# --------------------------------------------------------------------------

def _toy_setup(qsgd_bits=None):
    cfg = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                     algorithm="dsar_split_allgather", min_sparse_size=1024,
                     qsgd_bits=qsgd_bits, qsgd_bucket=128, impl="ref",
                     fusion_bucket_bytes=1 << 14)
    shapes = {"a": jax.ShapeDtypeStruct((3000,), jnp.float32),
              "b": jax.ShapeDtypeStruct((77,), jnp.float32),
              "c": jax.ShapeDtypeStruct((513,), jnp.float32)}
    specs = {"a": P(), "b": P(), "c": P()}
    plan = comm.build_sync_plan(shapes, specs, cfg, 8)
    key = jax.random.PRNGKey(3)
    grads_r = {k: jax.random.normal(jax.random.fold_in(key, i),
                                    (8,) + s.shape)
               for i, (k, s) in enumerate(shapes.items())}
    res = plan.init_residuals()
    return cfg, plan, grads_r, res


@pytest.mark.parametrize("qsgd_bits", [None, 4])
def test_executor_parity_manual_vs_spmd(mesh8, qsgd_bits):
    cfg, plan, grads_r, res = _toy_setup(qsgd_bits)
    key = jax.random.PRNGKey(9)

    def manual(gr, r, native):
        g = jax.tree.map(lambda x: x[0], gr)
        leaves, tree = jax.tree.flatten(g)
        rank = jax.lax.axis_index("data")
        out, new_res = comm.execute_plan(
            plan, leaves, r, key, data_axis="data", p_data=8,
            native=native, data_rank=None if native else rank)
        return tree.unflatten(out), new_res

    rspecs = {k: P("data", None, None) for k in res}
    outs = {}
    for native in (True, False):
        f = shard_map(lambda gr, r: manual(gr, r, native), mesh=mesh8,
                      in_specs=({k: P("data", None) for k in grads_r},
                                rspecs),
                      out_specs=({k: P() for k in grads_r}, rspecs),
                      check_vma=False)
        outs[native] = f(grads_r, res)
    # auto-SPMD formulation outside any shard_map
    leaves_r, tree = jax.tree.flatten(grads_r)
    spmd_leaves, spmd_res = comm.execute_plan_spmd(
        plan, leaves_r, res, key, p_data=8)
    spmd_out = tree.unflatten(spmd_leaves)

    for k in grads_r:
        a = np.asarray(outs[True][0][k])
        b = np.asarray(outs[False][0][k])
        c = np.asarray(spmd_out[k])
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-6)
    for name in res:
        np.testing.assert_allclose(np.asarray(outs[True][1][name]),
                                   np.asarray(spmd_res[name]),
                                   rtol=1e-5, atol=1e-6)


def test_executor_parity_size1_pod_qsgd():
    """A size-1 pod axis must not perturb the QSGD rounding keys: the
    manual lowering (which sees pod_rank=0) and the auto-SPMD lowering
    (which skips the degenerate pod fold) must produce identical bits."""
    from repro.compat import make_mesh

    cfg, plan, grads_r, res = _toy_setup(qsgd_bits=4)
    mesh = make_mesh((1, 8), ("pod", "data"))
    key = jax.random.PRNGKey(9)

    def manual(gr, r):
        g = jax.tree.map(lambda x: x[0], gr)
        leaves, tree = jax.tree.flatten(g)
        out, new_res = comm.execute_plan(
            plan, leaves, r, key, data_axis="data", p_data=8,
            pod_axis="pod", p_pod=1)
        return tree.unflatten(out), new_res

    rspecs = {k: P(("pod", "data"), None, None) for k in res}
    f = shard_map(manual, mesh=mesh,
                  in_specs=({k: P(("pod", "data"), None) for k in grads_r},
                            rspecs),
                  out_specs=({k: P() for k in grads_r}, rspecs),
                  check_vma=False)
    man_out, _ = f(grads_r, res)
    leaves_r, tree = jax.tree.flatten(grads_r)
    spmd_leaves, _ = comm.execute_plan_spmd(plan, leaves_r, res, key,
                                            p_data=8, p_pod=1)
    spmd_out = tree.unflatten(spmd_leaves)
    for k in grads_r:
        np.testing.assert_allclose(np.asarray(man_out[k]),
                                   np.asarray(spmd_out[k]),
                                   rtol=1e-5, atol=1e-6)


def test_fused_matches_oracle(mesh8):
    """Fused bucket sync == hand-computed pack -> per-rank TopK -> mean."""
    cfg, plan, grads_r, res = _toy_setup()
    key = jax.random.PRNGKey(1)
    leaves_r, tree = jax.tree.flatten(grads_r)
    out_leaves, _ = comm.execute_plan_spmd(plan, leaves_r, res, key, p_data=8)
    out = tree.unflatten(out_leaves)

    # oracle over the single flat group
    (g,) = plan.groups
    packed = np.stack([
        np.asarray(comm.pack_group(g, [l[r] for l in leaves_r],
                                   cfg.bucket_size))
        for r in range(8)
    ])                                                   # (8, 1, cols)
    dens = []
    for r in range(8):
        u, _ = topk_mod.compress2d(jnp.asarray(packed[r]), cfg.k_per_bucket,
                                   cfg.bucket_size)
        dens.append(np.asarray(u.densify()))
    oracle_buf = np.stack(dens).sum(0) / 8.0
    for leaf_id, arr in comm.unpack_group(g, jnp.asarray(oracle_buf),
                                          [l[0] for l in leaves_r]):
        np.testing.assert_allclose(np.asarray(out_leaves[leaf_id]),
                                   np.asarray(arr), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# The headline claim: collectives per step scale with buckets, not leaves
# --------------------------------------------------------------------------

def _count_prims(jaxpr, names: set) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in names:
            total += 1
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                total += _count_prims(sub, names)
    return total


try:  # moved out of jax.core in newer JAX
    from jax.extend.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr
except ImportError:
    from jax.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr


def _subjaxprs(v):
    out = []
    if isinstance(v, _ClosedJaxpr):
        out.append(v.jaxpr)
    elif isinstance(v, _Jaxpr):
        out.append(v)
    elif isinstance(v, (list, tuple)):
        for x in v:
            out.extend(_subjaxprs(x))
    return out


def test_step_collectives_scale_with_buckets_not_leaves(mesh8):
    """>= 8 sparse-path leaves lower to <= ceil(total_canonical_bytes /
    fusion_bucket_bytes) data-axis SPARSE collectives (one fused a2a per
    DSAR bucket), where the per-leaf pipeline paid one per leaf."""
    n_leaves = 10
    cfg = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=512,
                     algorithm="dsar_split_allgather", min_sparse_size=1024,
                     impl="ref", fusion_bucket_bytes=1 << 18)
    shapes = {f"w{i}": jax.ShapeDtypeStruct((16384,), jnp.float32)
              for i in range(n_leaves)}
    specs = {k: P() for k in shapes}
    plan = comm.build_sync_plan(shapes, specs, cfg, 8)
    assert plan.num_sparse_buckets >= 1
    total_bytes = sum(
        g.rows * g.cols * 4 for g in plan.groups)
    ceil_bound = math.ceil(total_bytes / cfg.fusion_bucket_bytes)
    assert plan.num_buckets <= ceil_bound
    # legacy routing would have dense-psum'd NONE of these (all above
    # min_sparse_size=1024) but paid one collective pipeline per leaf;
    # with paper-default min_sparse_size every one fell to dense psum.
    assert comm.build_per_leaf_plan(
        shapes, specs,
        SyncConfig(mode="sparcml", bucket_size=512), 8).num_buckets == 0

    res = plan.init_residuals()
    key = jax.random.PRNGKey(0)

    def sync(gr, r):
        g = jax.tree.map(lambda x: x[0], gr)
        leaves, tree = jax.tree.flatten(g)
        out, new_res = comm.execute_plan(plan, leaves, r, key,
                                         data_axis="data", p_data=8)
        return tree.unflatten(out), new_res

    rspecs = {k: P("data", None, None) for k in res}
    f = shard_map(sync, mesh=mesh8,
                  in_specs=({k: P("data", None) for k in shapes}, rspecs),
                  out_specs=({k: P() for k in shapes}, rspecs),
                  check_vma=False)
    grads_r = {k: jnp.ones((8,) + s.shape, jnp.float32)
               for k, s in shapes.items()}
    jaxpr = jax.make_jaxpr(f)(grads_r, res).jaxpr
    n_a2a = _count_prims(jaxpr, {"all_to_all"})
    assert n_a2a == plan.num_sparse_buckets, (n_a2a, plan.describe())
    assert n_a2a <= ceil_bound
    assert n_a2a < n_leaves
    # and the result is still correct: identical all-ones ranks mean back
    # to the TopK selection — k of every bucket survive at value 1.0
    out, _ = f(grads_r, res)
    per_leaf_selected = 16384 // cfg.bucket_size * cfg.k_per_bucket
    np.testing.assert_allclose(np.asarray(out["w0"]).sum(),
                               per_leaf_selected, rtol=1e-5)


def test_full_train_step_collective_count():
    """The acceptance claim end-to-end: a real train step whose model has
    >= 8 sparse-path leaves lowers to <= ceil(total_canonical_bytes /
    fusion_bucket_bytes) data-axis sparse collectives (an 8x1 mesh takes
    the manual/native lowering — the trivial model axis creates no
    subgroups — so the a2a count IS the DSAR bucket count)."""
    from repro.compat import make_mesh
    from repro.models.config import ModelConfig
    from repro.models.model import build_model
    from repro.optim.optimizers import OptimizerConfig
    from repro.optim.schedule import ScheduleConfig
    from repro.train.state import TrainConfig
    from repro.train.train_step import (
        build_train_step,
        sparcml_uses_manual_collectives,
    )

    mesh = make_mesh((8, 1), ("data", "model"))
    assert sparcml_uses_manual_collectives(mesh)
    cfg = ModelConfig(name="ts", family="dense", num_layers=2, d_model=256,
                      num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
                      dtype=jnp.float32, param_dtype=jnp.float32,
                      max_seq_len=64)
    sync = SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=128,
                      algorithm="dsar_split_allgather", min_sparse_size=1024,
                      impl="ref", fusion_bucket_bytes=1 << 20)
    tcfg = TrainConfig(sync=sync, optimizer=OptimizerConfig(),
                       schedule=ScheduleConfig(peak_lr=1e-3, warmup_steps=2,
                                               total_steps=10), zero1=False)
    model = build_model(cfg)
    with mesh:
        step_fn, (shapes, _) = build_train_step(model, tcfg, mesh)
        pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        from repro.models.specs import param_specs
        plan = comm.build_sync_plan(pshapes, param_specs(pshapes, cfg, None),
                                    sync, 8)
        n_leaves = len(jax.tree.leaves(pshapes))
        assert n_leaves >= 8
        total_bytes = sum(g.rows * g.cols * 4 for g in plan.groups)
        ceil_bound = max(1, math.ceil(total_bytes / sync.fusion_bucket_bytes))
        b = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        jaxpr = jax.make_jaxpr(step_fn)(shapes, b, key).jaxpr
    n_a2a = _count_prims(jaxpr, {"all_to_all"})
    assert 1 <= n_a2a == plan.num_sparse_buckets <= ceil_bound, (
        n_a2a, ceil_bound, plan.describe())
    assert n_a2a < n_leaves
