"""Quickstart: train a small LM with SparCML gradient compression.

    PYTHONPATH=src python examples/quickstart.py

Runs on CPU with 8 emulated devices (4-way data parallel x 2-way tensor
parallel), comparing dense allreduce vs the paper's Quantized TopK SGD
(Alg. 2: bucketed top-k + error feedback + DSAR split/allgather + 4-bit
QSGD second phase).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.core.compressor import SyncConfig, wire_bytes_per_step
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.train.state import TrainConfig
from repro.train.train_step import build_train_step, init_state


def main():
    mesh = make_host_mesh(data=4, model=2)
    cfg = ModelConfig(name="quickstart-12m", family="dense", num_layers=4,
                      d_model=256, num_heads=8, num_kv_heads=4, d_ff=512,
                      vocab_size=2048, dtype=jnp.float32,
                      param_dtype=jnp.float32, max_seq_len=256)
    model = build_model(cfg)
    data = DataConfig(global_batch=16, seq_len=128, vocab_size=2048)

    for label, sync in [
        ("dense allreduce      ", SyncConfig(mode="dense")),
        ("sparcml topk 1.6%+EF ", SyncConfig(
            mode="sparcml", k_per_bucket=8, bucket_size=512,
            algorithm="dsar_split_allgather", qsgd_bits=4,
            min_sparse_size=16384, impl="ref")),
    ]:
        tcfg = TrainConfig(sync=sync, optimizer=OptimizerConfig(),
                           schedule=ScheduleConfig(peak_lr=1e-3,
                                                   warmup_steps=10,
                                                   total_steps=500))
        step_fn, (shapes, _) = build_train_step(model, tcfg, mesh)
        state, _ = init_state(model, tcfg, mesh)
        key = jax.random.PRNGKey(0)
        with mesh:
            for i in range(40):
                batch = jax.tree.map(jnp.asarray, synthetic_batch(data, i))
                state, m = step_fn(state, batch, jax.random.fold_in(key, i))
                if i % 10 == 0:
                    print(f"  [{label}] step {i:3d} loss {float(m['loss']):.4f}")
        rep = wire_bytes_per_step(shapes.params, sync, p=4)
        print(f"  [{label}] final loss {float(m['loss']):.4f} | "
              f"wire bytes/step: {rep['sparcml_bytes']/1e6:.2f} MB "
              f"({rep['ratio']:.1f}x less than dense)\n")


if __name__ == "__main__":
    main()
