"""End-to-end training driver: a ~100M-parameter LM with the full
production stack — SparCML Quantized-TopK gradient sync, WSD schedule,
ZeRO-1 optimizer sharding, checkpointing + automatic resume, straggler
watchdog, deterministic data pipeline.

    PYTHONPATH=src python examples/train_lm_topk.py --steps 300
    PYTHONPATH=src python examples/train_lm_topk.py --fast   # ~12M params
    PYTHONPATH=src python examples/train_lm_topk.py --fast --pipeline

--pipeline drives the non-blocking runtime (DESIGN.md §6) instead of the
synchronous Trainer.run: one-step-stale pipelined supersteps dispatched
asynchronously with background data prefetch. A short synchronous probe
runs first so the measured overlap win can be printed. Checkpoints are
interchangeable between the two loops.

A crash / Ctrl-C mid-run resumes from the latest checkpoint on restart
(same command). ~100M x 300 steps is a few hours on this small CPU
container; --fast demonstrates the identical code path in ~2 minutes.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax.numpy as jnp

from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.train.state import TrainConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/sparcml_lm_ckpt")
    ap.add_argument("--pipeline", action="store_true",
                    help="non-blocking runtime: pipelined stale-gradient "
                         "supersteps + async driver (DESIGN.md §6)")
    ap.add_argument("--superstep", type=int, default=4,
                    help="steps per scanned superstep (with --pipeline)")
    ap.add_argument("--zero", action="store_true",
                    help="ZeRO-sharded training state (DESIGN.md §11): the "
                         "gradient exchange stops at the owner shard "
                         "(scattered output mode, no allgather) and the "
                         "optimizer moments live on the owned chunks; "
                         "checkpoints interoperate with replicated runs")
    ap.add_argument("--adapt", action="store_true",
                    help="closed-loop re-planning (DESIGN.md §7): measured "
                         "per-bucket densities + calibrated alpha-beta "
                         "model re-select collective algorithms at drain "
                         "barriers (with --pipeline)")
    ap.add_argument("--trace", type=str, default=None, metavar="PATH",
                    help="export a Chrome-trace JSON of the run "
                         "(host spans + derived device compute/comm "
                         "phases, DESIGN.md §10)")
    ap.add_argument("--metrics-out", type=str, default=None, metavar="PATH",
                    help="write the metrics/event JSONL (per-bucket "
                         "nnz/wire histograms, plan swaps, step times) "
                         "and run a cost-model drift audit at the end")
    ap.add_argument("--blackbox", type=str, default=None, metavar="PATH",
                    help="attach the flight recorder (DESIGN.md §10.6): "
                         "a bounded ring of driver retires dumped to this "
                         "path on exception, watchdog fire, or SIGTERM/"
                         "SIGINT — the post-mortem for a killed run")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="chaos-injection smoke (DESIGN.md §12): run a "
                         "seed-derived FaultPlan of recoverable faults "
                         "(grad NaN/Inf, straggler, data stall, collective "
                         "raise, checkpoint corruption) against the "
                         "pipelined runtime; the run must complete via the "
                         "guarded step + retry/backoff recovery (implies "
                         "--pipeline)")
    args = ap.parse_args()

    from repro import obs as obs_mod

    chaos = args.chaos is not None
    if chaos:
        args.pipeline = True  # guard/inject hooks live in the async driver
    obs = obs_mod.configure(trace=bool(args.trace),
                            metrics=bool(args.metrics_out) or bool(args.trace)
                            or chaos,
                            audit=bool(args.metrics_out),
                            recorder=args.blackbox or False)
    if obs.recorder is not None:
        obs.recorder.install_signal_handlers()

    if args.fast:
        cfg = ModelConfig(name="lm-12m", family="dense", num_layers=4,
                          d_model=256, num_heads=8, num_kv_heads=4, d_ff=512,
                          vocab_size=2048, dtype=jnp.float32,
                          param_dtype=jnp.float32, max_seq_len=256)
        data = DataConfig(global_batch=16, seq_len=128, vocab_size=2048)
        steps = min(args.steps, 60)
    else:
        # ~100M: 12 layers x d=768 (GPT-2-small-like with GQA + SwiGLU)
        cfg = ModelConfig(name="lm-100m", family="dense", num_layers=12,
                          d_model=768, num_heads=12, num_kv_heads=4,
                          d_ff=2048, vocab_size=32768, dtype=jnp.float32,
                          param_dtype=jnp.float32, max_seq_len=1024)
        data = DataConfig(global_batch=32, seq_len=512, vocab_size=32768)
        steps = args.steps

    model = build_model(cfg)
    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.1f}M params")

    tcfg = TrainConfig(
        sync=SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=512,
                        algorithm="dsar_split_allgather", qsgd_bits=4,
                        min_sparse_size=65536, impl="ref",
                        output_mode="scattered" if args.zero else
                        "replicated"),
        optimizer=OptimizerConfig(kind="adamw"),
        schedule=ScheduleConfig(kind="wsd", peak_lr=6e-4, warmup_steps=20,
                                total_steps=steps),
        microbatches=2,
        zero1=True,
    )
    mesh = make_host_mesh(data=4, model=2)
    if args.zero:
        from repro.launch.dryrun import state_memory_breakdown

        mem = state_memory_breakdown(model, tcfg, mesh)
        print("zero: per-device state "
              + ", ".join(f"{k}={v/1e6:.1f}MB" for k, v in mem.items()))
    # shorter checkpoint cadence under chaos: the corrupt-then-restore
    # pair needs steps > 2*ckpt_every, and a CI-sized smoke (~30 steps)
    # should still cross several save boundaries
    ckpt_every = 10 if chaos else 25
    trainer = Trainer(model, tcfg, mesh, data, ckpt_dir=args.ckpt_dir,
                      ckpt_every=ckpt_every, obs=obs)
    start = trainer.init_or_resume()
    print(f"starting at step {start} (resume={'yes' if start else 'no'})")

    def med(times):
        return sorted(times)[len(times) // 2]

    injector = recovery = None
    if chaos:
        from repro.runtime.faults import (FaultInjector, FaultPlan,
                                          RecoveryConfig)

        plan = FaultPlan.chaos(args.chaos, steps, ckpt_every=ckpt_every)
        injector = FaultInjector(plan)
        recovery = RecoveryConfig(backoff_base_s=0.01, backoff_max_s=0.1)
        print("chaos plan (seed {}): ".format(args.chaos)
              + ", ".join(f"{s.kind}@{s.step}" for s in plan.specs))

    if args.pipeline:
        # short synchronous probe first, so the overlap win is measurable
        # (skipped under chaos: the probe loop has no recovery hooks)
        probe_to = start if chaos else min(start + 8, steps)
        if probe_to > start:
            trainer.run(probe_to)
        n_sync = len(trainer.log.step_times)
        # drop sync's first entry (it carries the jit compile); keep ALL
        # pipelined entries, compile included — the mean is exact in
        # aggregate (fill/drain intervals tile the run). Charging the
        # pipelined arm its own compile AND every checkpoint drain/save
        # (the short sync probe crosses no ckpt boundary) keeps the
        # printed win strictly conservative.
        sync_times = trainer.log.step_times[1:n_sync]
        log = trainer.run_pipelined(steps, staleness=1,
                                    superstep=args.superstep, depth=2,
                                    adapt=args.adapt, injector=injector,
                                    recovery=recovery)
        pipe_times = log.step_times[n_sync:]
        if sync_times and pipe_times:
            sync_avg = sum(sync_times) / len(sync_times)
            pipe_avg = sum(pipe_times) / len(pipe_times)
            print(f"overlap win: sync {sync_avg*1e3:.0f} ms/step -> "
                  f"pipelined {pipe_avg*1e3:.0f} ms/step "
                  f"({sync_avg/pipe_avg:.2f}x, staleness=1, "
                  f"superstep={args.superstep}, depth=2)")
        if args.adapt:
            print(f"adaptive re-planning: {len(log.plan_swaps)} plan "
                  f"swap(s)" + "".join(
                      f"\n  step {s}: {sig.split(',')[0]}..."
                      for s, sig in log.plan_swaps))
    else:
        log = trainer.run(steps)
    print(f"done: step {steps}, loss {log.losses[0]:.3f} -> {log.losses[-1]:.3f}, "
          f"avg step {sum(log.step_times)/len(log.step_times)*1e3:.0f} ms "
          f"(median {med(log.step_times)*1e3:.0f} ms), "
          f"restarts={log.restarts}, stragglers={len(log.straggler_events)}")
    if chaos:
        m = obs.metrics
        counters = {n: c.value for n, c in sorted(m.metrics.items())
                    if getattr(c, "kind", None) == "counter"
                    and n.startswith(("faults/", "recovery/", "guard/"))}
        print("chaos recovery: survived "
              f"{injector.fired_total} injected fault(s), "
              f"restarts={log.restarts}; "
              + " ".join(f"{n}={v}" for n, v in counters.items()))
        if injector.fired_total == 0:
            raise SystemExit("chaos: the plan injected nothing — seed/step "
                             "range mismatch, the smoke proved nothing")

    if obs.enabled:
        # drift audit: probe each distinct (algorithm, n, k) bucket of
        # the plan the run actually ended on, join against the cost
        # model's bucket_time prediction (DESIGN.md §10)
        plan = getattr(trainer, "last_plan", None)
        if obs.audit is not None and plan is not None:
            from repro.obs import audit_sync_plan

            audit_sync_plan(plan, mesh, axis_name="data",
                            net=getattr(trainer, "_net_cal", None),
                            auditor=obs.audit, registry=obs.metrics)
            print(obs.audit.summary())
        if obs.metrics_on:
            # compression-health verdict over the whole run (DESIGN.md
            # §10.5): EF growth, coverage floor, step-time p99 — reuse
            # the monitor the pipelined driver evaluated at drains
            from repro.obs import HealthMonitor

            mon = trainer.last_health or HealthMonitor(
                obs.metrics, audit=obs.audit)
            mon.evaluate()
            print("health:", mon.summary())
        obs.export(trace_path=args.trace, metrics_path=args.metrics_out)
        if obs.metrics_on:
            print(obs.metrics.summary())
        for p in (args.trace, args.metrics_out):
            if p:
                print(f"obs: wrote {p}")


if __name__ == "__main__":
    main()
