"""End-to-end training driver: a ~100M-parameter LM with the full
production stack — SparCML Quantized-TopK gradient sync, WSD schedule,
ZeRO-1 optimizer sharding, checkpointing + automatic resume, straggler
watchdog, deterministic data pipeline.

    PYTHONPATH=src python examples/train_lm_topk.py --steps 300
    PYTHONPATH=src python examples/train_lm_topk.py --fast   # ~12M params

A crash / Ctrl-C mid-run resumes from the latest checkpoint on restart
(same command). ~100M x 300 steps is a few hours on this 1-core CPU
container; --fast demonstrates the identical code path in ~2 minutes.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax.numpy as jnp

from repro.core.compressor import SyncConfig
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.train.state import TrainConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/sparcml_lm_ckpt")
    args = ap.parse_args()

    if args.fast:
        cfg = ModelConfig(name="lm-12m", family="dense", num_layers=4,
                          d_model=256, num_heads=8, num_kv_heads=4, d_ff=512,
                          vocab_size=2048, dtype=jnp.float32,
                          param_dtype=jnp.float32, max_seq_len=256)
        data = DataConfig(global_batch=16, seq_len=128, vocab_size=2048)
        steps = min(args.steps, 60)
    else:
        # ~100M: 12 layers x d=768 (GPT-2-small-like with GQA + SwiGLU)
        cfg = ModelConfig(name="lm-100m", family="dense", num_layers=12,
                          d_model=768, num_heads=12, num_kv_heads=4,
                          d_ff=2048, vocab_size=32768, dtype=jnp.float32,
                          param_dtype=jnp.float32, max_seq_len=1024)
        data = DataConfig(global_batch=32, seq_len=512, vocab_size=32768)
        steps = args.steps

    model = build_model(cfg)
    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.1f}M params")

    tcfg = TrainConfig(
        sync=SyncConfig(mode="sparcml", k_per_bucket=8, bucket_size=512,
                        algorithm="dsar_split_allgather", qsgd_bits=4,
                        min_sparse_size=65536, impl="ref"),
        optimizer=OptimizerConfig(kind="adamw"),
        schedule=ScheduleConfig(kind="wsd", peak_lr=6e-4, warmup_steps=20,
                                total_steps=steps),
        microbatches=2,
        zero1=True,
    )
    mesh = make_host_mesh(data=4, model=2)
    trainer = Trainer(model, tcfg, mesh, data, ckpt_dir=args.ckpt_dir,
                      ckpt_every=25)
    start = trainer.init_or_resume()
    print(f"starting at step {start} (resume={'yes' if start else 'no'})")
    log = trainer.run(steps)
    print(f"done: step {steps}, loss {log.losses[0]:.3f} -> {log.losses[-1]:.3f}, "
          f"median step {sorted(log.step_times)[len(log.step_times)//2]*1e3:.0f} ms, "
          f"restarts={log.restarts}, stragglers={len(log.straggler_events)}")


if __name__ == "__main__":
    main()
