"""Large-scale sparse classification (paper §8.2 / Table 2, the MPI-OPT
scenario): logistic regression over a URL-like trigram-sparse dataset on
8 data-parallel ranks, exploiting NATURAL gradient sparsity losslessly.

    PYTHONPATH=src python examples/classify_sparse.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allreduce import make_sparse_allreduce
from repro.data.sparse_datasets import make_url_like_dataset
from repro.launch.mesh import make_host_mesh


def main():
    n_feat = 1 << 20
    idx, val, y = make_url_like_dataset(n_samples=2048, n_features=n_feat,
                                        nnz_per_sample=64)
    mesh = jax.make_mesh((8,), ("data",))
    print(f"dataset: 2048 samples x {n_feat} trigram features "
          f"(density {64/n_feat:.5%}) — gradients are naturally sparse")

    w = np.zeros(n_feat, np.float32)
    lr, bs = 0.5, 16  # per-rank batch

    def rank_grad(w, rank, step):
        lo = (step * 8 + rank) * bs % 2048
        ii, vv, yy = idx[lo:lo + bs], val[lo:lo + bs], y[lo:lo + bs]
        m = (vv * w[ii]).sum(1)
        coef = (-yy / (1 + np.exp(yy * m)) / bs).astype(np.float32)
        g = np.zeros(n_feat, np.float32)
        np.add.at(g, ii.ravel(), (coef[:, None] * vv).ravel())
        return g

    def accuracy(w):
        m = (val * w[idx]).sum(1)
        return float((np.sign(m) == y).mean())

    for algo in ("dense", "ssar_split_allgather"):
        f = make_sparse_allreduce(mesh, "data", n_feat, k_per_bucket=8,
                                  bucket_size=512, algorithm=algo)
        w = np.zeros(n_feat, np.float32)
        t0 = time.perf_counter()
        for step in range(16):
            grads = np.stack([rank_grad(w, r, step) for r in range(8)])
            summed = np.asarray(f(jnp.asarray(grads).reshape(-1), None))
            w -= lr * summed / 8
        dt = time.perf_counter() - t0
        print(f"  {algo:22s}: 16 steps in {dt:.2f}s, "
              f"train accuracy {accuracy(w):.3f}")


if __name__ == "__main__":
    main()
