"""Serving demo: static batched decode (the PR-0 reference engine) or —
with ``--continuous`` — the continuous-batching scheduler driving the
slot decode engine with plan-driven sparse MoE dispatch (DESIGN.md §8).

    PYTHONPATH=src python examples/serve_decode.py                # static
    PYTHONPATH=src python examples/serve_decode.py --continuous   # scheduler
    PYTHONPATH=src python examples/serve_decode.py --fast --continuous

``--continuous`` runs a Poisson arrival trace of ragged-prompt requests
through the adaptive engine and prints throughput, wire bytes, and the
sparse<->dense dispatch swaps the telemetry drove.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.serve import (
    ContinuousServeEngine,
    Request,
    ServeConfig,
    ServeEngine,
    poisson_trace,
)

import jax.numpy as jnp


def build(fast: bool):
    mesh = make_host_mesh(data=4, model=2)
    kw = dict(num_layers=2, d_model=128, d_ff=256) if fast else \
        dict(num_layers=4, d_model=256, d_ff=512)
    cfg = ModelConfig(name="serve-demo", family="moe", num_heads=8,
                      num_kv_heads=4, vocab_size=2048, dtype=jnp.float32,
                      param_dtype=jnp.float32, max_seq_len=256,
                      num_experts=4, experts_per_token=2,
                      moe_d_ff=kw["d_ff"] // 2, capacity_factor=4.0, **kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return mesh, model, params


def run_static(mesh, model, params, batch: int, tokens: int, obs=None):
    engine = ServeEngine(model, mesh, params, cache_len=128, batch_size=batch,
                         obs=obs)
    prompts = np.random.default_rng(0).integers(
        0, 2048, (batch, 16)).astype(np.int32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, max_new_tokens=tokens)
    dt = time.perf_counter() - t0
    print(f"static: {out.shape} tokens for {batch} requests in {dt:.2f}s "
          f"({out.size / dt:.0f} tok/s on emulated CPU devices)")
    print("first request:", out[0].tolist())
    out2 = engine.generate(prompts, max_new_tokens=tokens)
    assert np.array_equal(out, out2)
    print("greedy decode is deterministic: OK")


def run_continuous(mesh, model, params, batch: int, tokens: int, obs=None,
                   slo: "ServeConfig | None" = None, injector=None):
    rng = np.random.default_rng(0)
    n_req = 2 * batch
    arrivals = poisson_trace(n_req, rate=0.5, seed=0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 2048, int(rng.integers(4, 20))),
                    max_new_tokens=int(rng.integers(tokens // 2, tokens + 1)),
                    arrival=float(arrivals[i]))
            for i in range(n_req)]
    engine = ContinuousServeEngine(model, mesh, params, cache_len=128,
                                   batch_size=batch, dispatch="adaptive",
                                   obs=obs, serve_cfg=slo, injector=injector)
    res = engine.run(reqs)
    occ = [r["active"] for r in res.step_log]
    print(f"continuous: {len(reqs)} requests, {res.tokens} tokens in "
          f"{res.decode_steps} decode steps / {res.wall_s:.2f}s "
          f"({res.tok_per_s:.0f} tok/s; occupancy {min(occ)}..{max(occ)} "
          f"of {batch} slots)")
    print(f"dispatch wire: {res.wire_bytes / 1e3:.1f} kB modeled; "
          f"plan swaps: {[(s['step'], s['reason'], s['signature']) for s in res.swap_log]}")
    if res.latency:
        lat = res.latency
        print("latency (decode-step units): "
              f"ttft p50={lat['ttft']['p50']:.1f} p99={lat['ttft']['p99']:.1f}; "
              f"tpot p50={lat['tpot']['p50']:.2f}; "
              f"e2e p99={lat['e2e']['p99']:.1f}")
    if slo is not None and obs is not None and obs.metrics_on:
        # res.health only carries verdicts when the registry was live
        misses = [(e.severity, e.subject) for e in res.health]
        print(f"SLO targets {slo.slo_targets()}: "
              + (f"{len(misses)} miss(es) {misses}" if misses
                 else "all attained"))
    # under load shedding (queue_limit / shed deadline) a request may be
    # retired via the shed list instead of outputs; every request must
    # still be accounted for exactly once
    assert len(res.outputs) + len(res.shed) == n_req
    if res.shed:
        print(f"load shed: {len(res.shed)} request(s) {sorted(res.shed)}")
    if injector is not None:
        retries = obs.metrics.counter("serve/retries").value if (
            obs is not None and obs.metrics_on) else 0
        print("chaos recovery: survived "
              f"{injector.fired_total} injected fault(s), "
              f"tick retries={retries}, shed={len(res.shed)}")
        if injector.fired_total == 0:
            raise SystemExit("chaos: the plan injected nothing — seed/step "
                             "range mismatch, the smoke proved nothing")
    else:
        print("all requests completed: OK")
    return engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller model + fewer tokens (CI smoke)")
    ap.add_argument("--batch", type=int, default=8,
                    help="decode slots (continuous) / batch size (static)")
    ap.add_argument("--tokens", type=int, default=None,
                    help="max new tokens per request")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching + adaptive sparse dispatch")
    ap.add_argument("--trace", type=str, default=None, metavar="PATH",
                    help="export a Chrome-trace JSON of the run "
                         "(prefill/decode/admit spans, DESIGN.md §10)")
    ap.add_argument("--metrics-out", type=str, default=None, metavar="PATH",
                    help="write the metrics/event JSONL (occupancy/queue/"
                         "wire histograms, latency percentiles, plan "
                         "swaps) and run a serve-plan drift audit")
    ap.add_argument("--slo-ttft", type=float, default=16.0,
                    help="p99 time-to-first-token target in decode-step "
                         "units (DESIGN.md §10.5); misses become ranked "
                         "health/serve_slo events")
    ap.add_argument("--slo-e2e", type=float, default=96.0,
                    help="p99 arrival->retirement target in decode-step "
                         "units")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="chaos-injection smoke (DESIGN.md §12): a "
                         "seed-derived FaultPlan of recoverable serve "
                         "faults (collective raise, straggler, pipeline "
                         "stall) against the decode loop; the run must "
                         "complete via pre-dispatch tick retries "
                         "(implies --continuous)")
    args = ap.parse_args()
    tokens = args.tokens if args.tokens is not None else (8 if args.fast else 24)

    from repro import obs as obs_mod

    chaos = args.chaos is not None
    if chaos:
        args.continuous = True  # tick retry/shed hooks live in the scheduler
    obs = obs_mod.configure(trace=bool(args.trace),
                            metrics=bool(args.metrics_out) or bool(args.trace)
                            or chaos,
                            audit=bool(args.metrics_out))
    mesh, model, params = build(args.fast)
    injector = None
    if chaos:
        from repro.runtime.faults import FaultInjector, FaultPlan

        # recoverable serve classes only: nonfinite/sigterm abort a
        # decode run by design (donated state cannot be replayed)
        plan = FaultPlan.chaos(args.chaos, 16,
                               classes=("collective", "straggler", "stall"))
        injector = FaultInjector(plan)
        print("chaos plan (seed {}): ".format(args.chaos)
              + ", ".join(f"{s.kind}@tick{s.step}" for s in plan.specs))
    engine = None
    if args.continuous:
        slo = ServeConfig(slo_ttft_p99=args.slo_ttft,
                          slo_e2e_p99=args.slo_e2e)
        engine = run_continuous(mesh, model, params, args.batch, tokens,
                                obs=obs, slo=slo, injector=injector)
    else:
        run_static(mesh, model, params, args.batch, tokens, obs=obs)

    if obs.enabled:
        plan = getattr(engine, "_plan", None) if engine is not None else None
        if obs.audit is not None and plan is not None:
            from repro.obs import audit_serve_plan

            # probe each activation bucket of the plan the engine ended
            # on and join against bucket_time (DESIGN.md §10)
            audit_serve_plan(plan, mesh, axis_name="model",
                             auditor=obs.audit, registry=obs.metrics)
            print(obs.audit.summary())
        obs.export(trace_path=args.trace, metrics_path=args.metrics_out)
        if obs.metrics_on:
            print(obs.metrics.summary())
        for p in (args.trace, args.metrics_out):
            if p:
                print(f"obs: wrote {p}")


if __name__ == "__main__":
    main()
