"""Batched serving demo: prefill a batch of prompts, decode greedily with
the sharded KV cache (TP over heads, DP over request slots).

    PYTHONPATH=src python examples/serve_decode.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.serve.engine import ServeEngine

import jax.numpy as jnp


def main():
    mesh = make_host_mesh(data=4, model=2)
    cfg = ModelConfig(name="serve-demo", family="dense", num_layers=4,
                      d_model=256, num_heads=8, num_kv_heads=4, d_ff=512,
                      vocab_size=2048, dtype=jnp.float32,
                      param_dtype=jnp.float32, max_seq_len=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, mesh, params, cache_len=128, batch_size=8)

    prompts = np.random.default_rng(0).integers(0, 2048, (8, 16)).astype(np.int32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, max_new_tokens=24)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} tokens for 8 requests in {dt:.2f}s "
          f"({out.size/dt:.0f} tok/s on emulated CPU devices)")
    print("first request:", out[0].tolist())
    # deterministic greedy decode
    out2 = engine.generate(prompts, max_new_tokens=24)
    assert np.array_equal(out, out2)
    print("greedy decode is deterministic: OK")


if __name__ == "__main__":
    main()
