"""Data-axis collectives with a psum-emulated fallback (DESIGN.md §4).

Inside the training step the data-parallel axes are MANUAL (shard_map)
while 'model' stays AUTO so XLA keeps inserting the tensor-parallel
collectives. On some backends (XLA-CPU in the pinned container build)
the SPMD partitioner hard-aborts on every explicit collective except
``psum`` when lowered in such a partial-manual region. The
:class:`CollectiveContext` therefore carries a ``native`` switch:

* native=True  — ``jax.lax`` collectives (TPU, or fully-manual regions);
* native=False — the same semantics built from ONE psum each: the rank
  writes its contribution into a zero buffer at its slot and the psum
  concatenates. Wire volume is that of a dense allreduce — correctness
  scaffolding for hosts where the partitioner is broken, not a fast path.

The emulated path cannot use ``jax.lax.axis_index`` (PartitionId is also
unsupported there), so the rank arrives as DATA: a (1,) int32 slice of a
``jnp.arange(p)`` sharded over the axis (see train_step's rank feed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


def _f32_safe(x: jax.Array) -> tuple[jax.Array, object]:
    """16-bit operands round-trip psum through f32 (XLA-CPU partial-manual
    bug with sub-32-bit reductions — same workaround as safe_psum)."""
    if x.dtype in (jnp.bfloat16, jnp.float16):
        return x.astype(jnp.float32), x.dtype
    return x, None


@dataclass(frozen=True)
class CollectiveContext:
    """How to talk over one mesh axis. ``rank`` is required (as a traced
    scalar) when native=False."""

    axis_name: str
    p: int
    native: bool = True
    rank: Optional[jax.Array] = None

    def axis_rank(self) -> jax.Array:
        if self.native:
            return jax.lax.axis_index(self.axis_name)
        assert self.rank is not None, "emulated collectives need a rank feed"
        return self.rank

    # -- sum ---------------------------------------------------------------
    def psum(self, x: jax.Array) -> jax.Array:
        xs, orig = _f32_safe(x)
        out = jax.lax.psum(xs, self.axis_name)
        return out.astype(orig) if orig is not None else out

    # -- all_gather (tiled, along `axis`) ----------------------------------
    def all_gather(self, x: jax.Array, *, axis: int) -> jax.Array:
        if self.native:
            return jax.lax.all_gather(x, self.axis_name, axis=axis, tiled=True)
        w = x.shape[axis]
        shape = list(x.shape)
        shape[axis] = w * self.p
        xs, orig = _f32_safe(x)
        buf = jnp.zeros(shape, xs.dtype)
        buf = jax.lax.dynamic_update_slice_in_dim(
            buf, xs, self.axis_rank() * w, axis=axis)
        out = jax.lax.psum(buf, self.axis_name)
        return out.astype(orig) if orig is not None else out

    # -- all_to_all (tiled, split+concat along `axis`) ---------------------
    def all_to_all(self, x: jax.Array, *, axis: int) -> jax.Array:
        assert x.shape[axis] % self.p == 0, (x.shape, axis, self.p)
        if self.native:
            return jax.lax.all_to_all(
                x, self.axis_name, split_axis=axis, concat_axis=axis,
                tiled=True)
        chunk = x.shape[axis] // self.p
        rank = self.axis_rank()
        xs, orig = _f32_safe(x)
        buf = jnp.zeros((self.p,) + xs.shape, xs.dtype)
        buf = jax.lax.dynamic_update_slice(
            buf, xs[None], (rank,) + (0,) * x.ndim)
        allx = jax.lax.psum(buf, self.axis_name)          # (p, *x.shape)
        mine = jax.lax.dynamic_slice_in_dim(
            allx, rank * chunk, chunk, axis=axis + 1)     # (p, ..., chunk, ..)
        out = jnp.moveaxis(mine, 0, axis).reshape(x.shape)
        return out.astype(orig) if orig is not None else out
