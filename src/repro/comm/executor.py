"""Plan executor: one TopK-compress + sparse allreduce per bucket.

Runs INSIDE the training shard_map (manual over the dp axes). For each
group the leaves are fused into one canonical buffer (pure reshapes),
then per fusion bucket:

    residual  +=  bucket slice            (error feedback, Alg. 2 line 1)
    stream, residual' = bucketed TopK     (Alg. 2 line 2)
    reduced   = <bucket's algorithm>      (Alg. 2 line 3 — ONE planned
                                           collective pipeline per bucket)
    [+ dense psum over the pod axis — hierarchical, DCN traffic already
       compressed by the within-pod reduction]

Dense buckets (below ``min_sparse_size`` or cost-model-selected) skip
compression and ride a single psum — still fused, still one collective.

Error-feedback state is keyed by bucket name (``plan.residual_shapes``):
the bucket is the unit of compression, so it is the unit of feedback.

The collective flavor (native vs psum-emulated, DESIGN.md §4) arrives via
``native`` + the rank feeds; SSAR algorithms need native collectives and
fall back to DSAR when emulated (same dense result, different wire path).

The pipeline is split into compose-able halves (DESIGN.md §6): the
REDUCE half (``reduce_buckets`` / ``reduce_buckets_spmd``) produces
name-keyed reduced bucket buffers plus the new EF residuals, the APPLY
half (``apply_buckets`` / ``apply_buckets_spmd``) unpacks them back to
leaf layouts. ``execute_plan*`` is the synchronous composition; the
non-blocking runtime (``repro/runtime``) holds the reduced buffers in
flight for one step between the halves.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.comm.buckets import pack_group, unpack_group
from repro.comm.collectives import CollectiveContext
from repro.comm.plan import SyncPlan

# repro.core is imported lazily inside the functions below: core/__init__
# re-exports core.compressor, which imports comm — see plan.py.


def _qsgd_rand(key, bucket_idx: int, coll: CollectiveContext,
               pod_rank, shard_elems: int, p: int):
    """Stochastic-rounding bits for one bucket's QSGD phase.

    Native: my shard's bits, keyed by (step key, bucket, my data rank[,
    pod rank]). Emulated: every range's bits stacked (p, shard) — each
    rank replays every owner's rounding on the replicated psum result, so
    the emulated output is bit-identical to the native wire."""
    sub = jax.random.fold_in(key, bucket_idx)
    if pod_rank is not None:
        sub = jax.random.fold_in(sub, pod_rank)
    if coll.native:
        sub = jax.random.fold_in(sub, coll.axis_rank())
        return jax.random.bits(sub, (shard_elems,), dtype=jnp.uint32)
    return jnp.stack([
        jax.random.bits(jax.random.fold_in(sub, j), (shard_elems,),
                        dtype=jnp.uint32)
        for j in range(p)
    ])


def _bucket_telemetry(out, plan, group, b, p_data: int, p_pod: int,
                      coll: Optional[CollectiveContext] = None,
                      mass: Optional[jax.Array] = None):
    """In-graph per-bucket stats (DESIGN.md §7, §10.5): a (2,) f32 vector
    of [post-reduction nnz, modeled wire bytes at the measured nnz] — or,
    when ``mass`` is supplied, a (4,) vector extended with
    [compressed-mass coverage, EF-residual norm]. The nnz count runs on
    the already-materialized reduced buffer — O(n) local work, no
    collectives — and is replicated across ranks because the buffer is.
    Scattered manual lowerings are the exception: ``out`` is my owned
    shard only, so the global nnz is one scalar psum over the disjoint
    shards (``coll`` supplies it; the SPMD formulation sees the full
    buffer and needs none). The adaptive controller windows these on the
    host. Emitted for EF (compressed) buckets only: raw-dense buckets
    have no replan freedom, so their stats could never influence a
    decision.

    ``mass`` is the globally-summed (3,) vector
    [Σ‖topk‖², Σ‖g+r‖², Σ‖r'‖²] (callers psum it where the formulation
    is per-rank): coverage = ‖topk‖²/‖g+r‖² — the fraction of
    pre-compression gradient mass the wire actually carried this step —
    and ef_norm = ‖r'‖₂, the post-step residual magnitude the health
    engine watches for EF blowup. An all-zero accumulator counts as full
    coverage (nothing to carry)."""
    from repro.core.cost_model import bucket_wire_bytes, pod_wire_bytes

    cfg = plan.cfg
    nnz = jnp.count_nonzero(out).astype(jnp.float32)
    if plan.scattered and coll is not None:
        nnz = coll.psum(nnz)
    k = plan.bucket_k(group, b)
    vb = cfg.qsgd_bits if cfg.qsgd_bits is not None else 32
    wire = bucket_wire_bytes(b.algorithm, p_data, k, b.n, nnz=nnz,
                             value_bits=vb, scattered=plan.scattered)
    if p_pod > 1:
        sparse_pod = b.pod_sparse and group.rows == 1
        wire = wire + pod_wire_bytes(p_pod, b.n, min(b.n, p_data * k),
                                     pod_sparse=sparse_pod)
    base = jnp.stack([nnz, jnp.asarray(wire, jnp.float32)])
    if mass is None:
        return base
    coverage = jnp.where(mass[1] > 0,
                         mass[0] / jnp.maximum(mass[1], jnp.float32(1e-30)),
                         jnp.float32(1.0))
    ef_norm = jnp.sqrt(mass[2])
    return jnp.concatenate([base, jnp.stack([coverage, ef_norm])])


def _local_mass(u_val, acc, residual) -> jax.Array:
    """Per-rank (3,) f32 [Σ‖topk‖², Σ‖g+r‖², Σ‖r'‖²] — the summands of
    the mass-coverage/EF-norm telemetry. Sums over EVERY axis so the
    same helper serves the per-rank manual slices and the (R, ...) SPMD
    stacks (where the leading-axis sum already makes it global)."""
    return jnp.stack([
        jnp.sum(jnp.square(u_val.astype(jnp.float32))),
        jnp.sum(jnp.square(acc.astype(jnp.float32))),
        jnp.sum(jnp.square(residual.astype(jnp.float32))),
    ])


def _pod_sparse_exchange(out, pod_axis: str, cap: int) -> jax.Array:
    """Cross-pod phase as a sparse stream exchange (DESIGN.md §7): the
    within-pod reduced (1, n) buffer is re-sparsified (its nnz is bounded
    by p_data * k, so ``cap`` loses nothing), every pod's (idx,val)
    stream is all-gathered, and the union scatter-adds back to dense.
    Exact — the same sum as the dense psum, at p_pod*cap items on the
    wire instead of the full n-vector. Native collectives only; the
    emulated lowering keeps the psum (identical numerics)."""
    from repro.core import sparse_stream as ss

    flat = out[0]
    stream = ss.from_mask(flat, flat != 0, cap)
    idx_all = jax.lax.all_gather(stream.idx, pod_axis)    # (p_pod, cap)
    val_all = jax.lax.all_gather(stream.val, pod_axis)
    dense = jnp.zeros_like(flat).at[idx_all.reshape(-1)].add(
        val_all.reshape(-1), mode="drop")                 # SENTINEL drops
    return dense[None]


def _reduce_flat_sparse(u_flat, algorithm: str, *,
                        coll: CollectiveContext, impl: str = "auto",
                        scatter: bool = False):
    """SSAR variants for flat (rows==1) buckets; returns (dense (n,),
    fold). ``fold`` is the capacity-clamped pre-scale mass of the
    portfolio algorithms (DESIGN.md §9) — the caller adds it into the
    bucket's EF residual (the global-residual rule) — and None for the
    unclamped classics.

    ``scatter`` (DESIGN.md §11) returns (my owned (n/p,) shard, fold)
    instead: the portfolio algorithms terminate at the shard natively
    (their final allgather never runs — the wire win); the classics have
    no reduce-scatter wire form, so they reduce replicated and slice —
    correct, no wire saving, and the cost model charges them the
    replicated rate (their registry entries are not scatter-capable)."""
    from repro.core import sparse_stream as ss
    from repro.core.allreduce import (
        ssar_balanced_split_inside,
        ssar_rearranged_rs_inside,
        ssar_recursive_double_inside,
        ssar_split_allgather_inside,
    )

    def _mine(dense):
        w = u_flat.n // coll.p
        return jax.lax.dynamic_slice_in_dim(
            dense.reshape(coll.p, w), coll.axis_rank(), 1, axis=0
        ).reshape(w)

    if algorithm == "ssar_recursive_double":
        out = ssar_recursive_double_inside(
            u_flat.to_stream(), axis_name=coll.axis_name, p=coll.p,
            n=u_flat.n)
        dense = out.to_dense(u_flat.n)
        return (_mine(dense) if scatter else dense), None
    if algorithm == "ssar_split_allgather":
        stream = ssar_split_allgather_inside(
            u_flat, axis_name=coll.axis_name, p=coll.p)
        dense = ss.densify(stream, u_flat.n)
        return (_mine(dense) if scatter else dense), None
    if algorithm == "ssar_balanced_split":
        return ssar_balanced_split_inside(
            u_flat, axis_name=coll.axis_name, p=coll.p, impl=impl,
            scatter=scatter)
    if algorithm == "ssar_rearranged_rs":
        return ssar_rearranged_rs_inside(
            u_flat, axis_name=coll.axis_name, p=coll.p, scatter=scatter)
    raise ValueError(f"not a flat sparse algorithm: {algorithm!r}")


def reduce_buckets(
    plan: SyncPlan,
    leaves: Sequence[jax.Array],
    residuals: dict,
    key: jax.Array,
    *,
    data_axis: str = "data",
    p_data: int,
    pod_axis: Optional[str] = None,
    p_pod: int = 1,
    native: bool = True,
    data_rank: Optional[jax.Array] = None,
    pod_rank: Optional[jax.Array] = None,
    telemetry: bool = True,
):
    """The REDUCE half of the bucket pipeline: pack -> EF add -> TopK ->
    per-bucket collective. Returns (reduced, new_residuals, telemetry)
    where ``reduced`` maps bucket name -> the fully reduced, scaled
    (rows, cols) f32 buffer (replicated over the dp axes once the
    collective is done) and ``telemetry`` maps each EF bucket's name ->
    the (4,) f32 [post-reduction nnz, wire bytes, mass coverage,
    EF-residual norm] stats vector (DESIGN.md §7, §10.5) — cheap
    in-graph counts the adaptive controller and health engine consume on
    the host (raw-dense buckets are not re-plannable and emit none).
    ``telemetry=False`` compiles the stats out entirely: the returned
    dict is empty and NO telemetry ops (including the mass psum) are
    traced — not merely DCE'd, absent from the jaxpr.

    Splitting here is what makes the non-blocking runtime possible
    (DESIGN.md §6): the pipelined superstep holds ``reduced`` in flight as
    TrainState.inflight for one step and applies it while the NEXT step's
    collectives run; :func:`apply_buckets` is the other half.

    Scattered plans (DESIGN.md §11) stop at the owner shard: every
    reduced value is my (1, rows, cols/p) owned column chunk (leading
    replica axis, like the residuals) instead of the replicated (rows,
    cols) buffer. Scatter-capable algorithms skip their final allgather
    (the wire win); raw-dense buckets lower to a true psum_scatter;
    non-capable algorithms and the emulated lowering reduce replicated
    and slice (exact parity, no wire saving). Clamp folds are self-local
    — each rank's fold covers only mass it clamped — so the EF residual
    update below is unchanged and residuals stay full width.

    leaves: flat per-rank grad leaves (original layouts, jax.tree.leaves
    order of the plan's param tree).
    residuals: bucket-keyed dict; inside shard_map each value carries its
    rank's slice with a leading replica axis of size 1.
    """
    from repro.core import topk as topk_mod
    from repro.core.allreduce import (
        dsar_split_allgather_batched_inside,
        safe_psum,
    )
    from repro.core.topk import UniformStream

    cfg = plan.cfg
    scattered = plan.scattered
    if scattered and p_pod > 1:
        raise ValueError(
            "scattered output mode is single-pod only (p_pod == 1): the "
            "owner shard of the cross-pod sum is not local to any pod")
    replicas = p_data * p_pod
    scale = 1.0 / replicas if cfg.mean else 1.0
    coll = CollectiveContext(data_axis, p_data, native=native, rank=data_rank)

    def _own_cols(out2d):
        """Replicated (rows, cols) -> my (rows, cols/p) column shard."""
        rows, cols = out2d.shape
        w = cols // p_data
        return jax.lax.dynamic_slice_in_dim(
            out2d.reshape(rows, p_data, w), coll.axis_rank(), 1, axis=1
        ).reshape(rows, w)

    def _psum_scatter_cols(x2d):
        """Dense reduce-scatter over columns: rank r keeps the summed
        columns [r*w, (r+1)*w) — the true (P-1)/P·n wire form natively;
        the psum-only lowering sums replicated and slices."""
        if native:
            return jax.lax.psum_scatter(
                x2d, data_axis, scatter_dimension=1, tiled=True)
        return _own_cols(coll.psum(x2d))

    if pod_axis is not None and pod_rank is None:
        if not native:
            raise ValueError("emulated multi-pod sync needs a pod rank feed")
        # Native callers (the per-leaf wrapper) may omit the feed; the
        # QSGD rounding key must still fold the pod rank so pods don't
        # share rounding bits.
        pod_rank = jax.lax.axis_index(pod_axis)

    reduced: dict = {}
    new_residuals: dict = {}
    telem: dict = {}
    bucket_idx = 0
    for group in plan.groups:
        buf = pack_group(group, leaves, cfg.bucket_size)     # (rows, cols) f32
        for b in group.buckets:
            seg = jax.lax.slice_in_dim(buf, b.col_start,
                                       b.col_start + b.cols, axis=1)
            if not b.sparse and b.name not in residuals:
                # Fused dense bucket: no feedback state, plain psum —
                # and no telemetry: nothing a replan could change here.
                # Scattered: the psum becomes a true reduce-scatter.
                if scattered:
                    out = _psum_scatter_cols(seg)
                    if pod_axis is not None:          # p_pod == 1 (guard)
                        out = safe_psum(out, pod_axis)
                    reduced[b.name] = (out * scale)[None]
                    bucket_idx += 1
                    continue
                out = safe_psum(seg, data_axis)
                if pod_axis is not None:
                    out = safe_psum(out, pod_axis)
                reduced[b.name] = out * scale
                bucket_idx += 1
                continue

            res = residuals[b.name][0]                        # strip replica axis
            acc = res.astype(jnp.float32) + seg               # Alg. 2 line 1
            u, residual = topk_mod.compress2d(
                acc, cfg.k_per_bucket, cfg.bucket_size)       # Alg. 2 line 2

            algorithm = b.algorithm
            # QSGD belongs to DSAR's dense gather phase ONLY: an SSAR
            # bucket rerouted to DSAR by the emulated fallback stays
            # unquantized, so every lowering of the same plan produces
            # the same values (the executor-parity invariant).
            qsgd = cfg.qsgd() if algorithm == "dsar_split_allgather" else None
            # A size-1 pod axis must not fold the (always-0) pod rank
            # into the rounding key: _qsgd_rand_all skips that fold, and
            # the two lowerings must draw identical bits (parity).
            qsgd_pod_rank = pod_rank if p_pod > 1 else None
            if not native and algorithm.startswith("ssar"):
                algorithm = "dsar_split_allgather"            # DESIGN.md §4
            fold = None
            if algorithm == "dense":
                # Residual-bearing bucket whose cost model picked a dense
                # end-representation (paper §5.3.3): STILL compress + EF,
                # then allreduce the densified stream — the legacy 'auto
                # -> dense' semantics of sparse_allreduce_inside.
                out = (_psum_scatter_cols(u.densify()) if scattered
                       else safe_psum(u.densify(), data_axis))
            elif algorithm == "dsar_split_allgather":
                rand = None
                if qsgd is not None:
                    rand = _qsgd_rand(key, bucket_idx, coll, qsgd_pod_rank,
                                      group.rows * b.cols // p_data, p_data)
                out = dsar_split_allgather_batched_inside(   # Alg. 2 line 3
                    u, axis_name=data_axis, p=p_data, qsgd=qsgd,
                    rand=rand, out_dtype=jnp.float32, impl=cfg.impl,
                    coll=coll, scatter=scattered)
            else:
                # SSAR keeps a sparse end-representation; flat rows only.
                assert group.rows == 1, (b.name, algorithm)
                flat = UniformStream(u.lidx[0], u.val[0], cfg.bucket_size)
                out, fold = _reduce_flat_sparse(flat, algorithm, coll=coll,
                                                impl=cfg.impl,
                                                scatter=scattered)
                out = out[None, :]
            if pod_axis is not None:
                if scattered:
                    out = safe_psum(out, pod_axis)  # p_pod == 1 (guard)
                elif b.pod_sparse and native and group.rows == 1:
                    # Adaptive cross-pod demotion (DESIGN.md §7): the
                    # within-pod result stayed under delta, so the DCN
                    # hop rides a sparse stream exchange, not dense psum.
                    cap = min(b.n, p_data * plan.bucket_k(group, b))
                    out = _pod_sparse_exchange(out, pod_axis, cap)
                else:
                    out = safe_psum(out, pod_axis)            # hierarchical
            reduced[b.name] = (out * scale)[None] if scattered else out * scale
            if fold is not None:
                # Global-residual rule (DESIGN.md §9): mass clamped off
                # the wire by a portfolio algorithm re-enters THIS rank's
                # EF residual at pre-scale magnitude, so it is
                # contributed exactly once on a later step — no gradient
                # mass is silently lost. Folded BEFORE the telemetry
                # read so the reported EF norm covers the clamped mass.
                residual = residual + fold[None, :]
            if telemetry:
                # Mass stats are per-rank sums here; ONE extra (3,) psum
                # per EF bucket makes them global (in-graph collective —
                # no host sync point, the no-added-sync invariant holds).
                m = coll.psum(_local_mass(u.val, acc, residual))
                if pod_axis is not None and p_pod > 1:
                    m = safe_psum(m, pod_axis)
                telem[b.name] = _bucket_telemetry(out, plan, group, b,
                                                  p_data, p_pod, coll=coll,
                                                  mass=m)
            new_residuals[b.name] = residual.astype(res.dtype)[None]
            bucket_idx += 1
    return reduced, new_residuals, telem


def apply_buckets(plan: SyncPlan, reduced: dict, leaves: Sequence[jax.Array]):
    """The APPLY half: reassemble each group buffer from its reduced
    buckets (name-keyed, as produced by :func:`reduce_buckets` — possibly
    a step earlier, via TrainState.inflight) and unpack back to the
    original leaf layouts. Pure reshapes/concats, no communication.

    leaves: shape/dtype references for the unpack (any per-rank leaf tree
    of the plan's layout). Returns the flat new-leaf list; leaves not
    covered by the plan come back as None.

    Scattered owner chunks are NOT unpackable here — the optimizer
    consumes them directly and the allgather moves to the PARAM side
    (train/train_step.py); the SPMD formulation may first rebuild full
    buffers via :func:`unchunk_buckets_spmd` and then apply. The shape
    check below catches the misuse before it becomes an opaque reshape.
    """
    for group in plan.groups:
        for b in group.buckets:
            if reduced[b.name].shape != (group.rows, b.cols):
                raise ValueError(
                    f"apply_buckets expects replicated (rows, cols) "
                    f"buffers; got {reduced[b.name].shape} for {b.name} — "
                    "scattered chunks feed the shard update "
                    "(_zero_scattered_update) or unchunk_buckets_spmd")
    new_leaves: list = [None] * plan.num_leaves
    for group in plan.groups:
        parts = [reduced[b.name] for b in group.buckets]
        out_buf = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        for leaf_id, arr in unpack_group(group, out_buf, leaves):
            new_leaves[leaf_id] = arr
    return new_leaves


def execute_plan(
    plan: SyncPlan,
    leaves: Sequence[jax.Array],
    residuals: dict,
    key: jax.Array,
    *,
    data_axis: str = "data",
    p_data: int,
    pod_axis: Optional[str] = None,
    p_pod: int = 1,
    native: bool = True,
    data_rank: Optional[jax.Array] = None,
    pod_rank: Optional[jax.Array] = None,
):
    """Synchronous sync of the planned leaves: :func:`reduce_buckets`
    composed immediately with :func:`apply_buckets` (the staleness=0
    path). Returns (new_leaves, new_residuals); telemetry is compiled
    out here — callers that want it compose the halves themselves."""
    reduced, new_residuals, _ = reduce_buckets(
        plan, leaves, residuals, key, data_axis=data_axis, p_data=p_data,
        pod_axis=pod_axis, p_pod=p_pod, native=native,
        data_rank=data_rank, pod_rank=pod_rank, telemetry=False)
    return apply_buckets(plan, reduced, leaves), new_residuals


# --------------------------------------------------------------------------
# Auto-SPMD formulation (no shard_map) — DESIGN.md §4.2
# --------------------------------------------------------------------------

def _qsgd_rand_all(key, bucket_idx: int, p_pod: int, p_data: int,
                   shard_elems: int):
    """(p_pod, p_data, shard) rounding bits — bit-compatible with the
    per-rank fold order of :func:`_qsgd_rand` (bucket, pod, data)."""
    sub = jax.random.fold_in(key, bucket_idx)
    pods = []
    for a in range(p_pod):
        sp = jax.random.fold_in(sub, a) if p_pod > 1 else sub
        pods.append(jnp.stack([
            jax.random.bits(jax.random.fold_in(sp, j), (shard_elems,),
                            dtype=jnp.uint32)
            for j in range(p_data)
        ]))
    return jnp.stack(pods)


def reduce_buckets_spmd(
    plan: SyncPlan,
    leaves_r: Sequence[jax.Array],
    residuals: dict,
    key: jax.Array,
    *,
    p_data: int,
    p_pod: int = 1,
    telemetry: bool = True,
):
    """The same REDUCE half as :func:`reduce_buckets`, expressed as
    plain auto-SPMD array ops OUTSIDE any shard_map.

    Used on backends whose partitioner cannot lower a partial-manual
    training step at all (XLA-CPU container build: every explicit
    collective but psum, ``lax.scan`` bodies, and PartitionId abort — see
    DESIGN.md §4.2). The replica axis is a real leading axis instead:

    leaves_r: per-rank grads stacked as (R, *leaf_shape), R = p_pod*p_data,
    leading axis sharded over the dp mesh axes — "rank r's grads" IS the
    r-th slice, so per-rank TopK/EF semantics are preserved exactly and
    the reductions below lower to XLA's own all-reduces over the dp axes.
    residuals: bucket-keyed, FULL (R, rows, cols) arrays (not slices).

    Returns (reduced {bucket name -> (rows, cols) f32 buffer}, new
    bucket-keyed residuals (full arrays), telemetry {name -> (4,) f32
    [nnz, wire bytes, mass coverage, EF norm]; empty and fully compiled
    out under ``telemetry=False``). The mass sums need no collective
    here — the (R, ...) stacks already hold every rank's slice, so the
    all-axis sums ARE global. Numerics match the manual executor: sums over
    the leading axis are the allreduce; DSAR+QSGD replays every (pod,
    range-owner) quantization on the pod-local sums. SSAR algorithms
    reduce exactly (their wire layout has no numeric effect), so they
    fold into the same sum here — as does the sparse pod exchange of
    ``pod_sparse`` buckets (exact by construction). Telemetry still
    reports the wire cost of the NATIVE path this formulation models.

    Scattered plans (DESIGN.md §11): reduced values become the FULL
    (p_data, rows, cols/p) owner-chunk stack — chunk r holds exactly the
    columns rank r owns, bit-identical elements to the replicated
    buffer — laid out to shard 1/P per device under
    ``plan.scattered_specs``. XLA's partitioner turns the sum + chunked
    use into its own reduce-scatter; the formulation models the same
    wire the native scatter path pays.
    """
    from repro.comm.buckets import to_canonical
    from repro.core import topk as topk_mod

    cfg = plan.cfg
    scattered = plan.scattered
    if scattered and p_pod > 1:
        raise ValueError(
            "scattered output mode is single-pod only (p_pod == 1)")
    replicas = p_data * p_pod
    scale = 1.0 / replicas if cfg.mean else 1.0
    qsgd = cfg.qsgd()

    def _chunked(out2d):
        """(rows, cols) full sum -> (p_data, rows, cols/p) owner chunks."""
        rows, cols = out2d.shape
        w = cols // p_data
        return out2d.reshape(rows, p_data, w).transpose(1, 0, 2)

    reduced: dict = {}
    new_residuals: dict = {}
    telem: dict = {}
    bucket_idx = 0
    for group in plan.groups:
        segs = [
            jax.vmap(lambda g, s=slot: to_canonical(g, s.spec, cfg.bucket_size)
                     .astype(jnp.float32))(leaves_r[slot.leaf_id])
            for slot in group.slots
        ]
        buf = segs[0] if len(segs) == 1 else jnp.concatenate(segs, axis=2)
        pad = group.cols - buf.shape[2]
        if pad:
            buf = jnp.pad(buf, ((0, 0), (0, 0), (0, pad)))  # (R, rows, cols)
        for b in group.buckets:
            seg = jax.lax.slice_in_dim(buf, b.col_start,
                                       b.col_start + b.cols, axis=2)
            if not b.sparse and b.name not in residuals:
                # raw-dense: no telemetry (see _bucket_telemetry)
                out = seg.sum(axis=0) * scale
                reduced[b.name] = _chunked(out) if scattered else out
                bucket_idx += 1
                continue
            res = residuals[b.name]                           # (R, rows, cols)
            acc = res.astype(jnp.float32) + seg
            u, residual = topk_mod.compress2d(
                acc, cfg.k_per_bucket, cfg.bucket_size)
            dens = u.densify()                                # (R, rows, m*B)
            rows, mb = dens.shape[1], dens.shape[2]
            dpod = dens.reshape(p_pod, p_data, rows, mb).sum(axis=1)
            if qsgd is not None and b.algorithm == "dsar_split_allgather":
                shard = mb // p_data
                bq = qsgd.bucket_size
                nbq = shard // bq
                x = dpod.reshape(p_pod, rows, p_data, shard)
                x = x.transpose(0, 2, 1, 3)        # (p_pod, p_data, rows, shard)
                rand = _qsgd_rand_all(key, bucket_idx, p_pod, p_data,
                                      rows * shard)
                xq = _qsgd_roundtrip_spmd(
                    x.reshape(p_pod * p_data * rows * nbq, bq),
                    rand.reshape(p_pod * p_data * rows * nbq, bq),
                    qsgd, cfg.impl)
                dpod = (xq.reshape(p_pod, p_data, rows, shard)
                        .transpose(0, 2, 1, 3).reshape(p_pod, rows, mb))
            out = dpod.sum(axis=0)
            reduced[b.name] = (_chunked(out * scale) if scattered
                               else out * scale)
            if telemetry:
                telem[b.name] = _bucket_telemetry(
                    out, plan, group, b, p_data, p_pod,
                    mass=_local_mass(u.val, acc, residual))
            new_residuals[b.name] = residual.astype(res.dtype)
            bucket_idx += 1
    return reduced, new_residuals, telem


def unchunk_buckets_spmd(plan: SyncPlan, reduced: dict) -> dict:
    """Scattered (p, rows, w) owner-chunk stacks -> replicated (rows,
    cols) buffers. Pure reshapes: the SPMD formulation holds the full
    stack (chunk r IS columns [r*w, (r+1)*w)), so the inverse of the
    executor's ``_chunked`` is exact — XLA materializes the gather this
    implies, which is precisely the param/grad allgather the manual
    scattered path issues explicitly."""
    out = dict(reduced)
    for group in plan.groups:
        for b in group.buckets:
            ch = reduced[b.name]
            p, rows, w = ch.shape
            out[b.name] = ch.transpose(1, 0, 2).reshape(rows, p * w)
    return out


def apply_buckets_spmd(plan: SyncPlan, reduced: dict,
                       leaves_r: Sequence[jax.Array]):
    """APPLY half of the auto-SPMD formulation: unpack name-keyed reduced
    buffers back to original leaf layouts (replica-replicated). leaves_r
    carry the (R, *leaf) per-rank layout; rank-0 slices stand in as the
    shape/dtype references for the unpack."""
    ref_leaves = [l[0] for l in leaves_r]
    return apply_buckets(plan, reduced, ref_leaves)


def execute_plan_spmd(
    plan: SyncPlan,
    leaves_r: Sequence[jax.Array],
    residuals: dict,
    key: jax.Array,
    *,
    p_data: int,
    p_pod: int = 1,
):
    """Synchronous auto-SPMD sync: :func:`reduce_buckets_spmd` composed
    immediately with :func:`apply_buckets_spmd` (the staleness=0 path).
    Returns (synced leaves in original layout, new residuals); telemetry
    is compiled out, as in :func:`execute_plan`."""
    reduced, new_residuals, _ = reduce_buckets_spmd(
        plan, leaves_r, residuals, key, p_data=p_data, p_pod=p_pod,
        telemetry=False)
    return apply_buckets_spmd(plan, reduced, leaves_r), new_residuals


def _qsgd_roundtrip_spmd(x2d, rand2d, qsgd, impl: str):
    from repro.core.allreduce import _qsgd_roundtrip

    return _qsgd_roundtrip(x2d, rand2d, qsgd, impl, jnp.float32)


# --------------------------------------------------------------------------
# Serve-time activation exchange (DESIGN.md §8)
# --------------------------------------------------------------------------
#
# The decode-time MoE combine is an allreduce of a (T, d) buffer over the
# expert/model axis whose per-shard partial is ROW-sparse: token row t is
# nonzero only when token t is active AND routed one of its experts to
# this shard. The ServePlan (comm/plan.py) picks the wire representation
# per compiled decode step; these two functions are its executor.
#
# Exactness contract (the serve analogue of the pod_sparse exchange): the
# stream path computes THE SAME SUM as the dense psum, bit for bit, as
# long as every shard's nonzero row count stays under the stream capacity
# — which the engine's occupancy guard enforces before dispatching a
# sparse-plan step.


def _row_stream_roundtrip(partial: jax.Array, cap: int) -> jax.Array:
    """(T, d) partial -> row stream at capacity ``cap`` -> dense again.
    Identity (bit-for-bit) while nonzero rows <= cap; materializing the
    round-trip in-graph is what makes the emulated/SPMD lowerings of the
    stream path numerically IDENTICAL to the dense reference — and makes
    a capacity overflow visible as a parity break instead of silence."""
    from repro.core import sparse_stream as ss

    mask = jnp.any(partial != 0, axis=1)
    return ss.densify_rows(ss.from_row_mask(partial, mask, cap),
                           partial.shape[0])


def exchange_activation(
    partial: jax.Array,
    algorithm: str,
    *,
    coll: CollectiveContext,
):
    """One shard's (T, d) combine partial -> the fully-summed (T, d),
    INSIDE a shard_map manual over the expert/model axis.

    'dense': the reference psum. 'stream_gather@C': the planned (idx,val)
    row-stream exchange — native lowerings all-gather each rank's stream
    and scatter every foreign stream back to dense before the sum;
    emulated (psum-only) lowerings round-trip the partial through the
    stream locally and ride the psum wire, exactly like the pod_sparse
    demotion (DESIGN.md §7.2): modeled stream wire, identical numerics.
    """
    from repro.core import sparse_stream as ss

    if algorithm == "dense":
        return coll.psum(partial)
    from repro.core.cost_model import parse_stream_cap

    cap = parse_stream_cap(algorithm)
    if not coll.native:
        return coll.psum(_row_stream_roundtrip(partial, cap))
    t = partial.shape[0]
    stream = ss.from_row_mask(partial, jnp.any(partial != 0, axis=1), cap)
    idx_all = coll.all_gather(stream.idx[None], axis=0)     # (p, cap)
    val_all = coll.all_gather(stream.val[None], axis=0)     # (p, cap, d)
    dense_all = jax.vmap(
        lambda i, v: ss.densify_rows(
            ss.RowStream(i, v, jnp.asarray(0, jnp.int32)), t)
    )(idx_all, val_all)                                     # (p, T, d)
    return dense_all.sum(axis=0)


def exchange_activation_spmd(partials: jax.Array, algorithm: str):
    """The auto-SPMD formulation of :func:`exchange_activation`: the
    shard axis is a real leading axis (p, T, d) — shard s's partial IS
    the s-th slice — and the sum over it lowers to XLA's own all-reduce
    over the sharded axis (DESIGN.md §4.2). The stream path round-trips
    each shard's partial through its row stream first: bitwise the same
    summands as the dense path while under capacity, so sparse == dense
    exactly, whatever reduction order the backend picks."""
    from repro.core.cost_model import parse_stream_cap

    if algorithm != "dense":
        cap = parse_stream_cap(algorithm)
        partials = jax.vmap(lambda x: _row_stream_roundtrip(x, cap))(partials)
    return partials.sum(axis=0)
