"""Fusion-bucket gradient sync engine (DESIGN.md §3).

SparCML's scaling claim rests on amortizing the latency (alpha) term of
the collective over the WHOLE gradient, not paying it once per layer.
This package turns the per-leaf sync of ``core/compressor.py`` into a
planned, fused pipeline:

  plan.py        trace-time SyncPlan: packs all gradient leaves into a
                 small number of fixed-size fusion buckets in canonical
                 layout; per-bucket algorithm selection via the cost model
  buckets.py     leaf <-> bucket packing/unpacking (pure reshapes/concats)
  collectives.py data-axis collectives with a psum-emulated fallback for
                 partial-manual shard_map regions on backends whose SPMD
                 partitioner cannot lower them (XLA-CPU)
  executor.py    one TopK-compress + sparse allreduce per bucket, with
                 error-feedback residual state keyed by bucket

``core/allreduce.py`` stays the algorithm layer (SSAR/DSAR); the executor
invokes it per bucket. Per-leaf entry points in ``core/compressor.py``
are thin wrappers over a one-leaf-per-bucket plan.
"""
from repro.comm.buckets import pack_group, unpack_group
from repro.comm.collectives import CollectiveContext
from repro.comm.executor import (
    apply_buckets,
    apply_buckets_spmd,
    exchange_activation,
    exchange_activation_spmd,
    execute_plan,
    execute_plan_spmd,
    reduce_buckets,
    reduce_buckets_spmd,
    unchunk_buckets_spmd,
)
from repro.comm.plan import (
    ActivationBucketSpec,
    BucketSpec,
    GroupSpec,
    LeafSlot,
    ServePlan,
    SyncPlan,
    build_per_leaf_plan,
    build_serve_plan,
    build_sync_plan,
)

__all__ = [
    "ActivationBucketSpec",
    "BucketSpec",
    "CollectiveContext",
    "GroupSpec",
    "LeafSlot",
    "ServePlan",
    "SyncPlan",
    "apply_buckets",
    "apply_buckets_spmd",
    "build_per_leaf_plan",
    "build_serve_plan",
    "build_sync_plan",
    "exchange_activation",
    "exchange_activation_spmd",
    "execute_plan",
    "execute_plan_spmd",
    "pack_group",
    "reduce_buckets",
    "reduce_buckets_spmd",
    "unchunk_buckets_spmd",
    "unpack_group",
]
