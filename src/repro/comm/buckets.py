"""Canonical layout + leaf<->fusion-bucket packing (DESIGN.md §2, §3.2).

Canonical layout (moved here from ``core/compressor.py``; the old names
stay importable from there): the 'model'-sharded axis of a leaf is moved
to the front so the (m, B) bucket reshape never crosses a shard boundary
— zero resharding under SPMD. Leaves without a model-sharded axis
canonicalize to a single row.

Fusion packing: all leaves of a plan *group* (same canonical row count)
are concatenated along the column axis into one fused buffer, padded at
the tail to the plan's bucket quantum. Packing/unpacking are pure
reshape/concat/slice — no cross-rank communication and no data-dependent
shapes, so they fuse into the surrounding step program.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Sequence

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # avoid an import cycle with core.compressor
    from repro.comm.plan import GroupSpec


# --------------------------------------------------------------------------
# Canonical layout (model-sharded axis first, trailing dims bucket-padded)
# --------------------------------------------------------------------------

def model_axis(spec, model_axis_name: str = "model") -> int | None:
    """Index of the dim sharded over 'model' in a PartitionSpec, if any."""
    if spec is None:
        return None
    for i, s in enumerate(spec):
        names = s if isinstance(s, tuple) else (s,)
        if model_axis_name in [n for n in names if n]:
            return i
    return None


def canonical_shape(shape: tuple[int, ...], spec, bucket_size: int,
                    model_axis_name: str = "model") -> tuple[int, int]:
    """(rows, padded_cols) of the canonical 2-D layout for a leaf."""
    ax = model_axis(spec, model_axis_name)
    if ax is None or len(shape) <= 1:
        lead, rest = 1, int(np.prod(shape))
    else:
        lead = shape[ax]
        rest = int(np.prod(shape)) // lead
    cols = -(-rest // bucket_size) * bucket_size
    return lead, cols


def to_canonical(g: jax.Array, spec, bucket_size: int,
                 model_axis_name: str = "model") -> jax.Array:
    rows, cols = canonical_shape(g.shape, spec, bucket_size, model_axis_name)
    ax = model_axis(spec, model_axis_name)
    if ax is not None and g.ndim > 1 and ax != 0:
        g = jnp.moveaxis(g, ax, 0)
    g2 = g.reshape(rows, -1)
    pad = cols - g2.shape[1]
    if pad:
        g2 = jnp.pad(g2, ((0, 0), (0, pad)))
    return g2


def from_canonical(c: jax.Array, orig_shape: tuple[int, ...], spec,
                   model_axis_name: str = "model") -> jax.Array:
    ax = model_axis(spec, model_axis_name)
    if ax is None or len(orig_shape) <= 1:
        n = int(np.prod(orig_shape))
        return c.reshape(-1)[:n].reshape(orig_shape)
    moved = tuple([orig_shape[ax]] + [s for i, s in enumerate(orig_shape) if i != ax])
    rest = int(np.prod(moved[1:]))
    out = c[:, :rest].reshape(moved)
    return jnp.moveaxis(out, 0, ax)


# --------------------------------------------------------------------------
# Group pack / unpack
# --------------------------------------------------------------------------

def pack_group(group: "GroupSpec", leaves: Sequence[jax.Array],
               bucket_size: int, dtype=jnp.float32) -> jax.Array:
    """Fuse a group's leaves into one canonical (rows, group.cols) buffer.

    Column offsets follow ``group.slots`` (each leaf's canonical cols are
    already a bucket multiple, so slot boundaries stay bucket-aligned);
    the tail past the last slot is zero padding up to the bucket quantum.
    """
    segs = [
        to_canonical(leaves[slot.leaf_id], slot.spec, bucket_size).astype(dtype)
        for slot in group.slots
    ]
    buf = segs[0] if len(segs) == 1 else jnp.concatenate(segs, axis=1)
    pad = group.cols - buf.shape[1]
    if pad:
        buf = jnp.pad(buf, ((0, 0), (0, pad)))
    return buf


def unpack_group(group: "GroupSpec", buf: jax.Array,
                 leaves: Sequence[jax.Array]) -> list[tuple[int, jax.Array]]:
    """Split a reduced group buffer back into (leaf_id, leaf-shaped array)
    pairs, casting each to its original leaf dtype."""
    out = []
    for slot in group.slots:
        seg = jax.lax.slice_in_dim(buf, slot.offset, slot.offset + slot.cols,
                                   axis=1)
        leaf = leaves[slot.leaf_id]
        out.append((slot.leaf_id,
                    from_canonical(seg, slot.shape, slot.spec).astype(leaf.dtype)))
    return out
