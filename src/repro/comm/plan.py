"""Trace-time sync planning: leaves -> fusion buckets (DESIGN.md §3).

A :class:`SyncPlan` is built ONCE per train-step configuration from
``param_shapes`` + ``param_specs`` + ``SyncConfig`` + the data-parallel
world size — and may then be RE-derived at runtime: ``SyncPlan.replan``
produces versioned successors with re-selected per-bucket algorithms
from measured densities (the adaptive engine, DESIGN.md §7), keeping
the geometry and state layout invariant. The base plan decides, at
trace time:

* which *group* each leaf belongs to (leaves with the same canonical row
  count fuse together; model-sharded leaves keep their batched row axis,
  everything else lands in the single flat row-1 group — including the
  small leaves that the per-leaf path used to send over dense psum);
* how each group's fused column space is chopped into fixed-size
  *fusion buckets* (quantum = bucket_size x dp_total columns so the
  split phase always divides, x the QSGD bucket when quantizing);
* which algorithm each bucket runs (``cost_model.select_bucket_algorithm``
  per bucket: SSAR recursive-double for high-sparsity flat buckets,
  DSAR+QSGD for dense-ish ones, plain psum below ``min_sparse_size``).

Error-feedback residual state is keyed BY BUCKET (``plan.residual_*``),
not by leaf: a bucket is the unit of compression, so it is the unit of
feedback. The executor (executor.py) runs one TopK-compress + sparse
allreduce per bucket.

``cfg`` is duck-typed (``repro.core.compressor.SyncConfig``); importing
it here would cycle — compressor's per-leaf entry points are themselves
thin wrappers over :func:`build_per_leaf_plan`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.buckets import canonical_shape, model_axis

# NOTE: repro.core is imported lazily (inside functions) throughout comm:
# core/__init__ eagerly re-exports core.compressor, which imports comm for
# its thin wrappers — a module-level import here would close that cycle.

SPARSE_ALGORITHMS = ("ssar_recursive_double", "ssar_split_allgather",
                     "dsar_split_allgather",
                     # capacity-clamped portfolio (DESIGN.md §9): O(k)
                     # traffic; clamp drops fold into the EF residual
                     "ssar_balanced_split", "ssar_rearranged_rs")
# The batched (rows > 1) pipeline keeps the model-sharded row axis as a
# pure batch dim; only DSAR (and dense) are implemented batched.
BATCHED_ALGORITHMS = ("dsar_split_allgather", "dense")


@dataclass(frozen=True)
class LeafSlot:
    """Where one leaf lives inside its group's fused canonical buffer."""

    leaf_id: int                  # index in jax.tree.leaves order
    shape: tuple[int, ...]        # original leaf shape
    spec: Any                     # PartitionSpec (or None)
    rows: int                     # canonical rows
    cols: int                     # canonical padded cols (bucket multiple)
    offset: int                   # column offset inside the group buffer


@dataclass(frozen=True)
class BucketSpec:
    """One fusion bucket: a contiguous column range of a group buffer."""

    name: str                     # residual-state key, stable across runs
    col_start: int
    cols: int
    rows: int
    algorithm: str                # resolved: one of SPARSE_ALGORITHMS|'dense'
    # Adaptive re-planning (DESIGN.md §7): whether this bucket carries
    # error-feedback state is pinned at BUILD time (None = follow
    # `sparse`), so a replan that demotes a bucket's wire representation
    # to 'dense' keeps the residual dict — and therefore the TrainState
    # tree structure and every checkpoint — layout-invariant.
    ef: Optional[bool] = None
    # Route the cross-pod phase as a sparse (idx,val) stream exchange
    # instead of the dense psum, when the within-pod reduction stays
    # under the delta threshold. Wire-path only; numerics are exact.
    pod_sparse: bool = False

    @property
    def sparse(self) -> bool:
        return self.algorithm != "dense"

    @property
    def has_residual(self) -> bool:
        """Carries EF state: compress-then-reduce, whatever the current
        wire representation ('dense' here = the compressed stream's dense
        END-representation, paper §5.3.3 — NOT an uncompressed psum)."""
        return self.sparse if self.ef is None else self.ef

    @property
    def n(self) -> int:
        return self.rows * self.cols


@dataclass(frozen=True)
class GroupSpec:
    """All leaves sharing one canonical row count, fused along columns."""

    gid: int
    rows: int
    model_sharded: bool           # row axis carries the 'model' sharding
    cols: int                     # total padded cols (sum of bucket cols)
    slots: tuple[LeafSlot, ...]
    buckets: tuple[BucketSpec, ...]


@dataclass(frozen=True)
class SyncPlan:
    """The full fusion plan for one (param tree, SyncConfig, dp) triple.

    Plans are VERSIONED and re-derivable (DESIGN.md §7): ``replan``
    produces a successor with the same geometry (groups, buckets, leaf
    slots, residual layout) but re-selected per-bucket algorithms — the
    unit the adaptive runtime swaps at drain barriers."""

    cfg: Any                      # SyncConfig (duck-typed)
    dp_total: int
    num_leaves: int
    groups: tuple[GroupSpec, ...]
    version: int = 0              # bumped by every replan()
    # ZeRO-sharded exchange (DESIGN.md §11). 'replicated': every rank
    # re-densifies the full reduction (the classic sparse allreduce).
    # 'scattered': the exchange stops at the owner shard — rank r keeps
    # bucket columns [r*w, (r+1)*w), w = cols/dp_total — and the
    # optimizer update runs on the shard, followed by a dense param
    # allgather. Single-pod only (the cross-pod phase re-replicates).
    output_mode: str = "replicated"

    # -- summary -----------------------------------------------------------
    @property
    def scattered(self) -> bool:
        return self.output_mode == "scattered"

    def owned_cols(self, b: "BucketSpec") -> int:
        """Column width of one rank's owned range of a bucket. Always
        integral: the column quantum is bucket_size x dp_total."""
        assert b.cols % self.dp_total == 0, (b.name, b.cols, self.dp_total)
        return b.cols // self.dp_total

    @property
    def buckets(self) -> tuple[BucketSpec, ...]:
        return tuple(b for g in self.groups for b in g.buckets)

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    @property
    def num_sparse_buckets(self) -> int:
        return sum(1 for b in self.buckets if b.sparse)

    def covered_leaf_ids(self) -> set[int]:
        return {s.leaf_id for g in self.groups for s in g.slots}

    # -- adaptive re-planning (DESIGN.md §7) -------------------------------
    def algorithms(self) -> dict[str, str]:
        """Bucket name -> resolved algorithm (the serializable plan
        content; checkpoints carry this so restarts resume adapted)."""
        return {b.name: b.algorithm for b in self.buckets}

    def pod_sparse_flags(self) -> dict[str, bool]:
        return {b.name: b.pod_sparse for b in self.buckets}

    def signature(self) -> str:
        """Stable content key for the compiled-step cache and checkpoint
        meta: per-bucket algorithm (+pod-sparse marker), geometry-ordered.
        Scattered plans are prefixed — the output mode changes the
        compiled step's state layout, so it MUST key the cache (replicated
        signatures keep their historical form for checkpoint compat)."""
        algos = ",".join(
            f"{b.name}={b.algorithm}{'+ps' if b.pod_sparse else ''}"
            for b in self.buckets)
        return f"out=scattered|{algos}" if self.scattered else algos

    def bucket_k(self, group: "GroupSpec", b: "BucketSpec") -> int:
        """TOTAL selected items of one bucket per rank per step."""
        return group.rows * (b.cols // self.cfg.bucket_size) * \
            self.cfg.k_per_bucket

    def replan(self, densities: Optional[dict] = None, net=None, *,
               algorithms: Optional[dict] = None,
               pod_sparse: Optional[dict] = None,
               allow: Optional[tuple] = None,
               output_mode: Optional[str] = None) -> "SyncPlan":
        """A successor plan with re-selected bucket algorithms.

        Either re-run the cost model with MEASURED post-reduction nnz per
        bucket (``densities``: name -> nnz, from the telemetry window)
        and calibrated ``net`` params, or apply explicit ``algorithms``
        overrides (checkpoint resume). ``allow`` optionally restricts the
        candidate set further (the adaptive controller's configured allow
        set); structural constraints below still apply on top of it.
        Structural invariants:

        * buckets without EF state (raw-dense at build: under
          ``min_sparse_size`` or never planned sparse) stay raw-dense —
          they have no compression stats and no residual buffer to carry;
        * EF-bearing buckets keep their residual whatever the new wire
          representation (``ef`` pinned), so TrainState layout and
          checkpoints are invariant under every replan;
        * batched (rows > 1) buckets stay within BATCHED_ALGORITHMS.

        ``output_mode`` overrides the plan's output mode (None keeps it).
        NOTE: a mode change alters the inflight/optimizer state layout —
        only a runtime that rebuilds state (not the drain-barrier swap)
        may apply one; AdaptiveRuntime pins the mode for this reason.
        """
        from repro.core.cost_model import DEFAULT_NET, select_bucket_algorithm

        net = net or DEFAULT_NET
        cfg = self.cfg
        vb = cfg.qsgd_bits if cfg.qsgd_bits is not None else 32
        new_groups = []
        for g in self.groups:
            new_buckets = []
            for b in g.buckets:
                if not b.has_residual:
                    new_buckets.append(b)        # permanently raw-dense
                    continue
                allowed = (SPARSE_ALGORITHMS + ("dense",) if g.rows == 1
                           else BATCHED_ALGORITHMS)
                if allow is not None:
                    narrowed = tuple(a for a in allowed if a in allow)
                    allowed = narrowed or allowed
                if algorithms is not None:
                    algo = algorithms.get(b.name, b.algorithm)
                else:
                    nnz = None if densities is None else densities.get(b.name)
                    algo = select_bucket_algorithm(
                        self.dp_total, self.bucket_k(g, b), b.n, net,
                        value_bits=vb, allow=allowed, reduced_nnz=nnz)
                if algo not in allowed:
                    algo = "dsar_split_allgather"
                ps = b.pod_sparse if pod_sparse is None else \
                    bool(pod_sparse.get(b.name, b.pod_sparse))
                new_buckets.append(BucketSpec(
                    b.name, b.col_start, b.cols, b.rows, algo,
                    ef=b.has_residual, pod_sparse=ps and g.rows == 1))
            new_groups.append(GroupSpec(g.gid, g.rows, g.model_sharded,
                                        g.cols, g.slots, tuple(new_buckets)))
        import dataclasses

        mode = self.output_mode if output_mode is None else output_mode
        if mode not in ("replicated", "scattered"):
            raise ValueError(f"unknown output_mode {mode!r}")
        return dataclasses.replace(self, groups=tuple(new_groups),
                                   version=self.version + 1,
                                   output_mode=mode)

    # -- error-feedback residual state (keyed by bucket) -------------------
    def residual_shapes(self) -> dict[str, jax.ShapeDtypeStruct]:
        """Bucket-name -> ShapeDtypeStruct (leading per-replica axis).
        Raw-dense buckets carry no feedback state and are skipped; a
        replan-demoted bucket (``ef`` pinned True) keeps its residual."""
        out = {}
        for g in self.groups:
            for b in g.buckets:
                if b.has_residual:
                    out[b.name] = jax.ShapeDtypeStruct(
                        (self.dp_total, g.rows, b.cols), self.cfg.ef_dtype)
        return out

    def residual_specs(self, dp_axes=("pod", "data")) -> dict:
        from jax.sharding import PartitionSpec as P

        out = {}
        for g in self.groups:
            for b in g.buckets:
                if b.has_residual:
                    out[b.name] = P(dp_axes,
                                    "model" if g.model_sharded else None, None)
        return out

    def init_residuals(self) -> dict[str, jax.Array]:
        return {k: jnp.zeros(s.shape, s.dtype)
                for k, s in self.residual_shapes().items()}

    # -- owner-chunk layout (scattered mode, DESIGN.md §11) ----------------
    def scattered_shapes(self) -> dict[str, jax.ShapeDtypeStruct]:
        """Bucket-name -> (dp_total, rows, cols/dp_total) owner-chunk
        layout: chunk r is rank r's owned column range. The SAME leading-
        per-replica-axis convention as residuals — shard_map sees (1,
        rows, w), auto-SPMD the full chunked array. This is the layout of
        scattered reduced/inflight buffers AND of the sharded optimizer
        moments built on top of them."""
        out = {}
        for g in self.groups:
            for b in g.buckets:
                out[b.name] = jax.ShapeDtypeStruct(
                    (self.dp_total, g.rows, self.owned_cols(b)), jnp.float32)
        return out

    def scattered_specs(self, dp_axes=("pod", "data")) -> dict:
        from jax.sharding import PartitionSpec as P

        out = {}
        for g in self.groups:
            for b in g.buckets:
                out[b.name] = P(dp_axes,
                                "model" if g.model_sharded else None, None)
        return out

    # -- in-flight reduced-bucket state (non-blocking runtime, DESIGN §6) --
    def inflight_shapes(self) -> dict[str, jax.ShapeDtypeStruct]:
        """Bucket-name -> ShapeDtypeStruct of the REDUCED f32 buffer held
        between a superstep's reduce and the next superstep's apply.
        EVERY bucket has one (dense buckets too — their psum result is
        equally in flight); only sparse buckets carry residuals.
        Replicated mode: the full (rows, cols) buffer. Scattered mode:
        the (dp_total, rows, cols/dp_total) owner chunks — each rank only
        ever holds its 1/P shard of the reduction."""
        if self.scattered:
            return self.scattered_shapes()
        out = {}
        for g in self.groups:
            for b in g.buckets:
                out[b.name] = jax.ShapeDtypeStruct((g.rows, b.cols),
                                                   jnp.float32)
        return out

    def inflight_specs(self, dp_axes=("pod", "data")) -> dict:
        """Replicated reduced buffers are dp-replicated (the collective
        already ran); model-sharded groups keep their row sharding under
        auto. Scattered buffers shard their leading chunk axis over the
        dp axes, like residuals."""
        from jax.sharding import PartitionSpec as P

        if self.scattered:
            return self.scattered_specs(dp_axes)
        out = {}
        for g in self.groups:
            for b in g.buckets:
                out[b.name] = P("model" if g.model_sharded else None, None)
        return out

    def init_inflight(self) -> dict[str, jax.Array]:
        return {k: jnp.zeros(s.shape, s.dtype)
                for k, s in self.inflight_shapes().items()}

    # -- analytic wire traffic -------------------------------------------
    def wire_bytes(self, p: Optional[int] = None, *,
                   aggregate: bool = False) -> float:
        """GRADIENT-EXCHANGE bytes on the wire under this plan, per rank
        per step by default; ``aggregate=True`` multiplies by ``p`` (the
        whole data axis). ONE accounting for every mode and algorithm:
        each bucket delegates to ``cost_model.bucket_wire_bytes`` — the
        same registry entry the executor's in-graph telemetry charges —
        so the modeled figure, the measured figure, and the adaptive
        controller can never diverge (the PR-5 hand-written per-algorithm
        arithmetic here had drifted from the registry's capped-phase
        charges). Scattered mode drops each algorithm's gather/allgather
        term; the dense param allgather that replaces it is reported
        separately by :meth:`param_allgather_bytes` (it is overlappable
        and algorithm-independent, so mixing it into the per-algorithm
        exchange figure would blur what the mode actually saves)."""
        from repro.core.cost_model import bucket_wire_bytes

        p = p or self.dp_total
        cfg = self.cfg
        vb = cfg.qsgd_bits if cfg.qsgd_bits is not None else 32
        total = 0.0
        for g in self.groups:
            for b in g.buckets:
                total += bucket_wire_bytes(
                    b.algorithm, p, self.bucket_k(g, b), b.n,
                    value_bits=vb, scattered=self.scattered)
        return total * (p if aggregate else 1)

    def param_allgather_bytes(self, p: Optional[int] = None, *,
                              aggregate: bool = False) -> float:
        """Per-rank bytes of the dense updated-param allgather that
        scattered mode pays instead of the gradient-side gather: every
        bucket ships its (P-1)/P foreign fp32 columns. Zero in replicated
        mode (params never leave the rank). Overlappable with the next
        step's forward (DESIGN.md §11)."""
        if not self.scattered:
            return 0.0
        p = p or self.dp_total
        total = sum((p - 1) / p * b.n * 4 for b in self.buckets)
        return total * (p if aggregate else 1)

    def describe(self) -> str:
        lines = [f"SyncPlan: {self.num_leaves} leaves -> "
                 f"{self.num_buckets} buckets ({self.num_sparse_buckets} sparse)"
                 + (" [scattered]" if self.scattered else "")]
        for g in self.groups:
            lines.append(f"  group {g.gid}: rows={g.rows} cols={g.cols} "
                         f"leaves={len(g.slots)} "
                         f"model_sharded={g.model_sharded}")
            for b in g.buckets:
                lines.append(f"    {b.name}: cols={b.cols} algo={b.algorithm}")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Plan construction
# --------------------------------------------------------------------------

def _flatten_with_specs(param_shapes, param_specs):
    leaves, treedef = jax.tree.flatten(param_shapes)
    specs = treedef.flatten_up_to(param_specs)
    return leaves, specs


def _col_quantum(cfg, dp_total: int) -> int:
    """Bucket columns must divide into dp_total equal whole-TopK-bucket
    shards (split phase), and into whole QSGD buckets per shard."""
    q = cfg.bucket_size
    if cfg.qsgd_bits is not None:
        q = math.lcm(cfg.bucket_size, cfg.qsgd_bucket)
    return q * dp_total


def _bucket_capacity_cols(cfg, dp_total: int, rows: int) -> int:
    q = _col_quantum(cfg, dp_total)
    budget_elems = max(1, getattr(cfg, "fusion_bucket_bytes", 4 << 20) // 4)
    return max(q, budget_elems // rows // q * q)


def _resolve_algorithm(cfg, dp_total: int, rows: int, cols: int) -> str:
    n = rows * cols
    if n < cfg.min_sparse_size:
        return "dense"
    if cfg.algorithm != "auto":
        algo = cfg.algorithm
        if rows > 1 and algo not in BATCHED_ALGORITHMS:
            algo = "dsar_split_allgather"   # batched pipeline: DSAR only
        return algo
    from repro.core.cost_model import select_bucket_algorithm

    nnz = rows * (cols // cfg.bucket_size) * cfg.k_per_bucket
    allow = SPARSE_ALGORITHMS + ("dense",) if rows == 1 else BATCHED_ALGORITHMS
    return select_bucket_algorithm(
        dp_total, nnz, n,
        value_bits=(cfg.qsgd_bits if cfg.qsgd_bits is not None else 32),
        allow=allow)


def _chop(group_cols: int, cap: int, q: int) -> list[int]:
    out, remaining = [], group_cols
    while remaining > 0:
        take = min(cap, remaining)
        out.append(take)
        remaining -= take
    assert all(c % q == 0 for c in out), (out, q)
    return out


def build_sync_plan(param_shapes, param_specs, cfg, dp_total: int) -> SyncPlan:
    """The fused plan: every leaf rides a fusion bucket (small leaves are
    concatenated into the shared flat group instead of falling back to
    per-leaf dense psum; whether a BUCKET goes sparse or dense is the cost
    model's per-bucket decision)."""
    leaves, specs = _flatten_with_specs(param_shapes, param_specs)
    q = _col_quantum(cfg, dp_total)

    by_rows: dict[int, list[tuple[int, Any, Any, int, int]]] = {}
    for i, (sd, spec) in enumerate(zip(leaves, specs)):
        shape = tuple(sd.shape)
        rows, cols = canonical_shape(shape, spec, cfg.bucket_size)
        by_rows.setdefault(rows, []).append((i, shape, spec, rows, cols))

    groups = []
    # flat group (rows == 1) first, then rowed groups by ascending rows:
    # stable bucket names across config-invariant reorderings of the tree.
    for gid, rows in enumerate(sorted(by_rows, key=lambda r: (r != 1, r))):
        entries = by_rows[rows]
        slots, off = [], 0
        for i, shape, spec, r, cols in entries:
            slots.append(LeafSlot(i, shape, spec, r, cols, off))
            off += cols
        group_cols = -(-off // q) * q
        cap = _bucket_capacity_cols(cfg, dp_total, rows)
        buckets, start = [], 0
        for bi, bcols in enumerate(_chop(group_cols, cap, q)):
            algo = _resolve_algorithm(cfg, dp_total, rows, bcols)
            buckets.append(BucketSpec(f"g{gid}b{bi}", start, bcols, rows, algo))
            start += bcols
        model_sharded = rows > 1 and any(
            model_axis(spec) is not None for _, _, spec, _, _ in entries)
        groups.append(GroupSpec(gid, rows, model_sharded, group_cols,
                                tuple(slots), tuple(buckets)))
    mode = getattr(cfg, "output_mode", "replicated")
    if mode not in ("replicated", "scattered"):
        raise ValueError(f"unknown output_mode {mode!r}")
    return SyncPlan(cfg, dp_total, len(leaves), tuple(groups),
                    output_mode=mode)


# --------------------------------------------------------------------------
# Serve-time activation plan (DESIGN.md §8)
# --------------------------------------------------------------------------
#
# The serving engine reuses the SyncPlan machinery for a different wire:
# instead of gradient fusion buckets reduced over the data axes, the unit
# is an ACTIVATION bucket — the (T, d) MoE combine buffer one decode step
# exchanges over the expert/model axis. The plan decides the bucket's
# wire representation per compiled decode step:
#
#   'dense'              the reference psum of the full (T, d) buffer;
#   'stream_gather@C'    a row-stream all-gather at fixed row capacity C
#                        (each rank ships its <=C active-token rows as
#                        (row idx, d-vector) items) — exact as long as
#                        the occupancy stays under C, which the engine's
#                        admission guard enforces.
#
# ServePlan duck-types the SyncPlan surface the adaptive runtime consumes
# (groups/buckets, algorithms, signature, replan, versioning), so the
# SAME AdaptiveController + signature-keyed compiled-step cache drive
# serve-side sparse<->dense dispatch swaps.

SERVE_STREAM = "stream_gather"


@dataclass(frozen=True)
class ServeSyncConfig:
    """Duck-typed stand-in for SyncConfig on the serve side (the adaptive
    controller only reads ``qsgd_bits`` — activation exchange ships
    unquantized rows)."""

    qsgd_bits: Optional[int] = None


@dataclass(frozen=True)
class ActivationBucketSpec:
    """One serve-time activation bucket: a (tokens, d) exchange buffer."""

    name: str
    tokens: int                   # decode slot count T
    d: int                        # model width (row length on the wire)
    algorithm: str                # 'dense' | 'stream_gather@<cap_rows>'
    # SyncPlan-bucket duck-typing for the adaptive controller: activation
    # buckets never ride a cross-pod phase.
    pod_sparse: bool = False

    @property
    def sparse(self) -> bool:
        return self.algorithm != "dense"

    @property
    def cap(self) -> Optional[int]:
        """Row capacity of the stream representation (None when dense)."""
        if not self.sparse:
            return None
        return int(self.algorithm.split("@", 1)[1])

    @property
    def n(self) -> int:
        return self.tokens * self.d

    @property
    def has_residual(self) -> bool:
        """No EF residual: the activation exchange is exact, not lossy."""
        return False

    @property
    def rows(self) -> int:
        return self.tokens


@dataclass(frozen=True)
class ServeGroupSpec:
    gid: int
    buckets: tuple

    @property
    def rows(self) -> int:
        """GroupSpec duck-typing for the adaptive controller (its
        cross-pod rules ask for flat groups; activation buckets always
        qualify — and carry no residual, so those rules skip them)."""
        return 1


@dataclass(frozen=True)
class ServePlan:
    """Wire plan for one decode-step configuration (T slots, width d)
    exchanged over the expert/model axis of size ``dp_total``.

    Versioned and re-derivable exactly like SyncPlan: ``replan`` with the
    telemetry window's mean active-token count re-selects the wire
    representation (and the stream capacity, which is PART of the
    algorithm tag and therefore of the signature — each capacity is its
    own compiled decode step)."""

    cfg: Any                      # ServeSyncConfig (duck-typed)
    dp_total: int                 # exchange-axis world size (p_model)
    tokens: int
    d: int
    groups: tuple
    min_cap: int = 4              # smallest stream capacity ever planned
    headroom: float = 2.0         # cap >= headroom * measured occupancy
    version: int = 0

    @property
    def buckets(self) -> tuple:
        return tuple(b for g in self.groups for b in g.buckets)

    def algorithms(self) -> dict[str, str]:
        return {b.name: b.algorithm for b in self.buckets}

    def pod_sparse_flags(self) -> dict[str, bool]:
        return {b.name: False for b in self.buckets}

    def signature(self) -> str:
        return ",".join(f"{b.name}={b.algorithm}" for b in self.buckets)

    def bucket_k(self, group, b) -> int:
        """The controller's per-bucket ``k`` — for activation buckets the
        ROW width d (``stream_gather`` costing is capacity x row)."""
        return b.d

    # -- selection ---------------------------------------------------------
    def _select(self, nnz_rows: float, net) -> str:
        """Wire representation at a measured occupancy: the smallest
        power-of-2 capacity with ``headroom`` over the measurement, if
        the stream bytes beat the dense allreduce bytes; dense otherwise.
        The ONE byte accounting shared with the executor's telemetry
        (cost_model.stream_wire_bytes)."""
        import math as _math

        from repro.core.cost_model import bucket_wire_bytes, stream_wire_bytes
        from repro.core.sparse_stream import round_up_pow2

        cap = max(self.min_cap,
                  round_up_pow2(int(_math.ceil(nnz_rows * self.headroom))))
        if cap >= self.tokens:
            return "dense"
        sparse_bytes = stream_wire_bytes(self.dp_total, cap, self.d)
        dense_bytes = bucket_wire_bytes("dense", self.dp_total, self.d,
                                        self.tokens * self.d)
        return (f"{SERVE_STREAM}@{cap}" if sparse_bytes < dense_bytes
                else "dense")

    def replan(self, densities: Optional[dict] = None, net=None, *,
               algorithms: Optional[dict] = None,
               pod_sparse: Optional[dict] = None) -> "ServePlan":
        """Successor plan with re-selected wire representations.

        ``densities``: bucket name -> mean measured active-token count
        (the serve telemetry window). ``algorithms`` overrides win, as in
        SyncPlan.replan; ``pod_sparse`` is accepted for controller
        signature-compatibility and ignored (no cross-pod phase)."""
        new_groups = []
        for g in self.groups:
            new_buckets = []
            for b in g.buckets:
                if algorithms is not None:
                    algo = algorithms.get(b.name, b.algorithm)
                else:
                    nnz = None if densities is None else densities.get(b.name)
                    algo = b.algorithm if nnz is None else \
                        self._select(float(nnz), net)
                new_buckets.append(ActivationBucketSpec(
                    b.name, b.tokens, b.d, algo))
            new_groups.append(ServeGroupSpec(g.gid, tuple(new_buckets)))
        import dataclasses

        return dataclasses.replace(self, groups=tuple(new_groups),
                                   version=self.version + 1)

    def switch_forced(self, name: str, old: str, new: str,
                      nnz: Optional[float]) -> bool:
        """Correctness rule, never vetoed by hysteresis (the serve
        analogue of the delta switchover): once the measured occupancy
        reaches the CURRENT stream capacity, that representation can
        drop rows — it must move, whatever the modeled win."""
        if not old.startswith(SERVE_STREAM) or nnz is None:
            return False
        return nnz >= int(old.split("@", 1)[1])

    # -- analytic wire traffic (per rank per decode step) ------------------
    def wire_bytes(self) -> float:
        from repro.core.cost_model import bucket_wire_bytes

        return sum(bucket_wire_bytes(b.algorithm, self.dp_total, b.d, b.n)
                   for b in self.buckets)

    def describe(self) -> str:
        head = (f"ServePlan v{self.version}: T={self.tokens} d={self.d} "
                f"p={self.dp_total}")
        return "\n".join([head] + [
            f"  {b.name}: algo={b.algorithm} wire={self.wire_bytes():.0f}B"
            for b in self.buckets])


def build_serve_plan(p_model: int, tokens: int, d: int, *,
                     algorithm: str = "dense", min_cap: int = 4,
                     headroom: float = 2.0) -> ServePlan:
    """The serve-time activation plan: ONE bucket (the per-step MoE
    combine buffer — every layer shares the geometry, so one wire
    decision covers the step). Starts dense unless told otherwise: dense
    is exact at every occupancy, and the adaptive controller demotes to
    a stream as soon as the measured occupancy says it pays."""
    bucket = ActivationBucketSpec("act0", tokens, d, algorithm)
    return ServePlan(ServeSyncConfig(), p_model, tokens, d,
                     (ServeGroupSpec(0, (bucket,)),),
                     min_cap=min_cap, headroom=headroom)


# --------------------------------------------------------------------------
# Legacy per-leaf routing (thin-wrapper compatibility)
# --------------------------------------------------------------------------

def leaf_sparse_ok(shape, spec, cfg, dp_total: int) -> bool:
    """The PER-LEAF qualification rule of the pre-fusion pipeline: big
    enough (paper §8: N > 65k) and the per-row bucket count divides the
    split-phase group size. Kept for the compressor wrappers and for
    deciding which leaves a per-leaf plan covers."""
    if cfg.mode != "sparcml" or int(np.prod(shape)) < cfg.min_sparse_size:
        return False
    lead, cols = canonical_shape(shape, spec, cfg.bucket_size)
    m = cols // cfg.bucket_size
    if cfg.qsgd_bits is not None:
        if (cols // dp_total) % cfg.qsgd_bucket:
            return False
    return m % dp_total == 0


def build_per_leaf_plan(param_shapes, param_specs, cfg, dp_total: int) -> SyncPlan:
    """One group + one bucket per QUALIFYING leaf (legacy routing); leaves
    that fail :func:`leaf_sparse_ok` are not covered — callers psum them
    densely, exactly as the old ``sync_grads_inside`` did."""
    leaves, specs = _flatten_with_specs(param_shapes, param_specs)
    groups = []
    for i, (sd, spec) in enumerate(zip(leaves, specs)):
        shape = tuple(sd.shape)
        if not leaf_sparse_ok(shape, spec, cfg, dp_total):
            continue
        rows, cols = canonical_shape(shape, spec, cfg.bucket_size)
        gid = len(groups)
        algo = cfg.algorithm
        if algo == "auto":
            algo = _resolve_algorithm(cfg, dp_total, rows, cols)
        elif rows > 1 and algo not in BATCHED_ALGORITHMS:
            algo = "dsar_split_allgather"
        slot = LeafSlot(i, shape, spec, rows, cols, 0)
        bucket = BucketSpec(f"g{gid}b0", 0, cols, rows, algo)
        groups.append(GroupSpec(
            gid, rows, rows > 1 and model_axis(spec) is not None,
            cols, (slot,), (bucket,)))
    return SyncPlan(cfg, dp_total, len(leaves), tuple(groups))
