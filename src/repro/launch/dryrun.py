import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
cell on placeholder devices, record memory/cost/collective analysis.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--out experiments/dryrun]

Each cell writes <out>/<arch>__<shape>__<mesh>[__<sync>].json with:
  memory_analysis (bytes per device), cost_analysis (flops/bytes),
  per-chip collective wire bytes by kind (parsed from post-SPMD HLO),
  the three roofline terms, and lower/compile wall times.
"""

import argparse
import gc
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as cfgreg
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.train.state import TrainConfig
from repro.train.train_step import (
    batch_specs, build_train_step, dp_axes_of, dp_total_of, state_shapes)
from repro.serve.engine import build_serve_step, build_prefill, decode_state_specs
from repro.utils.hlo_analysis import parse_collectives, remat_duplication
from repro.utils.roofline import Roofline, model_flops_infer, model_flops_train


def batch_shapes(cfg, shape: cfgreg.ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    out = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
           "labels": jax.ShapeDtypeStruct((b, s), i32)}
    if cfg.family == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.vision_dim), jnp.float32)
    if cfg.family == "encoder":
        out["frames"] = jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.float32)
    return out


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell
    (weak-type-correct, shardable, no device allocation)."""
    cfg = cfgreg.get_config(arch)
    shape = cfgreg.SHAPES[shape_name]
    return batch_shapes(cfg, shape)


def _abstract(tree):
    return jax.tree.map(
        lambda x: x if x is None else jax.ShapeDtypeStruct(x.shape, x.dtype),
        tree, is_leaf=lambda x: x is None)


def lower_cell(arch: str, shape_name: str, mesh, sync_override: str | None = None):
    """Returns (lowered, meta) for one cell."""
    shape = cfgreg.SHAPES[shape_name]
    long_ctx = shape_name == "long_500k"
    if arch in ("zamba2-2.7b", "zamba2_2p7b"):
        cfg = cfgreg.get_config(arch, long_context=long_ctx)
    else:
        cfg = cfgreg.get_config(arch)
    model = build_model(cfg)
    meta = {"arch": cfg.name, "shape": shape_name,
            "params": cfg.param_count(), "active_params": cfg.active_param_count()}

    if shape.kind == "train":
        tcfg = cfgreg.get_train_config(arch, mesh=mesh)
        if sync_override:
            from repro.configs._common import make_train_config
            if sync_override == "dense":
                tcfg = make_train_config(sync_mode="dense", fsdp=True)
            elif sync_override == "sparcml":
                tcfg = cfgreg.get_train_config(arch)
        # keep per-microbatch rows divisible by the dp rank count, else
        # pods silently duplicate compute (found via per-chip FLOPs).
        import dataclasses as _dc
        mb_cap = max(1, shape.global_batch // dp_total_of(mesh))
        if tcfg.microbatches > mb_cap:
            tcfg = _dc.replace(tcfg, microbatches=mb_cap)
        step_fn, (shapes, specs) = build_train_step(model, tcfg, mesh)
        bshapes = batch_shapes(cfg, shape)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        lowered = step_fn.lower(shapes, bshapes, key)
        meta["sync_mode"] = tcfg.sync.mode
        meta["kind"] = "train"
        meta["state_memory"] = state_memory_breakdown(model, tcfg, mesh)
        meta["model_flops"] = model_flops_train(
            cfg.active_param_count(), shape.global_batch * shape.seq_len)
        return lowered, meta

    if shape.kind == "prefill":
        pre_fn, (pspecs, _) = build_prefill(model, mesh, cache_len=shape.seq_len,
                                            batch_size=shape.global_batch,
                                            fsdp=not _fits_replicated(cfg))
        pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        bshapes = batch_shapes(cfg, shape)
        bshapes.pop("labels", None)
        if cfg.family == "encoder":
            # encoder 'prefill' = full forward (no cache)
            dp = dp_axes_of(mesh)
            from repro.models.specs import param_specs as pspec_fn
            sh = lambda t: jax.tree.map(
                lambda s: NamedSharding(mesh, s if s is not None else P()), t,
                is_leaf=lambda x: x is None or isinstance(x, P))
            specs = pspec_fn(pshapes, cfg, None)
            fwd = jax.jit(
                lambda p, b: model.forward(p, b),
                in_shardings=(sh(specs), sh({"frames": P(dp, None, None)})),
                out_shardings=NamedSharding(mesh, P(dp, None, "model")))
            bshapes.pop("tokens", None)
            lowered = fwd.lower(pshapes, bshapes)
        else:
            lowered = pre_fn.lower(pshapes, bshapes)
        meta["kind"] = "prefill"
        meta["model_flops"] = model_flops_infer(
            cfg.active_param_count(), shape.global_batch * shape.seq_len)
        return lowered, meta

    # decode
    dec_fn, (pspecs, sspecs) = build_serve_step(
        model, mesh, batch_size=shape.global_batch, cache_len=shape.seq_len,
        fsdp=not _fits_replicated(cfg))
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    state_abs = jax.eval_shape(
        lambda: model.init_decode_state(shape.global_batch, shape.seq_len,
                                        prefix_len=shape.seq_len - 1))
    toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    lowered = dec_fn.lower(pshapes, state_abs, toks)
    meta["kind"] = "decode"
    meta["model_flops"] = model_flops_infer(
        cfg.active_param_count(), shape.global_batch)
    return lowered, meta


def _tree_device_bytes(shapes, specs, mesh) -> int:
    """Analytic per-device bytes of one abstract tree: each leaf's byte
    size divided by the product of the mesh axes its PartitionSpec
    shards over (None / unnamed dims replicate)."""
    total = 0
    s_leaves = jax.tree.leaves(shapes, is_leaf=lambda x: x is None)
    p_leaves = jax.tree.leaves(specs, is_leaf=lambda x: x is None
                               or isinstance(x, P))
    for sd, spec in zip(s_leaves, p_leaves):
        if sd is None:
            continue
        n = int(np.prod(sd.shape, dtype=np.int64)) * np.dtype(sd.dtype).itemsize
        denom = 1
        if spec is not None:
            for dim in spec:
                for ax in (dim if isinstance(dim, tuple) else (dim,)):
                    if ax:
                        denom *= mesh.shape[ax]
        total += n // denom
    return total


def state_memory_breakdown(model, tcfg, mesh) -> dict:
    """Per-device persistent TrainState bytes by component — the analytic
    companion of compiled.memory_analysis() (which reports one opaque
    argument_bytes blob). Makes the ZeRO win visible: under
    output_mode='scattered' (DESIGN.md §11) opt_mu/opt_nu drop to ~1/dp
    of the replicated layout. ``inflight`` is the pipelined runtime's
    in-flight reduce buffers (zero when not applicable)."""
    from repro.train import train_step as ts

    shapes, specs, plan = ts.state_shapes(model, tcfg, mesh,
                                          return_plan=True)
    out = {
        "params": _tree_device_bytes(shapes.params, specs.params, mesh),
        "opt_mu": _tree_device_bytes(shapes.opt["mu"], specs.opt["mu"],
                                     mesh),
        "opt_nu": (_tree_device_bytes(shapes.opt["nu"], specs.opt["nu"],
                                      mesh) if "nu" in shapes.opt else 0),
        "ef_residual": _tree_device_bytes(shapes.residuals,
                                          specs.residuals, mesh),
        "inflight": 0,
    }
    if plan is not None:
        dp_ax = dp_axes_of(mesh)
        out["inflight"] = _tree_device_bytes(plan.inflight_shapes(),
                                             plan.inflight_specs(dp_ax),
                                             mesh)
    out["total"] = sum(out.values())
    return out


def _fits_replicated(cfg) -> bool:
    """Can bf16 params fit DP-replicated after TP=16? (16 GB HBM heuristic)"""
    return cfg.param_count() * 2 / 16 < 8e9


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, sync_override: str | None = None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok"}
    ok, reason = cfgreg.applicable_shapes(arch)[shape_name]
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    try:
        t0 = time.perf_counter()
        with mesh:
            lowered, meta = lower_cell(arch, shape_name, mesh, sync_override)
            t_lower = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0

            mem = compiled.memory_analysis()
            try:
                mem_d = {
                    "bytes_per_device_total": int(
                        getattr(mem, "temp_size_in_bytes", 0)
                        + getattr(mem, "argument_size_in_bytes", 0)
                        + getattr(mem, "output_size_in_bytes", 0)
                        - getattr(mem, "alias_size_in_bytes", 0)),
                    "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                    "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                    "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                    "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
                    "generated_code_bytes": int(
                        getattr(mem, "generated_code_size_in_bytes", 0)),
                }
            except Exception:
                mem_d = {"raw": str(mem)}
            print(f"[{arch}|{shape_name}|{mesh_name}] memory_analysis:", mem_d)
            if "state_memory" in meta:
                print(f"[{arch}|{shape_name}|{mesh_name}] state_memory/device:",
                      meta["state_memory"])

            cost = compiled.cost_analysis() or {}
            xla_flops = float(cost.get("flops", 0.0))
            print(f"[{arch}|{shape_name}|{mesh_name}] cost_analysis: "
                  f"flops={xla_flops:.3e} (loop bodies counted once)")

            hlo = compiled.as_text()
            # trip-count-aware walk: XLA's cost_analysis counts while
            # bodies once; scan-over-layers needs the multiplier.
            from repro.utils.hlo_cost import total_cost
            mc = total_cost(hlo)
            print(f"[{arch}|{shape_name}|{mesh_name}] trip-aware: "
                  f"flops={mc.flops:.3e}/chip hbm={mc.hbm_bytes:.3e}B "
                  f"coll={mc.coll_bytes:.3e}B trips={mc.trip_counts[:4]}")
            roof = Roofline(
                flops=mc.flops * chips, hbm_bytes=mc.hbm_bytes * chips,
                coll_bytes_per_chip=mc.coll_bytes, chips=chips,
                model_flops=meta["model_flops"])
            rec.update(
                meta=meta,
                chips=chips,
                lower_s=round(t_lower, 2),
                compile_s=round(t_compile, 2),
                memory=mem_d,
                cost={"flops_per_chip": mc.flops,
                      "hbm_bytes_per_chip": mc.hbm_bytes,
                      "xla_flops_raw": xla_flops},
                collectives=mc.as_dict(),
                remat_dup=remat_duplication(hlo),
                roofline=roof.as_dict(),
                hlo_bytes=len(hlo),
            )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    finally:
        gc.collect()
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape_name}__{mesh_name}"
        if sync_override:
            tag += f"__{sync_override}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sync", type=str, default=None,
                    help="override sync mode for train cells (dense|sparcml)")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ([cfgreg.EXTERNAL_NAMES[a] for a in cfgreg.ARCH_IDS]
             if (args.all or args.arch is None) else [args.arch])
    shapes = list(cfgreg.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    results = []
    for a, s, m in cells:
        tag = f"{a}__{s}__{'pod2x16x16' if m else 'pod16x16'}"
        if args.sync:
            tag += f"__{args.sync}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"== {tag}: cached, skipping")
            with open(path) as f:
                results.append(json.load(f))
            continue
        print(f"== {tag}: lowering...", flush=True)
        rec = run_cell(a, s, m, out_dir=args.out, sync_override=args.sync)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" dominant={r['dominant']} bound={r['bound_s']:.4f}s "
                     f"mfu_bound={r['mfu_bound']:.2%} "
                     f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
        elif status == "error":
            extra = " " + rec["error"][:160]
        else:
            extra = " " + rec.get("reason", "")
        print(f"== {tag}: {status}{extra}", flush=True)
        results.append(rec)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\nDRY-RUN SUMMARY: {n_ok} ok / {n_skip} skipped / {n_err} errors "
          f"of {len(results)} cells")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
