"""Aggregate dry-run JSONs into the §Roofline markdown table.

    PYTHONPATH=src python -m repro.launch.roofline_report \
        [--dir experiments/dryrun] [--mesh pod16x16]
"""
from __future__ import annotations

import argparse
import json
import os


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def load(dirname: str, mesh: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(os.listdir(dirname)):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(dirname, f)))
        if mesh and rec.get("mesh") != mesh:
            continue
        recs.append(rec)
    return recs


ARCH_ORDER = ["llama-3.2-vision-11b", "mamba2-370m", "minicpm-2b", "qwen3-4b",
              "llama3-405b", "internlm2-20b", "dbrx-132b",
              "moonshot-v1-16b-a3b", "zamba2-2.7b", "hubert-xlarge"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def one_liner(rec: dict) -> str:
    """One sentence: what would move the dominant term down."""
    r = rec["roofline"]
    dom = r["dominant"]
    shape = rec["shape"]
    if dom == "memory":
        if shape == "train_4k":
            return ("chunked (flash) attention removes the S^2 score "
                    "materialization; bf16 residuals halve traffic")
        if shape == "prefill_32k":
            return "chunk the prefill attention; fuse RoPE+QKV"
        return "batch more decode slots per weight read (weights dominate)"
    if dom == "collective":
        if shape == "decode_32k":
            return ("shard KV on heads not sequence where divisible; "
                    "avoid per-step cache reshards")
        if rec.get("meta", {}).get("sync_mode") == "dense":
            return "SparCML TopK+QSGD compression of the grad reduce-scatter"
        return "raise k/bucket locality; overlap split phase with backward"
    return ("larger per-chip batch amortizes weight reads; "
            "already compute-bound — good")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--full", action="store_true",
                    help="include the what-would-help sentence")
    args = ap.parse_args()
    recs = {(r["arch"], r["shape"]): r for r in load(args.dir, args.mesh)}

    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound (s) "
           "| dominant | MODEL/HLO flops | MFU bound | mem/dev |")
    sep = "|" + "---|" * 10
    print(hdr)
    print(sep)
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = recs.get((arch, shape))
            if rec is None:
                continue
            if rec["status"] == "skipped":
                print(f"| {arch} | {shape} | — | — | — | — | SKIP | — | — | "
                      f"{rec['reason']} |")
                continue
            if rec["status"] != "ok":
                print(f"| {arch} | {shape} | ERROR | | | | | | | |")
                continue
            r = rec["roofline"]
            mem = rec.get("memory", {}).get("bytes_per_device_total", 0)
            print(
                f"| {arch} | {shape} "
                f"| {r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} "
                f"| {r['t_collective_s']:.3g} | {r['bound_s']:.3g} "
                f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
                f"| {r['mfu_bound']:.1%} | {fmt_bytes(mem)} |"
            )
            if args.full:
                print(f"|  |  | | | | | | | | ^ {one_liner(rec)} |")


if __name__ == "__main__":
    main()
