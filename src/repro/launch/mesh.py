"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests see 1 CPU device; only dryrun.py
sets the 512-placeholder-device XLA flag before any jax import).

All meshes are built through :func:`repro.compat.make_mesh`, which applies
``AxisType.Auto`` on JAX builds that support axis types and silently omits
it elsewhere — tests, benchmarks, and examples route through here so no
other module imports ``jax.sharding.AxisType`` directly.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2, pod: int = 0):
    """Small mesh over host devices (tests / examples)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
