"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests see 1 CPU device; only dryrun.py
sets the 512-placeholder-device XLA flag before any jax import).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 4, model: int = 2, pod: int = 0):
    """Small mesh over host devices (tests / examples)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
