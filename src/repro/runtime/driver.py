"""Double-buffered non-blocking host driver (DESIGN.md §6).

``Trainer.run`` dispatches one step, then blocks on its loss before
dispatching the next — the host round-trip serializes with the device.
This driver replaces that with a dispatch WINDOW:

  * **async dispatch** — up to ``depth`` units (steps, or K-step
    supersteps) are dispatched before the oldest is retired; JAX's async
    dispatch turns the returned arrays into futures, so the device queue
    stays full while the host prepares the next batch;
  * **data prefetch** — batch generation (the host-side cost) runs in a
    background thread ``prefetch`` units ahead of dispatch;
  * **retire-only syncing** — logging reads (loss, step time) block only
    on the unit leaving the window; checkpoints first drain the window,
    so the save reads a fully retired state (and the caller strips the
    in-flight bucket buffers — see TrainState.inflight).

Step times are retire-to-retire wall intervals divided by the unit's step
count: with the window full, that IS the steady-state per-step cost, with
dispatch overhead and data generation amortized/overlapped. The
attribution is exact in aggregate (the intervals tile the run), but
pipeline fill inflates the first interval and the final drain deflates
the last ones. The ONE summary statistic for ``log.step_times`` is
therefore the ROLLING MEDIAN of the last ``STRAGGLER_WINDOW`` steps —
robust to those fill/drain transients — and it is what the straggler
watchdog compares against (``record_step``), what the
``driver/straggler_median_s`` gauge exports, and what consumers should
read; the mean is only exact for whole-run aggregates.

Observability (DESIGN.md §10): ``run_pipelined`` takes an ``obs`` handle
(``repro.obs``). Host spans wrap dispatch/retire/drain/checkpoint, plan
swaps and restarts become structured events, and — when tracing — a
``phase_attr`` callback lays the cost model's compute/exposed-comm split
into each retire interval as derived device-phase spans. All of it is
host-side: with observability off the loop is byte-identical, and with
it on, retire remains the only ``block_until_ready`` (tests/test_obs.py
pins both properties).

The driver is state-linear (step functions donate their input state), so
after a dispatch only the returned state is live; on failure the window
is discarded and ``restore_fn`` supplies a replayable state (the data
pipeline is keyed by step, so replayed batches are identical).
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from dataclasses import dataclass
from statistics import median
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import resolve as _resolve_obs
from repro.obs.metrics import MetricsRegistry
from repro.runtime.faults import (
    NonFiniteEscalation,
    PrefetchStalled,
    RecoveryConfig,
    RetrySupervisor,
)

# Rolling window (in steps) of the documented step-time statistic: the
# median over this window is THE summary of ``log.step_times`` — used by
# the straggler watchdog, exported as ``driver/straggler_median_s``.
STRAGGLER_WINDOW = 50
# Minimum retired steps before the watchdog trusts the median at all.
STRAGGLER_WARMUP = 5


@dataclass(frozen=True)
class DriverConfig:
    depth: int = 2          # dispatched-but-unretired units (double-buffered)
    prefetch: int = 2       # units of host batches prepared ahead
    steps_per_unit: int = 1 # K of the scanned superstep fn (1 = plain step)
    # Bound on waiting for the prefetch thread before declaring the data
    # pipeline stalled (PrefetchStalled -> the recovery path). Generous:
    # batch generation is milliseconds; only a hung/dead producer hits it.
    prefetch_timeout_s: float = 60.0


class DriverLog:
    """Run log with registry-backed storage (duck-type-compatible with
    train.trainer.TrainerLog, which is an alias of this class).

    The public fields are the SAME plain lists PR-2 consumers have
    always indexed — but they are views of Series metrics living in a
    ``MetricsRegistry``, so a metrics-enabled run exports losses, step
    times, straggler and plan-swap events through the JSONL sink with no
    second bookkeeping path. With no registry supplied the log owns a
    private (disabled) one and behaves exactly like the old dataclass.
    """

    def __init__(self, registry: MetricsRegistry | None = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry(enabled=False)
        self.losses = self.registry.series("train/loss").data
        self.step_times = self.registry.series("train/step_time_s").data
        # (step, dt, rolling median) triples
        self.straggler_events = \
            self.registry.series("driver/straggler_events").data
        # (step, plan signature) pairs
        self.plan_swaps = self.registry.series("driver/plan_swaps").data

    @property
    def restarts(self) -> int:
        return self.registry.counter("driver/restarts").value

    @restarts.setter
    def restarts(self, v: int) -> None:
        self.registry.counter("driver/restarts").value = int(v)


def record_step(log, step: int, dt: float, loss: float,
                straggler_factor: float) -> None:
    """Append one step's (loss, wall time) to the log and run the
    straggler watchdog — the ONE logging policy shared by the synchronous
    Trainer.run loop and the async driver, so the two can never drift.

    The watchdog statistic is the rolling MEDIAN of the last
    ``STRAGGLER_WINDOW`` step times (the documented summary of
    ``log.step_times``); a step slower than ``straggler_factor`` times
    that median records a ``(step, dt, median)`` event, bumps the
    ``driver/stragglers`` counter, and the current median is exported as
    the ``driver/straggler_median_s`` gauge."""
    log.losses.append(loss)
    log.step_times.append(dt)
    reg = getattr(log, "registry", None)
    if len(log.step_times) >= STRAGGLER_WARMUP:
        med = median(log.step_times[-STRAGGLER_WINDOW:])
        if reg is not None:
            reg.gauge("driver/straggler_median_s").set(med)
        if dt > straggler_factor * med:
            log.straggler_events.append((step, dt, med))
            if reg is not None:
                reg.counter("driver/stragglers").inc()
                reg.event("driver/straggler", step=step, dt_s=dt,
                          median_s=med, factor=straggler_factor)


class _Prefetcher:
    """Background thread producing HOST batches ahead of dispatch (device
    transfer stays on the main thread). Restartable after a failure."""

    def __init__(self, batch_fn: Callable[[int], Any], prefetch_units: int,
                 steps_per_unit: int):
        self._batch_fn = batch_fn
        self._k = steps_per_unit
        self._cap = max(1, prefetch_units) * steps_per_unit
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def start(self, start_step: int, num_steps: int):
        self.stop()
        self._stop = threading.Event()
        self._q = queue.Queue(maxsize=self._cap)
        stop, q = self._stop, self._q

        def work():
            for s in range(start_step, num_steps):
                if stop.is_set():
                    return
                try:
                    item = (s, self._batch_fn(s))
                except BaseException as e:  # poison-pill: surface in take()
                    item = (None, e)
                while not stop.is_set():
                    try:
                        q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if item[0] is None:
                    return

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def take(self, step: int, timeout: float = 60.0):
        """Bounded get: the old unbounded ``q.get()`` hung the dispatch
        loop forever when the producer thread died without enqueueing its
        poison pill (or never produced at all). Poll with a short get so
        thread death is noticed within ~0.5s, and bound total waiting by
        ``timeout`` for a live-but-stalled producer. Both paths surface
        as :class:`PrefetchStalled` — classified 'stall' by the recovery
        supervisor, with the producer's own exception attached as the
        cause when one was captured."""
        assert self._q is not None, "prefetcher not started"
        deadline = time.perf_counter() + timeout
        while True:
            try:
                s, batch = self._q.get(timeout=0.5)
                break
            except queue.Empty:
                alive = self._thread is not None and self._thread.is_alive()
                if not alive and self._q.empty():
                    raise PrefetchStalled(
                        f"prefetch thread died before producing step {step}")
                if time.perf_counter() >= deadline:
                    raise PrefetchStalled(
                        f"no batch for step {step} within {timeout:.1f}s "
                        "(data pipeline stalled)")
        if s is None:  # producer died — re-raise on the driver thread
            raise PrefetchStalled(
                f"prefetch batch_fn failed at step {step}: {batch!r}",
                cause=batch) from batch
        assert s == step, (s, step)
        return batch

    def stop(self):
        if self._thread is not None:
            self._stop.set()
            try:  # drain so the producer can observe the stop flag
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=5)
            self._thread = None


def run_pipelined(
    step_fn: Callable,
    state,
    *,
    start_step: int,
    num_steps: int,
    batch_fn: Callable[[int], Any],
    key_fn: Callable[[int], jax.Array],
    cfg: DriverConfig = DriverConfig(),
    log=None,
    straggler_factor: float = 3.0,
    ckpt_every: Optional[int] = None,
    ckpt_fn: Optional[Callable[[Any], None]] = None,
    restore_fn: Optional[Callable[[], Any]] = None,
    adapt=None,
    obs=None,
    phase_attr: Optional[Callable[[float], list]] = None,
    health=None,
    recovery=None,
    injector=None,
):
    """Drive ``step_fn`` from ``start_step`` to ``num_steps`` (absolute).

    step_fn: jitted pipelined step ``(state, batch, key)`` when
    ``cfg.steps_per_unit == 1``, else the scanned superstep taking
    stacked ``(K, ...)`` batches and ``(K, 2)`` keys. A trailing unit
    shorter than K is dispatched with the smaller leading axis (one
    extra compile).
    batch_fn: step -> HOST batch dict (numpy); called from the prefetch
    thread, so it must be thread-compatible (the synthetic pipeline is).
    adapt: an ``runtime.adapt.AdaptiveRuntime`` (duck-typed: ``observe``
    + ``maybe_swap``). Retired units feed it telemetry; when it accepts a
    replan the window is DRAINED (every in-flight unit retired) and the
    compiled step function is swapped at that barrier — TrainState rides
    across unchanged (replans are layout-invariant, DESIGN.md §7), and
    the swap is recorded in ``log.plan_swaps``.
    obs: a ``repro.obs.Observability`` handle (None = session default,
    which defaults to OFF). Host spans + structured events only — the
    retire below stays the ONLY sync point either way.
    phase_attr: ``dt_unit_s -> [phase dict]`` (see
    ``obs.attribute_step_phases``); when tracing, each retire interval
    is tiled with the derived compute/exposed-comm device spans.
    health: a ``repro.obs.health.HealthMonitor`` — evaluated at drain
    barriers and at end of run (host-side registry reads, no sync);
    verdicts land as ``health/*`` events, and when ``adapt`` exposes an
    ``advise`` hook the critical findings are handed to it as the
    drain-barrier advisory (DESIGN.md §10.5).
    The flight recorder (``obs.recorder``, when attached) notes every
    retired unit and dumps ``blackbox.json`` on watchdog fire and on any
    exception — including ones the restore path survives.
    recovery: a ``runtime.faults.RecoveryConfig`` (or a prebuilt
    ``RetrySupervisor``) turning the bare restore-on-failure into the
    bounded retry/backoff policy of DESIGN.md §12.3: each failure is
    classified by fault class, charged against that class's retry
    budget, delayed by jittered exponential backoff, then restored;
    an exhausted budget escalates to ``RetryBudgetExhausted`` AFTER the
    blackbox dump (clean abort). ``None`` keeps the legacy unbounded
    restore. Independently, when the step function was built with
    ``guard=True`` the retire path reads ``metrics["nonfinite"]``: each
    tripped (skipped) step emits a critical ``health/nonfinite`` event,
    and ``max_consecutive_nonfinite`` consecutive trips raise
    ``NonFiniteEscalation`` into the same restore path (rewind to the
    last-good checkpoint; the replayed data is clean by the injector's
    one-shot contract, so recovery is bit-reproducible).
    injector: a ``runtime.faults.FaultInjector`` (chaos harness). The
    driver wraps ``batch_fn`` with its stall/nonfinite hooks — so the
    step function MUST then be built with ``inject=True`` — and fires
    its collective/sigterm hook before each dispatch and its straggler
    hook inside each retire.
    Returns (final state, log).
    """
    if cfg.depth < 1 or cfg.prefetch < 1 or cfg.steps_per_unit < 1:
        raise ValueError(f"DriverConfig fields must be >= 1: {cfg}")
    obs = _resolve_obs(obs)
    rec = getattr(obs, "recorder", None)
    if log is None:
        log = DriverLog(registry=obs.metrics if obs.metrics_on else None)
    reg = obs.metrics if obs.metrics_on else None
    supervisor = None
    if recovery is not None:
        supervisor = (recovery if isinstance(recovery, RetrySupervisor)
                      else RetrySupervisor(recovery, registry=reg))
    rcfg = supervisor.cfg if supervisor is not None else RecoveryConfig()
    if injector is not None:
        injector.bind(registry=reg)
        batch_fn = injector.wrap_batch_fn(batch_fn)
    k_unit = cfg.steps_per_unit
    prefetcher = _Prefetcher(batch_fn, cfg.prefetch, k_unit)
    prefetcher.start(start_step, num_steps)
    window: deque = deque()  # (first_step, n_steps, metrics)
    step = start_step
    last_retire_t = time.perf_counter()
    consec_nonfinite = 0  # consecutive guard-tripped steps (§12.2)

    def retire_one():
        nonlocal last_retire_t, consec_nonfinite
        s0, k, metrics = window.popleft()
        with obs.span("driver/retire", step=s0, k=k):
            jax.block_until_ready(metrics["loss"])      # the ONLY sync point
            if injector is not None:
                # straggler hook: the delay lands inside THIS retire
                # interval, so the watchdog sees it as a slow step
                med0 = (median(log.step_times[-STRAGGLER_WINDOW:])
                        if len(log.step_times) >= STRAGGLER_WARMUP else 0.0)
                injector.after_retire(s0, k, med0)
        now = time.perf_counter()
        dt_unit = now - last_retire_t
        dt = dt_unit / k
        prev_t = last_retire_t
        last_retire_t = now
        losses = np.atleast_1d(np.asarray(metrics["loss"]))
        n_stragglers = len(log.straggler_events)
        for i in range(k):
            record_step(log, s0 + i, dt,
                        float(losses[i] if k > 1 else losses[0]),
                        straggler_factor)
        if "nonfinite" in metrics:
            # guarded step (§12.2): each tripped step was a state no-op
            # on device; here it becomes a critical health event, and N
            # consecutive trips escalate to a rewind — skip-recovery is
            # not converging, so replay from the last-good checkpoint.
            trips = np.atleast_1d(np.asarray(metrics["nonfinite"]))
            for i in range(k):
                if float(trips[i] if k > 1 else trips[0]) > 0.5:
                    consec_nonfinite += 1
                    if reg is not None:
                        reg.counter("guard/nonfinite_trips").inc()
                    obs.event("health/nonfinite", severity="critical",
                              subject="grads", step=s0 + i,
                              consecutive=consec_nonfinite,
                              message="non-finite grads: apply skipped, "
                                      "EF/opt state preserved")
                    if rec is not None:
                        rec.note("guard/nonfinite", step=s0 + i,
                                 consecutive=consec_nonfinite)
                    if consec_nonfinite >= rcfg.max_consecutive_nonfinite:
                        raise NonFiniteEscalation(
                            f"{consec_nonfinite} consecutive non-finite "
                            f"steps ending at step {s0 + i}")
                else:
                    consec_nonfinite = 0
        if obs.metrics_on:
            obs.metrics.histogram("driver/retire_wall_s").observe(dt_unit)
        if rec is not None:
            rec.note("driver/retire", step=s0, k=k, dt_unit_s=dt_unit,
                     loss=float(losses[-1] if k > 1 else losses[0]))
            if len(log.straggler_events) > n_stragglers:
                rec._safe_dump("watchdog")
        if obs.trace_on and phase_attr is not None:
            # Lay the derived device phases into the measured interval
            # [prev retire, this retire] on their own trace track.
            for ph in phase_attr(dt_unit):
                obs.tracer.complete(
                    ph["name"], ph["cat"],
                    ts_us=obs.tracer.to_us(prev_t + ph["offset_s"]),
                    dur_us=ph["dur_s"] * 1e6, tid="device-phases",
                    **ph.get("args", {}))
        if adapt is not None:
            adapt.observe(s0, k, metrics)

    def drain():
        if window:
            with obs.span("driver/drain", inflight=len(window)):
                while window:
                    retire_one()
        health_check()

    def health_check():
        """Drain-barrier health evaluation: windowed rules over whatever
        the registry holds, critical findings handed to the adaptive
        controller as its urgency advisory. Pure host-side reads."""
        if health is None:
            return
        events = health.evaluate()
        if events and adapt is not None and hasattr(adapt, "advise"):
            adapt.advise(events)

    def check_swap():
        """Install a controller-accepted replan (DESIGN.md §7). Called
        wherever retires may have fed the controller — after dispatches,
        after checkpoint drains, and on the tail drain — so the active
        plan recorded in checkpoint meta is always one that has actually
        been installed (and logged), never a pending acceptance."""
        nonlocal step_fn
        if adapt is None:
            return
        swap = adapt.maybe_swap()
        if swap is None:
            return
        # Plan-swap barrier: drain every in-flight unit, then install
        # the re-planned compiled step. State needs no migration —
        # replans are layout-invariant.
        drain()
        step_fn, new_plan = swap
        if hasattr(log, "plan_swaps"):
            log.plan_swaps.append((step, new_plan.signature()))
        obs.event("driver/plan_swap", step=step,
                  signature=new_plan.signature(),
                  version=getattr(new_plan, "version", None))

    def dispatch(state, step):
        k = min(k_unit, num_steps - step)
        if injector is not None:
            # collective-raise / SIGTERM hook: BEFORE the jitted call, so
            # the donated state is never half-consumed and a restore (or
            # the signal handler's blackbox) sees a consistent world
            injector.before_dispatch(step, k)
        with obs.span("driver/dispatch", step=step, k=k):
            take = lambda s: prefetcher.take(s, cfg.prefetch_timeout_s)
            if k_unit == 1:
                batch = jax.tree.map(jnp.asarray, take(step))
                key = key_fn(step)
            else:
                host = [take(step + i) for i in range(k)]
                batch = jax.tree.map(
                    lambda *xs: jnp.asarray(np.stack(xs)), *host)
                key = jnp.stack([key_fn(step + i) for i in range(k)])
            new_state, metrics = step_fn(state, batch, key)
        window.append((step, k, metrics))
        return new_state, step + k

    try:
        # the final drain runs under the same restore protection as the
        # loop body: a fault surfacing in the last in-flight units is
        # survived exactly like one mid-run
        while step < num_steps or window:
            try:
                if step >= num_steps:
                    retire_one()
                    check_swap()  # keep meta/log consistent on the tail
                    continue
                prev = step
                state, step = dispatch(state, step)
                while len(window) >= cfg.depth:  # at most `depth` in flight
                    retire_one()
                check_swap()
                if (ckpt_every and ckpt_fn is not None and step < num_steps
                        and step // ckpt_every > prev // ckpt_every):
                    # a unit crossed a checkpoint boundary — drain the
                    # window so the save reads a fully retired state
                    # (the drain's retires may accept a replan: install
                    # it before the save records the active plan)
                    drain()
                    check_swap()
                    with obs.span("driver/checkpoint", step=step):
                        ckpt_fn(state)
            except Exception as e:
                if rec is not None:
                    # blackbox BEFORE restore or re-raise: the ring still
                    # holds the pre-failure steps a restart would erase
                    if (isinstance(e, PrefetchStalled)
                            and e.cause is not None):
                        rec.note("driver/prefetch_error",
                                 error=type(e.cause).__name__,
                                 message=str(e.cause))
                    rec._safe_dump(f"exception:{type(e).__name__}")
                if restore_fn is None:
                    raise
                if supervisor is not None:
                    # classify + charge the class budget; raises
                    # RetryBudgetExhausted (clean abort, blackbox above)
                    # when the class is spent, else returns the jittered
                    # backoff delay to wait out before the restore
                    time.sleep(supervisor.on_failure(e, step))
                window.clear()
                consec_nonfinite = 0
                log.restarts += 1
                obs.event("driver/restart", step=step,
                          error=type(e).__name__)
                if injector is not None:
                    # poison produced for never-dispatched steps dies
                    # with the prefetch queue — refund it so the replay
                    # injects it for real (`step` is still the frontier)
                    injector.refund_undispatched(step)
                state = restore_fn()
                step = int(state.step)
                prefetcher.start(step, num_steps)
                last_retire_t = time.perf_counter()
        health_check()  # end-of-run verdicts over the full registry
    finally:
        prefetcher.stop()
    return state, log
