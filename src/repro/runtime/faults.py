"""Deterministic chaos-injection harness + recovery policy (DESIGN.md §12).

SparCML's premise is that the collective is the bottleneck; its twin at
scale is *failure*. This module is the sense half's counterpart to the
obs layer's act half: a seedable :class:`FaultPlan` describes WHICH
fault classes fire at WHICH steps (or decode ticks), and a
:class:`FaultInjector` is the stateful host-side hook box the runtime
loops call at their natural boundaries. Everything is deterministic —
two runs with the same plan inject byte-identically — and every spec is
one-shot-per-repeat: after a rewind the replayed steps run CLEAN, which
is what makes recovery bit-reproducible against an uninjected run.

Fault classes (``FAULT_CLASSES``), with their injection points:

  nonfinite     NaN/Inf written into selected gradient leaves IN-GRAPH:
                the injector rides a ``__fault__`` vector inside the
                batch dict (``FAULT_KEY``; one f32 per grad leaf, 0 =
                clean, 1 = NaN, 2 = Inf) that the guarded pipelined step
                consumes (runtime/pipeline.py). Selected leaves select
                the fusion buckets they land in.
  straggler     multiplicative retire delay (``factor`` x the current
                rolling median step time, floor ``duration_s``) charged
                to one emulated rank's retire — trips the driver's
                watchdog, never the math.
  stall         the data-pipeline batch_fn blocks for ``duration_s``
                inside the prefetch thread (drives the bounded
                ``queue.get`` timeout / dead-thread propagation path).
  collective    a raised exception at the collective layer boundary
                (pre-dispatch, so state is never half-consumed).
  ckpt_corrupt  bytes flipped in the just-written checkpoint's
                arrays.npz — caught by the CRC verification on the next
                restore, which falls back to the newest VALID step.
  sigterm       SIGTERM delivered to the process mid-superstep; the
                flight recorder's signal handler dumps the blackbox and
                chains to the previous handler.

The recovery half (:class:`RecoveryConfig` + :class:`RetrySupervisor`)
is what ``runtime.driver.run_pipelined`` consults on every failure: a
bounded exponential-backoff retry loop with deterministic jittered
delays and PER-FAULT-CLASS retry budgets; exhausting a budget escalates
to a clean abort (:class:`RetryBudgetExhausted`) after the blackbox
dump. Classification is by exception type (``classify_fault``).
"""
from __future__ import annotations

import os
import signal
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

# Reserved batch-dict key carrying the per-grad-leaf injection vector
# (f32 (n_leaves,): 0 clean / 1 NaN / 2 Inf). Batch keys are data-field
# names ("tokens", "labels", ...), so the dunder cannot collide.
FAULT_KEY = "__fault__"

FAULT_CLASSES = ("nonfinite", "straggler", "stall", "collective",
                 "ckpt_corrupt", "sigterm")


# --------------------------------------------------------------------------
# Exceptions — the fault-class taxonomy the supervisor classifies by type
# --------------------------------------------------------------------------

class FaultError(RuntimeError):
    """Base of every fault-runtime exception."""


class FaultInjectionError(FaultError):
    """An injected collective-layer failure (the 'collective' class)."""


class NonFiniteEscalation(FaultError):
    """The guarded step tripped ``max_consecutive_nonfinite`` times in a
    row — skip-recovery is no longer converging; rewind to the last-good
    checkpoint."""


class PrefetchStalled(FaultError):
    """The background prefetch thread died or stopped producing within
    the bounded ``queue.get`` timeout. ``cause`` carries the thread's
    own exception when one was captured."""

    def __init__(self, msg: str, cause: Optional[BaseException] = None):
        super().__init__(msg)
        self.cause = cause


class RetryBudgetExhausted(FaultError):
    """A fault class used up its retry budget: clean abort."""


def classify_fault(exc: BaseException) -> str:
    """Map an exception to the retry-budget class it draws from."""
    if isinstance(exc, NonFiniteEscalation):
        return "nonfinite"
    if isinstance(exc, PrefetchStalled):
        return "stall"
    if type(exc).__name__ == "CheckpointCorrupt" or \
            isinstance(exc, (OSError, EOFError)):
        return "ckpt_corrupt"
    if isinstance(exc, FaultInjectionError):
        return "collective"
    if isinstance(exc, (KeyboardInterrupt, SystemExit)):
        return "sigterm"
    return "collective"  # unknown failures retry on the generic budget


# --------------------------------------------------------------------------
# Fault plans
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault. ``step`` is a global training step for the
    driver hooks, a decode tick for the serve hooks. ``repeat`` fires the
    same fault on that many consecutive steps (consecutive-trip tests).
    ``leaves`` selects grad-leaf indices for nonfinite injection (None =
    every leaf; leaves select the fusion buckets they land in)."""

    kind: str
    step: int
    mode: str = "nan"               # nonfinite: "nan" | "inf"
    leaves: Optional[tuple] = None  # nonfinite: grad-leaf indices
    factor: float = 4.0             # straggler: x rolling median
    duration_s: float = 0.0         # straggler floor / stall block time
    rank: int = 0                   # straggler: emulated rank charged
    repeat: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_CLASSES:
            raise ValueError(
                f"kind must be one of {FAULT_CLASSES}: {self.kind!r}")
        if self.mode not in ("nan", "inf"):
            raise ValueError(f"mode must be nan|inf: {self.mode!r}")
        if self.repeat < 1:
            raise ValueError(f"repeat must be >= 1: {self.repeat}")


@dataclass(frozen=True)
class FaultPlan:
    """An immutable schedule of :class:`FaultSpec` rows plus the seed
    that derives any randomized choices. ``FaultPlan(())`` is the clean
    plan — an injector over it is a no-op whose hooks still execute, so
    A/B runs share the exact host code path."""

    specs: tuple = ()
    seed: int = 0

    @staticmethod
    def single(kind: str, step: int, **kw) -> "FaultPlan":
        return FaultPlan(specs=(FaultSpec(kind=kind, step=step, **kw),))

    @staticmethod
    def chaos(seed: int, num_steps: int,
              classes: tuple = ("nonfinite", "straggler", "stall",
                                "collective"),
              ckpt_every: Optional[int] = None) -> "FaultPlan":
        """Deterministic random plan: one fault per class, each at a
        seed-derived step inside [warmup, num_steps). Only RECOVERABLE
        classes by default — the chaos-smoke CI job asserts the run
        completes. ``ckpt_every`` adds a ckpt_corrupt + collective pair
        (corrupt a save, then force the restore that must fall back)."""
        rng = np.random.default_rng(seed)
        lo = max(2, num_steps // 8)
        hi = max(lo + 1, num_steps - 2)
        specs = [FaultSpec(kind=k, step=int(rng.integers(lo, hi)),
                           duration_s=0.2 if k in ("straggler", "stall")
                           else 0.0)
                 for k in classes]
        if ckpt_every and num_steps > 2 * ckpt_every:
            c = int(rng.integers(1, num_steps // ckpt_every))
            specs.append(FaultSpec(kind="ckpt_corrupt",
                                   step=c * ckpt_every))
            specs.append(FaultSpec(kind="collective",
                                   step=min(num_steps - 2,
                                            c * ckpt_every + 1)))
        return FaultPlan(specs=tuple(specs), seed=seed)

    def by_kind(self, kind: str) -> tuple:
        return tuple(s for s in self.specs if s.kind == kind)


# --------------------------------------------------------------------------
# The injector — stateful hook box the runtime loops call
# --------------------------------------------------------------------------

class FaultInjector:
    """Executes a :class:`FaultPlan` against the runtime's host hooks.

    One-shot bookkeeping lives HERE (not in the immutable plan): each
    spec fires at most ``repeat`` times across the injector's lifetime,
    so a rewind replays the faulted steps clean — the property every
    bit-equal recovery test leans on. Hooks are thread-compatible (the
    stall hook runs inside the prefetch thread).

    ``bind`` attaches what the constructor cannot know: the number of
    gradient leaves (for the ``FAULT_KEY`` vector) and the metrics
    registry that counts fired faults (``faults/injected_<kind>``)."""

    def __init__(self, plan: FaultPlan, *, n_leaves: Optional[int] = None,
                 registry=None):
        self.plan = plan
        self.n_leaves = n_leaves
        self.registry = registry
        self._fired: dict[int, int] = {}   # spec index -> times fired
        self.log: list[tuple] = []         # (kind, step) audit trail

    def bind(self, *, n_leaves: Optional[int] = None,
             registry=None) -> "FaultInjector":
        if n_leaves is not None:
            self.n_leaves = int(n_leaves)
        if registry is not None:
            self.registry = registry
        return self

    # -- firing bookkeeping ------------------------------------------------
    def _take(self, kind: str, step: int) -> Optional[FaultSpec]:
        """The spec of ``kind`` scheduled at ``step`` if it still has
        unfired repeats, consuming one; else None. A spec with repeat=r
        covers steps [spec.step, spec.step + r)."""
        for i, s in enumerate(self.plan.specs):
            if s.kind != kind or not (s.step <= step < s.step + s.repeat):
                continue
            if self._fired.get(i, 0) >= s.repeat:
                continue
            self._fired[i] = self._fired.get(i, 0) + 1
            self.log.append((kind, step))
            if self.registry is not None:
                self.registry.counter(f"faults/injected_{kind}").inc()
                # field named "fault", not "kind": the JSONL sink writes
                # event rows as {"kind": "event", **fields} and a "kind"
                # field would clobber the row discriminator
                self.registry.event("faults/injected", fault=kind, step=step)
            return s
        return None

    @property
    def fired_total(self) -> int:
        return sum(self._fired.values())

    # -- training-driver hooks ---------------------------------------------
    def grad_flag(self, step: int) -> np.ndarray:
        """(n_leaves,) f32 injection vector for this step's batch
        (FAULT_KEY leaf): 0 clean, 1 NaN, 2 Inf per grad leaf."""
        if self.n_leaves is None:
            raise RuntimeError(
                "FaultInjector.bind(n_leaves=...) before grad_flag — the "
                "trainer knows the grad-leaf count, the plan does not")
        vec = np.zeros((self.n_leaves,), np.float32)
        spec = self._take("nonfinite", step)
        if spec is not None:
            val = 1.0 if spec.mode == "nan" else 2.0
            idx = (list(range(self.n_leaves)) if spec.leaves is None
                   else [i for i in spec.leaves if i < self.n_leaves])
            vec[idx] = val
        return vec

    def wrap_batch_fn(self, batch_fn: Callable[[int], dict],
                      inject_key: bool = True) -> Callable[[int], dict]:
        """Wrap the driver's ``batch_fn`` with the stall hook and (when
        ``inject_key``) the FAULT_KEY vector the guarded step consumes.
        Runs on the prefetch thread — sleeps there model a stalled data
        pipeline without touching the dispatch loop."""

        def wrapped(step: int) -> dict:
            stall = self._take("stall", step)
            if stall is not None and stall.duration_s > 0:
                time.sleep(stall.duration_s)
            batch = dict(batch_fn(step))
            if inject_key:
                batch[FAULT_KEY] = self.grad_flag(step)
            return batch

        return wrapped

    def before_dispatch(self, step: int, n_steps: int = 1) -> None:
        """Pre-dispatch hook: collective-layer raise and SIGTERM. The
        unit being dispatched covers steps [step, step + n_steps) — a
        K-step superstep dispatches once for K steps, and a spec
        scheduled anywhere inside the unit must still fire. Raised
        BEFORE the jitted call, so no donated state is half-consumed
        and a restore/retry replays the unit exactly."""
        for s in range(step, step + max(1, n_steps)):
            if self._take("collective", s) is not None:
                raise FaultInjectionError(
                    f"injected collective failure at step {s}")
            if self._take("sigterm", s) is not None:
                os.kill(os.getpid(), signal.SIGTERM)

    def refund_undispatched(self, frontier: int) -> int:
        """Rewind-side bookkeeping for batch-carried injections. A
        nonfinite spec is CONSUMED when the prefetch thread produces the
        poisoned batch, but its effect only lands when a step consumes
        that batch — and a driver restore throws the prefetch queue
        away. Poison produced for steps at or beyond the dispatch
        frontier (the failure point's next-dispatch step) never reached
        the model, so those repeats are refunded and re-fire when the
        restarted prefetcher reproduces them. Steps BELOW the frontier
        were dispatched: they stay spent, replays run clean (the
        bit-equal contract). Only nonfinite refunds — a stall's side
        effect (the sleep) happens at production time, so it genuinely
        fired. Returns the number of refunded repeats."""
        refunded = 0
        for i, s in enumerate(self.plan.specs):
            if s.kind != "nonfinite":
                continue
            f = self._fired.get(i, 0)
            # fired repeats cover [s.step, s.step + f) in step order;
            # the tail at steps >= frontier was produced but never used
            lost = max(0, s.step + f - max(s.step, int(frontier)))
            if lost:
                self._fired[i] = f - lost
                refunded += lost
        if refunded and self.registry is not None:
            self.registry.counter("faults/refunded").inc(refunded)
            self.registry.event("faults/refunded", n=refunded,
                                frontier=int(frontier))
        return refunded

    def after_retire(self, first_step: int, n_steps: int,
                     median_s: float) -> None:
        """Straggler hook: a retire interval covering the spec'd step
        blocks for ``factor`` x the current rolling median (floor
        ``duration_s``) — the chosen rank's retire arrives late."""
        for s in range(first_step, first_step + n_steps):
            spec = self._take("straggler", s)
            if spec is not None:
                time.sleep(max(spec.duration_s,
                               spec.factor * max(median_s, 0.0)))

    def corrupt_checkpoint(self, directory: str, step: int) -> Optional[str]:
        """Post-save hook: when a ckpt_corrupt spec covers ``step``, flip
        bytes mid-file in the newest checkpoint's arrays.npz (the torn
        write a crashed/buggy writer leaves). Returns the corrupted path
        or None."""
        if self._take("ckpt_corrupt", step) is None:
            return None
        from repro.train import checkpoint as ckpt

        latest = ckpt.latest_step(directory)
        if latest is None:
            return None
        path = os.path.join(directory, f"step_{latest:08d}", "arrays.npz")
        try:
            with open(path, "r+b") as f:
                f.seek(0, os.SEEK_END)
                size = f.tell()
                f.seek(size // 2)
                chunk = f.read(64)
                f.seek(size // 2)
                f.write(bytes(b ^ 0xFF for b in chunk))
        except OSError:
            return None
        return path

    # -- serve-engine hooks -------------------------------------------------
    def serve_tick(self, tick: int) -> None:
        """Per-decode-tick hook, called BEFORE the tick dispatches (slot
        state untouched on raise, so a pre-dispatch retry is exact):

          collective  raises FaultInjectionError (engine retries on its
                      budget)
          nonfinite   raises NonFiniteEscalation (decode state is
                      donated — no in-place retry exists, so the engine
                      aborts cleanly with a blackbox)
          straggler / stall   block for duration_s (latency/SLO path —
                      token outputs are unaffected by wall time)
          sigterm     SIGTERM to the process
          ckpt_corrupt  no-op (serving has no checkpoints)
        """
        if self._take("collective", tick) is not None:
            raise FaultInjectionError(
                f"injected collective failure at decode tick {tick}")
        if self._take("nonfinite", tick) is not None:
            raise NonFiniteEscalation(
                f"injected non-finite logits at decode tick {tick}")
        for kind in ("straggler", "stall"):
            spec = self._take(kind, tick)
            if spec is not None and spec.duration_s > 0:
                time.sleep(spec.duration_s)
        if self._take("sigterm", tick) is not None:
            os.kill(os.getpid(), signal.SIGTERM)


# --------------------------------------------------------------------------
# Recovery policy — retry budgets + exponential backoff with jitter
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class RecoveryConfig:
    """The driver's recovery policy (DESIGN.md §12.3).

    ``max_consecutive_nonfinite`` is N of the guarded step's escalation
    rule: N consecutive tripped steps raise NonFiniteEscalation, which
    the supervisor answers with a rewind to the last-good checkpoint.
    ``budgets`` caps restore+retry attempts PER FAULT CLASS; the
    ``default`` key covers unlisted classes. Delays are exponential in
    the per-class attempt count, capped at ``backoff_max_s``, with a
    deterministic seeded jitter so co-failing replicas don't restore in
    lockstep."""

    max_consecutive_nonfinite: int = 3
    budgets: dict = field(default_factory=lambda: {
        "nonfinite": 2, "stall": 2, "ckpt_corrupt": 2, "collective": 3,
        "sigterm": 0, "default": 2,
    })
    backoff_base_s: float = 0.05
    backoff_max_s: float = 2.0
    jitter: float = 0.25
    seed: int = 0

    def budget_for(self, cls: str) -> int:
        return int(self.budgets.get(cls, self.budgets.get("default", 2)))


class RetrySupervisor:
    """Bounded retry/backoff bookkeeping for one driver run.

    ``on_failure(exc, step)`` classifies the exception, charges the
    class's budget, and returns the jittered backoff delay to sleep
    before the restore — or raises :class:`RetryBudgetExhausted` (from
    the original exception) when the class is spent. Budgets are
    per-class and cumulative over the run: distinct fault classes don't
    steal each other's retries, and a flapping fault can't restart
    forever. Every decision is a ``recovery/*`` event."""

    def __init__(self, cfg: RecoveryConfig = RecoveryConfig(), *,
                 registry=None):
        self.cfg = cfg
        self.registry = registry
        self.attempts: dict[str, int] = {}
        self._rng = np.random.default_rng(cfg.seed)

    def _event(self, name: str, **fields) -> None:
        if self.registry is not None:
            self.registry.event(name, **fields)

    def backoff_s(self, cls: str) -> float:
        n = self.attempts.get(cls, 1)
        base = min(self.cfg.backoff_max_s,
                   self.cfg.backoff_base_s * (2.0 ** (n - 1)))
        return base * (1.0 + self.cfg.jitter * float(self._rng.random()))

    def on_failure(self, exc: BaseException, step: int) -> float:
        cls = classify_fault(exc)
        self.attempts[cls] = self.attempts.get(cls, 0) + 1
        n, budget = self.attempts[cls], self.cfg.budget_for(cls)
        if n > budget:
            if self.registry is not None:
                self.registry.counter("recovery/aborts").inc()
            self._event("recovery/abort", cls=cls, step=step,
                        attempts=n, budget=budget,
                        error=type(exc).__name__)
            raise RetryBudgetExhausted(
                f"fault class {cls!r} exhausted its retry budget "
                f"({budget}) at step {step}: {exc!r}") from exc
        delay = self.backoff_s(cls)
        if self.registry is not None:
            self.registry.counter("recovery/retries").inc()
            self.registry.counter(f"recovery/retries_{cls}").inc()
        self._event("recovery/retry", cls=cls, step=step, attempt=n,
                    budget=budget, delay_s=delay,
                    error=type(exc).__name__)
        return delay


def crc32_of(arr: np.ndarray) -> int:
    """The CRC32 the checkpoint integrity layer records per leaf —
    shared here so tests and tooling compute the identical digest."""
    a = np.ascontiguousarray(arr)
    return zlib.crc32(a.tobytes()) & 0xFFFFFFFF
