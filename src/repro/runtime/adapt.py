"""Adaptive re-planning: measured-density telemetry -> plan swaps
(DESIGN.md §7).

The trace-time ``SyncPlan`` freezes every per-bucket algorithm choice at
the ASSUMED TopK density; fill-in growth, EF-residual densification and
real wire costs never feed back. This module closes the loop:

  TelemetryWindow      windows the executor's per-bucket post-reduction
                       nnz stats (host-side, retired steps only)
  AdaptiveController   re-runs the cost model with measured densities and
                       calibrated NetworkParams, applies hysteresis so
                       plans don't flap, and emits an accepted replan
  AdaptiveRuntime      driver-facing adapter: controller + a
                       plan-signature-keyed compiled-step cache; the
                       driver drains its dispatch window, swaps the
                       compiled superstep, and keeps going
  TelemetryObserver    adapt-shaped observer that only records per-bucket
                       telemetry metrics — for runs that want the
                       observability without runtime re-planning

Every controller decision is also a STRUCTURED EVENT (DESIGN.md §10)
carrying the densities and modeled costs that justified it —
``adapt/replan_accepted``, ``adapt/hysteresis_veto``,
``adapt/delta_forced``, ``adapt/forced_switch``, ``adapt/forced_install``
— through the ``repro.obs`` handle, so a trace answers not just *what*
the controller did but *why*.

Replans are layout-invariant (``BucketSpec.ef`` pins the residual set),
so a swap never migrates TrainState — the in-flight reduced buffers and
EF residuals carry straight across, and checkpoints written under any
plan version restore under any other (the active plan's algorithm map is
carried in checkpoint meta so restarts RESUME the adapted plan instead
of re-warming from the static one).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.cost_model import (DEFAULT_NET, NetworkParams,
                                   algorithm_output_cap, bucket_time,
                                   t_param_allgather)
from repro.core.sparse_stream import delta_threshold
from repro.obs import resolve as _resolve_obs
from repro.obs.metrics import record_bucket_telemetry


@dataclass(frozen=True)
class AdaptConfig:
    """Knobs of the adaptive controller."""

    window: int = 8          # retired steps of telemetry per decision
    hysteresis: float = 0.2  # min fractional modeled win to switch a bucket
    patience: int = 2        # consecutive windows agreeing before a swap
    calibrate: bool = True   # fit NetworkParams from measured timings once
    pod_sparse: bool = True  # allow demoting the cross-pod dense psum
    allow: Optional[tuple] = None  # restrict replan candidates (None = all)
    # Fault demotion (DESIGN.md §12.5): decision windows a fault-demoted
    # bucket is HELD on the dense/exact algorithm before the normal
    # hysteresis+patience machinery may re-promote it.
    demote_hold: int = 4


class TelemetryWindow:
    """Fixed-size window of per-step, per-bucket post-reduction nnz."""

    def __init__(self, window: int):
        self.window = max(1, int(window))
        self._rows: list[dict] = []

    def push(self, nnz_by_bucket: dict) -> None:
        self._rows.append(dict(nnz_by_bucket))
        if len(self._rows) > self.window:
            self._rows = self._rows[-self.window:]

    @property
    def full(self) -> bool:
        return len(self._rows) >= self.window

    def mean_nnz(self) -> dict:
        out: dict = {}
        for row in self._rows:
            for name, nnz in row.items():
                out.setdefault(name, []).append(float(nnz))
        return {name: float(np.mean(v)) for name, v in out.items()}

    def clear(self) -> None:
        self._rows = []


class AdaptiveController:
    """Pure decision logic: windowed telemetry in, accepted replans out.

    Decision rule (DESIGN.md §7): every full window, re-run
    ``SyncPlan.replan`` with the window's mean measured nnz and the
    calibrated net params; a bucket's algorithm actually changes only if
    the cost model predicts at least ``hysteresis`` fractional win AT THE
    MEASURED DENSITY (flap damping #1), and the resulting plan must win
    ``patience`` consecutive windows before it is emitted (flap damping
    #2). Cross-pod demotion (``pod_sparse``) additionally requires the
    measured fill-in to stay under the delta threshold."""

    def __init__(self, plan, net: NetworkParams = DEFAULT_NET,
                 cfg: AdaptConfig = AdaptConfig(), p_pod: int = 1,
                 obs=None):
        self.plan = plan
        self.net = net
        self.cfg = cfg
        self.p_pod = max(1, int(p_pod))
        self.obs = _resolve_obs(obs)
        self.window = TelemetryWindow(cfg.window)
        self._pending_sig: Optional[str] = None
        self._pending_plan = None
        self._pending_count = 0
        self._urgent = False
        # fault-demoted buckets -> remaining hold windows (§12.5): while
        # held, _decide pins the bucket to "dense" whatever the model says
        self._demoted: dict = {}
        self.swaps = 0

    # -- health advisory ---------------------------------------------------
    def advise(self, events) -> None:
        """Drain-barrier advisory from the health engine (DESIGN.md
        §10.5): CRITICAL compression-health findings (EF-residual
        blowup, mass-coverage collapse) mark the controller urgent — its
        next pending proposal is accepted after a single agreeing window
        instead of waiting out the full ``patience``. Advisory only:
        nothing is forced, hysteresis still applies, and the flag clears
        at the next accepted swap (a persisting condition simply
        re-advises at the next barrier)."""
        crit = [e for e in events
                if getattr(e, "severity", None) == "critical"
                and getattr(e, "rule", None) in ("ef_growth",
                                                 "coverage_floor")]
        if not crit:
            return
        self._urgent = True
        self.obs.event("adapt/health_advisory",
                       buckets=sorted({e.subject for e in crit}),
                       rules=sorted({e.rule for e in crit}))

    def demote(self, buckets=None):
        """Fault demotion (DESIGN.md §12.5): a HealthMonitor FAULT verdict
        (non-finite grads) forces the dense/exact algorithm onto the
        offending buckets (None = every bucket — a non-finite grad cannot
        be attributed below the leaf->bucket packing) and HOLDS them
        there for ``demote_hold`` decision windows before the normal
        hysteresis+patience machinery may re-promote. Returns the forced
        plan to install at the next drain barrier, or None when the
        targets are already dense (the hold is refreshed — a persisting
        fault re-advises every barrier without re-forcing swaps)."""
        cur = self.plan.algorithms()
        names = [n for n in cur if buckets is None or n in buckets]
        if not names:
            return None
        for n in names:
            self._demoted[n] = self.cfg.demote_hold
        if all(cur[n] == "dense" for n in names):
            return None
        forced = self.plan.replan(algorithms={n: "dense" for n in names})
        self.obs.event("adapt/fault_demotion", buckets=names,
                       hold=self.cfg.demote_hold,
                       signature=forced.signature())
        self.force(forced)
        return forced

    # -- telemetry ingest --------------------------------------------------
    def observe_step(self, nnz_by_bucket: dict):
        """Feed one retired step's stats; returns an accepted new plan
        when a swap is due, else None."""
        self.window.push(nnz_by_bucket)
        if not self.window.full:
            return None
        decision = self._decide(self.window.mean_nnz())
        self.window.clear()    # non-overlapping windows
        return decision

    # -- decision ----------------------------------------------------------
    def _bucket_ctx(self):
        for g in self.plan.groups:
            for b in g.buckets:
                yield g, b, self.plan.bucket_k(g, b)

    def _pod_flags(self, densities: dict) -> dict:
        """Cross-pod demotion decisions, WITH the hysteresis damper: the
        byte comparison must win by the hysteresis margin to set a flag,
        and an already-set flag is only cleared when the measured fill-in
        actually crosses delta — a bucket hovering at the boundary keeps
        its current wire path instead of flapping (each flip costs a full
        dispatch-window drain)."""
        from repro.core.cost_model import pod_wire_bytes

        flags = {}
        if self.p_pod <= 1 or not self.cfg.pod_sparse:
            return flags
        p_data = self.plan.dp_total // self.p_pod
        for g, b, k in self._bucket_ctx():
            if g.rows != 1 or not b.has_residual:
                continue
            cap = min(b.n, p_data * k)
            sparse_bytes = pod_wire_bytes(self.p_pod, b.n, cap,
                                          pod_sparse=True)
            dense_bytes = pod_wire_bytes(self.p_pod, b.n, cap,
                                         pod_sparse=False)
            nnz = densities.get(b.name)
            delta = delta_threshold(b.n, self.net.isize)
            if b.pod_sparse:
                # sticky: clear only on a real delta crossing
                flags[b.name] = bool(nnz is None or nnz < delta)
            else:
                margin = 1.0 - self.cfg.hysteresis
                flags[b.name] = bool(
                    sparse_bytes <= margin * dense_bytes
                    and nnz is not None and nnz < margin * delta)
        return flags

    def _decide(self, densities: dict):
        cfg = self.plan.cfg
        vb = cfg.qsgd_bits if cfg.qsgd_bits is not None else 32
        p = self.plan.dp_total
        replan_kw = {"pod_sparse": self._pod_flags(densities)}
        if self.cfg.allow is not None:
            # SyncPlan.replan narrows its candidate set; ServePlan has no
            # allow knob (its portfolio is the stream-cap ladder).
            replan_kw["allow"] = self.cfg.allow
        candidate = self.plan.replan(densities, self.net, **replan_kw)
        # Hysteresis: revert any per-bucket change whose modeled win at
        # the measured density is under the threshold. Exception: when
        # the measured fill-in crossed the delta threshold, the sparse
        # end-representation can no longer win (Lemma 5.2) — the paper's
        # delta switchover is a rule, not a perf heuristic, so it is
        # never vetoed by hysteresis.
        cur_algo = self.plan.algorithms()
        keep: dict = {}
        for g, b, k in ((g, b, candidate.bucket_k(g, b))
                        for g in candidate.groups for b in g.buckets):
            old = cur_algo[b.name]
            if b.algorithm == old:
                continue
            nnz = densities.get(b.name)
            # Capacity-clamped algorithms (output_cap < delta) keep O(k)
            # traffic whatever the fill-in — the delta switchover rule
            # only binds algorithms whose result width tracks the fill.
            cap = algorithm_output_cap(old, p, k, b.n)
            forced = (old.startswith("ssar") and nnz is not None
                      and nnz >= delta_threshold(b.n, self.net.isize)
                      and (cap is None
                           or cap >= delta_threshold(b.n, self.net.isize)))
            # Plans may carry their own forced-switch rule (same principle
            # as the delta crossing — a correctness boundary, not a perf
            # heuristic): the serve ServePlan forces a stream off its
            # capacity once the measured occupancy reaches it.
            hook = getattr(self.plan, "switch_forced", None)
            hook_forced = False
            if not forced and hook is not None:
                hook_forced = bool(hook(b.name, old, b.algorithm, nnz))
            if forced or hook_forced:
                self.obs.event(
                    "adapt/delta_forced" if forced else "adapt/forced_switch",
                    bucket=b.name, old=old, new=b.algorithm, nnz=nnz)
                continue
            t_old = bucket_time(old, p, k, b.n, self.net, vb,
                                reduced_nnz=nnz)
            t_new = bucket_time(b.algorithm, p, k, b.n, self.net, vb,
                                reduced_nnz=nnz)
            win = t_new <= (1.0 - self.cfg.hysteresis) * t_old
            keep[b.name] = b.algorithm if win else old
            if not win:
                self.obs.event("adapt/hysteresis_veto", bucket=b.name,
                               old=old, new=b.algorithm, nnz=nnz,
                               t_old_s=t_old, t_new_s=t_new,
                               hysteresis=self.cfg.hysteresis)
        # Fault-demotion hold (§12.5): buckets inside their hold window
        # stay dense whatever the cost model proposes; the hold ticks
        # down one per decision window, and only after it expires does
        # the normal hysteresis+patience path get to re-promote.
        if self._demoted:
            for n in self._demoted:
                if n in cur_algo:
                    keep[n] = "dense"
            for n in list(self._demoted):
                self._demoted[n] -= 1
                if self._demoted[n] <= 0:
                    del self._demoted[n]
        if keep:
            # revert ONLY the vetoed buckets; delta-forced and clear-win
            # changes keep the candidate's choice (replan defaults every
            # unnamed bucket to its current algorithm). One accepted swap
            # = one version step, whatever the internal passes did.
            import dataclasses

            candidate = dataclasses.replace(
                candidate.replan(algorithms=keep),
                version=self.plan.version + 1)
        if candidate.signature() == self.plan.signature():
            self._pending_sig, self._pending_count = None, 0
            return None
        # Patience: the same proposal must win consecutive windows.
        sig = candidate.signature()
        if sig == self._pending_sig:
            self._pending_count += 1
        else:
            self._pending_sig, self._pending_plan = sig, candidate
            self._pending_count = 1
        need = 1 if self._urgent else self.cfg.patience
        if self._pending_count < need:
            self.obs.event("adapt/replan_pending", signature=sig,
                           count=self._pending_count,
                           patience=self.cfg.patience, densities=densities)
            return None
        accepted = self._pending_plan
        self.plan = accepted
        self._pending_sig, self._pending_count = None, 0
        self._urgent = False
        self.swaps += 1
        self.obs.event("adapt/replan_accepted", signature=accepted.signature(),
                       version=accepted.version, swaps=self.swaps,
                       densities=densities)
        return accepted

    def recommend_output_mode(self, densities: dict | None = None,
                              overlap_s: float = 0.0) -> str:
        """Advisory replicated <-> scattered decision (DESIGN.md §11).

        The output mode changes the OPTIMIZER-STATE LAYOUT (bucket-keyed
        shard chunks vs per-leaf replicas), so the pipelined runtime pins
        it for a run's lifetime — this is the restart-barrier decision,
        never a ``maybe_swap`` candidate. Sticky with the same hysteresis
        damper as per-bucket switches: the OTHER mode must beat the
        current one by the ``hysteresis`` fraction of modeled per-step
        comm time, so a workload hovering at the boundary keeps its
        layout instead of flapping across restarts.

        Scattered is charged its per-bucket scatter costs plus the dense
        param allgather's EXPOSED tail after ``overlap_s`` seconds of
        independent next-step compute (t_param_allgather is overlappable
        — DESIGN.md §11 — so it is weighed at its uncovered remainder,
        not at par)."""
        from repro.core.cost_model import plan_bucket_times

        cfg = self.plan.cfg
        p = self.plan.dp_total
        cur = self.plan.output_mode
        t_mode = {}
        for mode in ("replicated", "scattered"):
            trial = (self.plan if mode == cur
                     else self.plan.replan(output_mode=mode))
            t = sum(plan_bucket_times(trial, p, self.net,
                                      densities=densities))
            if mode == "scattered":
                t_ag = sum(t_param_allgather(p, b.n, self.net)
                           for g in trial.groups for b in g.buckets)
                t += max(0.0, t_ag - max(0.0, float(overlap_s)))
            t_mode[mode] = t
        other = "scattered" if cur == "replicated" else "replicated"
        switch = t_mode[other] <= (1.0 - self.cfg.hysteresis) * t_mode[cur]
        rec = other if switch else cur
        self.obs.event("adapt/mode_recommend", current=cur, recommended=rec,
                       t_replicated_s=t_mode["replicated"],
                       t_scattered_s=t_mode["scattered"],
                       overlap_s=overlap_s,
                       hysteresis=self.cfg.hysteresis)
        return rec

    def force(self, plan) -> None:
        """Install an externally-forced plan NOW, bypassing hysteresis
        and patience — the caller hit a correctness boundary (the serve
        engine's occupancy guard crossing a stream capacity before the
        windowed controller could react). Pending proposals and the
        half-full telemetry window are dropped: they described the plan
        that was just invalidated."""
        self.plan = plan
        self._pending_sig, self._pending_plan = None, None
        self._pending_count = 0
        self.window.clear()
        self.swaps += 1
        self.obs.event("adapt/forced_install", signature=plan.signature(),
                       version=getattr(plan, "version", None),
                       swaps=self.swaps)


class AdaptiveRuntime:
    """What ``runtime.driver.run_pipelined(adapt=...)`` drives: consumes
    retired metrics, and hands back a freshly compiled superstep (from a
    plan-signature-keyed cache) whenever the controller accepts a replan.
    Swaps happen only at drain barriers — the driver empties its dispatch
    window first — so at most one compiled program is ever in flight."""

    def __init__(self, model, tcfg, mesh, *, plan,
                 net: NetworkParams = DEFAULT_NET,
                 cfg: AdaptConfig = AdaptConfig(),
                 staleness: int = 1, superstep: int = 1,
                 unroll: bool = False,
                 build_fn: Optional[Callable] = None, obs=None,
                 guard: bool = False, inject: bool = False):
        from repro.train.train_step import dp_axes_of

        self.model, self.tcfg, self.mesh = model, tcfg, mesh
        self.staleness, self.superstep, self.unroll = (staleness, superstep,
                                                       unroll)
        self.guard, self.inject = guard, inject
        self.obs = _resolve_obs(obs)
        dp_ax = dp_axes_of(mesh)
        p_pod = mesh.shape[dp_ax[0]] if len(dp_ax) > 1 else 1
        self.controller = AdaptiveController(plan, net, cfg, p_pod=p_pod,
                                             obs=self.obs)
        # The output mode is PINNED for the runtime's lifetime: a mode
        # change alters the TrainState layout (bucket-keyed opt-state
        # shard chunks vs per-leaf replicas), which a drain-barrier swap
        # cannot migrate. Controller replans inherit the mode (SyncPlan.
        # replan only changes it when asked); the guard in maybe_swap
        # turns any future violation into a loud failure instead of a
        # shape error deep inside the swapped-in compiled step.
        self._output_mode = getattr(plan, "output_mode", "replicated")
        self._build_fn = build_fn or self._default_build
        self._cache: dict = {}
        self._swap_to = None

    # -- compiled-step cache ----------------------------------------------
    def _default_build(self, plan):
        from repro.runtime import pipeline as rt_pipeline

        if self.superstep > 1:
            fn, _, _ = rt_pipeline.build_superstep(
                self.model, self.tcfg, self.mesh, staleness=self.staleness,
                steps=self.superstep, unroll=self.unroll, plan=plan,
                guard=self.guard, inject=self.inject)
        else:
            fn, _, _ = rt_pipeline.build_pipelined_step(
                self.model, self.tcfg, self.mesh, staleness=self.staleness,
                plan=plan, guard=self.guard, inject=self.inject)
        return fn

    def step_fn_for(self, plan):
        sig = plan.signature()
        if sig not in self._cache:
            self._cache[sig] = self._build_fn(plan)
        return self._cache[sig]

    @property
    def current_plan(self):
        return self.controller.plan

    def current_fn(self):
        return self.step_fn_for(self.current_plan)

    # -- driver hooks ------------------------------------------------------
    def observe(self, first_step: int, n_steps: int, metrics) -> None:
        """Retire hook: pull per-bucket telemetry off a retired unit's
        metrics (already host-synced by the driver) and feed the
        controller, one row per step of the unit."""
        telem = metrics.get("telemetry") if hasattr(metrics, "get") else None
        if not telem:
            return
        arrs = {name: np.atleast_2d(np.asarray(v)) for name, v in
                telem.items()}
        # (k, 2) [nnz, wire] or (k, 4) [nnz, wire, mass coverage, EF
        # norm] rows — col 0 (nnz) drives replans either way; the mass
        # cols feed the health-engine histograms via the recorder below.
        record_bucket_telemetry(self.obs.metrics, arrs)
        k = min(a.shape[0] for a in arrs.values())
        for i in range(k):
            row = {name: float(a[i, 0]) for name, a in arrs.items()}
            accepted = self.controller.observe_step(row)
            if accepted is not None:
                self._swap_to = accepted

    def advise(self, events) -> None:
        """Forward the driver's drain-barrier health advisory to the
        controller (see AdaptiveController.advise), and act on FAULT
        verdicts (§12.5): a critical ``nonfinite`` finding demotes the
        offending buckets to the dense/exact algorithm — the forced plan
        installs at the next drain barrier via maybe_swap, with the
        controller's demote-hold gating re-promotion."""
        self.controller.advise(events)
        crit = [e for e in events
                if getattr(e, "severity", None) == "critical"
                and getattr(e, "rule", None) == "nonfinite"]
        if not crit:
            return
        bucket_names = {b.name for g in self.controller.plan.groups
                        for b in g.buckets}
        subjects = {getattr(e, "subject", None) for e in crit} & bucket_names
        forced = self.controller.demote(subjects or None)
        if forced is not None:
            self._swap_to = forced

    def maybe_swap(self):
        """Returns (new_step_fn, new_plan) once after each accepted
        replan, else None. The driver calls this between dispatches and
        drains its window before installing the new function."""
        if self._swap_to is None:
            return None
        plan, self._swap_to = self._swap_to, None
        if getattr(plan, "output_mode", "replicated") != self._output_mode:
            raise RuntimeError(
                "adaptive replan changed output_mode "
                f"({self._output_mode!r} -> {plan.output_mode!r}); the mode "
                "is pinned per run — use AdaptiveController."
                "recommend_output_mode and restart (DESIGN.md §11)")
        return self.step_fn_for(plan), plan


class TelemetryObserver:
    """``run_pipelined(adapt=...)`` duck-type that RECORDS the in-graph
    per-bucket telemetry (nnz / wire-bytes histograms) without ever
    proposing a replan — the metrics path for runs that compile telemetry
    in but leave the adaptive controller off."""

    def __init__(self, obs=None):
        self.obs = _resolve_obs(obs)

    def observe(self, first_step: int, n_steps: int, metrics) -> None:
        telem = metrics.get("telemetry") if hasattr(metrics, "get") else None
        if not telem or not self.obs.metrics_on:
            return
        arrs = {name: np.atleast_2d(np.asarray(v)) for name, v in
                telem.items()}
        record_bucket_telemetry(self.obs.metrics, arrs)

    def maybe_swap(self):
        return None
