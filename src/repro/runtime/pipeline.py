"""Pipelined stale-gradient supersteps (DESIGN.md §6).

The synchronous sparcml step serializes compute and communication:

    grads_t -> reduce(grads_t) -> apply -> update      (blocks on the wire)

The pipelined step splits the executor into its compose-able halves
(``comm.reduce_buckets`` / ``comm.apply_buckets``) and staggers them by
``staleness`` steps (bounded at 1):

    step t:  grads_t = backward(params_t, batch_t)
             params_{t+1} = update(params_t, apply(inflight))   # = R(g_{t-1})
             inflight' = reduce_buckets(grads_t)                # in flight
                                                                # until t+1

so the collectives of step t-1 drain while step t's forward/backward
runs — on hardware with async collectives the scheduler overlaps them;
on the host driver the removed per-step dependency is what lets dispatch
run ahead. Error-feedback residuals stay keyed by bucket and are updated
by the REDUCE half every step, exactly as in the synchronous executor.

``staleness=0`` degenerates to the synchronous composition (execute_plan)
with no in-flight state — the same ops in the same order, so its output
matches the synchronous step bit-for-bit.

In-flight buffers carry a scalar validity flag (``VALID_KEY``): steps
that would apply INVALID (all-zero) buffers — the first step, and the
first step after every attach/resume/restore — run at lr 0, so
parameters are untouched until a real reduction lands (the optimizer's
count still advances and its moments decay once — a one-step offset,
negligible and documented).

Three lowerings, mirroring ``train_step`` (DESIGN.md §4): ``manual``
(shard_map + native collectives), ``emulated`` (shard_map + psum-emulated
collectives), ``spmd`` (auto-SPMD, no shard_map). ``build_superstep``
wraps the step in a jitted ``lax.scan`` over K steps, so one dispatch
covers a whole superstep and the per-step jaxpr keeps O(num_buckets)
collectives.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import comm, compat
from repro.models.model import Model
from repro.optim.optimizers import clip_by_global_norm, opt_update
from repro.optim.schedule import make_schedule
from repro.runtime.faults import FAULT_KEY
from repro.train import train_step as ts
from repro.train.state import TrainConfig, TrainState

LOWERINGS = ("manual", "emulated", "spmd")

# Scalar validity flag carried inside the in-flight dict (f32 0/1): zero
# in-flight buffers (fresh start, resume, post-restore attach) must be
# applied at lr 0 REGARDLESS of the step counter — gating on step alone
# would apply a zero gradient at full lr after every resume. Bucket names
# are "g<gid>b<idx>", so the key cannot collide.
VALID_KEY = "__valid__"


def resolve_lowering(mesh: Mesh, lowering: Optional[str] = None) -> str:
    """Default to the same backend detection as build_train_step; tests
    force a specific lowering to assert cross-lowering parity."""
    if lowering is None:
        return "manual" if ts.sparcml_uses_manual_collectives(mesh) else "spmd"
    if lowering not in LOWERINGS:
        raise ValueError(f"lowering must be one of {LOWERINGS}: {lowering!r}")
    return lowering


def pipelined_state_shapes(model: Model, tcfg: TrainConfig, mesh: Mesh, *,
                           staleness: int = 1, plan=None):
    """(abstract TrainState, spec TrainState, SyncPlan) for the pipelined
    step: the synchronous state plus — when staleness > 0 — the in-flight
    reduced-bucket buffers (``TrainState.inflight``, keyed like residuals
    by bucket name).

    ``plan`` substitutes a REPLANNED SyncPlan (DESIGN.md §7) for the
    freshly derived one. Replans are layout-invariant by construction
    (``BucketSpec.ef`` pins the residual set), so the returned shapes are
    identical for every version of one base plan — asserted here."""
    if tcfg.sync.mode != "sparcml":
        raise ValueError(
            "the pipelined runtime overlaps the planned sparse collectives "
            "and requires sync.mode='sparcml' (dense mode has no explicit "
            "reduce to defer — XLA owns its collectives)")
    if staleness not in (0, 1):
        raise ValueError(f"staleness is bounded at 1, got {staleness}")
    shapes, specs, built = ts.state_shapes(model, tcfg, mesh,
                                           return_plan=True)
    if plan is None:
        plan = built
    elif (plan.residual_shapes() != built.residual_shapes()
          or plan.inflight_shapes() != built.inflight_shapes()):
        # full name->shape dicts, not just key sets: a plan from another
        # (dp, bucket-size) configuration can reuse the generic g<i>b<j>
        # names and would otherwise die later inside jit with an opaque
        # XLA shape error instead of this one
        raise ValueError(
            "plan override changes the residual/in-flight layout — replans "
            "must come from SyncPlan.replan() of this configuration's base "
            "plan")
    if staleness:
        shapes = shapes._replace(inflight={
            **plan.inflight_shapes(),
            VALID_KEY: jax.ShapeDtypeStruct((), jnp.float32)})
        specs = specs._replace(inflight={
            **plan.inflight_specs(ts.dp_axes_of(mesh)),
            VALID_KEY: P()})
    return shapes, specs, plan


def attach_inflight(state: TrainState, plan, mesh: Mesh) -> TrainState:
    """Zero in-flight buffers onto a synchronous-shaped TrainState (resume
    from a checkpoint, or hand-off from Trainer.run): the validity flag
    starts at 0, so the first pipelined step applies a zero gradient at
    lr 0 (the optimizer moments still decay once) — whatever the step."""
    if state.inflight is not None:
        return state
    shapes = plan.inflight_shapes()
    specs = plan.inflight_specs(ts.dp_axes_of(mesh))
    zeros = {
        k: jax.device_put(jnp.zeros(s.shape, s.dtype),
                          NamedSharding(mesh, specs[k]))
        for k, s in shapes.items()
    }
    zeros[VALID_KEY] = jax.device_put(jnp.zeros((), jnp.float32),
                                      NamedSharding(mesh, P()))
    return state._replace(inflight=zeros)


# --------------------------------------------------------------------------
# Step-body construction (shared by single-step and superstep builders)
# --------------------------------------------------------------------------

def _pipelined_batch_specs(cfg, mesh: Mesh, inject: bool) -> dict:
    """Batch specs for the pipelined builders: the data fields plus —
    when the chaos harness is riding along — the replicated per-grad-leaf
    injection vector (``faults.FAULT_KEY``, (n_leaves,) f32)."""
    b = ts.batch_specs(cfg, mesh)
    if inject:
        b = {**b, FAULT_KEY: P()}
    return b


def _make_raw_step(model: Model, tcfg: TrainConfig, mesh: Mesh,
                   staleness: int, lowering: Optional[str],
                   plan=None, telemetry: bool = True,
                   guard: bool = False, inject: bool = False):
    """Un-jitted pipelined step (state, batch, key) -> (state, metrics),
    plus (shapes, specs, plan). The body mirrors build_train_step's
    sparcml branches with the sync split at the staleness boundary —
    kept as a twin on purpose (folding them would put the runtime on the
    synchronous hot path); tests/test_runtime.py compares the two
    implementations output-for-output on every lowering, so any silent
    divergence between the twins fails CI.

    ``plan`` runs a replanned SyncPlan (adaptive runtime, DESIGN.md §7)
    instead of the derived base plan. ``telemetry=False`` drops the
    per-bucket stats from the metrics dict AND from the traced graph:
    the flag is threaded into the executor so the nnz/wire/mass counts
    (and the mass psum) are never emitted, not merely DCE'd — asserted
    at the jaxpr level in tests (the overhead A/B in
    benchmarks/bench_adapt.py and bench_obs_health.py).

    ``guard=True`` adds the in-graph all-finite check over the RAW grad
    leaves (DESIGN.md §12.2): a non-finite gradient anywhere makes the
    step a no-op on params, optimizer state, EF residuals AND in-flight
    buffers (the step counter still advances), and ``metrics["nonfinite"]``
    reports 1.0 for the tripped step. ``inject=True`` additionally
    consumes a ``faults.FAULT_KEY`` leaf from the batch dict — the chaos
    harness's per-grad-leaf NaN/Inf vector, applied by pure select before
    the reduce half, so an all-zero vector is bit-exact with no injector."""
    cfg = model.cfg
    sched = make_schedule(tcfg.schedule)
    lowering = resolve_lowering(mesh, lowering)
    shapes, specs, plan = pipelined_state_shapes(model, tcfg, mesh,
                                                 staleness=staleness,
                                                 plan=plan)
    pspecs = specs.params
    dp_ax = ts.dp_axes_of(mesh)
    dp_total = ts.dp_total_of(mesh)
    n_micro = tcfg.microbatches
    data_axis = dp_ax[-1]
    p_data = mesh.shape[data_axis]
    pod_axis = dp_ax[0] if len(dp_ax) > 1 else None
    p_pod = mesh.shape[pod_axis] if pod_axis else 1
    grad_clip = tcfg.optimizer.grad_clip
    # Scattered plans (DESIGN.md §11): the in-flight buffers are owner
    # CHUNKS; the apply half is the shard update itself (no grad-side
    # allgather ever runs) and the dense param allgather it issues sits
    # at the tail of step t's graph next to the reduce — independent of
    # it — so both drain while step t+1's forward runs ahead.
    scattered = plan.scattered

    def _guard_state(fin, new_state, old_state):
        """Roll every stateful component back to its pre-step value on a
        guard trip (fin 0.0); the step counter still advances so the
        schedule/data replay stay aligned. Keeping the OLD in-flight
        buffers means the previous step's (clean) reduction is re-applied
        on the next clean step — nothing is lost but the poisoned grads.
        The old VALID_KEY rides along unchanged."""
        if fin is None:
            return new_state
        return TrainState(
            ts.guard_select(fin, new_state.params, old_state.params),
            ts.guard_select(fin, new_state.opt, old_state.opt),
            ts.guard_select(fin, new_state.residuals, old_state.residuals),
            new_state.step,
            None if new_state.inflight is None else ts.guard_select(
                fin, new_state.inflight, old_state.inflight))

    def _finish(state, applied, loss, lr, new_res, new_inflight, telem, *,
                zero1_update, fin=None):
        """Clip + optimizer update + state assembly (lowering-agnostic).
        zero1_update: callable(params, grads, opt, lr) for this lowering.
        fin: guard verdict (f32 1/0) or None when the guard is off."""
        applied, gnorm = clip_by_global_norm(applied, grad_clip)
        # Gate applies of INVALID (all-zero) in-flight buffers — first
        # step, and first step after every attach/resume — to lr 0.
        lr_eff = lr if staleness == 0 else lr * state.inflight[VALID_KEY]
        if tcfg.zero1:
            new_p, new_opt = zero1_update(state.params, applied, state.opt,
                                          lr_eff)
        else:
            new_p, new_opt = opt_update(state.params, applied, state.opt,
                                        lr_eff, tcfg.optimizer)
        new_state = TrainState(new_p, new_opt, new_res, state.step + 1,
                               new_inflight)
        new_state = _guard_state(fin, new_state, state)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr_eff}
        if fin is not None:
            metrics["nonfinite"] = 1.0 - fin
        if telemetry:
            metrics["telemetry"] = telem
        return new_state, metrics

    if lowering == "spmd":
        # ----- auto-SPMD: replica axis is a real leading axis (§4.2) -----
        def raw_step(state: TrainState, batch, key):
            lr = sched(state.step)
            batch = dict(batch)
            fault_vec = batch.pop(FAULT_KEY) if inject else None

            def split_ranks(x):
                out = x.reshape((dp_total, x.shape[0] // dp_total)
                                + x.shape[1:])
                spec = P(tuple(dp_ax), *([None] * (out.ndim - 1)))
                return jax.lax.with_sharding_constraint(
                    out, NamedSharding(mesh, spec))

            batch_r = jax.tree.map(split_ranks, batch)
            loss_r, grads_r = jax.vmap(
                lambda b: ts._accumulated_grads(model, state.params, b,
                                                n_micro))(batch_r)
            loss = jnp.mean(loss_r)
            leaves_r, gtree = jax.tree.flatten(grads_r)
            leaves_spec = gtree.flatten_up_to(pspecs)
            leaves_r = [
                jax.lax.with_sharding_constraint(
                    g, NamedSharding(mesh, P(tuple(dp_ax),
                                             *(s if s is not None else ()))))
                for g, s in zip(leaves_r, leaves_spec)
            ]
            if fault_vec is not None:
                leaves_r = ts.inject_nonfinite_leaves(leaves_r, fault_vec)
            # Guard verdict on the raw (post-injection) grads: the leaves
            # here are full global arrays, so the check covers every rank.
            fin = ts.all_finite_leaves(leaves_r) if guard else None
            if staleness == 0:
                # execute_plan_spmd minus the telemetry drop: same ops,
                # same order (the staleness=0 == synchronous invariant).
                reduced, new_res, telem = comm.reduce_buckets_spmd(
                    plan, leaves_r, state.residuals, key,
                    p_data=p_data, p_pod=p_pod, telemetry=telemetry)
                chunks = reduced
                new_inflight = None
            else:
                chunks = state.inflight
                new_inflight, new_res, telem = comm.reduce_buckets_spmd(
                    plan, leaves_r, state.residuals, key,
                    p_data=p_data, p_pod=p_pod, telemetry=telemetry)
                new_inflight[VALID_KEY] = jnp.ones((), jnp.float32)
            if scattered:
                applied_leaves = comm.apply_buckets_spmd(
                    plan, comm.unchunk_buckets_spmd(plan, chunks), leaves_r)
                applied = gtree.unflatten(applied_leaves)
                applied, gnorm = clip_by_global_norm(applied, grad_clip)
                lr_eff = (lr if staleness == 0
                          else lr * state.inflight[VALID_KEY])
                new_p, new_opt = ts._zero_scattered_update_spmd(
                    state.params, applied, state.opt, lr_eff, tcfg, plan)
                new_state = TrainState(new_p, new_opt, new_res,
                                       state.step + 1, new_inflight)
                new_state = _guard_state(fin, new_state, state)
                metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr_eff}
                if fin is not None:
                    metrics["nonfinite"] = 1.0 - fin
                if telemetry:
                    metrics["telemetry"] = telem
                return new_state, metrics
            applied_leaves = comm.apply_buckets_spmd(plan, chunks, leaves_r)
            applied = gtree.unflatten(applied_leaves)
            return _finish(
                state, applied, loss, lr, new_res, new_inflight, telem,
                zero1_update=lambda p, g, o, l: ts._zero1_update_spmd(
                    p, g, o, l, tcfg, pspecs, dp_total), fin=fin)

        return raw_step, shapes, specs, plan

    # ----- manual dp (shard_map), native or psum-emulated collectives -----
    native = lowering == "manual"

    def inner(state: TrainState, batch, key, rid):
        lr = sched(state.step)
        batch = dict(batch)
        fault_vec = batch.pop(FAULT_KEY) if inject else None
        loss, grads = ts._accumulated_grads(model, state.params, batch,
                                            n_micro)
        loss = jax.lax.pmean(loss, dp_ax[-1])
        if len(dp_ax) > 1:
            loss = jax.lax.pmean(loss, dp_ax[0])
        dp_index = rid[0]
        data_rank = dp_index % p_data
        pod_rank = dp_index // p_data if pod_axis else None
        leaves_g, gtree = jax.tree.flatten(grads)
        if fault_vec is not None:
            leaves_g = ts.inject_nonfinite_leaves(leaves_g, fault_vec)
        if guard:
            # Local verdict, then the cross-rank AND via pmin — a plain
            # lax reduction, so it lowers under both the native and the
            # psum-emulated collective paths (same as the loss pmean).
            fin = ts.all_finite_leaves(leaves_g)
            fin = jax.lax.pmin(fin, dp_ax[-1])
            if len(dp_ax) > 1:
                fin = jax.lax.pmin(fin, dp_ax[0])
        else:
            fin = None
        coll_kwargs = dict(
            data_axis=data_axis, p_data=p_data, pod_axis=pod_axis,
            p_pod=p_pod, native=native, data_rank=data_rank,
            pod_rank=pod_rank, telemetry=telemetry)
        if scattered:
            if staleness == 0:
                reduced, new_res, telem = comm.reduce_buckets(
                    plan, leaves_g, state.residuals, key, **coll_kwargs)
                chunks = reduced
                new_inflight = None
            else:
                chunks = state.inflight
                new_inflight, new_res, telem = comm.reduce_buckets(
                    plan, leaves_g, state.residuals, key, **coll_kwargs)
                new_inflight[VALID_KEY] = jnp.ones((), jnp.float32)
            lr_eff = (lr if staleness == 0
                      else lr * state.inflight[VALID_KEY])
            coll = comm.CollectiveContext(data_axis, p_data, native=native,
                                          rank=data_rank)
            new_p, new_opt, gnorm = ts._zero_scattered_update(
                state.params, chunks, state.opt, lr_eff, tcfg, plan, coll)
            new_state = TrainState(new_p, new_opt, new_res, state.step + 1,
                                   new_inflight)
            new_state = _guard_state(fin, new_state, state)
            metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr_eff}
            if fin is not None:
                metrics["nonfinite"] = 1.0 - fin
            if telemetry:
                metrics["telemetry"] = telem
            return new_state, metrics
        if staleness == 0:
            # execute_plan minus the telemetry drop (same ops, same order).
            reduced, new_res, telem = comm.reduce_buckets(
                plan, leaves_g, state.residuals, key, **coll_kwargs)
            applied_leaves = comm.apply_buckets(plan, reduced, leaves_g)
            new_inflight = None
        else:
            applied_leaves = comm.apply_buckets(plan, state.inflight,
                                                leaves_g)
            new_inflight, new_res, telem = comm.reduce_buckets(
                plan, leaves_g, state.residuals, key, **coll_kwargs)
            new_inflight[VALID_KEY] = jnp.ones((), jnp.float32)
        applied = gtree.unflatten(applied_leaves)

        def zero1_update(params, grads_, opt, lr_):
            gather_ctxs = [
                comm.CollectiveContext(ax, mesh.shape[ax], native=native,
                                       rank=(pod_rank if ax == pod_axis
                                             else data_rank))
                for ax in dp_ax
            ]
            return ts._zero1_update(params, grads_, opt, lr_, tcfg, pspecs,
                                    dp_ax, dp_index, dp_total, gather_ctxs)

        return _finish(state, applied, loss, lr, new_res, new_inflight,
                       telem, zero1_update=zero1_update, fin=fin)

    in_state_specs = ts.manual_only_tree(specs)
    in_batch_specs = ts.manual_only_tree(
        _pipelined_batch_specs(cfg, mesh, inject))
    rid_spec = P(tuple(dp_ax))
    mapped = compat.shard_map(
        inner, mesh=mesh,
        in_specs=(in_state_specs, in_batch_specs, P(), rid_spec),
        out_specs=(in_state_specs, P()),
        check_vma=False,
        axis_names=set(dp_ax),
    )

    def raw_step(state: TrainState, batch, key):
        # rank-id feed: each rank's slice of arange(dp_total) — the
        # emulated collectives cannot lower axis_index (DESIGN.md §4).
        rid = jnp.arange(dp_total, dtype=jnp.int32)
        return mapped(state, batch, key, rid)

    return raw_step, shapes, specs, plan


# --------------------------------------------------------------------------
# Public builders
# --------------------------------------------------------------------------

def build_pipelined_step(model: Model, tcfg: TrainConfig, mesh: Mesh, *,
                         staleness: int = 1, lowering: Optional[str] = None,
                         donate: bool = True, plan=None,
                         telemetry: bool = True, guard: bool = False,
                         inject: bool = False):
    """Single pipelined step, jitted. Returns
    (step_fn(state, batch, key) -> (state, metrics), (shapes, specs), plan).
    ``plan``/``telemetry``/``guard``/``inject``: see :func:`_make_raw_step`.
    """
    raw_step, shapes, specs, plan = _make_raw_step(model, tcfg, mesh,
                                                   staleness, lowering,
                                                   plan, telemetry,
                                                   guard, inject)
    bspecs = _pipelined_batch_specs(model.cfg, mesh, inject)
    sh = lambda t: ts.shardings_tree(mesh, t)
    jitted = jax.jit(
        raw_step,
        in_shardings=(sh(specs), sh(bspecs), NamedSharding(mesh, P())),
        out_shardings=(sh(specs), NamedSharding(mesh, P())),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, (shapes, specs), plan


def build_superstep(model: Model, tcfg: TrainConfig, mesh: Mesh, *,
                    staleness: int = 1, steps: int = 4,
                    lowering: Optional[str] = None, donate: bool = True,
                    unroll: bool = False, plan=None,
                    telemetry: bool = True, guard: bool = False,
                    inject: bool = False):
    """K-step superstep: one jitted K-step loop over the pipelined step.
    Returns (superstep_fn, (shapes, specs), plan) where
    ``superstep_fn(state, batches, keys) -> (state, metrics)`` takes
    per-leaf batches stacked on a leading (steps,) axis and keys stacked
    as (steps, 2), and returns metrics stacked the same way.

    One dispatch covers K training steps, so the host syncs (and pays the
    per-call dispatch cost — substantial for multi-device programs) once
    per superstep instead of once per step. ``unroll=False`` uses
    ``lax.scan`` (body traced once: compile time and per-step collective
    count are O(1) in K, but XLA may copy loop carries per iteration);
    ``unroll=True`` lays the K steps out straight-line (carries alias
    freely — faster on backends with expensive loop carries, e.g. the
    emulated-CPU host — at K-times the trace/compile cost).
    """
    if steps < 1:
        raise ValueError(f"superstep needs steps >= 1, got {steps}")
    raw_step, shapes, specs, plan = _make_raw_step(model, tcfg, mesh,
                                                   staleness, lowering,
                                                   plan, telemetry,
                                                   guard, inject)
    bspecs = _pipelined_batch_specs(model.cfg, mesh, inject)
    stacked_bspecs = jax.tree.map(lambda s: P(None, *s), bspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    sh = lambda t: ts.shardings_tree(mesh, t)

    if unroll:
        def superstep(state: TrainState, batches, keys):
            n = jax.tree.leaves(batches)[0].shape[0]
            ms = []
            for i in range(n):
                b = jax.tree.map(lambda x: x[i], batches)
                state, m = raw_step(state, b, keys[i])
                ms.append(m)
            return state, jax.tree.map(lambda *xs: jnp.stack(xs), *ms)
    else:
        def superstep(state: TrainState, batches, keys):
            def body(carry, bk):
                b, k = bk
                return raw_step(carry, b, k)

            return jax.lax.scan(body, state, (batches, keys))

    jitted = jax.jit(
        superstep,
        in_shardings=(sh(specs), sh(stacked_bspecs), NamedSharding(mesh, P())),
        out_shardings=(sh(specs), NamedSharding(mesh, P())),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, (shapes, specs), plan
