"""Non-blocking sync runtime (DESIGN.md §6) + adaptive re-planning (§7).

Three mechanisms on top of the fusion-bucket sync engine:

  pipeline.py  pipelined stale-gradient supersteps: a jitted/scanned
               K-step loop where step t's forward/backward runs while the
               bucketed sparse allreduce of step t-1's gradients completes
               and is applied (one-step-bounded staleness; staleness=0
               reproduces the synchronous path exactly)
  driver.py    double-buffered host driver: async dispatch N units deep,
               background data prefetch, logging/checkpoints that only
               sync on already-retired steps
  adapt.py     closed-loop re-planning: windowed measured-density
               telemetry + calibrated alpha-beta cost model re-select
               each bucket's algorithm; accepted replans swap the
               compiled superstep at drain barriers (hysteresis +
               patience damp flapping)
  faults.py    fault-tolerant runtime (DESIGN.md §12): deterministic
               chaos injection (FaultPlan/FaultInjector), fault
               classification, and the retry/backoff supervisor the
               driver escalates through (RecoveryConfig/RetrySupervisor)
"""
from repro.runtime.adapt import (
    AdaptConfig,
    AdaptiveController,
    AdaptiveRuntime,
    TelemetryWindow,
)
from repro.runtime.driver import DriverConfig, run_pipelined
from repro.runtime.faults import (
    FAULT_CLASSES,
    FAULT_KEY,
    FaultError,
    FaultInjectionError,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    NonFiniteEscalation,
    PrefetchStalled,
    RecoveryConfig,
    RetryBudgetExhausted,
    RetrySupervisor,
    classify_fault,
)
from repro.runtime.pipeline import (
    attach_inflight,
    build_pipelined_step,
    build_superstep,
    pipelined_state_shapes,
    resolve_lowering,
)

__all__ = [
    "AdaptConfig",
    "AdaptiveController",
    "AdaptiveRuntime",
    "DriverConfig",
    "FAULT_CLASSES",
    "FAULT_KEY",
    "FaultError",
    "FaultInjectionError",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "NonFiniteEscalation",
    "PrefetchStalled",
    "RecoveryConfig",
    "RetryBudgetExhausted",
    "RetrySupervisor",
    "TelemetryWindow",
    "attach_inflight",
    "build_pipelined_step",
    "build_superstep",
    "classify_fault",
    "pipelined_state_shapes",
    "resolve_lowering",
    "run_pipelined",
]
