"""Non-blocking sync runtime (DESIGN.md §6).

Two overlap mechanisms on top of the fusion-bucket sync engine:

  pipeline.py  pipelined stale-gradient supersteps: a jitted/scanned
               K-step loop where step t's forward/backward runs while the
               bucketed sparse allreduce of step t-1's gradients completes
               and is applied (one-step-bounded staleness; staleness=0
               reproduces the synchronous path exactly)
  driver.py    double-buffered host driver: async dispatch N units deep,
               background data prefetch, logging/checkpoints that only
               sync on already-retired steps
"""
from repro.runtime.driver import DriverConfig, run_pipelined
from repro.runtime.pipeline import (
    attach_inflight,
    build_pipelined_step,
    build_superstep,
    pipelined_state_shapes,
    resolve_lowering,
)

__all__ = [
    "DriverConfig",
    "attach_inflight",
    "build_pipelined_step",
    "build_superstep",
    "pipelined_state_shapes",
    "resolve_lowering",
    "run_pipelined",
]
