"""SparCML core: sparse streams, TopK+EF, QSGD, sparse collectives.

The paper's primary contribution implemented as composable JAX modules:

- sparse_stream: the data representation (§5.1)
- topk:          bucketed TopK sparsification + error feedback (Alg. 2)
- qsgd:          bucketed stochastic quantization (§6)
- allreduce:     SSAR_Recursive_double / SSAR_Split_allgather /
                 DSAR_Split_allgather as shard_map collectives (§5.3)
- density:       expected fill-in analysis (App. B)
- cost_model:    alpha-beta bounds + algorithm auto-selection (§5.3)
- compressor:    gradient-sync layer integrating the above into training
"""

from repro.core.sparse_stream import (  # noqa: F401
    SENTINEL,
    SparseStream,
    delta_threshold,
    densify,
    from_dense_topk,
    from_mask,
    merge,
)
from repro.core.topk import UniformStream, compress  # noqa: F401
from repro.core.qsgd import QSGDConfig, dequantize, quantize  # noqa: F401
from repro.core.allreduce import (  # noqa: F401
    ReduceOut,
    dense_allreduce_inside,
    dsar_split_allgather_inside,
    make_sparse_allreduce,
    sparse_allreduce_inside,
    ssar_recursive_double_inside,
    ssar_split_allgather_inside,
)
from repro.core.compressor import SyncConfig, sync_grads_inside  # noqa: F401
from repro.core.cost_model import NetworkParams, select_algorithm  # noqa: F401
from repro.core.density import expected_nnz, reduced_density  # noqa: F401
