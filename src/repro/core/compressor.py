"""Gradient synchronization layer: SparCML as a first-class training feature.

Implements paper Algorithm 2 (Quantized TopK SGD) as a drop-in replacement
for the dense gradient all-reduce, running INSIDE a shard_map that is
manual over the data-parallel axes ('pod', 'data') and auto over 'model'
(XLA keeps tensor-parallel sharding transparent).

Key design points (DESIGN.md §2.2):

* Per-leaf compression in a *canonical layout*: the 'model'-sharded axis is
  moved to the front so the (nb, B) bucket reshape never crosses a shard
  boundary -> zero resharding under SPMD.
* Error-feedback residuals are rank-local state. Outside shard_map they
  carry a leading axis of size P_pod*P_data sharded over ('pod','data');
  inside, each rank sees exactly its slice.
* Leaves smaller than ``min_sparse_size`` use the dense psum path (the
  paper only claims wins for N > 65k; latency dominates below).
* ``mean=True`` divides the reduced sum by the replica count (the paper
  sums; modern optimizers expect means — both supported).
* Hierarchical multi-pod: sparse allreduce over 'data' within each pod
  (ICI), then dense psum over 'pod' (DCN) — bandwidth across the slow link
  is already compressed by the within-pod reduction.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topk as topk_mod
from repro.core.allreduce import safe_psum, sparse_allreduce_inside
from repro.core.qsgd import QSGDConfig


@dataclass(frozen=True)
class SyncConfig:
    """How gradients are synchronized across data-parallel replicas."""

    mode: str = "dense"              # 'dense' | 'sparcml'
    k_per_bucket: int = 4            # paper §8.3: 4/512 for ASR, 8..16/512 CIFAR
    bucket_size: int = 512
    algorithm: str = "auto"          # ssar_recursive_double|ssar_split_allgather|
                                     # dsar_split_allgather|dense|auto
    qsgd_bits: Optional[int] = None  # quantize DSAR dense phase (2/4/8)
    qsgd_bucket: int = 1024
    qsgd_scale: str = "l2"
    min_sparse_size: int = 65536     # leaves below this use dense psum (paper §8)
    mean: bool = True
    impl: str = "ref"                # kernel impl inside auto-SPMD regions
    ef_dtype: Any = jnp.float32

    @property
    def density(self) -> float:
        return self.k_per_bucket / self.bucket_size

    def qsgd(self) -> QSGDConfig | None:
        if self.qsgd_bits is None:
            return None
        return QSGDConfig(self.qsgd_bits, self.qsgd_bucket, self.qsgd_scale)


# --------------------------------------------------------------------------
# Canonical layout: model-sharded axis first, trailing dims bucket-padded
# --------------------------------------------------------------------------

def _model_axis(spec, model_axis_name: str = "model") -> int | None:
    """Index of the dim sharded over 'model' in a PartitionSpec, if any."""
    if spec is None:
        return None
    for i, s in enumerate(spec):
        names = s if isinstance(s, tuple) else (s,)
        if model_axis_name in [n for n in names if n]:
            return i
    return None


def canonical_shape(shape: tuple[int, ...], spec, bucket_size: int,
                    model_axis_name: str = "model") -> tuple[int, int]:
    """(rows, padded_cols) of the canonical 2-D layout for a leaf."""
    ax = _model_axis(spec, model_axis_name)
    if ax is None or len(shape) <= 1:
        lead, rest = 1, int(np.prod(shape))
    else:
        lead = shape[ax]
        rest = int(np.prod(shape)) // lead
    cols = -(-rest // bucket_size) * bucket_size
    return lead, cols


def to_canonical(g: jax.Array, spec, bucket_size: int,
                 model_axis_name: str = "model") -> jax.Array:
    rows, cols = canonical_shape(g.shape, spec, bucket_size, model_axis_name)
    ax = _model_axis(spec, model_axis_name)
    if ax is not None and g.ndim > 1 and ax != 0:
        g = jnp.moveaxis(g, ax, 0)
    g2 = g.reshape(rows, -1)
    pad = cols - g2.shape[1]
    if pad:
        g2 = jnp.pad(g2, ((0, 0), (0, pad)))
    return g2


def from_canonical(c: jax.Array, orig_shape: tuple[int, ...], spec,
                   model_axis_name: str = "model") -> jax.Array:
    ax = _model_axis(spec, model_axis_name)
    if ax is None or len(orig_shape) <= 1:
        n = int(np.prod(orig_shape))
        return c.reshape(-1)[:n].reshape(orig_shape)
    moved = tuple([orig_shape[ax]] + [s for i, s in enumerate(orig_shape) if i != ax])
    rest = int(np.prod(moved[1:]))
    out = c[:, :rest].reshape(moved)
    return jnp.moveaxis(out, 0, ax)


# --------------------------------------------------------------------------
# Residual (error-feedback) state
# --------------------------------------------------------------------------

def sparse_path_ok(shape, spec, cfg: SyncConfig, dp_total: int) -> bool:
    """Leaf qualifies for the sparse path: big enough (paper §8: N > 65k)
    and its PER-ROW bucket count divides the split-phase group size (the
    batched pipeline splits buckets within each canonical row so the
    model-sharded row axis is never reshaped away)."""
    if cfg.mode != "sparcml" or int(np.prod(shape)) < cfg.min_sparse_size:
        return False
    lead, cols = canonical_shape(shape, spec, cfg.bucket_size)
    m = cols // cfg.bucket_size
    if cfg.qsgd_bits is not None:
        # quantized second phase also needs whole qsgd buckets per shard
        if (cols // dp_total) % cfg.qsgd_bucket:
            return False
    return m % dp_total == 0


def residual_shapes(param_shapes, param_specs, cfg: SyncConfig, dp_total: int):
    """Pytree of ShapeDtypeStruct for EF residuals (canonical layout with a
    leading per-replica axis). Leaves on the dense path get None."""

    def one(shape_dtype, spec):
        shape = shape_dtype.shape
        if not sparse_path_ok(shape, spec, cfg, dp_total):
            return None
        lead, cols = canonical_shape(shape, spec, cfg.bucket_size)
        return jax.ShapeDtypeStruct((dp_total, lead, cols), cfg.ef_dtype)

    return jax.tree.map(one, param_shapes, param_specs,
                        is_leaf=lambda x: x is None)


def init_residuals(param_shapes, param_specs, cfg: SyncConfig, dp_total: int):
    shapes = residual_shapes(param_shapes, param_specs, cfg, dp_total)
    return jax.tree.map(
        lambda s: None if s is None else jnp.zeros(s.shape, s.dtype),
        shapes, is_leaf=lambda x: x is None,
    )


def residual_specs(param_shapes, param_specs, cfg: SyncConfig, dp_total: int,
                   dp_axes=("pod", "data")):
    """PartitionSpecs for residuals: leading axis over dp axes, canonical
    rows over 'model' when the leaf was model-sharded. Driven by the
    param_shapes tree (PartitionSpec is itself a tuple — never use it as
    the tree.map driver)."""
    from jax.sharding import PartitionSpec as P

    def one(shape_dtype, spec):
        shape = shape_dtype.shape if hasattr(shape_dtype, "shape") else shape_dtype
        if not sparse_path_ok(shape, spec, cfg, dp_total):
            return None
        ax = _model_axis(spec)
        return P(dp_axes, "model" if ax is not None else None, None)

    return jax.tree.map(one, param_shapes, param_specs)


# --------------------------------------------------------------------------
# The sync step (runs inside shard_map: manual over dp axes, auto 'model')
# --------------------------------------------------------------------------

def sync_grads_inside(
    grads,
    residuals,
    key: jax.Array,
    cfg: SyncConfig,
    param_specs,
    *,
    data_axis: str = "data",
    p_data: int,
    pod_axis: str | None = None,
    p_pod: int = 1,
):
    """Compress + allreduce a grad pytree. Returns (synced_grads, new_residuals).

    grads: per-rank (unreduced) gradients, leaves in original layout.
    residuals: canonical-layout EF state with leading per-replica axis of
    size 1 inside shard_map (each rank holds its slice), or None per leaf.
    """
    replicas = p_data * p_pod
    scale = 1.0 / replicas if cfg.mean else 1.0

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_r = treedef.flatten_up_to(residuals) if residuals is not None else [None] * len(leaves_g)
    leaves_s = treedef.flatten_up_to(param_specs)

    new_g, new_r = [], []
    for i, (g, r, spec) in enumerate(zip(leaves_g, leaves_r, leaves_s)):
        if cfg.mode != "sparcml" or r is None:
            # Dense path (small leaves / dense mode).
            out = safe_psum(g, data_axis)
            if pod_axis is not None:
                out = safe_psum(out, pod_axis)
            new_g.append(out * scale)
            new_r.append(r)
            continue

        canon = to_canonical(g, spec, cfg.bucket_size)            # (c, mB)
        res = r[0]                                                 # strip replica axis
        acc = res.astype(jnp.float32) + canon.astype(jnp.float32)  # Alg.2 line 1
        rows, cols = acc.shape
        # Batched pipeline: the (possibly 'model'-sharded) row axis is a
        # pure batch dim through compress + the data-axis collectives —
        # flattening it forced full-grad all-gathers over TP (dry-run HLO).
        u, residual = topk_mod.compress2d(
            acc, cfg.k_per_bucket, cfg.bucket_size)                # Alg.2 line 2
        rand = None
        if cfg.qsgd_bits is not None:
            sub = jax.random.fold_in(key, i)
            sub = jax.random.fold_in(sub, jax.lax.axis_index(data_axis))
            if pod_axis is not None:
                sub = jax.random.fold_in(sub, jax.lax.axis_index(pod_axis))
            rand = jax.random.bits(sub, (rows * cols // p_data,),
                                   dtype=jnp.uint32)
        from repro.core.allreduce import dsar_split_allgather_batched_inside
        out = dsar_split_allgather_batched_inside(                 # Alg.2 line 3
            u, axis_name=data_axis, p=p_data, qsgd=cfg.qsgd(), rand=rand,
            out_dtype=jnp.float32,
        )
        if pod_axis is not None:
            out = safe_psum(out, pod_axis)                         # hierarchical
        out = out * scale
        new_g.append(from_canonical(out, g.shape, spec).astype(g.dtype))
        new_r.append(residual.astype(r.dtype)[None])

    return treedef.unflatten(new_g), treedef.unflatten(new_r)


def wire_bytes_per_step(param_shapes, cfg: SyncConfig, p: int) -> dict:
    """Analytic bytes-on-wire per rank per step (for §8.4-style reporting:
    '80 MB -> <0.5 MB'). Dense = 2 (P-1)/P N isize (Rabenseifner);
    sparcml = split-phase sparse items + dense/quantized allgather."""
    from repro.core.sparse_stream import delta_threshold

    total_n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(param_shapes))
    dense = 2 * (p - 1) / p * total_n * 4
    if cfg.mode != "sparcml":
        return {"dense_bytes": dense, "sparcml_bytes": dense, "ratio": 1.0}
    k_items = total_n * cfg.density
    split = (p - 1) / p * k_items * 8  # idx+val
    q = cfg.qsgd()
    if q is not None:
        gather = (p - 1) / p * (total_n * q.bits / 8 + total_n / q.bucket_size * 4)
    else:
        gather = (p - 1) / p * total_n * 4  # DSAR dense phase fp32
    sparse = split + gather
    return {"dense_bytes": dense, "sparcml_bytes": sparse, "ratio": dense / sparse}
