"""Gradient synchronization layer: SparCML as a first-class training feature.

Implements paper Algorithm 2 (Quantized TopK SGD) as a drop-in replacement
for the dense gradient all-reduce, running INSIDE a shard_map that is
manual over the data-parallel axes ('pod', 'data') and auto over 'model'
(XLA keeps tensor-parallel sharding transparent).

As of the fusion refactor (DESIGN.md §3) the heavy lifting lives in
``repro.comm``: a trace-time :class:`~repro.comm.plan.SyncPlan` packs
leaves into fusion buckets and ``repro.comm.executor`` runs one planned
collective per bucket. THIS module keeps:

* :class:`SyncConfig` — the user-facing knob set;
* the PER-LEAF entry points (``sync_grads_inside``, ``residual_*``) as
  thin wrappers over a one-leaf-per-bucket plan, preserving the original
  per-leaf semantics (leaves below ``min_sparse_size`` dense-psum'd,
  residual state keyed by leaf) for the standalone-library API and tests;
* canonical-layout helpers re-exported from ``repro.comm.buckets`` (the
  implementation moved there so plan/executor avoid a cycle).

The fused train path (``train/train_step.py``) skips these wrappers and
drives ``comm.build_sync_plan`` + ``comm.execute_plan`` directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# Canonical layout: implementation moved to comm.buckets (re-exported
# under the historical names — external callers keep working).
from repro.comm.buckets import (  # noqa: F401
    canonical_shape,
    from_canonical,
    model_axis as _model_axis,
    to_canonical,
)
from repro.comm.executor import execute_plan
from repro.comm.plan import build_per_leaf_plan, leaf_sparse_ok
from repro.core.allreduce import safe_psum
from repro.core.qsgd import QSGDConfig


@dataclass(frozen=True)
class SyncConfig:
    """How gradients are synchronized across data-parallel replicas."""

    mode: str = "dense"              # 'dense' | 'sparcml'
    k_per_bucket: int = 4            # paper §8.3: 4/512 for ASR, 8..16/512 CIFAR
    bucket_size: int = 512
    algorithm: str = "auto"          # ssar_recursive_double|ssar_split_allgather|
                                     # dsar_split_allgather|dense|auto
    qsgd_bits: Optional[int] = None  # quantize DSAR dense phase (2/4/8)
    qsgd_bucket: int = 1024
    qsgd_scale: str = "l2"
    min_sparse_size: int = 65536     # buckets/leaves below this use dense psum
    mean: bool = True
    impl: str = "ref"                # kernel impl inside auto-SPMD regions
    ef_dtype: Any = jnp.float32
    fusion_bucket_bytes: int = 4 << 20  # fused-plan bucket size (DESIGN.md §3.2)
    # ZeRO-sharded exchange (DESIGN.md §11): 'replicated' re-densifies the
    # full reduction on every rank; 'scattered' stops at the owner shard
    # (scatter-capable algorithms skip their final allgather) and the
    # optimizer update runs on the shard, followed by a dense param
    # allgather at 1/P per rank.
    output_mode: str = "replicated"  # 'replicated' | 'scattered'

    @property
    def density(self) -> float:
        return self.k_per_bucket / self.bucket_size

    def qsgd(self) -> QSGDConfig | None:
        if self.qsgd_bits is None:
            return None
        return QSGDConfig(self.qsgd_bits, self.qsgd_bucket, self.qsgd_scale)


# --------------------------------------------------------------------------
# Per-leaf routing + residual (error-feedback) state — legacy API surface
# --------------------------------------------------------------------------

def sparse_path_ok(shape, spec, cfg: SyncConfig, dp_total: int) -> bool:
    """Leaf qualifies for the per-leaf sparse path (see
    :func:`repro.comm.plan.leaf_sparse_ok`; the fused plan instead packs
    every leaf into a bucket and decides sparsity per bucket)."""
    return leaf_sparse_ok(shape, spec, cfg, dp_total)


def residual_shapes(param_shapes, param_specs, cfg: SyncConfig, dp_total: int):
    """Pytree of ShapeDtypeStruct for PER-LEAF EF residuals (canonical
    layout with a leading per-replica axis). Dense-path leaves get None."""

    def one(shape_dtype, spec):
        shape = shape_dtype.shape
        if not sparse_path_ok(shape, spec, cfg, dp_total):
            return None
        lead, cols = canonical_shape(shape, spec, cfg.bucket_size)
        return jax.ShapeDtypeStruct((dp_total, lead, cols), cfg.ef_dtype)

    return jax.tree.map(one, param_shapes, param_specs,
                        is_leaf=lambda x: x is None)


def init_residuals(param_shapes, param_specs, cfg: SyncConfig, dp_total: int):
    shapes = residual_shapes(param_shapes, param_specs, cfg, dp_total)
    return jax.tree.map(
        lambda s: None if s is None else jnp.zeros(s.shape, s.dtype),
        shapes, is_leaf=lambda x: x is None,
    )


def residual_specs(param_shapes, param_specs, cfg: SyncConfig, dp_total: int,
                   dp_axes=("pod", "data")):
    """PartitionSpecs for per-leaf residuals: leading axis over dp axes,
    canonical rows over 'model' when the leaf was model-sharded. Driven by
    the param_shapes tree (PartitionSpec is itself a tuple — never use it
    as the tree.map driver)."""
    from jax.sharding import PartitionSpec as P

    def one(shape_dtype, spec):
        shape = shape_dtype.shape if hasattr(shape_dtype, "shape") else shape_dtype
        if not sparse_path_ok(shape, spec, cfg, dp_total):
            return None
        ax = _model_axis(spec)
        return P(dp_axes, "model" if ax is not None else None, None)

    return jax.tree.map(one, param_shapes, param_specs)


# --------------------------------------------------------------------------
# The per-leaf sync step (thin wrapper over a one-leaf-per-bucket plan)
# --------------------------------------------------------------------------

def sync_grads_inside(
    grads,
    residuals,
    key: jax.Array,
    cfg: SyncConfig,
    param_specs,
    *,
    data_axis: str = "data",
    p_data: int,
    pod_axis: str | None = None,
    p_pod: int = 1,
    native: bool = True,
    data_rank: jax.Array | None = None,
    pod_rank: jax.Array | None = None,
):
    """Compress + allreduce a grad pytree. Returns (synced_grads, new_residuals).

    grads: per-rank (unreduced) gradients, leaves in original layout.
    residuals: canonical-layout EF state with leading per-replica axis of
    size 1 inside shard_map (each rank holds its slice), or None per leaf.

    Internally builds a degenerate one-leaf-per-bucket :class:`SyncPlan`
    and runs the shared executor: identical numerics to the pre-fusion
    path, one code path for both pipelines.
    """
    replicas = p_data * p_pod
    scale = 1.0 / replicas if cfg.mean else 1.0

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_r = (treedef.flatten_up_to(residuals)
                if residuals is not None else [None] * len(leaves_g))
    leaves_s = treedef.flatten_up_to(param_specs)

    # Leaves with EF state ride the executor; the rest dense-psum below.
    shapes = treedef.unflatten(
        [jax.ShapeDtypeStruct(g.shape, g.dtype) for g in leaves_g])
    plan = build_per_leaf_plan(shapes, param_specs, cfg, replicas)
    covered = {s.leaf_id for g in plan.groups for s in g.slots}
    active = (cfg.mode == "sparcml")
    covered = {i for i in covered if active and leaves_r[i] is not None}
    import dataclasses

    plan = dataclasses.replace(
        plan, groups=tuple(g for g in plan.groups
                           if g.slots[0].leaf_id in covered))

    res_by_bucket = {
        g.buckets[0].name: leaves_r[g.slots[0].leaf_id] for g in plan.groups
    }
    synced, new_res_by_bucket = execute_plan(
        plan, leaves_g, res_by_bucket, key,
        data_axis=data_axis, p_data=p_data, pod_axis=pod_axis, p_pod=p_pod,
        native=native, data_rank=data_rank, pod_rank=pod_rank)

    new_g, new_r = [], []
    bucket_of_leaf = {g.slots[0].leaf_id: g.buckets[0].name
                      for g in plan.groups}
    for i, (g, r) in enumerate(zip(leaves_g, leaves_r)):
        if i in covered:
            new_g.append(synced[i])
            new_r.append(new_res_by_bucket[bucket_of_leaf[i]])
            continue
        out = safe_psum(g, data_axis)
        if pod_axis is not None:
            out = safe_psum(out, pod_axis)
        new_g.append(out * scale)
        new_r.append(r)

    return treedef.unflatten(new_g), treedef.unflatten(new_r)


# --------------------------------------------------------------------------
# Analytic wire-traffic reporting
# --------------------------------------------------------------------------

def wire_bytes_per_step(param_shapes, cfg: SyncConfig, p: int,
                        param_specs=None, plan=None) -> dict:
    """Analytic bytes-on-wire per rank per step (for §8.4-style reporting:
    '80 MB -> <0.5 MB').

    Accounting is PER LEAF (or per bucket when a fused ``plan`` is
    given): a leaf that ``sparse_path_ok`` routes to dense psum is
    charged the dense Rabenseifner cost — earlier revisions charged every
    leaf the sparse rate even when it actually rode dense psum, so the
    reported ratio overstated the win whenever small/indivisible leaves
    fell back. Dense mode: 2 (P-1)/P N isize per leaf.
    """
    leaves = jax.tree.leaves(param_shapes)
    specs = ([None] * len(leaves) if param_specs is None
             else jax.tree.structure(param_shapes).flatten_up_to(param_specs))
    total_n = sum(int(np.prod(s.shape)) for s in leaves)
    dense = 2 * (p - 1) / p * total_n * 4
    if cfg.mode != "sparcml":
        return {"dense_bytes": dense, "sparcml_bytes": dense, "ratio": 1.0,
                "sparse_frac": 0.0}

    if plan is not None:
        covered = plan.covered_leaf_ids()
        sparse = plan.wire_bytes(p)
        # sparse fraction by BUCKET: a fused plan covers every leaf, but
        # only the canonical range living in sparse buckets rides the
        # compressed path — dense buckets are psum traffic.
        all_buckets = plan.buckets
        sparse_n = (total_n * sum(b.n for b in all_buckets if b.sparse)
                    / max(1, sum(b.n for b in all_buckets)))
        for i, s in enumerate(leaves):       # uncovered leaves ride psum
            if i not in covered:
                sparse += 2 * (p - 1) / p * int(np.prod(s.shape)) * 4
    else:
        q = cfg.qsgd()
        sparse = 0.0
        sparse_n = 0
        for s, spec in zip(leaves, specs):
            n_leaf = int(np.prod(s.shape))
            if not sparse_path_ok(s.shape, spec, cfg, p):
                sparse += 2 * (p - 1) / p * n_leaf * 4
                continue
            sparse_n += n_leaf
            k_items = n_leaf * cfg.density
            sparse += (p - 1) / p * k_items * 8              # idx+val split
            if q is not None:
                sparse += (p - 1) / p * (n_leaf * q.bits / 8
                                         + n_leaf / q.bucket_size * 4)
            else:
                sparse += (p - 1) / p * n_leaf * 4           # fp32 gather
    return {"dense_bytes": dense, "sparcml_bytes": sparse,
            "ratio": dense / sparse, "sparse_frac": sparse_n / total_n}
