"""SparCML sparse allreduce algorithms (paper §5.3) as JAX collectives.

All ``*_inside`` functions run INSIDE ``jax.shard_map`` over a named mesh
axis (the data-parallel axis). Standalone jit-level wrappers at the bottom
build the shard_map for tests/benchmarks.

Algorithms (see DESIGN.md §2.1 for the MPI->ICI mapping):

  ssar_recursive_double   log2(P) rounds of XOR-partner ppermute + sparse
                          merge; capacity doubles per round following the
                          paper's |H1|+|H2| bound; switches to a dense
                          tail when the bound crosses the delta threshold.
  ssar_split_allgather    all_to_all split by index range (sparse
                          reduce-scatter), local merge, sparse allgather
                          (concatenation — ranges are disjoint).
  dsar_split_allgather    split phase as above, then DENSIFY the owned
                          range (bucket_scatter kernel) and run a dense
                          allgather, optionally QSGD-quantized (paper §6).
  ssar_balanced_split     Ok-Top-k-style balanced split-and-gather
                          (DESIGN.md §9): split as above, owner-local
                          re-top-k to (k/P)(1+eps) items, allgather at
                          that fixed capacity — O(k) per-node traffic;
                          clamped-off mass returns as an EF fold.
  ssar_rearranged_rs      SparDL-style rearranged reduce-scatter
                          (DESIGN.md §9): log2(P) recursive-halving
                          rounds in stream form end-to-end (no densify
                          between phases) + capacity-clamped allgather;
                          every clamp drop folds into the EF residual
                          (the global-residual rule).
  dense_allreduce         psum (the Cray-MPI/NCCL baseline).

The bucket-uniform fast path (k entries per 512-bucket, paper §8.3) routes
the split phase with pure reshapes — zero sorting, exact slot sizes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import sparse_stream as ss
from repro.core.sparse_stream import SENTINEL, SparseStream
from repro.core.topk import UniformStream, _topk_lowers_everywhere
from repro.core.qsgd import QSGDConfig, quantize, dequantize
from repro.core.cost_model import (
    balanced_shard_cap,
    rearranged_round_caps,
    select_algorithm,
)
from repro.kernels.bucket_scatter.ops import bucket_scatter


@dataclass(frozen=True)
class ReduceOut:
    """Static union: exactly one of (stream, dense) is set (trace-time)."""

    stream: Optional[SparseStream] = None
    dense: Optional[jax.Array] = None

    def to_dense(self, n: int) -> jax.Array:
        if self.dense is not None:
            return self.dense
        return ss.densify(self.stream, n)


def _xor_perm(p: int, dist: int) -> list[tuple[int, int]]:
    return [(i, i ^ dist) for i in range(p)]


def _exchange(stream: SparseStream, axis_name: str, perm) -> SparseStream:
    idx, val, nnz = jax.lax.ppermute(
        (stream.idx, stream.val, stream.nnz), axis_name, perm
    )
    return SparseStream(idx, val, nnz)


# --------------------------------------------------------------------------
# SSAR_Recursive_double (paper §5.3.1)
# --------------------------------------------------------------------------

def ssar_recursive_double_inside(
    stream: SparseStream,
    *,
    axis_name: str,
    p: int,
    n: int,
    delta: int | None = None,
    cap_max: int | None = None,
) -> ReduceOut:
    """Recursive doubling over an axis of size p (power of two).

    Capacity schedule: after round t the fill-in bound is k*2^(t+1)
    (paper §5.1 uses the same |H1|+|H2| bound at runtime). When the bound
    crosses ``delta`` the representation switches to dense for the remaining
    rounds (pairwise dense exchange+add keeps partial-group sums correct).
    """
    assert p & (p - 1) == 0, "P must be a power of two (paper assumption 2)"
    if delta is None:
        delta = ss.delta_threshold(n, jnp.dtype(stream.val.dtype).itemsize)
    if cap_max is None:
        cap_max = min(n, delta)
    rounds = int(math.log2(p))
    dense: jax.Array | None = None
    for t in range(rounds):
        perm = _xor_perm(p, 1 << t)
        if dense is not None:
            other = jax.lax.ppermute(dense, axis_name, perm)
            dense = dense + other
            continue
        cap_next = min(2 * stream.capacity, cap_max)
        if 2 * stream.capacity > delta:
            # Dynamic fill-in: switch to dense (paper §5.3.3) for the tail.
            dense = ss.densify(stream, n)
            other = jax.lax.ppermute(dense, axis_name, perm)
            dense = dense + other
            stream = None
            continue
        other = _exchange(stream, axis_name, perm)
        stream = ss.merge(stream, other, cap_next)
    return ReduceOut(stream=stream, dense=dense)


# --------------------------------------------------------------------------
# Split phase (shared by SSAR/DSAR _Split_allgather), uniform fast path
# --------------------------------------------------------------------------

def _split_uniform(u: UniformStream, axis_name: str, p: int):
    """Route bucket rows to their owning range via pure reshape + a2a.

    Range r owns bucket rows [r*nb/p, (r+1)*nb/p). Returns (lidx, val) of
    shape (p, nb/p, k): contribution of every source rank to MY rows.
    """
    nb, k = u.lidx.shape
    assert nb % p == 0, f"buckets ({nb}) must divide by P ({p})"
    lidx = u.lidx.reshape(p, nb // p, k)
    val = u.val.reshape(p, nb // p, k)
    lidx = jax.lax.all_to_all(lidx, axis_name, split_axis=0, concat_axis=0, tiled=True)
    val = jax.lax.all_to_all(val, axis_name, split_axis=0, concat_axis=0, tiled=True)
    return lidx.reshape(p, nb // p, k), val.reshape(p, nb // p, k)


def _reduce_range_dense(lidx, val, bucket_size: int, impl: str = "auto") -> jax.Array:
    """Densify the received (p, rows, k) contributions into my range."""
    p, rows, k = lidx.shape
    dense = bucket_scatter(
        lidx.reshape(p * rows, k), val.reshape(p * rows, k), bucket_size, impl=impl
    )
    return dense.reshape(p, rows * bucket_size).sum(axis=0)


# --------------------------------------------------------------------------
# SSAR_Split_allgather (paper §5.3.2)
# --------------------------------------------------------------------------

def ssar_split_allgather_inside(
    u: UniformStream,
    *,
    axis_name: str,
    p: int,
    range_cap: int | None = None,
) -> SparseStream:
    """Sparse reduce-scatter (split) + sparse allgather (concatenation).

    Returns a global SparseStream of capacity p * range_cap. Merging within
    the owned range uses the sort+combine path; ranges are disjoint so the
    allgather is plain concatenation (paper §5.1).
    """
    nb, k = u.lidx.shape
    b = u.bucket_size
    lidx, val = _split_uniform(u, axis_name, p)
    rows = nb // p
    # Global indices within my range, relative to range start.
    row_off = jax.lax.broadcasted_iota(jnp.int32, (p, rows, k), 1) * b
    rel = (lidx + row_off).reshape(-1)
    vals = val.reshape(-1)
    local = SparseStream(rel, vals, jnp.asarray(rel.shape[0], jnp.int32))
    if range_cap is None:
        range_cap = min(p * rows * k, rows * b)
    merged = ss.merge(local, ss.empty(0, vals.dtype), range_cap)  # sort+combine
    # Rebase to global index space: my range starts at rank * rows * b.
    my_rank = jax.lax.axis_index(axis_name)
    base = (my_rank * rows * b).astype(jnp.int32)
    gidx = jnp.where(merged.idx == SENTINEL, SENTINEL, merged.idx + base)
    # Sparse allgather = concatenation of disjoint ranges.
    all_idx = jax.lax.all_gather(gidx, axis_name, tiled=True)
    all_val = jax.lax.all_gather(merged.val, axis_name, tiled=True)
    total_nnz = jax.lax.psum(merged.nnz, axis_name)
    return SparseStream(all_idx, all_val, total_nnz)


# --------------------------------------------------------------------------
# Near-optimal portfolio (DESIGN.md §9): capacity-clamped algorithms.
# Both return (dense sum, fold): ``fold`` is the pre-scale mass this rank
# clamped off the wire, to be added into its EF residual by the executor —
# the SparDL "global residual" rule. Under non-binding caps (e.g. full
# index overlap) fold == 0 and the result equals the dense reference.
# --------------------------------------------------------------------------


def _top_cap_indices(mag: jax.Array, cap: int) -> jax.Array:
    """Indices of the ``cap`` largest magnitudes (ties -> lower index;
    both lax.top_k and a stable descending argsort break ties that way,
    so every rank picks deterministically whatever the lowering)."""
    if _topk_lowers_everywhere():
        _, idx = jax.lax.top_k(mag, cap)
        return idx
    return jnp.argsort(-mag, stable=True)[:cap]


def _take_top_stream(s: SparseStream, mask: jax.Array, cap: int):
    """Top-``cap``-|value| masked entries of ``s``, plus the clamped rest.

    Returns (kept stream of capacity ``cap`` sorted by index, (drop_idx,
    drop_val) SENTINEL-padded arrays of the masked entries past the cap).
    Magnitude ties break toward the lower index (streams are index-sorted
    and the argsort is stable), deterministic across ranks."""
    cap = min(cap, s.capacity)
    neg = jnp.where(mask, -jnp.abs(s.val), jnp.inf)
    order = jnp.argsort(neg, stable=True)   # masked first, big |v| first
    idx_o, val_o, m_o = s.idx[order], s.val[order], mask[order]
    sel_i = jnp.where(m_o[:cap], idx_o[:cap], SENTINEL)
    sel_v = jnp.where(m_o[:cap], val_o[:cap], 0)
    sel_i, sel_v = jax.lax.sort((sel_i, sel_v), num_keys=1)
    nnz = jnp.minimum(jnp.sum(mask), cap).astype(jnp.int32)
    drop_i = jnp.where(m_o[cap:], idx_o[cap:], SENTINEL)
    drop_v = jnp.where(m_o[cap:], val_o[cap:], 0)
    return SparseStream(sel_i, sel_v, nnz), (drop_i, drop_v)


def ssar_balanced_split_inside(
    u: UniformStream,
    *,
    axis_name: str,
    p: int,
    impl: str = "auto",
    scatter: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Balanced split-and-gather (Ok-Top-k style, DESIGN.md §9).

    Split phase: the bucket-uniform a2a route (exactly balanced by
    construction — every rank receives (P-1)/P * k items, the O(k)
    balance bound with eps=0). Owner phase: scatter-add the received
    contributions into my range, then re-top-k to the
    ``balanced_shard_cap`` capacity. Gather phase: allgather the clamped
    (idx, val) shards — (P-1) * cap items instead of split_allgather's
    O(kP) worst-case range union. Returns (dense (n,), fold (n,)): fold
    carries my range's clamped-off partial sums (zero when the cap does
    not bind, e.g. full index overlap).

    ``scatter`` (DESIGN.md §11) terminates at the owner shard: the
    gather phase — the capped allgather, which is exactly what the wire
    saves — is SKIPPED and the return is (shard (n/p,), fold (n,)).
    Bit-parity by construction: owned ranges are disjoint, so the
    replicated dense restricted to my range IS the clamped shard; the
    re-top-k and its fold are kept so EF trajectories match the
    replicated mode exactly."""
    nb, k = u.lidx.shape
    b = u.bucket_size
    n = nb * b
    lidx, val = _split_uniform(u, axis_name, p)
    shard = _reduce_range_dense(lidx, val, b, impl=impl)   # (n/p,) owner sums
    range_n = shard.shape[0]
    cap = min(balanced_shard_cap(nb * k, p, n), range_n)
    sel_idx = _top_cap_indices(jnp.abs(shard), cap)
    sel_val = shard[sel_idx]
    selected = jnp.zeros_like(shard).at[sel_idx].set(sel_val)
    my_rank = jax.lax.axis_index(axis_name)
    base = (my_rank * range_n).astype(jnp.int32)
    fold = jax.lax.dynamic_update_slice(
        jnp.zeros((n,), shard.dtype), shard - selected, (base,))
    if scatter:
        return selected, fold
    gidx = sel_idx.astype(jnp.int32) + base
    all_idx = jax.lax.all_gather(gidx, axis_name, tiled=True)   # (p*cap,)
    all_val = jax.lax.all_gather(sel_val, axis_name, tiled=True)
    dense = jnp.zeros((n,), shard.dtype).at[all_idx].add(all_val, mode="drop")
    return dense, fold


def ssar_rearranged_rs_inside(
    u: UniformStream,
    *,
    axis_name: str,
    p: int,
    scatter: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Rearranged reduce-scatter + allgather (SparDL style, DESIGN.md §9).

    log2(P) recursive-halving rounds: each round partitions my current
    index range at its midpoint, ships the partner's half as a stream
    (ppermute), and merges the received half — stream form end-to-end,
    no densify between phases. Capacities follow
    ``rearranged_round_caps``; entries past a send/merge cap are the
    smallest-magnitude ones and are accumulated into ``fold`` at their
    global coordinate (the global-residual rule) instead of being lost.
    Final phase: allgather of the disjoint owned shards. Returns
    (dense (n,), fold (n,)).

    ``scatter`` (DESIGN.md §11): the MSB-first halving ends rank r
    holding exactly the owned range [r*n/p, (r+1)*n/p) — the natural
    reduce-scatter. The final allgather is skipped and the return is
    (shard (n/p,), fold (n,)), densified at range-local coordinates;
    rounds and caps are untouched, so folds and numerics match the
    replicated mode restricted to the owned range."""
    assert p & (p - 1) == 0, "P must be a power of two (paper assumption 2)"
    nb, kpb = u.lidx.shape
    n = u.n
    caps = rearranged_round_caps(nb * kpb, n, p)
    s = u.to_stream()
    my_rank = jax.lax.axis_index(axis_name)
    fold = jnp.zeros((n,), s.val.dtype)
    lo = jnp.zeros((), jnp.int32)
    length = n
    for t, (send_cap, merged_cap) in enumerate(caps):
        dist = p >> (t + 1)
        perm = _xor_perm(p, dist)
        half = length // 2
        mid = lo + half
        keep_lower = (my_rank & dist) == 0      # MSB-first: rank r ends
        valid = s.idx != SENTINEL               # owning [r*n/p, (r+1)*n/p)
        in_lower = s.idx < mid
        send_mask = valid & (in_lower ^ keep_lower)
        keep_mask = valid & ~(in_lower ^ keep_lower)
        # Keep side stays at full capacity (no clamp, no drop) — only the
        # wire and the merged result are capacity-bound.
        kept = SparseStream(jnp.where(keep_mask, s.idx, SENTINEL),
                            jnp.where(keep_mask, s.val, 0),
                            jnp.sum(keep_mask).astype(jnp.int32))
        sent, (sd_i, sd_v) = _take_top_stream(s, send_mask, send_cap)
        fold = fold.at[sd_i].add(sd_v, mode="drop")
        recv = _exchange(sent, axis_name, perm)
        merged = ss.merge(kept, recv, kept.capacity + recv.capacity)
        clamped, (md_i, md_v) = _take_top_stream(
            merged, merged.idx != SENTINEL, merged_cap)
        fold = fold.at[md_i].add(md_v, mode="drop")
        s = clamped
        lo = jnp.where(keep_lower, lo, mid).astype(jnp.int32)
        length = half
    if scatter:
        # Owner-local densify at range-relative coordinates; SENTINEL
        # entries land far past n/p and drop. lo == my_rank * n/p here.
        shard = jnp.zeros((n // p,), s.val.dtype).at[s.idx - lo].add(
            s.val, mode="drop")
        return shard, fold
    # Owned ranges are disjoint: the allgather is plain concatenation and
    # the scatter-add places each shard at its global coordinates.
    all_idx = jax.lax.all_gather(s.idx, axis_name, tiled=True)
    all_val = jax.lax.all_gather(s.val, axis_name, tiled=True)
    dense = jnp.zeros((n,), s.val.dtype).at[all_idx].add(all_val, mode="drop")
    return dense, fold


# --------------------------------------------------------------------------
# DSAR_Split_allgather (paper §5.3.3 + §6 low-precision second phase)
# --------------------------------------------------------------------------

def dsar_split_allgather_inside(
    u: UniformStream,
    *,
    axis_name: str,
    p: int,
    qsgd: QSGDConfig | None = None,
    rand: jax.Array | None = None,
    out_dtype=jnp.float32,
    impl: str = "auto",
) -> jax.Array:
    """Split phase sparse, owned range densified, dense (optionally
    QSGD-quantized) allgather. Returns the dense global sum (n,)."""
    nb, k = u.lidx.shape
    b = u.bucket_size
    lidx, val = _split_uniform(u, axis_name, p)
    shard = _reduce_range_dense(lidx, val, b, impl=impl)  # (nb/p * b,)
    if qsgd is None:
        full = jax.lax.all_gather(shard.astype(out_dtype), axis_name, tiled=True)
        return full
    if rand is None:
        raise ValueError("QSGD second phase needs stochastic-rounding bits")
    packed, scale = quantize(shard, qsgd, rand.reshape(-1)[: shard.shape[0]], impl=impl)
    packed_all = jax.lax.all_gather(packed, axis_name, tiled=True)
    scale_all = jax.lax.all_gather(scale, axis_name, tiled=True)
    return dequantize(packed_all, scale_all, qsgd, nb * b, out_dtype, impl=impl)


# --------------------------------------------------------------------------
# Batched DSAR: leading row axis (e.g. 'model'-sharded canonical rows)
# rides through the data-axis collectives as a pure batch dim.
# --------------------------------------------------------------------------

def _qsgd_roundtrip(x2d, rand2d, qsgd: QSGDConfig, impl: str, out_dtype):
    """quantize -> dequantize (the wire fidelity without the wire)."""
    from repro.kernels.qsgd_pack.ops import qsgd_pack
    from repro.kernels.qsgd_unpack.ops import qsgd_unpack

    packed, scale = qsgd_pack(x2d, rand2d, qsgd.bits, qsgd.scale_mode,
                              impl=impl)
    return qsgd_unpack(packed, scale, qsgd.bits, out_dtype, impl=impl)


def dsar_split_allgather_batched_inside(
    u,  # BatchedStream: lidx/val (r, m, k)
    *,
    axis_name: str,
    p: int,
    qsgd: QSGDConfig | None = None,
    rand: jax.Array | None = None,
    out_dtype=jnp.float32,
    impl: str = "auto",
    coll=None,  # repro.comm.collectives.CollectiveContext | None (native)
    scatter: bool = False,
) -> jax.Array:
    """DSAR over the 'data' axis with a batched row dim. Returns (r, m*B),
    or the (r, m*B/p) owned column shard when ``scatter`` (DESIGN.md §11).

    Native lowering — ONE collective per phase:
      split: single fused a2a on the BUCKET axis (axis 1) carrying
             [val | lidx-as-f32] (lidx < B <= 512 is exact in f32);
      densify my bucket range (batched one-hot contraction);
      gather: single all_gather on axis 1 ([packed-bitcast-f32 | scale]
              when QSGD-quantized). ``scatter`` SKIPS the gather — the
      quantize->dequantize round-trip runs locally on my shard with my
      rand bits, so the shard is bit-equal to the replicated result
      restricted to my columns.

    Emulated lowering (coll.native=False — partial-manual regions on
    backends where only psum lowers, DESIGN.md §4): the full dense sum in
    one psum, then the identical per-range QSGD quantize->dequantize
    applied locally by every rank; ``scatter`` slices my range off the
    replicated result (exact parity, no wire saving — scaffolding only).
    Bit-identical results to the native path given the same per-range
    rand bits.

    rand: stochastic-rounding bits for the QSGD phase — my shard's
    (r*m*B/p,) u32 when native, all ranges' (p, r*m*B/p) when emulated
    (every rank replays every owner's rounding).
    """
    r, m, k = u.lidx.shape
    b = u.bucket_size
    assert m % p == 0, f"buckets-per-row {m} % p {p}"
    mp = m // p
    shard_cols = mp * b

    if coll is None:
        from repro.comm.collectives import CollectiveContext  # lazy: no cycle
        coll = CollectiveContext(axis_name, p)

    if not coll.native:
        dense = coll.psum(u.densify().astype(jnp.float32))   # (r, m*B)
        if qsgd is None:
            out = dense
        else:
            if rand is None:
                raise ValueError(
                    "QSGD second phase needs stochastic-rounding bits")
            bq = qsgd.bucket_size
            nbq = shard_cols // bq
            # (r, m*B) -> per-range rows exactly as each native owner sees
            xs = dense.reshape(r, p, shard_cols).transpose(1, 0, 2)
            xhat = _qsgd_roundtrip(
                xs.reshape(p * r * nbq, bq),
                rand.reshape(p * r * nbq, bq), qsgd, impl, jnp.float32)
            out = (xhat.reshape(p, r, shard_cols).transpose(1, 0, 2)
                   .reshape(r, m * b))
        if scatter:
            mine = jax.lax.dynamic_slice_in_dim(
                out.reshape(r, p, shard_cols),
                coll.axis_rank(), 1, axis=1)
            return mine.reshape(r, shard_cols).astype(out_dtype)
        return out.astype(out_dtype)

    assert b <= 1 << 24, "lidx-as-f32 wire format needs exact f32 ints"
    payload = jnp.concatenate(
        [u.val.astype(jnp.float32), u.lidx.astype(jnp.float32)], axis=-1)
    payload = coll.all_to_all(payload, axis=1)               # ONE a2a
    payload = payload.reshape(r, p, mp, 2 * k)
    val = payload[..., :k]
    lidx = payload[..., k:].astype(jnp.int32)
    # densify my bucket range and reduce over the p sources
    iota = jnp.arange(b, dtype=jnp.int32)
    onehot = (lidx[..., None] == iota).astype(jnp.float32)
    shard = jnp.einsum("rpmkb,rpmk->rmb", onehot, val).reshape(r, shard_cols)
    if scatter:
        # Stop at the owner shard: the gather phase never happens. With
        # QSGD the quantize->dequantize round-trip still runs (locally,
        # my rand bits) so the shard is bit-equal to the replicated
        # result restricted to my columns — wire fidelity without wire.
        if qsgd is None:
            return shard.astype(out_dtype)
        if rand is None:
            raise ValueError(
                "QSGD second phase needs stochastic-rounding bits")
        bq = qsgd.bucket_size
        nbq = shard_cols // bq
        xhat = _qsgd_roundtrip(
            shard.reshape(r * nbq, bq),
            rand.reshape(-1)[: r * nbq * bq].reshape(r * nbq, bq),
            qsgd, impl, jnp.float32)
        return xhat.reshape(r, shard_cols).astype(out_dtype)
    if qsgd is None:
        return coll.all_gather(shard.astype(out_dtype), axis=1)
    if rand is None:
        raise ValueError("QSGD second phase needs stochastic-rounding bits")
    from repro.kernels.qsgd_pack.ops import qsgd_pack
    from repro.kernels.qsgd_unpack.ops import qsgd_unpack

    bq = qsgd.bucket_size
    nbq = shard_cols // bq
    packed, scale = qsgd_pack(
        shard.reshape(r * nbq, bq),
        rand.reshape(-1)[: r * nbq * bq].reshape(r * nbq, bq), qsgd.bits,
        qsgd.scale_mode, impl=impl)
    w = packed.shape[-1]
    # ONE gather: [packed u32 bitcast to f32 | scale f32] along axis 1
    wire = jnp.concatenate(
        [jax.lax.bitcast_convert_type(packed.reshape(r, nbq * w), jnp.float32),
         scale.reshape(r, nbq)], axis=1)
    wire = coll.all_gather(wire, axis=1).reshape(r, p, nbq * w + nbq)
    packed_all = jax.lax.bitcast_convert_type(
        wire[..., : nbq * w], jnp.uint32)
    scale_all = wire[..., nbq * w:]
    xhat = qsgd_unpack(packed_all.reshape(r * p * nbq, w),
                       scale_all.reshape(r * p * nbq, 1), qsgd.bits,
                       jnp.float32, impl=impl)
    # received order is (r, p, shard) — identical to the pre-fusion
    # two-gather layout, so the reshape back to (r, m*B) is unchanged
    return xhat.reshape(r, m * b).astype(out_dtype)


# --------------------------------------------------------------------------
# Dispatcher + dense baseline
# --------------------------------------------------------------------------

def safe_psum(x: jax.Array, axis_name) -> jax.Array:
    """psum with an f32 round-trip for 16-bit operands.

    Works around an XLA-CPU partitioner bug in this JAX build: bf16/f16
    reductions inside a PARTIAL-manual shard_map (auto axes present) build
    an invalid binary 'copy' HLO and abort. 32-bit reductions are fine;
    real TPU backends don't hit this path (documented in DESIGN.md §5).
    """
    if x.dtype in (jnp.bfloat16, jnp.float16):
        return jax.lax.psum(x.astype(jnp.float32), axis_name).astype(x.dtype)
    return jax.lax.psum(x, axis_name)


def safe_pmean(x: jax.Array, axis_name) -> jax.Array:
    if x.dtype in (jnp.bfloat16, jnp.float16):
        return jax.lax.pmean(x.astype(jnp.float32), axis_name).astype(x.dtype)
    return jax.lax.pmean(x, axis_name)


def dense_allreduce_inside(x: jax.Array, *, axis_name: str) -> jax.Array:
    return safe_psum(x, axis_name)


def sparse_allreduce_inside(
    u: UniformStream,
    *,
    axis_name: str,
    p: int,
    algorithm: str = "auto",
    qsgd: QSGDConfig | None = None,
    rand: jax.Array | None = None,
    out_dtype=jnp.float32,
    impl: str = "auto",
) -> ReduceOut:
    """Reduce a bucket-uniform stream over the axis; auto-selects the
    algorithm from the alpha-beta cost model + expected fill-in (trace time,
    mirroring the paper's guidance that the user knows K roughly)."""
    n = u.n
    if algorithm == "auto":
        algorithm = select_algorithm(
            p, u.nnz, n, value_bits=(qsgd.bits if qsgd else 32)
        )
    if algorithm == "dense":
        return ReduceOut(dense=dense_allreduce_inside(u.densify(impl=impl), axis_name=axis_name))
    if algorithm == "ssar_recursive_double":
        return ssar_recursive_double_inside(
            u.to_stream(), axis_name=axis_name, p=p, n=n
        )
    if algorithm == "ssar_split_allgather":
        return ReduceOut(stream=ssar_split_allgather_inside(u, axis_name=axis_name, p=p))
    if algorithm == "ssar_balanced_split":
        # Standalone wrapper: no EF residual to fold the clamp drops into
        # (the plan executor keeps them); under non-binding caps fold==0.
        dense, _fold = ssar_balanced_split_inside(
            u, axis_name=axis_name, p=p, impl=impl)
        return ReduceOut(dense=dense)
    if algorithm == "ssar_rearranged_rs":
        dense, _fold = ssar_rearranged_rs_inside(u, axis_name=axis_name, p=p)
        return ReduceOut(dense=dense)
    if algorithm == "dsar_split_allgather":
        return ReduceOut(
            dense=dsar_split_allgather_inside(
                u, axis_name=axis_name, p=p, qsgd=qsgd, rand=rand,
                out_dtype=out_dtype, impl=impl,
            )
        )
    raise ValueError(f"unknown algorithm {algorithm!r}")


# --------------------------------------------------------------------------
# Standalone jit-level wrappers (tests / benchmarks / examples)
# --------------------------------------------------------------------------

def make_sparse_allreduce(
    mesh: jax.sharding.Mesh,
    axis_name: str,
    n: int,
    k_per_bucket: int,
    bucket_size: int = 512,
    algorithm: str = "auto",
    qsgd: QSGDConfig | None = None,
    impl: str = "auto",
):
    """Returns f(x_batched (P, n), rand (P, nbq*bq) u32|None) -> dense (n,)
    summing per-rank vectors with TopK compression + sparse allreduce.

    x rows live on distinct ranks (sharded over axis_name); the result is
    replicated. For benchmarks and the MPI-OPT-style examples.
    """
    from jax.sharding import PartitionSpec as P  # local import, avoids cycle
    from repro.compat import shard_map
    from repro.core import topk as topk_mod

    p = mesh.shape[axis_name]

    def inner(x, rand):
        x = x.reshape(-1)  # my row
        u, _res = topk_mod.compress(x, k_per_bucket, bucket_size, impl=impl)
        out = sparse_allreduce_inside(
            u, axis_name=axis_name, p=p, algorithm=algorithm,
            qsgd=qsgd, rand=rand.reshape(-1) if rand is not None else None,
            out_dtype=x.dtype, impl=impl,
        )
        return out.to_dense(u.n)[:n]

    spec_x = P(axis_name)
    spec_r = P(axis_name) if qsgd is not None else None
    in_specs = (spec_x, spec_r)
    return jax.jit(
        shard_map(
            inner, mesh=mesh, in_specs=in_specs, out_specs=P(None),
            check_vma=False,
        )
    )
