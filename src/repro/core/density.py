"""Stochastic density analysis (paper Appendix B).

Expected fill-in of the reduced result when each of P nodes contributes k
uniformly-random non-zero indices out of N. Drives algorithm selection
(SSAR vs DSAR) and reproduces Fig. 1 / Fig. 7.
"""
from __future__ import annotations

import numpy as np


def expected_nnz(k: int, n: int, p: int) -> float:
    """E[K] under uniform sparsity.

    Closed form: the inclusion-exclusion sum in App. B.1 telescopes to
    N * (1 - (1 - k/N)^P) when the k draws per node are i.i.d. uniform.
    """
    if k <= 0:
        return 0.0
    d = min(1.0, k / n)
    return n * (1.0 - (1.0 - d) ** p)


def expected_nnz_inclusion_exclusion(k: int, n: int, p: int) -> float:
    """The paper's literal alternating-series form (App. B.1), for validation.

    E[K] = N * sum_{i=1..P} (-1)^{i-1} C(P,i) (k/N)^i
    Matches `expected_nnz` because sum_{i} C(P,i)(-d)^i = (1-d)^P - 1.
    Computed in log-space-free float; fine for the P<=4096 we use in tests.
    """
    d = k / n
    total = 0.0
    term = 1.0  # C(P, i) * d^i, built incrementally
    for i in range(1, p + 1):
        term = term * (p - i + 1) / i * d if i > 1 else p * d
        total += (-1) ** (i - 1) * term
        if term < 1e-18:  # series tail is negligible
            break
    return n * total


def monte_carlo_nnz(k: int, n: int, p: int, trials: int = 16, seed: int = 0) -> float:
    """Empirical E[K]: sample P nodes x k uniform indices, count the union."""
    rng = np.random.default_rng(seed)
    counts = []
    for _ in range(trials):
        union = np.zeros(n, dtype=bool)
        for _ in range(p):
            union[rng.choice(n, size=k, replace=False)] = True
        counts.append(int(union.sum()))
    return float(np.mean(counts))


def reduced_density(k: int, n: int, p: int) -> float:
    """Fig. 1 quantity: density (fraction) of the reduced result."""
    return expected_nnz(k, n, p) / n


def fill_in_factor(k: int, n: int, p: int) -> float:
    """Fig. 7 quantity: multiplicative growth E[K]/k."""
    return expected_nnz(k, n, p) / max(1, k)
