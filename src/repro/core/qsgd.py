"""QSGD bucketed stochastic quantization (paper §6), flat-vector API.

Applied to the dense second phase of DSAR_Split_allgather: quantize the
reduced N/P shard before the allgather, cutting its bandwidth term by
32/bits (paper: "reduce the bandwidth cost of this last step by a constant
corresponding to the quantization").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.qsgd_pack.ops import qsgd_pack
from repro.kernels.qsgd_unpack.ops import qsgd_unpack


class QSGDConfig(NamedTuple):
    bits: int = 4
    bucket_size: int = 1024  # "in the order of 1024 consecutive entries" (§6)
    scale_mode: str = "l2"   # QSGD uses the bucket L2 norm

    @property
    def words_per_bucket(self) -> int:
        return self.bucket_size * self.bits // 32

    def wire_bytes(self, n: int) -> int:
        """Bytes on the wire for an n-length vector (packed codes + scales)."""
        nb = -(-n // self.bucket_size)
        return nb * self.words_per_bucket * 4 + nb * 4


def quantize(
    x: jax.Array, cfg: QSGDConfig, rand: jax.Array, impl: str = "auto"
) -> tuple[jax.Array, jax.Array]:
    """x: flat (n,), n a multiple of cfg.bucket_size after padding.

    rand: flat uint32 (n,). Returns (packed (nb, W) u32, scales (nb, 1) f32).
    """
    (n,) = x.shape
    bq = cfg.bucket_size
    nb = -(-n // bq)
    pad = nb * bq - n
    if pad:
        x = jnp.pad(x, (0, pad))
        rand = jnp.pad(rand, (0, pad))
    packed, scale = qsgd_pack(
        x.reshape(nb, bq), rand.reshape(nb, bq), cfg.bits, cfg.scale_mode, impl=impl
    )
    return packed, scale


def dequantize(
    packed: jax.Array, scale: jax.Array, cfg: QSGDConfig, n: int,
    out_dtype=jnp.float32, impl: str = "auto",
) -> jax.Array:
    xhat = qsgd_unpack(packed, scale, cfg.bits, out_dtype, impl=impl)
    return xhat.reshape(-1)[:n]


def random_bits_like(key: jax.Array, n: int) -> jax.Array:
    """Uniform u32 noise for stochastic rounding (explicit operand)."""
    return jax.random.bits(key, (n,), dtype=jnp.uint32)
