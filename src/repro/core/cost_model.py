"""Alpha-beta cost model for the SparCML collectives (paper §5.3).

Used for (a) trace-time algorithm auto-selection, (b) the Fig.-3 style
benchmark, (c) property tests of the paper's bound ordering and of the
Lemma 5.2 speedup cap.

TPU v5e constants (per chip): ~50 GB/s per ICI link, ~1 us per-hop latency.
The model is deliberately the paper's: T(L) = alpha + beta * L.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

from .density import expected_nnz
from .sparse_stream import INDEX_BYTES, delta_threshold


@dataclass(frozen=True)
class NetworkParams:
    alpha: float = 1e-6            # seconds per message/hop
    link_bytes_per_s: float = 50e9  # ICI per-link bandwidth
    isize: int = 4                  # bytes per value (fp32)

    @property
    def beta_d(self) -> float:
        """Seconds per dense value word."""
        return self.isize / self.link_bytes_per_s

    @property
    def beta_s(self) -> float:
        """Seconds per sparse (index,value) item. beta_s > beta_d (paper §5.2)."""
        return (self.isize + INDEX_BYTES) / self.link_bytes_per_s


DEFAULT_NET = NetworkParams()


def t_dense_allreduce(p: int, n: int, net: NetworkParams = DEFAULT_NET) -> float:
    """Rabenseifner (paper §5.3.2): 2 log2(P) alpha + 2 (P-1)/P N beta_d."""
    return 2 * math.log2(p) * net.alpha + 2 * (p - 1) / p * n * net.beta_d


def t_ssar_recursive_double(
    p: int, k: int, n: int, net: NetworkParams = DEFAULT_NET,
    expected: bool = True, reduced_nnz: float | None = None,
) -> tuple[float, float, float]:
    """(lower, expected, upper) for SSAR_Recursive_double.

    lower: full index overlap (k items per round);
    upper: zero overlap (2^t k items in round t, sums to (P-1)k);
    expected: per-round fill-in from the uniform model (App. B), or — when
    ``reduced_nnz`` (a MEASURED final fill-in, adaptive telemetry) is given
    — the uniform per-round curve rescaled so it lands on the measurement.
    """
    lat = math.log2(p) * net.alpha
    lo = lat + math.log2(p) * k * net.beta_s
    hi = lat + (p - 1) * k * net.beta_s
    scale = 1.0
    if reduced_nnz is not None:
        uniform_final = expected_nnz(k, n, p)
        if uniform_final > 0:
            scale = reduced_nnz / uniform_final
    # Round t carries at most 2^t * k items (zero overlap) and at most n;
    # the measured rescale must respect both, or 'expected' could exceed
    # its own upper bound and over-penalize this algorithm in selection.
    exp_items = sum(
        min(expected_nnz(k, n, 2**t) * scale, (2**t) * k, n)
        for t in range(int(math.log2(p)))
    )
    exp = lat + exp_items * net.beta_s
    return lo, exp, hi


def t_ssar_split_allgather(
    p: int, k: int, n: int, net: NetworkParams = DEFAULT_NET,
    reduced_nnz: float | None = None,
) -> tuple[float, float, float]:
    """(lower, expected, upper) for SSAR_Split_allgather (paper §5.3.2).

    Latency L2 = (P-1) alpha + log2(P) alpha (direct split sends + allgather).
    Bandwidth between 2 (P-1)/P k beta_s and P k beta_s. ``reduced_nnz``
    replaces the uniform-model expected reduced size with a measurement.
    """
    lat = (p - 1) * net.alpha + math.log2(p) * net.alpha
    lo = lat + 2 * (p - 1) / p * k * net.beta_s
    hi = lat + p * k * net.beta_s
    kk = (reduced_nnz if reduced_nnz is not None
          else expected_nnz(k, n, p))  # reduced size: measured or expected
    exp = lat + ((p - 1) / p * k + (p - 1) / p * kk) * net.beta_s
    return lo, exp, hi


def t_dsar_split_allgather(
    p: int, k: int, n: int, net: NetworkParams = DEFAULT_NET, value_bits: int = 32
) -> tuple[float, float]:
    """(lower, upper) for DSAR_Split_allgather (paper §5.3.3).

    Split phase sparse; second phase dense allgather of N/P-shards, whose
    word size can shrink by quantization (paper §6) to value_bits.
    """
    lat = (p - 1) * net.alpha + math.log2(p) * net.alpha
    beta_q = net.beta_d * value_bits / (8 * net.isize)
    lo = lat + (p - 1) / p * n * beta_q
    hi = lat + k * net.beta_s + (p - 1) / p * n * beta_q
    return lo, hi


# ---------------------------------------------------------------------------
# Near-optimal portfolio (DESIGN.md §9): capacity-clamped algorithms.
# Both bound the END representation to O(k) items per rank; entries past a
# clamp are never silently lost — the executor folds them into the owning
# bucket's EF residual (the "global residual" rule).
# ---------------------------------------------------------------------------

BALANCE_EPS = 0.25  # headroom of the balanced/rearranged capacity clamps


def balanced_shard_cap(k: int, p: int, n: Optional[int] = None,
                       eps: float = BALANCE_EPS) -> int:
    """Per-owner output capacity of ``ssar_balanced_split``: the balance
    pass re-top-k's each owned range down to ~(k/P)(1+eps) entries — the
    Ok-Top-k O(k) traffic bound. Never exceeds the owned range length."""
    cap = max(1, math.ceil(k / p * (1.0 + eps)))
    if n is not None:
        cap = min(cap, -(-n // p))
    return cap


def rearranged_round_caps(k: int, n: int, p: int,
                          eps: float = BALANCE_EPS) -> list[tuple[int, int]]:
    """(send_cap, merged_cap) per recursive-halving round of
    ``ssar_rearranged_rs``. Round 0 sends exactly k/2 items (bucket-
    uniform streams hold exactly half their entries in each half-range);
    round t >= 1 sends and keeps at most k(1+eps)/2^(t+1). Entries past
    a cap are the smallest-magnitude ones and fold into the EF residual,
    so total traffic stays O(k) without losing gradient mass."""
    caps = []
    for t in range(int(math.log2(p))):
        half = n >> (t + 1)
        merged = min(half, max(1, math.ceil(k * (1.0 + eps) / (1 << (t + 1)))))
        send = min(half, max(1, -(-k // 2))) if t == 0 else merged
        caps.append((send, merged))
    return caps


def t_ssar_balanced_split(
    p: int, k: int, n: int, net: NetworkParams = DEFAULT_NET,
    reduced_nnz: float | None = None,
) -> tuple[float, float, float]:
    """(lower, expected, upper) for ssar_balanced_split (Ok-Top-k style).

    Same latency shape as split_allgather ((P-1) direct split sends +
    log2(P) allgather rounds), but the gather phase ships each owner's
    re-top-k'd shard at the fixed (k/P)(1+eps) capacity instead of the
    O(kP) worst-case range union: total bandwidth <= k(2+eps) beta_s.
    ``reduced_nnz`` replaces the uniform-model reduced size, as in
    :func:`t_ssar_split_allgather`.
    """
    lat = (p - 1) * net.alpha + math.log2(p) * net.alpha
    cap = float(balanced_shard_cap(k, p, n))
    split = (p - 1) / p * k
    kk = (reduced_nnz if reduced_nnz is not None else expected_nnz(k, n, p))
    kk = min(max(kk, 0.0), float(p * k), float(n))
    lo = lat + (split + (p - 1) * min(k / p, cap)) * net.beta_s
    hi = lat + (split + (p - 1) * cap) * net.beta_s
    exp = lat + (split + (p - 1) * min(kk / p, cap)) * net.beta_s
    return lo, min(max(exp, lo), hi), hi


def t_ssar_rearranged_rs(
    p: int, k: int, n: int, net: NetworkParams = DEFAULT_NET,
    reduced_nnz: float | None = None,
) -> tuple[float, float, float]:
    """(lower, expected, upper) for ssar_rearranged_rs (SparDL style).

    log2(P) recursive-halving rounds in stream form (one ppermute each,
    no densify between phases) followed by a log2(P)-round allgather of
    the capacity-clamped owned shards: latency 2 log2(P) alpha — the
    Rabenseifner latency, (P-1)x below the split algorithms — and
    bandwidth <= ~2k(1+eps) beta_s. ``reduced_nnz`` rescales the
    per-round uniform fill-in curve as in t_ssar_recursive_double.
    """
    caps = rearranged_round_caps(k, n, p)
    lat = 2 * math.log2(p) * net.alpha
    scale = 1.0
    if reduced_nnz is not None:
        uniform_final = expected_nnz(k, n, p)
        if uniform_final > 0:
            scale = reduced_nnz / uniform_final
    rs_lo = rs_exp = rs_hi = 0.0
    for t, (send_cap, _) in enumerate(caps):
        # Entering round t the stream holds ~fill(2^t)/2^t entries of its
        # current range; it sends the half belonging to the partner.
        fill = min(expected_nnz(k, n, 2 ** t) * scale,
                   float((2 ** t) * k), float(n))
        rs_exp += min(fill / (1 << (t + 1)), float(send_cap))
        rs_lo += min(k / (1 << (t + 1)), float(send_cap))
        rs_hi += float(send_cap)
    final_cap = float(caps[-1][1] if caps else n)
    fill_p = min(expected_nnz(k, n, p) * scale, float(p * k), float(n))
    lo = lat + (rs_lo + (p - 1) * min(k / p, final_cap)) * net.beta_s
    hi = lat + (rs_hi + (p - 1) * final_cap) * net.beta_s
    exp = lat + (rs_exp + (p - 1) * min(fill_p / p, final_cap)) * net.beta_s
    return lo, min(max(exp, lo), hi), hi


def t_stream_allgather(p: int, cap_rows: int, d: int,
                       net: NetworkParams = DEFAULT_NET) -> float:
    """Row-stream all-gather: the serve-side activation exchange
    (DESIGN.md §8). Every rank broadcasts a fixed-capacity stream of
    ``cap_rows`` (row index, d-vector) items — one item per active token
    routed to a local expert — and receives the other P-1 streams."""
    row_bytes = d * net.isize + INDEX_BYTES
    return (math.log2(p) * net.alpha
            + (p - 1) * cap_rows * row_bytes / net.link_bytes_per_s)


def stream_wire_bytes(p: int, cap_rows: int, d: int, isize: int = 4) -> float:
    """Per-rank wire bytes of one row-stream all-gather step (receive
    side: P-1 foreign streams of cap_rows rows). The ONE accounting the
    serve executor's telemetry and the ServePlan selection rule share —
    they must never diverge (same contract as :func:`pod_wire_bytes`)."""
    if p <= 1:
        return 0.0
    return (p - 1) * cap_rows * float(d * isize + INDEX_BYTES)


def parse_stream_cap(algorithm: str) -> int:
    """Row capacity of a ``stream_gather@<cap>`` serve algorithm tag (the
    capacity is part of the plan signature, so it rides the string).

    Raises ValueError on malformed tags: the tag is checkpoint/user input
    (plan signatures, replan overrides), and the opaque ``int()`` crash it
    used to produce pointed at nothing."""
    head, sep, tail = algorithm.partition("@")
    if head != "stream_gather" or not sep:
        raise ValueError(
            f"malformed stream algorithm tag {algorithm!r}: "
            "expected 'stream_gather@<cap>'")
    try:
        cap = int(tail)
    except ValueError:
        raise ValueError(
            f"malformed stream algorithm tag {algorithm!r}: "
            f"capacity {tail!r} is not an integer") from None
    if cap <= 0:
        raise ValueError(
            f"malformed stream algorithm tag {algorithm!r}: "
            f"capacity must be positive, got {cap}")
    return cap


def dsar_speedup_cap(n: int, isize: int = 4) -> float:
    """Lemma 5.2: once the result is dense, sparsity alone buys at most
    2/kappa versus a bandwidth-optimal dense allreduce, kappa = delta/N."""
    kappa = delta_threshold(n, isize) / n
    return 2.0 / kappa


# ---------------------------------------------------------------------------
# Algorithm registry: the ONE place an algorithm declares its modeled cost
# and wire accounting. select_algorithm / bucket_time / bucket_wire_bytes
# all dispatch through it, so adding an algorithm is one registration —
# the chain of hand-written if/elif dispatches is gone.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AlgorithmEntry:
    """One registered allreduce algorithm.

    cost_fn(p, k, n, net, value_bits, reduced_nnz) -> expected seconds;
    wire_fn(p, k, n, nnz, value_bits, isize) -> per-rank bytes per step
    (pure arithmetic in ``nnz`` — it may be a traced telemetry scalar);
    sparse_result: the end-representation grows with fill-in, so the
    delta switchover (paper §5.3.3) rules it out once E[K] >= delta;
    output_cap_fn(p, k, n) -> post-reduction nnz bound of a capacity-
    clamped algorithm (None = unclamped). A clamped algorithm whose
    bound stays under delta SURVIVES the switchover: its result cannot
    densify past the bound, whatever the measured fill-in.

    scatter_cost_fn / scatter_wire_fn (same signatures): the SCATTERED
    output mode (DESIGN.md §11) — the algorithm terminates at the owner
    shard instead of re-replicating, dropping its gather/allgather
    phase. None = not scatter-capable: the executor computes the
    replicated result and slices, so the replicated charge stands."""

    cost_fn: Callable
    wire_fn: Callable
    sparse_result: bool = False
    output_cap_fn: Optional[Callable] = None
    scatter_cost_fn: Optional[Callable] = None
    scatter_wire_fn: Optional[Callable] = None

    @property
    def scatter_capable(self) -> bool:
        return self.scatter_wire_fn is not None


def _clamped_nnz(nnz, cap: float):
    """Clamp a host-side nnz at an algorithm's output capacity. A traced
    telemetry nnz is measured POST-clamp (count_nonzero of the clamped
    result), so it already respects the cap and passes through."""
    if isinstance(nnz, (int, float)):
        return min(float(nnz), float(cap))
    return nnz


def _cost_ssar_recursive_double(p, k, n, net, value_bits, reduced_nnz):
    return t_ssar_recursive_double(p, k, n, net, reduced_nnz=reduced_nnz)[1]


def _cost_ssar_split_allgather(p, k, n, net, value_bits, reduced_nnz):
    return t_ssar_split_allgather(p, k, n, net, reduced_nnz=reduced_nnz)[1]


def _cost_dsar_split_allgather(p, k, n, net, value_bits, reduced_nnz):
    return sum(t_dsar_split_allgather(p, k, n, net, value_bits)) / 2


def _cost_dense(p, k, n, net, value_bits, reduced_nnz):
    return t_dense_allreduce(p, n, net)


def _cost_ssar_balanced_split(p, k, n, net, value_bits, reduced_nnz):
    return t_ssar_balanced_split(p, k, n, net, reduced_nnz=reduced_nnz)[1]


def _cost_ssar_rearranged_rs(p, k, n, net, value_bits, reduced_nnz):
    return t_ssar_rearranged_rs(p, k, n, net, reduced_nnz=reduced_nnz)[1]


def _wire_dense(p, k, n, nnz, value_bits, isize):
    # compressed-dense end-representation OR raw psum: one dense
    # allreduce of the n-vector (Rabenseifner accounting).
    return 2 * (p - 1) / p * n * isize


def _wire_ssar_recursive_double(p, k, n, nnz, value_bits, isize):
    # log2(P) rounds; round t carries ~fill-in-many items. Charged at
    # the measured final fill per round (upper-bounds early rounds).
    return math.log2(p) * nnz * (isize + INDEX_BYTES)


def _wire_ssar_split_allgather(p, k, n, nnz, value_bits, isize):
    item = isize + INDEX_BYTES
    return (p - 1) / p * k * item + (p - 1) / p * nnz * item


def _wire_dsar_split_allgather(p, k, n, nnz, value_bits, isize):
    # value_bits < 32 also adds one fp32 scale per QSGD bucket; the
    # exact figure lives in plan.wire_bytes — telemetry keeps the
    # dominant terms only.
    item = isize + INDEX_BYTES
    return (p - 1) / p * k * item + (p - 1) / p * n * value_bits / 8


def _wire_ssar_balanced_split(p, k, n, nnz, value_bits, isize):
    # split phase as split_allgather; the gather phase is bounded by the
    # per-owner re-top-k capacity — the O(k) bound that is the point.
    item = isize + INDEX_BYTES
    cap_total = p * balanced_shard_cap(k, p, n)
    return ((p - 1) / p * k
            + (p - 1) / p * _clamped_nnz(nnz, cap_total)) * item


def _wire_ssar_rearranged_rs(p, k, n, nnz, value_bits, isize):
    # reduce-scatter rounds ship at most send_cap items each (static
    # caps); the allgather ships the measured (clamped) union.
    item = isize + INDEX_BYTES
    caps = rearranged_round_caps(k, n, p)
    final_cap = caps[-1][1] if caps else n
    rs = float(sum(send for send, _ in caps))
    return (rs + (p - 1) / p * _clamped_nnz(nnz, p * final_cap)) * item


def _balanced_output_cap(p, k, n):
    return p * balanced_shard_cap(k, p, n)


def _rearranged_output_cap(p, k, n):
    caps = rearranged_round_caps(k, n, p)
    return p * (caps[-1][1] if caps else n)


# -- scattered variants (DESIGN.md §11): stop at the owner shard ----------
#
# Each drops exactly its gather/allgather phase from the replicated
# accounting above; the split/reduce-scatter phase is unchanged. The
# dense param allgather that replaces the dropped phase is charged
# separately (t_param_allgather) — it is algorithm-independent and
# overlappable with the next step's forward, so folding it in here would
# make every scattered candidate look identical at the margin.

def _scost_dense(p, k, n, net, value_bits, reduced_nnz):
    # reduce-scatter half of Rabenseifner: log2(P) alpha + (P-1)/P N beta_d
    return math.log2(p) * net.alpha + (p - 1) / p * n * net.beta_d


def _scost_dsar_split_allgather(p, k, n, net, value_bits, reduced_nnz):
    # split phase only; the quantized dense gather disappears entirely
    return (p - 1) * net.alpha + (p - 1) / p * k * net.beta_s


def _scost_ssar_balanced_split(p, k, n, net, value_bits, reduced_nnz):
    # direct split sends, no allgather rounds (the re-top-k'd shard is
    # the OUTPUT now, not a wire representation)
    return (p - 1) * net.alpha + (p - 1) / p * k * net.beta_s


def _scost_ssar_rearranged_rs(p, k, n, net, value_bits, reduced_nnz):
    # the log2(P) recursive-halving rounds, expected fill as in
    # t_ssar_rearranged_rs; the capped-shard allgather disappears
    caps = rearranged_round_caps(k, n, p)
    scale = 1.0
    if reduced_nnz is not None:
        uniform_final = expected_nnz(k, n, p)
        if uniform_final > 0:
            scale = reduced_nnz / uniform_final
    rs_exp = 0.0
    for t, (send_cap, _) in enumerate(caps):
        fill = min(expected_nnz(k, n, 2 ** t) * scale,
                   float((2 ** t) * k), float(n))
        rs_exp += min(fill / (1 << (t + 1)), float(send_cap))
    return math.log2(p) * net.alpha + rs_exp * net.beta_s


def _swire_dense(p, k, n, nnz, value_bits, isize):
    return (p - 1) / p * n * isize


def _swire_dsar_split_allgather(p, k, n, nnz, value_bits, isize):
    return (p - 1) / p * k * (isize + INDEX_BYTES)


def _swire_ssar_balanced_split(p, k, n, nnz, value_bits, isize):
    return (p - 1) / p * k * (isize + INDEX_BYTES)


def _swire_ssar_rearranged_rs(p, k, n, nnz, value_bits, isize):
    caps = rearranged_round_caps(k, n, p)
    return float(sum(send for send, _ in caps)) * (isize + INDEX_BYTES)


def t_param_allgather(p: int, n: int, net: NetworkParams = DEFAULT_NET) -> float:
    """The dense updated-param allgather scattered mode pays per bucket:
    log2(P) rounds shipping (P-1)/P N fp32 words per rank. Overlappable
    with the NEXT step's forward (DESIGN.md §11) — the adaptive
    controller weighs it by its expected exposed fraction, not at par."""
    return math.log2(p) * net.alpha + (p - 1) / p * n * net.beta_d


ALGORITHM_REGISTRY: dict[str, AlgorithmEntry] = {
    "ssar_recursive_double": AlgorithmEntry(
        _cost_ssar_recursive_double, _wire_ssar_recursive_double,
        sparse_result=True),
    "ssar_split_allgather": AlgorithmEntry(
        _cost_ssar_split_allgather, _wire_ssar_split_allgather,
        sparse_result=True),
    "dsar_split_allgather": AlgorithmEntry(
        _cost_dsar_split_allgather, _wire_dsar_split_allgather,
        scatter_cost_fn=_scost_dsar_split_allgather,
        scatter_wire_fn=_swire_dsar_split_allgather),
    "dense": AlgorithmEntry(
        _cost_dense, _wire_dense,
        scatter_cost_fn=_scost_dense, scatter_wire_fn=_swire_dense),
    "ssar_balanced_split": AlgorithmEntry(
        _cost_ssar_balanced_split, _wire_ssar_balanced_split,
        sparse_result=True, output_cap_fn=_balanced_output_cap,
        scatter_cost_fn=_scost_ssar_balanced_split,
        scatter_wire_fn=_swire_ssar_balanced_split),
    "ssar_rearranged_rs": AlgorithmEntry(
        _cost_ssar_rearranged_rs, _wire_ssar_rearranged_rs,
        sparse_result=True, output_cap_fn=_rearranged_output_cap,
        scatter_cost_fn=_scost_ssar_rearranged_rs,
        scatter_wire_fn=_swire_ssar_rearranged_rs),
}

ALL_ALGORITHMS = tuple(ALGORITHM_REGISTRY)


def algorithm_output_cap(algorithm: str, p: int, k: int, n: int):
    """Post-reduction nnz bound of a capacity-clamped algorithm (None
    for unclamped ones): the quantity the delta switchover compares to
    delta, both in :func:`select_algorithm` and in the adaptive
    controller's forced-switch rule."""
    entry = ALGORITHM_REGISTRY.get(algorithm)
    if entry is None or entry.output_cap_fn is None:
        return None
    return int(entry.output_cap_fn(p, k, n))


def select_algorithm(
    p: int,
    k: int,
    n: int,
    net: NetworkParams = DEFAULT_NET,
    value_bits: int = 32,
    allow: tuple = ALL_ALGORITHMS,
    reduced_nnz: float | None = None,
    scattered: bool = False,
) -> str:
    """THE auto-selection entry point: pick the cheapest registered
    algorithm by expected alpha-beta cost (paper §5.3, DESIGN.md §3.3).
    ``k`` is the per-rank selected item count, ``n`` the vector's
    canonical length.

    Mirrors the paper's guidance: recursive doubling for small data
    (latency-bound), split_allgather for large sparse results, DSAR once
    the result exceeds the delta threshold — plus the capacity-clamped
    portfolio (DESIGN.md §9), which survives the delta switchover as
    long as its clamped output bound stays under delta. ``allow``
    restricts the candidate set — the batched (model-sharded rows)
    pipeline only implements DSAR/dense, and the fusion planner passes
    that in.

    ``reduced_nnz`` closes the loop (DESIGN.md §7): a MEASURED
    post-reduction nnz (adaptive telemetry) replaces the uniform-model
    ``expected_nnz`` everywhere — both in the sparse-vs-dense delta
    decision and in the gather-phase cost terms — so fill-in growth and
    EF-residual densification feed back into the choice.

    ``scattered`` costs each candidate under the scattered output mode
    (DESIGN.md §11): scatter-capable algorithms drop their gather phase;
    the rest keep the replicated charge (the executor computes the full
    result and slices). The delta-switchover filter is unchanged — the
    reduce-scatter rounds still densify with fill-in.
    """
    delta = delta_threshold(n, net.isize)
    exp_k = (reduced_nnz if reduced_nnz is not None
             else expected_nnz(k, n, p))
    fill_dense = exp_k >= delta
    candidates = {}
    for name, entry in ALGORITHM_REGISTRY.items():
        if name not in allow:
            continue
        if name == "dense":
            # dense competes only past the switchover: below it, the
            # compressed-stream paths always model cheaper.
            if not fill_dense:
                continue
        elif entry.sparse_result and fill_dense:
            # Sparse end-representation no longer pays (paper §5.3.3) —
            # EXCEPT capacity-clamped algorithms whose output bound
            # stays under delta: their result cannot densify.
            cap = (entry.output_cap_fn(p, k, n)
                   if entry.output_cap_fn is not None else None)
            if cap is None or cap >= delta:
                continue
        cost_fn = (entry.scatter_cost_fn
                   if scattered and entry.scatter_cost_fn is not None
                   else entry.cost_fn)
        candidates[name] = cost_fn(p, k, n, net, value_bits, reduced_nnz)
    if not candidates:  # everything filtered: dense always works
        return "dense"
    return min(candidates, key=candidates.get)


def select_bucket_algorithm(
    p: int,
    k: int,
    n: int,
    net: NetworkParams = DEFAULT_NET,
    value_bits: int = 32,
    allow: tuple = ALL_ALGORITHMS,
    reduced_nnz: float | None = None,
    scattered: bool = False,
) -> str:
    """Per-fusion-bucket view of :func:`select_algorithm` (``k`` = the
    bucket's TOTAL selected items: rows x buckets-per-row x k_per_bucket,
    ``n`` its total canonical length). Thin wrapper — the one selection
    implementation lives in :func:`select_algorithm`."""
    return select_algorithm(p, k, n, net, value_bits, allow, reduced_nnz,
                            scattered)


# ---------------------------------------------------------------------------
# Overlap-aware step costing (non-blocking runtime, DESIGN.md §6)
# ---------------------------------------------------------------------------

def bucket_time(algorithm: str, p: int, k: int, n: int,
                net: NetworkParams = DEFAULT_NET, value_bits: int = 32,
                reduced_nnz: float | None = None,
                scattered: bool = False) -> float:
    """Expected collective time of ONE fusion bucket under its resolved
    algorithm (the per-bucket term the overlap model hides or exposes).
    ``reduced_nnz`` substitutes a measured post-reduction fill-in for the
    uniform model, exactly as in :func:`select_algorithm`.

    Serve-side activation buckets (DESIGN.md §8) use the
    ``stream_gather@<cap>`` algorithm family, where ``k`` is the ROW
    width (d) and the row capacity rides the tag: the cost is capacity-
    bound, not nnz-bound, because the stream ships at fixed cap."""
    if algorithm.startswith("stream_gather"):
        return t_stream_allgather(p, parse_stream_cap(algorithm), k, net)
    entry = ALGORITHM_REGISTRY.get(algorithm)
    if entry is None:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    if scattered and entry.scatter_cost_fn is not None:
        return entry.scatter_cost_fn(p, k, n, net, value_bits, reduced_nnz)
    return entry.cost_fn(p, k, n, net, value_bits, reduced_nnz)


def bucket_wire_bytes(algorithm: str, p: int, k: int, n: int,
                      nnz=None, value_bits: int = 32, isize: int = 4,
                      scattered: bool = False):
    """Per-rank data-axis wire bytes of one bucket for one step. Pure
    arithmetic in ``nnz`` (a traced scalar inside the telemetry emitter,
    or a float on the host), so the executor can report measured wire
    volume in-graph. ``nnz`` defaults to the worst case (p*k).
    ``scattered`` charges the scatter variant where one exists (the
    gather phase drops); non-capable algorithms keep the replicated
    charge — the executor really does run them replicated and slice."""
    if algorithm.startswith("stream_gather"):
        # serve activation exchange: capacity-bound, k is the row width
        return stream_wire_bytes(p, parse_stream_cap(algorithm), k, isize)
    entry = ALGORITHM_REGISTRY.get(algorithm)
    if entry is None:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    if nnz is None:
        nnz = float(min(n, p * k))
    if scattered and entry.scatter_wire_fn is not None:
        return entry.scatter_wire_fn(p, k, n, nnz, value_bits, isize)
    return entry.wire_fn(p, k, n, nnz, value_bits, isize)


def pod_wire_bytes(p_pod: int, n: int, cap: int,
                   pod_sparse: bool = False, isize: int = 4) -> float:
    """Per-rank CROSS-POD wire bytes of one bucket: the dense psum
    (Rabenseifner accounting) or the sparse (idx,val) stream exchange of
    ``pod_sparse`` buckets at stream capacity ``cap`` (DESIGN.md §7.2).
    The ONE accounting both the executor's telemetry and the adaptive
    controller's demotion rule use — they must never diverge."""
    if p_pod <= 1:
        return 0.0
    if pod_sparse:
        return p_pod * cap * float(isize + INDEX_BYTES)
    return 2.0 * (p_pod - 1) / p_pod * n * isize


def plan_bucket_times(plan, p: int | None = None,
                      net: NetworkParams = DEFAULT_NET,
                      densities: dict | None = None) -> list[float]:
    """Expected per-bucket collective times for a comm ``SyncPlan`` (duck-
    typed — importing repro.comm here would cycle), in plan order: the
    drain sequence the pipelined superstep overlaps with compute.
    ``densities`` maps bucket name -> measured post-reduction nnz (the
    adaptive telemetry window), overriding the uniform fill-in model."""
    p = p or plan.dp_total
    cfg = plan.cfg
    vb = cfg.qsgd_bits if cfg.qsgd_bits is not None else 32
    scattered = bool(getattr(plan, "scattered", False))
    out = []
    for g in plan.groups:
        for b in g.buckets:
            k = plan.bucket_k(g, b)
            nnz = None if densities is None else densities.get(b.name)
            out.append(bucket_time(b.algorithm, p, k, b.n, net, vb,
                                   reduced_nnz=nnz, scattered=scattered))
    return out


def exposed_bucket_times(t_buckets, t_overlap: float) -> list[float]:
    """Per-bucket EXPOSED comm time when the buckets drain back-to-back
    under ``t_overlap`` seconds of independent compute (the next step's
    forward/backward): a bucket fully hidden under compute costs 0, the
    bucket straddling the compute edge costs only its uncovered tail,
    every later bucket is fully exposed."""
    out, cum = [], 0.0
    for t in t_buckets:
        hidden = min(t, max(0.0, t_overlap - cum))
        out.append(t - hidden)
        cum += t
    return out


def t_step_overlapped(t_compute: float, t_buckets,
                      staleness: int = 1) -> float:
    """Modeled steady-state per-step time of the pipelined runtime.

    staleness=0 serializes compute with the whole bucket drain (the
    synchronous step); staleness>=1 runs the previous step's drain under
    this step's compute, paying only the exposed fraction — equivalently
    max(t_compute, sum(t_buckets)). Pipelined is never slower in this
    model: the exposed sum is <= the full drain."""
    if staleness == 0:
        return t_compute + sum(t_buckets)
    return t_compute + sum(exposed_bucket_times(t_buckets, t_compute))
