"""Alpha-beta cost model for the SparCML collectives (paper §5.3).

Used for (a) trace-time algorithm auto-selection, (b) the Fig.-3 style
benchmark, (c) property tests of the paper's bound ordering and of the
Lemma 5.2 speedup cap.

TPU v5e constants (per chip): ~50 GB/s per ICI link, ~1 us per-hop latency.
The model is deliberately the paper's: T(L) = alpha + beta * L.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .density import expected_nnz
from .sparse_stream import INDEX_BYTES, delta_threshold


@dataclass(frozen=True)
class NetworkParams:
    alpha: float = 1e-6            # seconds per message/hop
    link_bytes_per_s: float = 50e9  # ICI per-link bandwidth
    isize: int = 4                  # bytes per value (fp32)

    @property
    def beta_d(self) -> float:
        """Seconds per dense value word."""
        return self.isize / self.link_bytes_per_s

    @property
    def beta_s(self) -> float:
        """Seconds per sparse (index,value) item. beta_s > beta_d (paper §5.2)."""
        return (self.isize + INDEX_BYTES) / self.link_bytes_per_s


DEFAULT_NET = NetworkParams()


def t_dense_allreduce(p: int, n: int, net: NetworkParams = DEFAULT_NET) -> float:
    """Rabenseifner (paper §5.3.2): 2 log2(P) alpha + 2 (P-1)/P N beta_d."""
    return 2 * math.log2(p) * net.alpha + 2 * (p - 1) / p * n * net.beta_d


def t_ssar_recursive_double(
    p: int, k: int, n: int, net: NetworkParams = DEFAULT_NET,
    expected: bool = True, reduced_nnz: float | None = None,
) -> tuple[float, float, float]:
    """(lower, expected, upper) for SSAR_Recursive_double.

    lower: full index overlap (k items per round);
    upper: zero overlap (2^t k items in round t, sums to (P-1)k);
    expected: per-round fill-in from the uniform model (App. B), or — when
    ``reduced_nnz`` (a MEASURED final fill-in, adaptive telemetry) is given
    — the uniform per-round curve rescaled so it lands on the measurement.
    """
    lat = math.log2(p) * net.alpha
    lo = lat + math.log2(p) * k * net.beta_s
    hi = lat + (p - 1) * k * net.beta_s
    scale = 1.0
    if reduced_nnz is not None:
        uniform_final = expected_nnz(k, n, p)
        if uniform_final > 0:
            scale = reduced_nnz / uniform_final
    # Round t carries at most 2^t * k items (zero overlap) and at most n;
    # the measured rescale must respect both, or 'expected' could exceed
    # its own upper bound and over-penalize this algorithm in selection.
    exp_items = sum(
        min(expected_nnz(k, n, 2**t) * scale, (2**t) * k, n)
        for t in range(int(math.log2(p)))
    )
    exp = lat + exp_items * net.beta_s
    return lo, exp, hi


def t_ssar_split_allgather(
    p: int, k: int, n: int, net: NetworkParams = DEFAULT_NET,
    reduced_nnz: float | None = None,
) -> tuple[float, float, float]:
    """(lower, expected, upper) for SSAR_Split_allgather (paper §5.3.2).

    Latency L2 = (P-1) alpha + log2(P) alpha (direct split sends + allgather).
    Bandwidth between 2 (P-1)/P k beta_s and P k beta_s. ``reduced_nnz``
    replaces the uniform-model expected reduced size with a measurement.
    """
    lat = (p - 1) * net.alpha + math.log2(p) * net.alpha
    lo = lat + 2 * (p - 1) / p * k * net.beta_s
    hi = lat + p * k * net.beta_s
    kk = (reduced_nnz if reduced_nnz is not None
          else expected_nnz(k, n, p))  # reduced size: measured or expected
    exp = lat + ((p - 1) / p * k + (p - 1) / p * kk) * net.beta_s
    return lo, exp, hi


def t_dsar_split_allgather(
    p: int, k: int, n: int, net: NetworkParams = DEFAULT_NET, value_bits: int = 32
) -> tuple[float, float]:
    """(lower, upper) for DSAR_Split_allgather (paper §5.3.3).

    Split phase sparse; second phase dense allgather of N/P-shards, whose
    word size can shrink by quantization (paper §6) to value_bits.
    """
    lat = (p - 1) * net.alpha + math.log2(p) * net.alpha
    beta_q = net.beta_d * value_bits / (8 * net.isize)
    lo = lat + (p - 1) / p * n * beta_q
    hi = lat + k * net.beta_s + (p - 1) / p * n * beta_q
    return lo, hi


def t_stream_allgather(p: int, cap_rows: int, d: int,
                       net: NetworkParams = DEFAULT_NET) -> float:
    """Row-stream all-gather: the serve-side activation exchange
    (DESIGN.md §8). Every rank broadcasts a fixed-capacity stream of
    ``cap_rows`` (row index, d-vector) items — one item per active token
    routed to a local expert — and receives the other P-1 streams."""
    row_bytes = d * net.isize + INDEX_BYTES
    return (math.log2(p) * net.alpha
            + (p - 1) * cap_rows * row_bytes / net.link_bytes_per_s)


def stream_wire_bytes(p: int, cap_rows: int, d: int, isize: int = 4) -> float:
    """Per-rank wire bytes of one row-stream all-gather step (receive
    side: P-1 foreign streams of cap_rows rows). The ONE accounting the
    serve executor's telemetry and the ServePlan selection rule share —
    they must never diverge (same contract as :func:`pod_wire_bytes`)."""
    if p <= 1:
        return 0.0
    return (p - 1) * cap_rows * float(d * isize + INDEX_BYTES)


def parse_stream_cap(algorithm: str) -> int:
    """Row capacity of a ``stream_gather@<cap>`` serve algorithm tag (the
    capacity is part of the plan signature, so it rides the string)."""
    return int(algorithm.split("@", 1)[1])


def dsar_speedup_cap(n: int, isize: int = 4) -> float:
    """Lemma 5.2: once the result is dense, sparsity alone buys at most
    2/kappa versus a bandwidth-optimal dense allreduce, kappa = delta/N."""
    kappa = delta_threshold(n, isize) / n
    return 2.0 / kappa


ALL_ALGORITHMS = ("ssar_recursive_double", "ssar_split_allgather",
                  "dsar_split_allgather", "dense")


def select_algorithm(
    p: int,
    k: int,
    n: int,
    net: NetworkParams = DEFAULT_NET,
    value_bits: int = 32,
    allow: tuple = ALL_ALGORITHMS,
    reduced_nnz: float | None = None,
) -> str:
    """THE auto-selection entry point: pick the cheapest algorithm by
    expected alpha-beta cost (paper §5.3, DESIGN.md §3.3). ``k`` is the
    per-rank selected item count, ``n`` the vector's canonical length.

    Mirrors the paper's guidance: recursive doubling for small data
    (latency-bound), split_allgather for large sparse results, DSAR once
    the result exceeds the delta threshold. ``allow`` restricts the
    candidate set — the batched (model-sharded rows) pipeline only
    implements DSAR/dense, and the fusion planner passes that in.

    ``reduced_nnz`` closes the loop (DESIGN.md §7): a MEASURED
    post-reduction nnz (adaptive telemetry) replaces the uniform-model
    ``expected_nnz`` everywhere — both in the sparse-vs-dense delta
    decision and in the gather-phase cost terms — so fill-in growth and
    EF-residual densification feed back into the choice.
    """
    delta = delta_threshold(n, net.isize)
    exp_k = (reduced_nnz if reduced_nnz is not None
             else expected_nnz(k, n, p))
    candidates = {
        "ssar_recursive_double":
            t_ssar_recursive_double(p, k, n, net, reduced_nnz=reduced_nnz)[1],
        "ssar_split_allgather":
            t_ssar_split_allgather(p, k, n, net, reduced_nnz=reduced_nnz)[1],
        "dsar_split_allgather":
            sum(t_dsar_split_allgather(p, k, n, net, value_bits)) / 2,
    }
    if exp_k >= delta:
        # Sparse end-representation no longer pays (paper §5.3.3).
        candidates.pop("ssar_recursive_double")
        candidates.pop("ssar_split_allgather")
        candidates["dense"] = t_dense_allreduce(p, n, net)
    candidates = {a: t for a, t in candidates.items() if a in allow}
    if not candidates:  # everything filtered: dense always works
        return "dense"
    return min(candidates, key=candidates.get)


def select_bucket_algorithm(
    p: int,
    k: int,
    n: int,
    net: NetworkParams = DEFAULT_NET,
    value_bits: int = 32,
    allow: tuple = ALL_ALGORITHMS,
    reduced_nnz: float | None = None,
) -> str:
    """Per-fusion-bucket view of :func:`select_algorithm` (``k`` = the
    bucket's TOTAL selected items: rows x buckets-per-row x k_per_bucket,
    ``n`` its total canonical length). Thin wrapper — the one selection
    implementation lives in :func:`select_algorithm`."""
    return select_algorithm(p, k, n, net, value_bits, allow, reduced_nnz)


# ---------------------------------------------------------------------------
# Overlap-aware step costing (non-blocking runtime, DESIGN.md §6)
# ---------------------------------------------------------------------------

def bucket_time(algorithm: str, p: int, k: int, n: int,
                net: NetworkParams = DEFAULT_NET, value_bits: int = 32,
                reduced_nnz: float | None = None) -> float:
    """Expected collective time of ONE fusion bucket under its resolved
    algorithm (the per-bucket term the overlap model hides or exposes).
    ``reduced_nnz`` substitutes a measured post-reduction fill-in for the
    uniform model, exactly as in :func:`select_algorithm`.

    Serve-side activation buckets (DESIGN.md §8) use the
    ``stream_gather@<cap>`` algorithm family, where ``k`` is the ROW
    width (d) and the row capacity rides the tag: the cost is capacity-
    bound, not nnz-bound, because the stream ships at fixed cap."""
    if algorithm == "dense":
        return t_dense_allreduce(p, n, net)
    if algorithm.startswith("stream_gather"):
        return t_stream_allgather(p, parse_stream_cap(algorithm), k, net)
    if algorithm == "ssar_recursive_double":
        return t_ssar_recursive_double(p, k, n, net,
                                       reduced_nnz=reduced_nnz)[1]
    if algorithm == "ssar_split_allgather":
        return t_ssar_split_allgather(p, k, n, net,
                                      reduced_nnz=reduced_nnz)[1]
    if algorithm == "dsar_split_allgather":
        return sum(t_dsar_split_allgather(p, k, n, net, value_bits)) / 2
    raise ValueError(f"unknown algorithm {algorithm!r}")


def bucket_wire_bytes(algorithm: str, p: int, k: int, n: int,
                      nnz=None, value_bits: int = 32, isize: int = 4):
    """Per-rank data-axis wire bytes of one bucket for one step. Pure
    arithmetic in ``nnz`` (a traced scalar inside the telemetry emitter,
    or a float on the host), so the executor can report measured wire
    volume in-graph. ``nnz`` defaults to the worst case (p*k)."""
    item = isize + INDEX_BYTES
    if algorithm == "dense":
        # compressed-dense end-representation OR raw psum: one dense
        # allreduce of the n-vector (Rabenseifner accounting).
        return 2 * (p - 1) / p * n * isize
    if algorithm.startswith("stream_gather"):
        # serve activation exchange: capacity-bound, k is the row width
        return stream_wire_bytes(p, parse_stream_cap(algorithm), k, isize)
    if nnz is None:
        nnz = float(min(n, p * k))
    if algorithm == "ssar_recursive_double":
        # log2(P) rounds; round t carries ~fill-in-many items. Charged at
        # the measured final fill per round (upper-bounds early rounds).
        return math.log2(p) * nnz * item
    if algorithm == "ssar_split_allgather":
        return (p - 1) / p * k * item + (p - 1) / p * nnz * item
    if algorithm == "dsar_split_allgather":
        # value_bits < 32 also adds one fp32 scale per QSGD bucket; the
        # exact figure lives in plan.wire_bytes — telemetry keeps the
        # dominant terms only.
        return (p - 1) / p * k * item + (p - 1) / p * n * value_bits / 8
    raise ValueError(f"unknown algorithm {algorithm!r}")


def pod_wire_bytes(p_pod: int, n: int, cap: int,
                   pod_sparse: bool = False, isize: int = 4) -> float:
    """Per-rank CROSS-POD wire bytes of one bucket: the dense psum
    (Rabenseifner accounting) or the sparse (idx,val) stream exchange of
    ``pod_sparse`` buckets at stream capacity ``cap`` (DESIGN.md §7.2).
    The ONE accounting both the executor's telemetry and the adaptive
    controller's demotion rule use — they must never diverge."""
    if p_pod <= 1:
        return 0.0
    if pod_sparse:
        return p_pod * cap * float(isize + INDEX_BYTES)
    return 2.0 * (p_pod - 1) / p_pod * n * isize


def plan_bucket_times(plan, p: int | None = None,
                      net: NetworkParams = DEFAULT_NET,
                      densities: dict | None = None) -> list[float]:
    """Expected per-bucket collective times for a comm ``SyncPlan`` (duck-
    typed — importing repro.comm here would cycle), in plan order: the
    drain sequence the pipelined superstep overlaps with compute.
    ``densities`` maps bucket name -> measured post-reduction nnz (the
    adaptive telemetry window), overriding the uniform fill-in model."""
    p = p or plan.dp_total
    cfg = plan.cfg
    vb = cfg.qsgd_bits if cfg.qsgd_bits is not None else 32
    out = []
    for g in plan.groups:
        for b in g.buckets:
            k = plan.bucket_k(g, b)
            nnz = None if densities is None else densities.get(b.name)
            out.append(bucket_time(b.algorithm, p, k, b.n, net, vb,
                                   reduced_nnz=nnz))
    return out


def exposed_bucket_times(t_buckets, t_overlap: float) -> list[float]:
    """Per-bucket EXPOSED comm time when the buckets drain back-to-back
    under ``t_overlap`` seconds of independent compute (the next step's
    forward/backward): a bucket fully hidden under compute costs 0, the
    bucket straddling the compute edge costs only its uncovered tail,
    every later bucket is fully exposed."""
    out, cum = [], 0.0
    for t in t_buckets:
        hidden = min(t, max(0.0, t_overlap - cum))
        out.append(t - hidden)
        cum += t
    return out


def t_step_overlapped(t_compute: float, t_buckets,
                      staleness: int = 1) -> float:
    """Modeled steady-state per-step time of the pipelined runtime.

    staleness=0 serializes compute with the whole bucket drain (the
    synchronous step); staleness>=1 runs the previous step's drain under
    this step's compute, paying only the exposed fraction — equivalently
    max(t_compute, sum(t_buckets)). Pipelined is never slower in this
    model: the exposed sum is <= the full drain."""
    if staleness == 0:
        return t_compute + sum(t_buckets)
    return t_compute + sum(exposed_bucket_times(t_buckets, t_compute))
