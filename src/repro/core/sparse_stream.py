"""Sparse streams (paper §5.1), adapted to XLA's static-shape world.

A stream stores up to ``cap`` (index, value) pairs plus an explicit ``nnz``
count. Padding slots carry ``idx == SENTINEL`` (sorts after every valid
index) and ``val == 0`` (the neutral element of SUM, per paper §5.2).

The paper's sparse->dense switch at threshold delta = N*isize/(c+isize)
is a *trace-time* decision here (see DESIGN.md §2.1): capacities follow the
same |H1|+|H2| upper bound the paper uses at runtime.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Largest i32; sorts after any valid index (valid indices < N < 2**31).
SENTINEL = jnp.iinfo(jnp.int32).max

INDEX_BYTES = 4  # paper §8: "we fix the datatype for storing an index to an unsigned int"


class SparseStream(NamedTuple):
    """Fixed-capacity sparse vector: idx i32[cap], val dtype[cap], nnz i32[]."""

    idx: jax.Array
    val: jax.Array
    nnz: jax.Array

    @property
    def capacity(self) -> int:
        return self.idx.shape[-1]


def empty(cap: int, dtype=jnp.float32) -> SparseStream:
    return SparseStream(
        idx=jnp.full((cap,), SENTINEL, jnp.int32),
        val=jnp.zeros((cap,), dtype),
        nnz=jnp.zeros((), jnp.int32),
    )


def delta_threshold(n: int, isize: int = 4, index_bytes: int = INDEX_BYTES) -> int:
    """Paper §5.1: sparse format wins while nnz <= delta = N*isize/(c+isize)."""
    return (n * isize) // (index_bytes + isize)


def from_dense_topk(x: jax.Array, k: int) -> SparseStream:
    """Global (non-bucketed) top-|k| magnitude selection -> sorted stream."""
    (n,) = x.shape
    k = min(k, n)
    mag = jnp.abs(x)
    _, top_idx = jax.lax.top_k(mag, k)
    top_idx = jnp.sort(top_idx)
    return SparseStream(
        idx=top_idx.astype(jnp.int32),
        val=x[top_idx],
        nnz=jnp.asarray(k, jnp.int32),
    )


def from_mask(x: jax.Array, mask: jax.Array, cap: int) -> SparseStream:
    """Compact masked entries of ``x`` into a sorted stream of capacity cap.

    Entries where mask is False are dropped. If popcount(mask) > cap the
    largest-index extras are dropped (callers size cap so this cannot occur).
    """
    (n,) = x.shape
    idx = jnp.where(mask, jnp.arange(n, dtype=jnp.int32), SENTINEL)
    val = jnp.where(mask, x, 0)
    # Stable two-operand sort: padding (SENTINEL) moves to the back.
    idx_s, val_s = jax.lax.sort((idx, val), num_keys=1)
    return SparseStream(
        idx=idx_s[:cap],
        val=val_s[:cap],
        nnz=jnp.minimum(jnp.sum(mask).astype(jnp.int32), cap),
    )


def densify(s: SparseStream, n: int) -> jax.Array:
    """Scatter-add the stream into a dense length-n vector.

    Padding (idx == SENTINEL) is out of bounds and dropped by mode='drop'.
    The Pallas `bucket_scatter` kernel is the TPU-optimized variant for
    bucket-uniform streams; this is the general path.
    """
    out = jnp.zeros((n,), s.val.dtype)
    return out.at[s.idx].add(s.val, mode="drop")


def merge(a: SparseStream, b: SparseStream, cap_out: int) -> SparseStream:
    """Sum two streams ("efficient summation", paper §5.1).

    concat -> bitonic sort by index -> combine duplicate indices by
    segment-add -> compact to cap_out. Duplicate combining follows the
    classic sorted-run trick: head flags + cumsum positions + scatter.
    """
    idx = jnp.concatenate([a.idx, b.idx])
    val = jnp.concatenate([a.val, b.val])
    idx, val = jax.lax.sort((idx, val), num_keys=1)
    prev = jnp.concatenate([jnp.full((1,), -1, idx.dtype), idx[:-1]])
    head = idx != prev
    pos = jnp.cumsum(head) - 1  # group id for each element
    out_idx = jnp.full((cap_out,), SENTINEL, jnp.int32)
    out_val = jnp.zeros((cap_out,), val.dtype)
    valid = idx != SENTINEL
    out_idx = out_idx.at[jnp.where(valid, pos, cap_out)].set(idx, mode="drop")
    out_val = out_val.at[jnp.where(valid, pos, cap_out)].add(
        jnp.where(valid, val, 0), mode="drop"
    )
    nnz = jnp.sum(head & valid).astype(jnp.int32)
    return SparseStream(out_idx, out_val, jnp.minimum(nnz, cap_out))


def concat(streams: list[SparseStream], cap_out: int | None = None) -> SparseStream:
    """Concatenate streams with *disjoint* index ranges (paper §5.1: the sum
    of dimension-partitioned vectors is plain concatenation).

    A ``cap_out`` below the true union size keeps the cap_out smallest
    indices (sort moves padding behind every valid entry) and the ``nnz``
    count saturates at the capacity — the same overflow contract as
    :func:`merge`. Callers size capacities from the |H1|+|H2| bound so
    overflow cannot occur on the collective paths."""
    idx = jnp.concatenate([s.idx for s in streams])
    val = jnp.concatenate([s.val for s in streams])
    nnz = sum(s.nnz for s in streams)
    if cap_out is not None and cap_out != idx.shape[0]:
        idx, val = jax.lax.sort((idx, val), num_keys=1)
        idx, val = idx[:cap_out], val[:cap_out]
        nnz = jnp.minimum(jnp.asarray(nnz, jnp.int32), cap_out)
    return SparseStream(idx, val, jnp.asarray(nnz, jnp.int32))


class RowStream(NamedTuple):
    """Fixed-capacity ROW-sparse matrix: up to ``cap`` (row index, row
    vector) pairs of a (T, d) buffer. The serve-side activation exchange
    (DESIGN.md §8) ships whole token rows — an (idx, val) stream whose
    value is a d-vector — because MoE combine partials are row-sparse:
    a token row is nonzero only where the token routed to a local expert.
    Padding rows carry ``idx == SENTINEL`` and all-zero vectors."""

    idx: jax.Array                 # i32[cap]
    val: jax.Array                 # dtype[cap, d]
    nnz: jax.Array                 # i32[]

    @property
    def capacity(self) -> int:
        return self.idx.shape[-1]


def from_row_mask(x: jax.Array, mask: jax.Array, cap: int) -> RowStream:
    """Compact the masked ROWS of ``x`` (T, d) into a RowStream.

    Rows where mask is False are dropped. Exactness contract: when
    popcount(mask) <= cap AND every unmasked row of ``x`` is all-zero,
    ``densify_rows`` inverts this bit-for-bit (the serve engine's
    occupancy guard enforces the capacity side)."""
    t = x.shape[0]
    idx = jnp.where(mask, jnp.arange(t, dtype=jnp.int32), SENTINEL)
    order = jnp.argsort(idx)            # valid rows first, index-ascending
    idx_s = idx[order][:cap]
    val_s = jnp.where((idx_s != SENTINEL)[:, None], x[order][:cap], 0)
    return RowStream(
        idx=idx_s, val=val_s,
        nnz=jnp.minimum(jnp.sum(mask).astype(jnp.int32), cap))


def densify_rows(s: RowStream, t: int) -> jax.Array:
    """Scatter the row stream back into a dense (t, d) buffer. Padding
    rows (idx == SENTINEL) are out of bounds and dropped; valid row
    indices are unique within a stream, so the scatter-add is a set."""
    out = jnp.zeros((t,) + s.val.shape[1:], s.val.dtype)
    return out.at[s.idx].add(s.val, mode="drop")


def pad_to(s: SparseStream, cap: int) -> SparseStream:
    """Grow capacity (padding stays at the back because streams are sorted)."""
    if cap == s.capacity:
        return s
    if cap < s.capacity:
        raise ValueError(f"cannot shrink stream {s.capacity} -> {cap}")
    extra = cap - s.capacity
    return SparseStream(
        idx=jnp.concatenate([s.idx, jnp.full((extra,), SENTINEL, jnp.int32)]),
        val=jnp.concatenate([s.val, jnp.zeros((extra,), s.val.dtype)]),
        nnz=s.nnz,
    )


def round_up_pow2(x: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(1, x))))
