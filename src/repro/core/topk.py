"""Bucketed TopK sparsification with error feedback (paper Alg. 2, §8.3).

The paper selects k entries out of every bucket of 512 consecutive gradient
values ("For CIFAR-10 we select k = 8 and 16 entries from every bucket of
512"). Bucketing has a crucial systems property we exploit throughout
(DESIGN.md §2.1): per-index-range counts are EXACTLY uniform, so the
all_to_all split phase of the allreduce needs no dynamic message sizes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.bucket_topk.ops import bucket_topk
from repro.kernels.bucket_scatter.ops import bucket_scatter
from repro.core.sparse_stream import SparseStream


def _topk_lowers_everywhere() -> bool:
    """lax.top_k is the fast path; the pinned old-JAX XLA-CPU build
    aborts on its partitioner rule in partial-manual regions (and
    compress2d cannot know its lowering context), so that build takes
    the argsort fallback globally."""
    from repro import compat

    return compat.HAS_JAX_SHARD_MAP or jax.default_backend() != "cpu"


class UniformStream(NamedTuple):
    """A bucket-uniform sparse vector: exactly k entries per B-wide bucket.

    lidx: (nb, k) int32, ascending within bucket, values in [0, B)
    val:  (nb, k)
    Global index of entry (r, j) = r * B + lidx[r, j]; total length nb * B.
    """

    lidx: jax.Array
    val: jax.Array
    bucket_size: int

    @property
    def num_buckets(self) -> int:
        return self.lidx.shape[0]

    @property
    def k(self) -> int:
        return self.lidx.shape[1]

    @property
    def n(self) -> int:
        return self.num_buckets * self.bucket_size

    @property
    def nnz(self) -> int:
        return self.lidx.shape[0] * self.lidx.shape[1]

    def to_stream(self) -> SparseStream:
        """Flat global-index stream (sorted: buckets are contiguous)."""
        nb, k = self.lidx.shape
        gidx = (jnp.arange(nb, dtype=jnp.int32)[:, None] * self.bucket_size
                + self.lidx)
        return SparseStream(
            idx=gidx.reshape(-1),
            val=self.val.reshape(-1),
            nnz=jnp.asarray(nb * k, jnp.int32),
        )

    def densify(self, impl: str = "auto") -> jax.Array:
        return bucket_scatter(self.lidx, self.val, self.bucket_size, impl=impl).reshape(-1)


def compress(
    x: jax.Array, k_per_bucket: int, bucket_size: int = 512, impl: str = "auto"
) -> tuple[UniformStream, jax.Array]:
    """TopK-compress a flat vector. Returns (stream, residual).

    x is zero-padded up to a bucket multiple; padding positions always lose
    the top-k race only if real values beat them (zeros may be selected in
    degenerate all-zero buckets — harmless: their value is 0).
    residual = x - densify(stream) restricted to the original length.
    """
    (n,) = x.shape
    nb = -(-n // bucket_size)
    pad = nb * bucket_size - n
    xp = jnp.pad(x, (0, pad)) if pad else x
    val, lidx, res = bucket_topk(xp.reshape(nb, bucket_size), k_per_bucket, impl=impl)
    stream = UniformStream(lidx, val, bucket_size)
    residual = res.reshape(-1)[:n]
    return stream, residual


class BatchedStream(NamedTuple):
    """Bucket-uniform stream with leading batch axes that are NEVER
    reshaped away — so a 'model'-sharded canonical row axis (and, in the
    auto-SPMD fallback, a leading replica axis) rides through compression
    and the data-axis collectives untouched (flattening it forced a
    full-gradient all-gather over TP; found via dry-run HLO).

    lidx/val: (*lead, m, k) — lead batch dims (sharded ok), m buckets each.
    """

    lidx: jax.Array
    val: jax.Array
    bucket_size: int

    @property
    def k(self) -> int:
        return self.lidx.shape[-1]

    def densify(self) -> jax.Array:
        """(*lead, m*B) via batched one-hot contraction (k small)."""
        *lead, m, k = self.lidx.shape
        b = self.bucket_size
        iota = jnp.arange(b, dtype=jnp.int32)
        onehot = (self.lidx[..., None] == iota).astype(self.val.dtype)
        dense = jnp.einsum("...mkb,...mk->...mb", onehot, self.val)
        return dense.reshape(*lead, m * b)


def compress2d(
    x: jax.Array, k_per_bucket: int, bucket_size: int = 512
) -> tuple[BatchedStream, jax.Array]:
    """Batched TopK compression of a canonical (*lead, cols) layout.

    Pure batched-jnp (sort/take_along_axis operate on the last axis
    only — the leading dims are never merged or split), so every leading
    axis keeps whatever sharding it has. Returns
    (stream, residual (*lead, cols))."""
    *lead, cols = x.shape
    b = bucket_size
    assert cols % b == 0, (x.shape, b)
    m = cols // b
    xb = x.reshape(*lead, m, b)
    mag = jnp.abs(xb)
    if _topk_lowers_everywhere():
        _, order = jax.lax.top_k(mag, k_per_bucket)          # (*lead, m, k)
    else:
        # Stable argsort fallback: identical selection (ties go to the
        # lower index, same as top_k), but top_k's partitioner rule
        # aborts in partial-manual regions on the pinned XLA-CPU build
        # while sort lowers fine everywhere (DESIGN.md §5.2). O(B log B)
        # vs O(B) — paid only on the correctness backend.
        order = jnp.argsort(-mag, axis=-1)[..., :k_per_bucket]
    lidx = jnp.sort(order, axis=-1).astype(jnp.int32)
    val = jnp.take_along_axis(xb, lidx, axis=-1)
    iota = jnp.arange(b, dtype=jnp.int32)
    sel = jnp.any(lidx[..., None] == iota, axis=-2)          # (*lead, m, b)
    residual = jnp.where(sel, 0, xb).reshape(*lead, cols)
    return BatchedStream(lidx, val, b), residual
