"""Continuous-batching request scheduler (DESIGN.md §8.1).

Pure host-side bookkeeping — no device code, no model knowledge. The
decode engine (serve/sparse_decode.py) asks three questions each step:

  admit_ready()     which waiting requests go into which free slots NOW
                    (FIFO by arrival; ragged prompt lengths are the
                    engine's problem — admission is per-request prefill)
  record(slot, tok) one decoded token landed in a slot; retire the slot
                    when the token is the EOS id (early-EOS retirement)
                    or the request's own max_new_tokens is reached
  advance()/skip()  move the step clock (skip fast-forwards an idle
                    engine to the next arrival instead of spinning)

The clock is counted in DECODE STEPS, not seconds: arrivals are given in
step units so runs are exactly reproducible and independent of host
speed. ``poisson_trace`` generates such arrivals from a seeded Poisson
process (exponential inter-arrival gaps at a given rate per step).

Per-request LIFECYCLE (DESIGN.md §10): admission and retirement stamp a
``lifecycle`` record per rid — arrival, admit clock, prompt length,
retire clock, emitted tokens — and :meth:`latency_stats` reduces those to
the serve latency distributions (queue delay, TTFT, TPOT, end-to-end),
all in the same deterministic step units, so percentiles over a fixed
Poisson trace are exactly reproducible.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Request:
    """One generation request. ``arrival`` is in decode-step units."""

    rid: int
    prompt: np.ndarray                 # (S,) int32 token ids
    max_new_tokens: int
    arrival: float = 0.0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size >= 1, "empty prompt"
        assert self.max_new_tokens >= 1


@dataclass
class Slot:
    """One occupied decode slot (engine-facing view)."""

    rid: int
    next_token: int                    # token the next decode step consumes
    emitted: list = field(default_factory=list)
    max_new: int = 0


@dataclass(frozen=True)
class ServeConfig:
    """Operator-declared serving objectives (DESIGN.md §10.5).

    SLO targets are p99 bounds in the scheduler's deterministic
    DECODE-STEP units (the same units :meth:`ContinuousScheduler.
    latency_stats` reports), so attainment over a fixed Poisson trace is
    exactly reproducible. ``None`` leaves a dimension untargeted. The
    engine emits the declared targets as a ``serve/slo_targets`` event
    at end of run and hands them to the health engine
    (:class:`repro.obs.health.HealthMonitor`), which turns misses into
    severity-ranked ``health/serve_slo`` events; ``repro.obs.report``
    renders the attainment table from both."""

    slo_ttft_p99: Optional[float] = None         # admission -> first token
    slo_tpot_p99: Optional[float] = None         # steps per output token
    slo_queue_delay_p99: Optional[float] = None  # arrival -> admission
    slo_e2e_p99: Optional[float] = None          # arrival -> retirement
    # graceful degradation under overload (DESIGN.md §12.5): bound on
    # ARRIVED-but-unadmitted waiters (newest shed first when crossed),
    # and the queue-wait deadline in decode steps past which a request
    # is shed instead of admitted. Shedding is OPT-IN: slo_* targets
    # alone are monitoring declarations (missed targets become health
    # verdicts, DESIGN.md §10.5), never an admission policy. Once
    # shedding is enabled — a ``queue_limit`` or an explicit
    # ``shed_deadline`` — the deadline falls back to ``slo_ttft_p99``:
    # in this scheduler TTFT == queue delay, so an overdue request is
    # provably going to miss its TTFT target.
    queue_limit: Optional[int] = None
    shed_deadline: Optional[float] = None

    def effective_shed_deadline(self) -> Optional[float]:
        """The queue-wait bound shedding enforces: the explicit
        ``shed_deadline`` when set; the declared TTFT target when
        shedding was enabled via ``queue_limit``; None (shedding off)
        when neither degradation knob was touched."""
        if self.shed_deadline is not None:
            return float(self.shed_deadline)
        if self.queue_limit is None or self.slo_ttft_p99 is None:
            return None
        return float(self.slo_ttft_p99)

    def slo_targets(self) -> dict:
        """{latency key -> target}, omitting untargeted dimensions —
        the mapping HealthMonitor(serve_slo=...) consumes."""
        pairs = {"ttft": self.slo_ttft_p99, "tpot": self.slo_tpot_p99,
                 "queue_delay": self.slo_queue_delay_p99,
                 "e2e": self.slo_e2e_p99}
        return {k: float(v) for k, v in pairs.items() if v is not None}


def poisson_trace(n: int, rate: float, seed: int = 0,
                  start: float = 0.0) -> np.ndarray:
    """n Poisson arrival times (decode-step units) at ``rate`` requests
    per step: cumulative sum of seeded exponential gaps."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=n)
    return start + np.cumsum(gaps)


class ContinuousScheduler:
    """Slot lifecycle over a fixed pool of ``num_slots`` decode slots.

    Requests wait in arrival order; a request is admissible once the
    step clock has passed its arrival AND a slot is free. Retirement
    frees the slot the same step, so the next waiting request can be
    admitted at the following boundary (continuous batching)."""

    def __init__(self, num_slots: int, requests: list[Request],
                 eos_id: Optional[int] = None):
        self.num_slots = int(num_slots)
        self.eos_id = eos_id
        self.waiting: deque[Request] = deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))
        self.slots: list[Optional[Slot]] = [None] * self.num_slots
        self.clock = 0.0
        self.completed: dict[int, np.ndarray] = {}
        self.retirements: list[tuple[float, int]] = []   # (clock, rid)
        self.shed: dict[int, str] = {}                   # rid -> reason
        # rid -> {arrival, admit, prompt_len, retire, tokens} (step units)
        self.lifecycle: dict[int, dict] = {
            r.rid: {"arrival": float(r.arrival), "admit": None,
                    "prompt_len": int(r.prompt.size), "retire": None,
                    "tokens": 0, "shed": None}
            for r in requests}

    # -- state queries -----------------------------------------------------
    @property
    def active_count(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def active_mask(self) -> np.ndarray:
        return np.array([s is not None for s in self.slots], bool)

    @property
    def done(self) -> bool:
        return not self.waiting and self.active_count == 0

    def slot(self, i: int) -> Optional[Slot]:
        return self.slots[i]

    # -- admission ---------------------------------------------------------
    def admit_ready(self) -> list[tuple[int, Request]]:
        """(slot index, request) pairs to admit at this step boundary:
        FIFO over arrived requests, lowest free slot first. The caller
        (the engine) prefills each and then calls :meth:`install`."""
        out = []
        free = [i for i, s in enumerate(self.slots) if s is None]
        while free and self.waiting and self.waiting[0].arrival <= self.clock:
            out.append((free.pop(0), self.waiting.popleft()))
        return out

    def install(self, slot_idx: int, req: Request, first_token: int) -> bool:
        """Occupy a slot with a freshly prefilled request. The prefill's
        argmax IS the first emitted token (exactly as ServeEngine.generate
        counts it); a 1-token request (or an immediate EOS) retires on
        the spot. Returns True when the slot retired immediately."""
        assert self.slots[slot_idx] is None, slot_idx
        self.slots[slot_idx] = Slot(rid=req.rid, next_token=int(first_token),
                                    max_new=req.max_new_tokens)
        self.lifecycle[req.rid]["admit"] = self.clock
        return self.record(slot_idx, int(first_token))

    # -- load shedding (DESIGN.md §12.5) -----------------------------------
    def _shed(self, req: Request, reason: str) -> None:
        self.shed[req.rid] = reason
        lc = self.lifecycle[req.rid]
        lc["shed"] = self.clock
        lc["shed_reason"] = reason

    def shed_overdue(self, deadline: float) -> list[int]:
        """Shed every arrived-but-unadmitted request whose queue wait
        exceeds ``deadline`` steps. TTFT == queue delay here, so such a
        request has already lost its TTFT budget — rejecting it fast is
        strictly better than serving a guaranteed SLO miss. Returns the
        shed rids (FIFO order)."""
        out, keep = [], deque()
        while self.waiting:
            r = self.waiting.popleft()
            if r.arrival <= self.clock and self.clock - r.arrival > deadline:
                self._shed(r, "deadline")
                out.append(r.rid)
            else:
                keep.append(r)
        self.waiting = keep
        return out

    def shed_overflow(self, limit: int) -> list[int]:
        """Bounded admission queue: keep the oldest ``limit`` ARRIVED
        waiters, shed the newest beyond the bound (future arrivals in
        the trace don't count against it). Returns the shed rids."""
        arrived = [r for r in self.waiting if r.arrival <= self.clock]
        excess = len(arrived) - int(limit)
        if excess <= 0:
            return []
        victims = {r.rid for r in arrived[len(arrived) - excess:]}
        out, keep = [], deque()
        while self.waiting:
            r = self.waiting.popleft()
            if r.rid in victims:
                self._shed(r, "queue_full")
                out.append(r.rid)
            else:
                keep.append(r)
        self.waiting = keep
        return out

    # -- decode-step bookkeeping -------------------------------------------
    def record(self, slot_idx: int, token: int) -> bool:
        """One emitted token for an occupied slot; retires the slot on
        EOS or when max_new_tokens is reached. Returns True on retire."""
        s = self.slots[slot_idx]
        assert s is not None, slot_idx
        s.emitted.append(int(token))
        s.next_token = int(token)
        self.lifecycle[s.rid]["tokens"] = len(s.emitted)
        if (self.eos_id is not None and token == self.eos_id) \
                or len(s.emitted) >= s.max_new:
            self.completed[s.rid] = np.asarray(s.emitted, np.int32)
            self.retirements.append((self.clock, s.rid))
            self.lifecycle[s.rid]["retire"] = self.clock
            self.slots[slot_idx] = None
            return True
        return False

    def advance(self) -> None:
        self.clock += 1.0

    # -- latency distributions ---------------------------------------------
    def latency_stats(self) -> dict[str, np.ndarray]:
        """Per-retired-request latency arrays in DECODE-STEP units, one
        entry per completed rid (sorted), deterministic on a fixed trace:

          queue_delay  admit clock - arrival (waiting for a free slot)
          ttft         time to first token == queue_delay: the prefill's
                       argmax IS the first emitted token, landed at the
                       admission boundary (see :meth:`install`)
          tpot         (retire - admit) / (tokens - 1): per-token time of
                       the decode phase (0 for 1-token requests)
          e2e          retire clock - arrival

        Convert to seconds by multiplying with a measured step wall time
        (the engine reports ``wall_s / decode_steps``)."""
        done = sorted(rid for rid, lc in self.lifecycle.items()
                      if lc["retire"] is not None)
        q, tpot, e2e, toks = [], [], [], []
        for rid in done:
            lc = self.lifecycle[rid]
            q.append(lc["admit"] - lc["arrival"])
            n = max(1, lc["tokens"])
            tpot.append((lc["retire"] - lc["admit"]) / max(1, n - 1))
            e2e.append(lc["retire"] - lc["arrival"])
            toks.append(n)
        return {
            "rids": np.asarray(done, np.int64),
            "queue_delay": np.asarray(q, np.float64),
            "ttft": np.asarray(q, np.float64),
            "tpot": np.asarray(tpot, np.float64),
            "e2e": np.asarray(e2e, np.float64),
            "tokens": np.asarray(toks, np.int64),
        }

    def skip_to_next_arrival(self) -> None:
        """Idle engine (no active slots, nothing admissible): jump the
        clock to the next arrival instead of decoding empty batches."""
        if self.waiting:
            self.clock = max(self.clock, self.waiting[0].arrival)


def truncate_at_eos(tokens: np.ndarray, eos_id: Optional[int]) -> np.ndarray:
    """Reference-side helper: cut a greedy decode at (and including) the
    first EOS — what early-EOS retirement makes the scheduler emit."""
    tokens = np.asarray(tokens)
    if eos_id is None:
        return tokens
    hits = np.nonzero(tokens == eos_id)[0]
    return tokens[: hits[0] + 1] if hits.size else tokens
