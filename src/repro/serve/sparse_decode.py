"""Slot-based decode engine with plan-driven sparse expert dispatch
(DESIGN.md §8).

``build_slot_decode_step`` compiles ONE decode superstep for a fixed
(batch slots, cache length, ServePlan signature) triple: per-slot
positions (each request at its own depth), active-slot masking, and —
for MoE families — the combine exchange lowered through the comm plan
(``exchange_activation_spmd``: dense psum reference or the (idx,val)
row-stream wire, bit-identical while under stream capacity).

``ContinuousServeEngine`` is the host loop: a ContinuousScheduler admits
ragged prompts into free slots (per-request prefill inserted into the
slot's cache rows), every step decodes one token for all active slots,
early-EOS/maxed slots retire and free their slot, and — in adaptive
dispatch mode — the step's telemetry ([active-token nnz, wire bytes],
same shape as the training executor's) feeds the PR-3
``AdaptiveController``; accepted replans swap the compiled decode step
via the signature-keyed cache at step barriers, and the occupancy guard
force-demotes a stream plan whose capacity the admitted batch just
crossed (correctness rule, bypasses hysteresis/patience).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm.plan import ServePlan, build_serve_plan
from repro.core.cost_model import DEFAULT_NET, NetworkParams
from repro.models.model import Model
from repro.models.moe import ServeDispatch
from repro.models.specs import param_specs
from repro.obs import resolve as _resolve_obs
from repro.runtime.adapt import AdaptConfig, AdaptiveRuntime
from repro.runtime.faults import FaultInjectionError
from repro.serve.engine import _div, _logit_spec, _sh, decode_state_specs
from repro.serve.scheduler import ContinuousScheduler, Request
from repro.train.train_step import dp_axes_of, dp_total_of


# --------------------------------------------------------------------------
# Compiled slot decode step
# --------------------------------------------------------------------------

def build_slot_decode_step(model: Model, mesh: Mesh,
                           plan: Optional[ServePlan],
                           batch_size: int, cache_len: int,
                           shardings: Optional[tuple] = None):
    """Jitted fn(params, state, tokens, active) -> (logits, state', telem).

    ``state.pos`` is the (B,) per-slot position vector; ``active`` the
    (B,) live-slot mask. ``plan`` (MoE families) pins the combine
    exchange's wire representation — the plan SIGNATURE is the compile
    key, so each accepted replan is its own cached program. ``telem``
    maps the activation bucket to a (2,) f32 [active nnz, modeled wire
    bytes] vector, the exact shape the adaptive controller consumes.
    ``shardings``: precomputed (param, state) NamedSharding trees — the
    engine passes its own so plan swaps don't re-derive specs."""
    cfg = model.cfg
    sh = _sh(mesh)
    if shardings is not None:
        param_sh, state_sh = shardings
    else:
        param_sh = sh(param_specs(
            jax.eval_shape(model.init, jax.random.PRNGKey(0)), cfg, None))
        state_sh = sh(decode_state_specs(model, mesh, batch_size, cache_len))
    dp = dp_axes_of(mesh) if _div(batch_size, dp_total_of(mesh)) else None
    p_model = mesh.shape["model"]

    if plan is not None:
        bucket = plan.buckets[0]
        algorithm, bname = bucket.algorithm, bucket.name
        wire = plan.wire_bytes()

    def step(params, state, tokens, active):
        md = None
        if plan is not None:
            from repro.comm.executor import exchange_activation_spmd

            md = ServeDispatch(
                active=active,
                exchange=lambda parts: exchange_activation_spmd(
                    parts, algorithm),
                p_shards=p_model)
        logits, st = model.decode_step(params, state, tokens, moe_serve=md)
        telem = {}
        if plan is not None:
            nnz = jnp.sum(active).astype(jnp.float32)
            telem[bname] = jnp.stack(
                [nnz, jnp.asarray(wire, jnp.float32)])
        return logits, st, telem

    telem_sh = {plan.buckets[0].name: NamedSharding(mesh, P())} \
        if plan is not None else {}
    return jax.jit(
        step,
        in_shardings=(param_sh, state_sh,
                      NamedSharding(mesh, P(dp, None)),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, _logit_spec(cfg, mesh, batch_size)),
                       state_sh, telem_sh),
        donate_argnums=(1,),
    )


def insert_slot_state(cfg, state, sub, slot_idx):
    """Write a B=1 prefill's caches into slot ``slot_idx`` (static or
    traced) of the batch decode state (and its per-slot position). The
    slot's previous content — a retired request's garbage — is fully
    overwritten; nothing else moves. The batch axis sits at axis 1 of
    every stacked cache for the supported families (vlm's nested
    self-attn cache would sit at 2)."""
    if cfg.family == "vlm":
        raise NotImplementedError("continuous batching: vlm caches")

    def ins(dst, src):
        return dst if dst is None else dst.at[:, slot_idx].set(src[:, 0])

    new = {}
    for name in ("kv", "cross_kv", "conv", "ssm"):
        dst, src = getattr(state, name), getattr(sub, name)
        new[name] = jax.tree.map(ins, dst, src) if dst is not None else None
    pos = state.pos.at[slot_idx].set(sub.pos.astype(jnp.int32))
    return state._replace(pos=pos, **new)


# --------------------------------------------------------------------------
# The continuous-batching engine
# --------------------------------------------------------------------------

@dataclass
class ServeResult:
    """What one ``ContinuousServeEngine.run`` produced."""

    outputs: dict                      # rid -> np.int32 emitted tokens
    decode_steps: int = 0
    tokens: int = 0                    # total emitted (incl. prefill argmax)
    wall_s: float = 0.0
    wire_bytes: float = 0.0            # modeled per-rank dispatch bytes, total
    swap_log: list = field(default_factory=list)
    step_log: list = field(default_factory=list)
    # per-retired-request latency percentiles in DECODE-STEP units
    # (deterministic on a fixed trace) — {metric: {p50, p90, p99, mean}}
    latency: dict = field(default_factory=dict)
    # HealthEvent verdicts from the end-of-run SLO evaluation (empty
    # without a ServeConfig carrying targets, or with metrics off),
    # plus the backpressure verdict whenever requests were shed
    health: list = field(default_factory=list)
    # rid -> reason for requests load-shed instead of served
    # (DESIGN.md §12.5); disjoint from ``outputs``
    shed: dict = field(default_factory=dict)

    @property
    def tok_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s > 0 else 0.0


class ContinuousServeEngine:
    """Continuous-batching greedy decoding over ``batch_size`` slots.

    dispatch='dense'     the exact reference: every step's MoE combine
                         is the dense psum, whatever the occupancy.
    dispatch='adaptive'  plan-driven: starts dense (exact at any
                         occupancy), the controller demotes to the
                         row-stream wire as the telemetry window shows
                         occupancy draining, and back up as it rises —
                         swapping compiled decode steps by plan
                         signature. Output is bit-identical to 'dense'
                         (the stream exchange is exact under its
                         capacity, which the occupancy guard enforces).

    Non-MoE families serve with the same scheduler and per-slot decode;
    there is no cross-device dispatch to plan, so no controller runs.
    """

    def __init__(self, model: Model, mesh: Mesh, params,
                 cache_len: int = 128, batch_size: int = 8,
                 dispatch: str = "adaptive", eos_id: Optional[int] = None,
                 adapt: Optional[AdaptConfig] = None,
                 net: NetworkParams = DEFAULT_NET,
                 min_cap: int = 4, headroom: float = 2.0, obs=None,
                 serve_cfg=None, injector=None, max_tick_retries: int = 3):
        assert dispatch in ("dense", "adaptive"), dispatch
        cfg = model.cfg
        # ServeConfig (serve/scheduler.py) or None: declared SLO targets
        # evaluated by the health engine at end of each run, plus the
        # load-shedding policy (queue_limit / shed_deadline, §12.5).
        self.serve_cfg = serve_cfg
        # FaultInjector (runtime/faults.py) or None: chaos hook called
        # once per decode tick BEFORE dispatch. Pre-dispatch failures
        # are retryable (nothing donated yet); anything past dispatch
        # aborts cleanly — the decode state buffer is donated.
        self.injector = injector
        self.max_tick_retries = int(max_tick_retries)
        if cfg.family == "vlm" or not cfg.is_decoder:
            raise NotImplementedError(
                f"continuous batching: family {cfg.family!r}")
        self.model, self.mesh, self.params = model, mesh, params
        self.cache_len, self.batch_size = cache_len, batch_size
        self.eos_id = eos_id
        self.obs = _resolve_obs(obs)
        if injector is not None:
            injector.bind(
                registry=self.obs.metrics if self.obs.metrics_on else None)
        self._state_sh = _sh(mesh)(
            decode_state_specs(model, mesh, batch_size, cache_len))
        self._param_sh = _sh(mesh)(param_specs(
            jax.eval_shape(model.init, jax.random.PRNGKey(0)), model.cfg,
            None))
        self._admit_fns: dict = {}
        self.runtime = None
        self._plan = None
        self.swap_log: list = []
        if cfg.family == "moe":
            base = build_serve_plan(mesh.shape["model"], batch_size,
                                    cfg.d_model, algorithm="dense",
                                    min_cap=min_cap, headroom=headroom)
            if dispatch == "dense":
                self._plan = base
                self._fn = self._build(base)
            else:
                acfg = adapt or AdaptConfig(window=4, hysteresis=0.2,
                                            patience=1, calibrate=False,
                                            pod_sparse=False)
                self.runtime = AdaptiveRuntime(
                    model, None, mesh, plan=base, net=net, cfg=acfg,
                    build_fn=self._build, obs=self.obs)
                self._plan = self.runtime.current_plan
                self._fn = self.runtime.current_fn()
        else:
            self._fn = self._build(None)

    def _build(self, plan):
        return build_slot_decode_step(
            self.model, self.mesh, plan, self.batch_size, self.cache_len,
            shardings=(self._param_sh, self._state_sh))

    # -- slot admission ----------------------------------------------------
    def _admit_fn(self, prompt_len: int):
        """One jitted admission program per distinct prompt length
        (ragged admission: prefill B=1 at the prompt's own length +
        cache splice + first-token argmax, state donated). Compiled
        once per length, cached for the engine's lifetime."""
        if prompt_len not in self._admit_fns:
            cfg = self.model.cfg

            def admit(params, state, toks, slot_idx):
                logits, sub = self.model.prefill(
                    params, {"tokens": toks}, self.cache_len)
                state = insert_slot_state(cfg, state, sub, slot_idx)
                return state, jnp.argmax(logits[0]).astype(jnp.int32)

            from jax.sharding import NamedSharding as NS

            self._admit_fns[prompt_len] = jax.jit(
                admit,
                in_shardings=(self._param_sh, self._state_sh,
                              NS(self.mesh, P()), NS(self.mesh, P())),
                out_shardings=(self._state_sh, NS(self.mesh, P())),
                donate_argnums=(1,),
            )
        return self._admit_fns[prompt_len]

    def _admit(self, state, slot_idx: int, req: Request):
        """Per-request ragged prefill: run the prompt at its own length
        (B=1), take the first greedy token from the prefill logits —
        exactly as ServeEngine.generate does — and splice the caches
        into the slot's rows."""
        assert req.prompt.size + req.max_new_tokens <= self.cache_len, \
            (req.rid, req.prompt.size, req.max_new_tokens, self.cache_len)
        state, first = self._admit_fn(req.prompt.size)(
            self.params, state, jnp.asarray(req.prompt[None, :]),
            jnp.asarray(slot_idx, jnp.int32))
        return state, int(first)

    # -- plan swaps --------------------------------------------------------
    def _install(self, fn, plan, clock: float, reason: str):
        self._fn, self._plan = fn, plan
        self.swap_log.append({"step": clock, "reason": reason,
                              "signature": plan.signature(),
                              "version": plan.version})
        self.obs.event("serve/plan_swap", step=clock, reason=reason,
                       signature=plan.signature(), version=plan.version)

    def _occupancy_guard(self, active_count: int, clock: float):
        """Force-demote a stream plan the admitted batch just outgrew —
        the stream would silently drop rows above its capacity. Runs
        BEFORE dispatch (the controller's windowed view lags by design);
        bypasses hysteresis and patience, like the delta rule."""
        plan = self._plan
        if plan is None:
            return
        b = plan.buckets[0]
        if b.sparse and active_count > b.cap:
            forced = plan.replan(algorithms={b.name: "dense"})
            if self.runtime is not None:
                self.runtime.controller.force(forced)
                fn = self.runtime.step_fn_for(forced)
            else:
                fn = self._build(forced)
            self._install(fn, forced, clock, "occupancy-guard")

    # -- the serving loop --------------------------------------------------
    def run(self, requests: list[Request],
            max_steps: int = 100_000) -> ServeResult:
        sched = ContinuousScheduler(self.batch_size, requests,
                                    eos_id=self.eos_id)
        self.swap_log = []             # per-run log (the engine and its
        # compiled-plan cache are reusable across runs; a re-run starts
        # from the PREVIOUS run's adapted plan — steady-state serving)
        state = self.model.init_decode_state(self.batch_size, self.cache_len)
        state = state._replace(
            pos=jnp.zeros((self.batch_size,), jnp.int32))
        next_tok = np.zeros((self.batch_size,), np.int32)
        res = ServeResult(outputs=sched.completed, swap_log=self.swap_log)
        t0 = time.perf_counter()
        obs = self.obs
        rec = getattr(obs, "recorder", None)
        if self.injector is not None:
            # re-bind per run: the injector (and the obs handle it counts
            # through) may have been swapped since construction
            self.injector.bind(
                registry=obs.metrics if obs.metrics_on else None)
        try:
            self._run_loop(sched, state, next_tok, res, max_steps)
        except Exception as e:
            # flight-recorder trigger (DESIGN.md §10.6): leave a
            # parseable blackbox behind before surfacing the failure
            if rec is not None:
                rec._safe_dump(f"exception:{type(e).__name__}")
            raise
        res.wall_s = time.perf_counter() - t0
        res.shed = dict(sched.shed)
        stats = sched.latency_stats()
        res.latency = {
            name: {"p50": float(np.percentile(v, 50)),
                   "p90": float(np.percentile(v, 90)),
                   "p99": float(np.percentile(v, 99)),
                   "mean": float(np.mean(v))}
            for name, v in stats.items()
            if name in ("queue_delay", "ttft", "tpot", "e2e") and v.size
        }
        if obs.metrics_on:
            m = obs.metrics
            for name in ("queue_delay", "ttft", "tpot", "e2e"):
                if stats[name].size:
                    m.histogram(f"serve/{name}_steps").observe_many(
                        stats[name])
            m.gauge("serve/tok_per_s").set(res.tok_per_s)
            m.gauge("serve/decode_steps").set(res.decode_steps)
            targets = (self.serve_cfg.slo_targets()
                       if self.serve_cfg is not None else {})
            if targets:
                # declared objectives ride the JSONL so the report CLI
                # can join them against the measured percentiles, and
                # the health engine ranks the misses
                from repro.obs.health import HealthMonitor

                m.event("serve/slo_targets", **targets)
                res.health = HealthMonitor(
                    m, serve_slo=targets, audit=obs.audit).evaluate()
        if res.shed:
            # backpressure verdict (DESIGN.md §12.5): shedding is the
            # degradation policy WORKING, but the operator must see it —
            # a warn-level health event rides the result and the JSONL
            from repro.obs.health import HealthEvent, rank_events

            counts: dict = {}
            for reason in res.shed.values():
                counts[reason] = counts.get(reason, 0) + 1
            by = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
            ev = HealthEvent(
                "warn", "serve_shed", "admission",
                f"{len(res.shed)} of {len(res.shed) + len(res.outputs)} "
                f"requests load-shed under backpressure ({by})",
                float(len(res.shed)), 0.0)
            res.health = rank_events(list(res.health) + [ev])
            if obs.metrics_on:
                obs.metrics.event(
                    "health/serve_shed", severity=ev.severity,
                    subject=ev.subject, value=ev.value,
                    threshold=ev.threshold, message=ev.message)
        return res

    def _shed_pass(self, sched, obs, *, deadline: bool = False,
                   overflow: bool = False) -> None:
        """Graceful degradation (DESIGN.md §12.5). ``deadline`` runs
        BEFORE admission (an overdue request's TTFT budget is spent —
        it must not take a slot from one that can still meet it);
        ``overflow`` runs AFTER (free slots absorb the burst first, the
        bounded queue only sheds what admission could not place).
        Shedding instead of queueing keeps the served requests' outputs
        and latencies identical to an unloaded run."""
        scfg = self.serve_cfg
        if scfg is None:
            return
        shed_now = []
        limit = scfg.effective_shed_deadline()
        if deadline and limit is not None:
            shed_now += [(rid, "deadline")
                         for rid in sched.shed_overdue(limit)]
        if overflow and scfg.queue_limit is not None:
            shed_now += [(rid, "queue_full")
                         for rid in sched.shed_overflow(scfg.queue_limit)]
        for rid, reason in shed_now:
            obs.event("serve/shed", rid=rid, reason=reason,
                      step=sched.clock)
            if obs.metrics_on:
                obs.metrics.counter("serve/shed_requests").inc()
                obs.metrics.counter(f"serve/shed_{reason}").inc()

    def _chaos_tick(self, tick: int, clock: float, obs) -> None:
        """Pre-dispatch injection point with a bounded retry: a
        collective fault raised here touched nothing (the donated
        decode-state dispatch hasn't happened), so retrying is safe.
        Injected one-shots clear on the retry; a genuinely stuck fault
        exhausts ``max_tick_retries`` and aborts with the blackbox."""
        for attempt in range(1, self.max_tick_retries + 1):
            try:
                self.injector.serve_tick(tick)
                return
            except FaultInjectionError as e:
                if attempt >= self.max_tick_retries:
                    raise
                if obs.metrics_on:
                    obs.metrics.counter("serve/retries").inc()
                obs.event("recovery/serve_retry", step=clock,
                          attempt=attempt, error=type(e).__name__,
                          message=str(e))

    def _run_loop(self, sched, state, next_tok, res, max_steps: int):
        obs = self.obs
        rec = getattr(obs, "recorder", None)
        with self.mesh:
            while not sched.done and res.decode_steps < max_steps:
                self._shed_pass(sched, obs, deadline=True)
                for slot_idx, req in sched.admit_ready():
                    with obs.span("serve/admit", rid=req.rid, slot=slot_idx,
                                  prompt_len=int(req.prompt.size)):
                        state, first = self._admit(state, slot_idx, req)
                    sched.install(slot_idx, req, first)
                    res.tokens += 1
                self._shed_pass(sched, obs, overflow=True)
                active = sched.active_mask
                n_active = int(active.sum())
                if n_active == 0:
                    sched.skip_to_next_arrival()
                    continue
                self._occupancy_guard(n_active, sched.clock)
                if self.injector is not None:
                    self._chaos_tick(res.decode_steps, sched.clock, obs)
                for i, s in enumerate(sched.slots):
                    if s is not None:
                        next_tok[i] = s.next_token
                with obs.span("serve/decode_step", step=sched.clock,
                              active=n_active):
                    logits, state, telem = self._fn(
                        self.params, state, jnp.asarray(next_tok[:, None]),
                        jnp.asarray(active))
                    lg = np.asarray(logits)
                for i in np.nonzero(active)[0]:
                    tok = int(np.argmax(lg[i]))
                    sched.record(int(i), tok)
                    res.tokens += 1
                wire = float(np.asarray(telem[self._plan.buckets[0].name])[1]) \
                    if telem else 0.0
                res.wire_bytes += wire
                res.step_log.append({
                    "step": sched.clock, "active": n_active,
                    "wire_bytes": wire,
                    "signature": (self._plan.signature()
                                  if self._plan is not None else "-")})
                if rec is not None:
                    rec.note("serve/step", step=sched.clock,
                             active=n_active, wire_bytes=wire)
                if obs.metrics_on:
                    m = obs.metrics
                    m.histogram("serve/occupancy").observe(n_active)
                    m.histogram("serve/queue_depth").observe(
                        len(sched.waiting))
                    if telem:
                        m.histogram("serve/wire_bytes").observe(wire)
                if self.runtime is not None and telem:
                    self.runtime.observe(
                        res.decode_steps, 1,
                        {"telemetry": {k: np.asarray(v)
                                       for k, v in telem.items()}})
                    sw = self.runtime.maybe_swap()
                    if sw is not None:
                        # every step boundary of this synchronous host
                        # loop is a drain barrier: nothing is in flight
                        # when the compiled step is swapped (§8.3)
                        self._install(sw[0], sw[1], sched.clock, "telemetry")
                sched.advance()
                res.decode_steps += 1
