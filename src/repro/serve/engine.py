"""Serving: batched prefill + synchronous batched greedy decode.

``build_serve_step`` returns the jitted one-token decode function — the
object the dry-run lowers for decode_32k / long_500k cells. The engine
wraps it with a minimal batching loop (fixed slots, batch-synchronous);
it is the per-request EXACTNESS REFERENCE for the continuous-batching
engine in ``serve/sparse_decode.py`` (DESIGN.md §8).

Cache sharding is divisibility-aware (found via the 40-cell dry-run):
  * batch over dp only when global_batch divides dp (long_500k has B=1:
    the cell is TP-only, honestly reported as such in the roofline),
  * KV W (sequence) axis over 'model' when kv-heads < TP (GQA: 8 kv heads
    cannot shard 16 ways) — i.e. context-parallel attention decode,
  * head axis over 'model' when it divides evenly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.models.specs import param_specs
from repro.train.train_step import dp_axes_of, dp_total_of


def _div(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def decode_state_specs(model: Model, mesh: Mesh, batch_size: int,
                       cache_len: int):
    """Shard caches: batch over dp (if divisible), heads or sequence over
    'model' (whichever divides)."""
    cfg = model.cfg
    tp = mesh.shape["model"]
    dp_ax = dp_axes_of(mesh)
    dp = dp_ax if _div(batch_size, dp_total_of(mesh)) else None

    w = cache_len
    if cfg.sliding_window:
        w = min(w, cfg.sliding_window)

    def kv_spec(leading: int):
        # (lead..., B, W, nkv, hd)
        if _div(cfg.num_kv_heads, tp):
            return P(*([None] * leading), dp, None, "model", None)
        if _div(w, tp):
            return P(*([None] * leading), dp, "model", None, None)
        return P(*([None] * leading), dp, None, None, None)

    from repro.models.model import DecodeState
    from repro.models.layers import KVCache

    kv = cross_kv = conv = ssm = None
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    if cfg.family in ("dense", "moe"):
        kv = KVCache(kv_spec(1), kv_spec(1))
    elif cfg.family == "hybrid":
        kv = KVCache(kv_spec(1), kv_spec(1))
        conv = P(None, dp, None, "model" if _div(conv_dim, tp) else None)
        ssm = P(None, dp, "model" if _div(cfg.ssm_heads, tp) else None, None, None)
    elif cfg.family == "ssm":
        conv = P(None, dp, None, "model" if _div(conv_dim, tp) else None)
        ssm = P(None, dp, "model" if _div(cfg.ssm_heads, tp) else None, None, None)
    elif cfg.family == "vlm":
        # self-attn caches (nsb, every-1, B, W, nkv, hd)
        kv = KVCache(kv_spec(2), kv_spec(2))
        # image K/V (nsb, B, T_img, nkv, hd): shard T_img over model
        t_ok = _div(cfg.num_image_tokens, tp)
        ckv = P(None, dp, "model" if t_ok else None, None, None)
        cross_kv = (ckv, ckv)
    return DecodeState(pos=P(), kv=kv, cross_kv=cross_kv, conv=conv, ssm=ssm)


def _sh(mesh: Mesh):
    return lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()), t,
        is_leaf=lambda x: x is None or isinstance(x, P))


def _logit_spec(cfg, mesh: Mesh, batch_size: int) -> P:
    dp = dp_axes_of(mesh) if _div(batch_size, dp_total_of(mesh)) else None
    return P(dp, "model" if _div(cfg.padded_vocab, mesh.shape["model"]) else None)


def build_serve_step(model: Model, mesh: Mesh, batch_size: int = 8,
                     cache_len: int = 4096, fsdp: bool = False):
    """(jitted decode_step(params, state, tokens) -> (logits, state'),
    (param_specs, state_specs))."""
    cfg = model.cfg
    pspecs = param_specs(
        jax.eval_shape(model.init, jax.random.PRNGKey(0)), cfg,
        dp_axes_of(mesh) if fsdp else None)
    sspecs = decode_state_specs(model, mesh, batch_size, cache_len)
    dp = dp_axes_of(mesh) if _div(batch_size, dp_total_of(mesh)) else None
    sh = _sh(mesh)

    def step(params, state, tokens):
        return model.decode_step(params, state, tokens)

    jitted = jax.jit(
        step,
        in_shardings=(sh(pspecs), sh(sspecs),
                      NamedSharding(mesh, P(dp, None))),
        out_shardings=(NamedSharding(mesh, _logit_spec(cfg, mesh, batch_size)),
                       sh(sspecs)),
        donate_argnums=(1,),
    )
    return jitted, (pspecs, sspecs)


def build_prefill(model: Model, mesh: Mesh, cache_len: int,
                  batch_size: int = 8, fsdp: bool = False):
    cfg = model.cfg
    pspecs = param_specs(
        jax.eval_shape(model.init, jax.random.PRNGKey(0)), cfg,
        dp_axes_of(mesh) if fsdp else None)
    sspecs = decode_state_specs(model, mesh, batch_size, cache_len)
    dp = dp_axes_of(mesh) if _div(batch_size, dp_total_of(mesh)) else None
    sh = _sh(mesh)

    def pre(params, batch):
        return model.prefill(params, batch, cache_len)

    bspec = {"tokens": P(dp, None)}
    if cfg.family == "vlm":
        bspec["image_embeds"] = P(dp, None, None)
    jitted = jax.jit(
        pre,
        in_shardings=(sh(pspecs), sh(bspec)),
        out_shardings=(NamedSharding(mesh, _logit_spec(cfg, mesh, batch_size)),
                       sh(sspecs)),
    )
    return jitted, (pspecs, sspecs)


class ServeEngine:
    """Minimal batched greedy-decoding engine over fixed slots."""

    def __init__(self, model: Model, mesh: Mesh, params, cache_len: int = 256,
                 batch_size: int = 8, obs=None):
        from repro.obs import resolve as _resolve_obs

        self.model = model
        self.mesh = mesh
        self.params = params
        self.cache_len = cache_len
        self.obs = _resolve_obs(obs)
        self.decode_fn, (_, sspecs) = build_serve_step(
            model, mesh, batch_size=batch_size, cache_len=cache_len)
        self._state_sh = _sh(mesh)(sspecs)
        dp = dp_axes_of(mesh) if _div(batch_size, dp_total_of(mesh)) else None
        self._tok_sh = NamedSharding(mesh, P(dp, None))

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16,
                 image_embeds: Optional[np.ndarray] = None) -> np.ndarray:
        """prompts: (B, S) int32 -> (B, max_new_tokens) greedy tokens."""
        batch = {"tokens": jnp.asarray(prompts)}
        if image_embeds is not None:
            batch["image_embeds"] = jnp.asarray(image_embeds)
        rec = getattr(self.obs, "recorder", None)
        try:
            return self._generate(batch, max_new_tokens)
        except Exception as e:
            if rec is not None:
                rec._safe_dump(f"exception:{type(e).__name__}")
            raise

    def _generate(self, batch: dict, max_new_tokens: int) -> np.ndarray:
        prompts = batch["tokens"]
        with self.mesh:
            with self.obs.span("serve/prefill",
                               batch=int(np.asarray(prompts).shape[0]),
                               prompt_len=int(np.asarray(prompts).shape[1])):
                logits, state = self.model.prefill(self.params, batch,
                                                   self.cache_len)
            # The eager prefill may COMMIT cache shardings (models with
            # internal sharding constraints, e.g. MoE dispatch); the
            # jitted step's donated state arg needs its own layout.
            state = jax.device_put(state, self._state_sh)
            toks = []
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            with self.obs.span("serve/decode", tokens=max_new_tokens):
                for _ in range(max_new_tokens):
                    toks.append(np.asarray(cur))
                    # argmax of committed logits is itself committed (with
                    # a replicated layout); re-lay it out for the decode
                    # step
                    cur = jax.device_put(cur, self._tok_sh)
                    logits, state = self.decode_fn(self.params, state, cur)
                    cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return np.concatenate(toks, axis=1)
