from repro.serve.engine import ServeEngine, build_serve_step  # noqa: F401
