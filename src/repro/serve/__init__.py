"""Serving subsystem (DESIGN.md §8): the static-batch reference engine
plus the continuous-batching scheduler + plan-driven sparse decode."""
from repro.serve.engine import ServeEngine, build_serve_step  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    ContinuousScheduler,
    Request,
    ServeConfig,
    poisson_trace,
    truncate_at_eos,
)
from repro.serve.sparse_decode import (  # noqa: F401
    ContinuousServeEngine,
    ServeResult,
    build_slot_decode_step,
    insert_slot_state,
)
