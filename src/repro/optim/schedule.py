"""Learning-rate schedules: cosine, linear, and WSD (warmup-stable-decay,
the minicpm-2b schedule [arXiv:2404.06395])."""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class ScheduleConfig:
    kind: str = "cosine"       # cosine | linear | wsd | constant
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    final_frac: float = 0.1    # final lr as fraction of peak
    wsd_decay_frac: float = 0.1  # last fraction of steps spent decaying


def make_schedule(cfg: ScheduleConfig):
    def sched(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, s / jnp.maximum(1, cfg.warmup_steps))
        if cfg.kind == "constant":
            frac = 1.0
        elif cfg.kind == "linear":
            t = jnp.clip((s - cfg.warmup_steps)
                         / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
            frac = 1.0 - (1.0 - cfg.final_frac) * t
        elif cfg.kind == "cosine":
            t = jnp.clip((s - cfg.warmup_steps)
                         / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
            frac = cfg.final_frac + (1 - cfg.final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        elif cfg.kind == "wsd":
            decay_start = cfg.total_steps * (1.0 - cfg.wsd_decay_frac)
            t = jnp.clip((s - decay_start)
                         / jnp.maximum(1, cfg.total_steps - decay_start), 0, 1)
            frac = 1.0 - (1.0 - cfg.final_frac) * t  # stable then linear decay
        else:
            raise ValueError(cfg.kind)
        return cfg.peak_lr * warm * frac

    return sched
