"""Optimizers: AdamW and SGD+momentum (the paper's Alg. 2 setting), pure
pytree transforms. Optimizer-state dtype is configurable (bf16 states for
llama3-405b keep the 256-chip pod under HBM — DESIGN.md §2.3)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"            # adamw | sgdm
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9          # sgdm
    state_dtype: Any = jnp.float32 # bf16 for very large models
    grad_clip: float = 1.0


def init_opt_state(params, cfg: OptimizerConfig):
    z = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    if cfg.kind == "adamw":
        return {"mu": jax.tree.map(z, params), "nu": jax.tree.map(z, params),
                "count": jnp.zeros((), jnp.int32)}
    if cfg.kind == "sgdm":
        return {"mu": jax.tree.map(z, params), "count": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.kind)


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype), grads), norm


def adamw(params, grads, state, lr, cfg: OptimizerConfig):
    count = state["count"] + 1
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        step = (m2 / c1) / (jnp.sqrt(v2 / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"mu": new_m, "nu": new_v, "count": count}


def sgd_momentum(params, grads, state, lr, cfg: OptimizerConfig):
    count = state["count"] + 1

    def upd(p, g, m):
        m2 = cfg.momentum * m.astype(jnp.float32) + g.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * m2
        return p2.astype(p.dtype), m2.astype(m.dtype)

    out = jax.tree.map(upd, params, grads, state["mu"])
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"mu": new_m, "count": count}


def opt_update(params, grads, state, lr, cfg: OptimizerConfig):
    if cfg.grad_clip:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    if cfg.kind == "adamw":
        return adamw(params, grads, state, lr, cfg)
    if cfg.kind == "sgdm":
        return sgd_momentum(params, grads, state, lr, cfg)
    raise ValueError(cfg.kind)
