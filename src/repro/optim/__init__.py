from repro.optim.optimizers import (  # noqa: F401
    OptimizerConfig, adamw, init_opt_state, opt_update, sgd_momentum,
)
from repro.optim.schedule import make_schedule, ScheduleConfig  # noqa: F401
