"""Unified architecture configuration covering all assigned families."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # attention details
    qk_norm: bool = False           # qwen3
    rope_theta: float = 10000.0
    causal: bool = True
    sliding_window: int = 0         # 0 = full attention; >0 = windowed (hybrid long ctx)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0               # per-expert ffn dim (dbrx/moonshot style)
    capacity_factor: float = 1.25
    moe_shared_ff: int = 0          # moonshot has a shared expert path

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4

    # hybrid (zamba2): shared attention block every N mamba layers
    attn_every: int = 0

    # vlm (llama-3.2-vision): cross-attention block every N layers
    cross_attn_every: int = 0
    num_image_tokens: int = 0
    vision_dim: int = 0

    # encoder-only (hubert): stub frontend provides frame embeddings
    frontend_dim: int = 0           # dim of precomputed frame embeddings

    # compute / numerics
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    remat: bool = True
    # dp axes for activation sharding constraints (set by the train/serve
    # builders in auto-SPMD mode; None inside shard_map where dp is manual)
    act_dp_axes: Any = None
    act_fn: str = "silu"            # silu (llama-family) | gelu (hubert)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 8192

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived ----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a 256 multiple so TP/FSDP shardings divide
        evenly (MaxText-style padding; pad logits are harmless in the
        softmax and labels never reference them)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_decoder(self) -> bool:
        return self.family != "encoder"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k (sub-quadratic / bounded-state)?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
        d, ff, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd, nh, nkv = self.head_dim, self.num_heads, self.num_kv_heads
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        mlp = 3 * d * ff if self.act_fn == "silu" else 2 * d * ff
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "dense" or self.family == "vlm":
            n = L * (attn + mlp) + emb
            if self.family == "vlm" and self.cross_attn_every:
                n_cross = L // self.cross_attn_every
                n += n_cross * (attn + mlp) + self.vision_dim * d
            return n
        if self.family == "moe":
            moe = self.num_experts * 3 * d * self.moe_d_ff
            shared = 3 * d * self.moe_shared_ff if self.moe_shared_ff else 0
            router = d * self.num_experts
            return L * (attn + moe + shared + router) + emb
        if self.family in ("ssm", "hybrid"):
            di, ns, nh_s = self.d_inner, self.ssm_state, self.ssm_heads
            # in_proj -> (z, x, B, C, dt) + conv + out_proj
            mamba = (d * (2 * di + 2 * ns + nh_s)
                     + self.conv_width * (di + 2 * ns)
                     + di * d + 2 * nh_s)
            n = L * mamba + emb
            if self.family == "hybrid" and self.attn_every:
                n += attn + mlp  # one shared block
            return n
        if self.family == "encoder":
            return L * (attn + mlp) + v * d + self.frontend_dim * d
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        hd, nh, nkv = self.head_dim, self.num_heads, self.num_kv_heads
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        moe_active = self.experts_per_token * 3 * d * self.moe_d_ff
        shared = 3 * d * self.moe_shared_ff if self.moe_shared_ff else 0
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + moe_active + shared + d * self.num_experts) + emb
