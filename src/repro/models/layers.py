"""Shared neural building blocks: RMSNorm, RoPE, GQA attention (train /
prefill / ring-buffer decode, optional qk-norm and sliding window),
SwiGLU/GELU MLP, gated cross-attention (VLM).

Parameters are plain nested dicts; every block has ``init_*`` and a pure
apply function so blocks can be stacked under jax.lax.scan with a leading
layer axis.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


# -- init helpers ----------------------------------------------------------

def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# -- rotary ----------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half
    )  # (half,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    ang = ang[..., :, None, :]  # one head axis: (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- attention -------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array  # (B, W, nkv, hd) — W = max_seq or sliding window
    v: jax.Array


def attn_init(key, cfg: ModelConfig, d: int | None = None) -> dict:
    d = d or cfg.d_model
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, nh * hd), cfg.param_dtype),
        "wk": _dense_init(ks[1], (d, nkv * hd), cfg.param_dtype),
        "wv": _dense_init(ks[2], (d, nkv * hd), cfg.param_dtype),
        "wo": _dense_init(ks[3], (nh * hd, d), cfg.param_dtype,
                          scale=1.0 / math.sqrt(nh * hd * 2 * cfg.num_layers)),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, cfg.param_dtype)
        p["k_norm"] = rmsnorm_init(hd, cfg.param_dtype)
    return p


def _qkv(p, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, nh, hd)
    k = (x @ p["wk"]).reshape(b, s, nkv, hd)
    v = (x @ p["wv"]).reshape(b, s, nkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, hd: int):
    """q (B,S,nh,hd), k/v (B,T,nkv,hd); GQA via KV-head repeat; fp32 softmax.

    KV heads are REPEATED to nh instead of reshaping q to (nkv, g, hd):
    a (nkv, g) reshape makes the head axis unshardable when nkv < TP
    (GSPMD replicates the full score tensor — found via dry-run HLO:
    700 GB/layer of replicated f32 scores on the 405B cell). The repeat
    keeps the head axis divisible by TP; duplicate K/V per device is
    nkv*hd*T bytes — negligible next to the score tensor it avoids.

    mask may be (B, 1, 1, S, T)-broadcastable; we use (B, 1, S, T).
    """
    b, s, nh, _ = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    # f32 ACCUMULATION inside the bf16 dot (a post-cast would make XLA
    # materialize f32 operands — found via dry-run HLO inspection).
    scores = jnp.einsum("bsnh,btnh->bnst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    mask = jnp.broadcast_to(mask.reshape(mask.shape[0], -1, mask.shape[-2], mask.shape[-1])[:, :1],
                            (b, 1, s, t))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnst,btnh->bsnh", probs, v)
    return out.reshape(b, s, nh * hd)


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def flash_attention(q, k, v, positions, causal: bool, window: int, kc: int):
    """Online-softmax attention with a recomputing backward (flash-style,
    pure JAX). Never materializes (S,T) scores in fwd OR bwd: the naive
    path's ~6 full-S^2 f32 tensors (fwd) + their saved copies (bwd) were
    the dominant memory term of every attention train cell (dry-run HLO).

    q: (B,S,nh,hd); k/v: (B,T,nh,hd) — GQA repeat happens in the caller.
    """
    out, _ = _flash_fwd(q, k, v, positions, causal, window, kc)
    return out


def _flash_fwd(q, k, v, positions, causal, window, kc):
    b, s, nh, hd = q.shape
    t = k.shape[1]
    nc = t // kc
    kck = k.reshape(b, nc, kc, nh, hd).transpose(1, 0, 2, 3, 4)
    vck = v.reshape(b, nc, kc, nh, hd).transpose(1, 0, 2, 3, 4)
    kpos = positions.reshape(nc, kc)
    qpos = positions[:, None]
    scale = 1.0 / math.sqrt(hd)

    def body(carry, chunk):
        m_prev, l_prev, acc = carry
        kc_, vc_, kp = chunk
        scores = jnp.einsum("bsnh,bcnh->bnsc", q, kc_,
                            preferred_element_type=jnp.float32) * scale
        mask = (qpos >= kp[None, :]) if causal else jnp.ones((s, kc), bool)
        if window:
            mask = mask & (qpos - kp[None, :] < window)
        scores = jnp.where(mask[None, None], scores, -1e30)
        m_new = jnp.maximum(m_prev, scores.max(-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_new = l_prev * corr + p.sum(-1, keepdims=True)
        pv = jnp.einsum("bnsc,bcnh->bnsh", p.astype(vc_.dtype), vc_,
                        preferred_element_type=jnp.float32)
        acc = acc * corr + pv
        return (m_new, l_new, acc), None

    init = (jnp.full((b, nh, s, 1), -jnp.inf, jnp.float32),
            jnp.zeros((b, nh, s, 1), jnp.float32),
            jnp.zeros((b, nh, s, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (kck, vck, kpos))
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]  # (B,nh,S)
    return out.transpose(0, 2, 1, 3), lse


def _flash_fwd_vjp(q, k, v, positions, causal, window, kc):
    out, lse = _flash_fwd(q, k, v, positions, causal, window, kc)
    return out, (q, k, v, positions, out, lse)


def _flash_bwd(causal, window, kc, res, dout):
    q, k, v, positions, out, lse = res
    b, s, nh, hd = q.shape
    t = k.shape[1]
    nc = t // kc
    scale = 1.0 / math.sqrt(hd)
    kck = k.reshape(b, nc, kc, nh, hd).transpose(1, 0, 2, 3, 4)
    vck = v.reshape(b, nc, kc, nh, hd).transpose(1, 0, 2, 3, 4)
    kpos = positions.reshape(nc, kc)
    qpos = positions[:, None]
    # D = rowsum(dO * O) per query (B,nh,S)
    d = jnp.einsum("bsnh,bsnh->bns", dout.astype(jnp.float32),
                   out.astype(jnp.float32))

    def body(dq_acc, chunk):
        kc_, vc_, kp = chunk
        scores = jnp.einsum("bsnh,bcnh->bnsc", q, kc_,
                            preferred_element_type=jnp.float32) * scale
        mask = (qpos >= kp[None, :]) if causal else jnp.ones((s, kc), bool)
        if window:
            mask = mask & (qpos - kp[None, :] < window)
        scores = jnp.where(mask[None, None], scores, -1e30)
        p = jnp.exp(scores - lse[..., None])               # (B,nh,S,C)
        dv_c = jnp.einsum("bnsc,bsnh->bcnh", p,
                          dout.astype(jnp.float32))
        dp = jnp.einsum("bsnh,bcnh->bnsc", dout, vc_,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - d[..., None]) * scale               # (B,nh,S,C)
        dq_acc = dq_acc + jnp.einsum("bnsc,bcnh->bsnh", ds.astype(kc_.dtype),
                                     kc_, preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bnsc,bsnh->bcnh", ds.astype(q.dtype), q,
                          preferred_element_type=jnp.float32)
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros((b, s, nh, hd), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(body, dq0, (kck, vck, kpos))
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(b, t, nh, hd)
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(b, t, nh, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), None)


flash_attention.defvjp(_flash_fwd_vjp, _flash_bwd)


def _sdpa_chunked(q, k, v, positions, causal: bool, window: int, hd: int,
                  kc: int = 1024):
    """Online-softmax attention over key chunks (flash-style, pure JAX).

    Never materializes the (S,T) score matrix: per scan step only a
    (B,nh,S,kc) block is live — at S=4096 this cuts the attention HBM
    term ~6x vs the naive path (each full-S^2 tensor was read/written
    several times by sub/exp/mul/select). Exact (online max/sum), runs
    under lax.scan so the trip-aware roofline accounts it.
    """
    b, s, nh, _ = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    nc = t // kc
    kck = k.reshape(b, nc, kc, nh, hd).transpose(1, 0, 2, 3, 4)
    vck = v.reshape(b, nc, kc, nh, hd).transpose(1, 0, 2, 3, 4)
    kpos = positions.reshape(nc, kc)
    qpos = positions[:, None]                       # (S,1)
    scale = 1.0 / math.sqrt(hd)

    def body(carry, chunk):
        m_prev, l_prev, acc = carry                 # (B,nh,S,1) x2, (B,nh,S,hd)
        kc_, vc_, kp = chunk
        scores = jnp.einsum("bsnh,bcnh->bnsc", q, kc_,
                            preferred_element_type=jnp.float32) * scale
        mask = (qpos >= kp[None, :]) if causal else jnp.ones((s, kc), bool)
        if window:
            mask = mask & (qpos - kp[None, :] < window)
        scores = jnp.where(mask[None, None], scores, -1e30)
        m_new = jnp.maximum(m_prev, scores.max(-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_new = l_prev * corr + p.sum(-1, keepdims=True)
        pv = jnp.einsum("bnsc,bcnh->bnsh", p.astype(vc_.dtype), vc_,
                        preferred_element_type=jnp.float32)
        acc = acc * corr + pv
        return (m_new, l_new, acc), None

    init = (jnp.full((b, nh, s, 1), -jnp.inf, jnp.float32),
            jnp.zeros((b, nh, s, 1), jnp.float32),
            jnp.zeros((b, nh, s, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (kck, vck, kpos))
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return out.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)


def attention(p, cfg: ModelConfig, x, positions, *, causal=True) -> jax.Array:
    """Full-sequence attention (training / encoder / prefill compute).

    Sequences longer than ``_CHUNKED_MIN`` use the online-softmax chunked
    path; short sequences (smoke tests) take the exact naive path.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    if s >= _CHUNKED_MIN and s % 1024 == 0:
        g = cfg.num_heads // cfg.num_kv_heads
        if g > 1:  # GQA repeat outside the custom_vjp (clean grads)
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        out = flash_attention(q, k, v, positions, causal,
                              cfg.sliding_window, 1024)
        return out.reshape(b, s, -1) @ p["wo"]
    i = positions[..., :, None]  # query pos
    j = positions[..., None, :]  # key pos
    mask = (i >= j) if causal else jnp.ones((s, s), bool)
    if cfg.sliding_window:
        mask = mask & (i - j < cfg.sliding_window)
    mask = jnp.broadcast_to(mask, (b, 1, 1, s, s))
    out = _sdpa(q, k, v, mask, cfg.head_dim)
    return out @ p["wo"]


_CHUNKED_MIN = 2048


def attention_prefill(p, cfg: ModelConfig, x, positions, cache_len: int):
    """Forward over the prompt; returns (out, KVCache padded to cache_len).

    RoPE is applied to K at write time, so decode never re-rotates cache.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    if s >= _CHUNKED_MIN and s % 1024 == 0:
        g = cfg.num_heads // cfg.num_kv_heads
        kr = jnp.repeat(k, g, axis=2) if g > 1 else k
        vr = jnp.repeat(v, g, axis=2) if g > 1 else v
        out = flash_attention(q, kr, vr, positions, True,
                              cfg.sliding_window, 1024)
        out = out.reshape(b, s, -1) @ p["wo"]
    else:
        i = positions[..., :, None]
        j = positions[..., None, :]
        mask = i >= j
        if cfg.sliding_window:
            mask = mask & (i - j < cfg.sliding_window)
        mask = jnp.broadcast_to(mask, (b, 1, 1, s, s))
        out = _sdpa(q, k, v, mask, cfg.head_dim) @ p["wo"]
    w = cache_len
    if cfg.sliding_window:
        w = min(w, cfg.sliding_window)
    if s >= w:  # keep last w entries (ring layout: slot = pos % w)
        sel = (jnp.arange(w) + (s - w)) if not cfg.sliding_window else None
        if cfg.sliding_window:
            # ring buffer: slot = pos % w
            slots = positions[..., -w:] % w
            kk = jnp.zeros((b, w) + k.shape[2:], k.dtype).at[:, slots[-w:]].set(k[:, -w:])
            vv = jnp.zeros((b, w) + v.shape[2:], v.dtype).at[:, slots[-w:]].set(v[:, -w:])
        else:
            kk, vv = k[:, sel], v[:, sel]
    else:
        pad = w - s
        kk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, KVCache(kk, vv)


def attention_decode(p, cfg: ModelConfig, x, cache: KVCache, pos):
    """One-token decode. x: (B, 1, d); pos: scalar current position, or a
    (B,) vector of PER-SLOT positions (continuous batching, DESIGN.md §8
    — each request slot is at its own depth in its own cache rows).

    Full-attention: cache slot = pos (cache width >= seq_len).
    Sliding-window: ring buffer, slot = pos % window.
    """
    b = x.shape[0]
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    w = cache.k.shape[1]
    q = (x @ p["wq"]).reshape(b, 1, nh, hd)
    k = (x @ p["wk"]).reshape(b, 1, nkv, hd)
    v = (x @ p["wv"]).reshape(b, 1, nkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    slot_ids = jnp.arange(w)
    if getattr(pos, "ndim", 0) == 1:
        # Per-slot path: same math per batch row as the scalar path —
        # rope at each row's own position, per-row cache slot write,
        # per-row validity mask. Inactive slots may sit past the cache
        # end; the write clamps (their rows are garbage by contract and
        # overwritten at admission).
        posv = pos[:, None]                                  # (B, 1)
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)
        slot = pos % w if cfg.sliding_window else jnp.minimum(pos, w - 1)
        bidx = jnp.arange(b)
        kc = cache.k.at[bidx, slot].set(k[:, 0])
        vc = cache.v.at[bidx, slot].set(v[:, 0])
        if cfg.sliding_window:
            age = (slot[:, None] - slot_ids[None, :]) % w
            valid = age < jnp.minimum(pos + 1, w)[:, None]
        else:
            valid = slot_ids[None, :] <= pos[:, None]        # (B, W)
        mask = valid[:, None, None, None, :]
    else:
        posv = jnp.full((1,), pos, jnp.int32)
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)
        slot = pos % w if cfg.sliding_window else pos
        kc = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
        # valid slots: those holding positions <= pos and within window
        if cfg.sliding_window:
            age = (slot - slot_ids) % w  # steps since the slot was written
            valid = (age < jnp.minimum(pos + 1, w))
        else:
            valid = slot_ids <= pos
        mask = jnp.broadcast_to(valid[None, None, None, None, :],
                                (b, 1, 1, 1, w))
    out = _sdpa(q, kc, vc, mask, hd) @ p["wo"]
    return out, KVCache(kc, vc)


# -- cross-attention (VLM) -------------------------------------------------

def cross_attn_init(key, cfg: ModelConfig) -> dict:
    d, nh, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": _dense_init(ks[0], (d, nh * hd), cfg.param_dtype),
        "wk": _dense_init(ks[1], (d, nkv * hd), cfg.param_dtype),
        "wv": _dense_init(ks[2], (d, nkv * hd), cfg.param_dtype),
        "wo": _dense_init(ks[3], (nh * hd, d), cfg.param_dtype),
        "gate": jnp.zeros((), cfg.param_dtype),  # tanh gate, init 0 (llama3.2)
        "q_norm": rmsnorm_init(hd, cfg.param_dtype),
        "k_norm": rmsnorm_init(hd, cfg.param_dtype),
    }


def cross_attention(p, cfg: ModelConfig, x, kv_feats) -> jax.Array:
    """x: (B, S, d) text; kv_feats: (B, T_img, d) projected vision tokens."""
    b, s, _ = x.shape
    t = kv_feats.shape[1]
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, nh, hd)
    k = (kv_feats @ p["wk"]).reshape(b, t, nkv, hd)
    v = (kv_feats @ p["wv"]).reshape(b, t, nkv, hd)
    q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
    k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    mask = jnp.ones((b, 1, 1, s, t), bool)
    out = _sdpa(q, k, v, mask, hd) @ p["wo"]
    return jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * out


# -- MLP ---------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "wi": _dense_init(ks[0], (d, ff), cfg.param_dtype),
        "wo": _dense_init(ks[1], (ff, d), cfg.param_dtype,
                          scale=1.0 / math.sqrt(ff * 2 * cfg.num_layers)),
    }
    if cfg.act_fn == "silu":
        p["wg"] = _dense_init(ks[2], (d, ff), cfg.param_dtype)
    return p


def mlp(p, cfg: ModelConfig, x) -> jax.Array:
    h = x @ p["wi"]
    if cfg.act_fn == "silu":
        h = jax.nn.silu(x @ p["wg"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"]
