"""Model zoo: the 10 assigned architectures as composable JAX modules.

Families: dense GQA decoders (llama/qwen/minicpm/internlm/405B), MoE
(dbrx, moonshot), SSM (mamba2), hybrid SSM+shared-attention (zamba2),
encoder-only audio (hubert), VLM cross-attention decoder (llama-3.2-vision).

All models share: scan-over-layers (compile time O(1) in depth), remat,
TP/FSDP sharding rules, bf16 compute, train/prefill/decode entry points.
"""

from repro.models.config import ModelConfig  # noqa: F401
from repro.models.model import Model, build_model  # noqa: F401
