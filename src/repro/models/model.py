"""Model assembly: stacked-layer scan, per-family blocks, train/prefill/decode.

Layer parameters are STACKED on a leading axis and consumed by
``jax.lax.scan`` so compile time and HLO size are O(1) in depth (critical
for the 126-layer 405B dry-run). Mixed-layout families scan over
*superblocks*:

  vlm    (llama-3.2-vision): superblock = (cross_attn_every-1) self layers
         + 1 gated cross-attention layer; nested scan.
  hybrid (zamba2): superblock = attn_every mamba layers + one invocation of
         the SHARED attention+MLP block (params reused across invocations,
         zamba2's signature trick); each invocation site keeps its own KV
         cache at decode time.

Decode state is a pytree of stacked per-layer caches; entry points:
  forward_train(params, batch)          -> logits
  loss_fn(params, batch)                -> scalar CE
  prefill(params, batch, cache_len)     -> (last_logits, state)
  decode_step(params, state, tokens)    -> (logits, state')
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.layers import KVCache
from repro.models import moe as moe_mod
from repro.models import mamba2 as ssm_mod


# ==========================================================================
# Parameter initialization
# ==========================================================================

def _stack_init(fn, key, n: int):
    """vmap an init over n layers -> leaves with leading axis n."""
    return jax.vmap(fn)(jax.random.split(key, n))


def _dense_block_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "attn": L.attn_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_init(k2, cfg)
    else:
        p["mlp"] = L.mlp_init(k2, cfg)
    return p


def _mamba_block_init(key, cfg: ModelConfig) -> dict:
    return {
        "ln": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "mixer": ssm_mod.mamba_init(key, cfg),
    }


def _cross_block_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "xattn": L.cross_attn_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "mlp": L.mlp_init(k2, cfg),
        "mlp_gate": jnp.zeros((), cfg.param_dtype),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.padded_vocab
    params: dict = {
        "embed": (jax.random.normal(ks[0], (v, d), jnp.float32) * 0.02).astype(cfg.param_dtype),
        "final_norm": L.rmsnorm_init(d, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(ks[1], (d, v), jnp.float32)
                             * (d ** -0.5)).astype(cfg.param_dtype)

    fam = cfg.family
    if fam in ("dense", "moe"):
        params["blocks"] = _stack_init(
            lambda k: _dense_block_init(k, cfg), ks[2], cfg.num_layers)
    elif fam == "encoder":
        params["blocks"] = _stack_init(
            lambda k: _dense_block_init(k, cfg), ks[2], cfg.num_layers)
        params["frontend_proj"] = L._dense_init(
            ks[3], (cfg.frontend_dim or d, d), cfg.param_dtype)
        params["pos_embed"] = (jax.random.normal(ks[4], (cfg.max_seq_len, d), jnp.float32)
                               * 0.02).astype(cfg.param_dtype)
    elif fam == "ssm":
        params["blocks"] = _stack_init(
            lambda k: _mamba_block_init(k, cfg), ks[2], cfg.num_layers)
    elif fam == "hybrid":
        nsb = cfg.num_layers // cfg.attn_every
        params["blocks"] = _stack_init(
            lambda k: _stack_init(lambda k2: _mamba_block_init(k2, cfg), k, cfg.attn_every),
            ks[2], nsb)
        params["shared_block"] = _dense_block_init(ks[3], cfg)
    elif fam == "vlm":
        every = cfg.cross_attn_every
        nsb = cfg.num_layers // every
        params["blocks"] = {
            "selfs": _stack_init(
                lambda k: _stack_init(lambda k2: _dense_block_init(k2, cfg), k, every - 1),
                ks[2], nsb),
            "cross": _stack_init(lambda k: _cross_block_init(k, cfg), ks[3], nsb),
        }
        params["vision_proj"] = L._dense_init(
            ks[4], (cfg.vision_dim, d), cfg.param_dtype)
    else:
        raise ValueError(fam)
    return params


# ==========================================================================
# Block application (single layer, unstacked params)
# ==========================================================================

def _dense_block(p, cfg: ModelConfig, x, positions, causal=True):
    h = L.attention(p["attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                    positions, causal=causal)
    x = x + h
    z = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        b, s, d = z.shape
        f = moe_mod.moe_apply(p["moe"], cfg, z.reshape(b * s, d)).reshape(b, s, d)
    else:
        f = L.mlp(p["mlp"], cfg, z)
    return x + f


def _mamba_block(p, cfg: ModelConfig, x):
    h, state = ssm_mod.mamba_apply(p["mixer"], cfg, L.rmsnorm(p["ln"], x, cfg.norm_eps))
    return x + h, state


def _cross_block(p, cfg: ModelConfig, x, kv_feats):
    h = L.cross_attention(p["xattn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps), kv_feats)
    x = x + h
    g = jnp.tanh(p["mlp_gate"].astype(jnp.float32)).astype(x.dtype)
    f = L.mlp(p["mlp"], cfg, L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + g * f


# ==========================================================================
# Full forward (training / encoder inference)
# ==========================================================================

def _maybe_remat(cfg: ModelConfig):
    """Decorator factory: jax.checkpoint when cfg.remat else identity."""
    return jax.checkpoint if cfg.remat else (lambda fn: fn)


def _cb(x, cfg: ModelConfig):
    """Constrain activation batch sharding to the dp axes (auto-SPMD mode).

    GSPMD loses the batch sharding after embedding gathers / loss gathers
    (found via dry-run HLO: batch-replicated f32 score tensors). A bare
    PartitionSpec constraint uses the ambient mesh; no-op when
    cfg.act_dp_axes is None (shard_map manual-dp context or smoke tests).
    """
    if not cfg.act_dp_axes:
        return x
    from jax.sharding import PartitionSpec as P

    from repro.compat import ambient_mesh_shape
    axes = list(cfg.act_dp_axes)
    mesh_shape = ambient_mesh_shape()
    # drop leading dp axes until the batch dim divides evenly (microbatches
    # can be narrower than pod x data)
    import numpy as _np
    while axes and mesh_shape and x.shape[0] % int(
            _np.prod([mesh_shape.get(a, 1) for a in axes])):
        axes.pop(0)
    if not axes:
        return x
    spec = P(tuple(axes), *([None] * (x.ndim - 1)))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        return x


def _cv(logits, cfg: ModelConfig):
    """Constrain logits' vocab axis over 'model'.

    Tied-embedding models otherwise materialize REPLICATED (B,S,V) f32
    logits after the d-contraction psum (found via dry-run HLO: 6x13GB
    tensors dominating mamba2's memory term). Works in both auto mode
    (dp axes + model) and inside shard_map (model is the auto axis).
    """
    from jax.sharding import PartitionSpec as P
    dp = tuple(cfg.act_dp_axes) if cfg.act_dp_axes else None
    spec = P(dp, *([None] * (logits.ndim - 2)), "model")
    try:
        return jax.lax.with_sharding_constraint(logits, spec)
    except (ValueError, RuntimeError, TypeError):
        return logits


def forward(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """batch: {'tokens': (B,S) int32} (+ 'image_embeds' vlm, 'frames' encoder).
    Returns logits (B, S, V)."""
    fam = cfg.family
    if fam == "encoder":
        frames = batch["frames"]  # (B, S, frontend_dim) — stub frontend output
        x = frames.astype(cfg.dtype) @ params["frontend_proj"]
        s = x.shape[1]
        x = x + params["pos_embed"][:s][None]
    else:
        tokens = batch["tokens"]
        # The GSPMD gather partitioner mishandles sharded-indices +
        # offset-sharded-operand (verifier failure on the 2x16x16 mesh);
        # replicating the (tiny, i32) indices makes it a clean local
        # gather of each device's d-slice. _cb re-shards the output.
        if cfg.act_dp_axes:
            from jax.sharding import PartitionSpec as _P
            try:
                tokens = jax.lax.with_sharding_constraint(tokens, _P())
            except (ValueError, RuntimeError, TypeError):
                pass
        x = params["embed"][tokens].astype(cfg.dtype)
        s = x.shape[1]
    x = _cb(x, cfg)
    positions = jnp.arange(s, dtype=jnp.int32)

    if fam in ("dense", "moe", "encoder"):
        causal = cfg.is_decoder

        def body(h, lp):
            h = _cb(h, cfg)
            return _maybe_remat(cfg)(
                lambda hh: _dense_block(lp, cfg, hh, positions, causal=causal)
            )(h), None

        x, _ = jax.lax.scan(body, x, params["blocks"])

    elif fam == "ssm":
        def body(h, lp):
            h = _cb(h, cfg)
            out, _state = _maybe_remat(cfg)(lambda hh: _mamba_block(lp, cfg, hh))(h)
            return out, None

        x, _ = jax.lax.scan(body, x, params["blocks"])

    elif fam == "hybrid":
        shared = params["shared_block"]

        def inner(h, lp):
            h = _cb(h, cfg)
            out, _ = _maybe_remat(cfg)(lambda hh: _mamba_block(lp, cfg, hh))(h)
            return out, None

        def superblock(h, sbp):
            h = _cb(h, cfg)
            h, _ = jax.lax.scan(inner, h, sbp)
            h = _maybe_remat(cfg)(
                lambda hh: _dense_block(shared, cfg, hh, positions, causal=True)
            )(h)
            return h, None

        x, _ = jax.lax.scan(superblock, x, params["blocks"])

    elif fam == "vlm":
        kv_feats = (batch["image_embeds"].astype(cfg.dtype)
                    @ params["vision_proj"])

        def inner(h, lp):
            h = _cb(h, cfg)
            return _maybe_remat(cfg)(
                lambda hh: _dense_block(lp, cfg, hh, positions, causal=True)
            )(h), None

        def superblock(h, sbp):
            h = _cb(h, cfg)
            h, _ = jax.lax.scan(inner, h, sbp["selfs"])
            h = _maybe_remat(cfg)(
                lambda hh: _cross_block(sbp["cross"], cfg, hh, kv_feats)
            )(h)
            return h, None

        x, _ = jax.lax.scan(superblock, x, params["blocks"])
    else:
        raise ValueError(fam)

    x = L.rmsnorm(params["final_norm"], _cb(x, cfg), cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = (x @ unembed.astype(cfg.dtype)).astype(jnp.float32)
    return _cv(logits, cfg)


def loss_fn(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Mean next-token (decoder) or per-frame (encoder) cross-entropy."""
    logits = forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.is_decoder:
        logits, labels = logits[:, :-1], labels[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


# ==========================================================================
# Serving: prefill + decode
# ==========================================================================

class DecodeState(NamedTuple):
    pos: jax.Array                 # scalar int32: next position to write
    kv: Any = None                 # stacked KVCache (L_attn leading)
    cross_kv: Any = None           # vlm: stacked (nsb, ...) K/V of image tokens
    conv: Any = None               # ssm: (L, B, W-1, conv_dim)
    ssm: Any = None                # ssm: (L, B, H, P, N)


def _attn_cache_width(cfg: ModelConfig, cache_len: int) -> int:
    return min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len


def prefill(params, cfg: ModelConfig, batch: dict, cache_len: int):
    """Run the prompt, return (last-token logits (B,V), DecodeState)."""
    fam = cfg.family
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.arange(s, dtype=jnp.int32)
    w = _attn_cache_width(cfg, cache_len)

    kv = cross_kv = conv = ssm_states = None

    if fam in ("dense", "moe"):
        def body(h, lp):
            hn = L.rmsnorm(lp["ln1"], h, cfg.norm_eps)
            a, cache = L.attention_prefill(lp["attn"], cfg, hn, positions, cache_len)
            h = h + a
            z = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
            if cfg.family == "moe":
                bb, ss, dd = z.shape
                f = moe_mod.moe_apply(lp["moe"], cfg, z.reshape(bb * ss, dd)).reshape(bb, ss, dd)
            else:
                f = L.mlp(lp["mlp"], cfg, z)
            return h + f, cache

        x, kv = jax.lax.scan(body, x, params["blocks"])

    elif fam == "ssm":
        def body(h, lp):
            hn = L.rmsnorm(lp["ln"], h, cfg.norm_eps)
            out, state = ssm_mod.mamba_apply(lp["mixer"], cfg, hn)
            # conv tail: last (W-1) conv inputs
            zxbcdt = hn @ lp["mixer"]["in_proj"]
            di, n = cfg.d_inner, cfg.ssm_state
            conv_in = zxbcdt[..., di:2 * di + 2 * n]
            tail = conv_in[:, -(cfg.conv_width - 1):, :]
            return h + out, (state, tail)

        x, (ssm_states, conv) = jax.lax.scan(body, x, params["blocks"])

    elif fam == "hybrid":
        shared = params["shared_block"]

        def inner(h, lp):
            hn = L.rmsnorm(lp["ln"], h, cfg.norm_eps)
            out, state = ssm_mod.mamba_apply(lp["mixer"], cfg, hn)
            zxbcdt = hn @ lp["mixer"]["in_proj"]
            di, n = cfg.d_inner, cfg.ssm_state
            tail = (zxbcdt[..., di:2 * di + 2 * n])[:, -(cfg.conv_width - 1):, :]
            return h + out, (state, tail)

        def superblock(h, sbp):
            h, states = jax.lax.scan(inner, h, sbp)
            hn = L.rmsnorm(shared["ln1"], h, cfg.norm_eps)
            a, cache = L.attention_prefill(shared["attn"], cfg, hn, positions, cache_len)
            h = h + a
            h = h + L.mlp(shared["mlp"], cfg, L.rmsnorm(shared["ln2"], h, cfg.norm_eps))
            return h, (states, cache)

        x, ((ssm_states, conv), kv) = jax.lax.scan(superblock, x, params["blocks"])
        # flatten (nsb, every, ...) -> (L, ...)
        ssm_states = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), ssm_states)
        conv = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), conv)

    elif fam == "vlm":
        kv_feats = batch["image_embeds"].astype(cfg.dtype) @ params["vision_proj"]
        nkv, hd = cfg.num_kv_heads, cfg.head_dim
        t = kv_feats.shape[1]

        def superblock(h, sbp):
            def inner(hh, lp):
                hn = L.rmsnorm(lp["ln1"], hh, cfg.norm_eps)
                a, cache = L.attention_prefill(lp["attn"], cfg, hn, positions, cache_len)
                hh = hh + a
                hh = hh + L.mlp(lp["mlp"], cfg, L.rmsnorm(lp["ln2"], hh, cfg.norm_eps))
                return hh, cache

            h, caches = jax.lax.scan(inner, h, sbp["selfs"])
            cp = sbp["cross"]
            h = _cross_block(cp, cfg, h, kv_feats)
            # cache image K/V for decode (static across steps)
            k_img = (kv_feats @ cp["xattn"]["wk"]).reshape(b, t, nkv, hd)
            k_img = L.rmsnorm(cp["xattn"]["k_norm"], k_img, cfg.norm_eps)
            v_img = (kv_feats @ cp["xattn"]["wv"]).reshape(b, t, nkv, hd)
            return h, (caches, (k_img, v_img))

        x, (kv, cross_kv) = jax.lax.scan(superblock, x, params["blocks"])
    else:
        raise ValueError(fam)

    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = (x @ unembed.astype(cfg.dtype))[:, 0].astype(jnp.float32)
    return logits, DecodeState(
        pos=jnp.asarray(s, jnp.int32), kv=kv, cross_kv=cross_kv,
        conv=conv, ssm=ssm_states,
    )


def init_decode_state(cfg: ModelConfig, batch_size: int, cache_len: int,
                      prefix_len: int = 0) -> DecodeState:
    """Empty decode state (for dry-running serve_step without a prefill)."""
    b = batch_size
    w = _attn_cache_width(cfg, cache_len)
    nkv, hd = cfg.num_kv_heads, cfg.head_dim
    kv = cross_kv = conv = ssm_states = None
    dt = cfg.dtype
    if cfg.family in ("dense", "moe"):
        kv = KVCache(
            jnp.zeros((cfg.num_layers, b, w, nkv, hd), dt),
            jnp.zeros((cfg.num_layers, b, w, nkv, hd), dt),
        )
    elif cfg.family == "ssm":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        conv = jnp.zeros((cfg.num_layers, b, cfg.conv_width - 1, conv_dim), dt)
        ssm_states = jnp.zeros(
            (cfg.num_layers, b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32)
    elif cfg.family == "hybrid":
        nsb = cfg.num_layers // cfg.attn_every
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        conv = jnp.zeros((cfg.num_layers, b, cfg.conv_width - 1, conv_dim), dt)
        ssm_states = jnp.zeros(
            (cfg.num_layers, b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32)
        kv = KVCache(
            jnp.zeros((nsb, b, w, nkv, hd), dt),
            jnp.zeros((nsb, b, w, nkv, hd), dt),
        )
    elif cfg.family == "vlm":
        every = cfg.cross_attn_every
        nsb = cfg.num_layers // every
        kv = KVCache(
            jnp.zeros((nsb, every - 1, b, w, nkv, hd), dt),
            jnp.zeros((nsb, every - 1, b, w, nkv, hd), dt),
        )
        cross_kv = (
            jnp.zeros((nsb, b, cfg.num_image_tokens, nkv, hd), dt),
            jnp.zeros((nsb, b, cfg.num_image_tokens, nkv, hd), dt),
        )
    return DecodeState(pos=jnp.asarray(prefix_len, jnp.int32), kv=kv,
                       cross_kv=cross_kv, conv=conv, ssm=ssm_states)


def decode_step(params, cfg: ModelConfig, state: DecodeState, tokens: jax.Array,
                moe_serve=None):
    """One autoregressive step. tokens: (B, 1) -> (logits (B,V), state').

    ``state.pos`` may be a scalar (batch-synchronous decode) or a (B,)
    per-slot position vector (continuous batching — see attention_decode).
    ``moe_serve``: an optional :class:`repro.models.moe.ServeDispatch`;
    when given, MoE layers route through the serve-time dispatch (active-
    slot masking + planned combine exchange, DESIGN.md §8) instead of the
    training-style :func:`moe_apply`."""
    fam = cfg.family
    assert cfg.is_decoder, "encoder-only archs have no decode step"
    x = params["embed"][tokens].astype(cfg.dtype)
    pos = state.pos
    kv = cross_kv = conv = ssm_states = None

    if fam in ("dense", "moe"):
        def body(h, inp):
            lp, cache = inp
            hn = L.rmsnorm(lp["ln1"], h, cfg.norm_eps)
            a, cache = L.attention_decode(lp["attn"], cfg, hn, cache, pos)
            h = h + a
            z = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
            if cfg.family == "moe":
                bb, ss, dd = z.shape
                z2 = z.reshape(bb * ss, dd)
                if moe_serve is not None:
                    f = moe_mod.moe_apply_serve(lp["moe"], cfg, z2, moe_serve)
                else:
                    f = moe_mod.moe_apply(lp["moe"], cfg, z2)
                f = f.reshape(bb, ss, dd)
            else:
                f = L.mlp(lp["mlp"], cfg, z)
            return h + f, cache

        x, kv = jax.lax.scan(body, x, (params["blocks"], state.kv))

    elif fam == "ssm":
        def body(h, inp):
            lp, cv, st = inp
            hn = L.rmsnorm(lp["ln"], h, cfg.norm_eps)
            out, cv, st = ssm_mod.mamba_decode(lp["mixer"], cfg, hn, cv, st)
            return h + out, (cv, st)

        x, (conv, ssm_states) = jax.lax.scan(
            body, x, (params["blocks"], state.conv, state.ssm))

    elif fam == "hybrid":
        shared = params["shared_block"]
        every = cfg.attn_every
        nsb = cfg.num_layers // every
        conv_s = state.conv.reshape((nsb, every) + state.conv.shape[1:])
        ssm_s = state.ssm.reshape((nsb, every) + state.ssm.shape[1:])

        def inner(h, inp):
            lp, cv, st = inp
            hn = L.rmsnorm(lp["ln"], h, cfg.norm_eps)
            out, cv, st = ssm_mod.mamba_decode(lp["mixer"], cfg, hn, cv, st)
            return h + out, (cv, st)

        def superblock(h, inp):
            sbp, cv, st, cache = inp
            h, (cv, st) = jax.lax.scan(inner, h, (sbp, cv, st))
            hn = L.rmsnorm(shared["ln1"], h, cfg.norm_eps)
            a, cache = L.attention_decode(shared["attn"], cfg, hn, cache, pos)
            h = h + a
            h = h + L.mlp(shared["mlp"], cfg, L.rmsnorm(shared["ln2"], h, cfg.norm_eps))
            return h, (cv, st, cache)

        x, (conv, ssm_states, kv) = jax.lax.scan(
            superblock, x, (params["blocks"], conv_s, ssm_s, state.kv))
        conv = conv.reshape((-1,) + conv.shape[2:])
        ssm_states = ssm_states.reshape((-1,) + ssm_states.shape[2:])

    elif fam == "vlm":
        def superblock(h, inp):
            sbp, cache, (k_img, v_img) = inp

            def inner(hh, inp2):
                lp, c = inp2
                hn = L.rmsnorm(lp["ln1"], hh, cfg.norm_eps)
                a, c = L.attention_decode(lp["attn"], cfg, hn, c, pos)
                hh = hh + a
                hh = hh + L.mlp(lp["mlp"], cfg, L.rmsnorm(lp["ln2"], hh, cfg.norm_eps))
                return hh, c

            h, cache = jax.lax.scan(inner, h, (sbp["selfs"], cache))
            # cross-attention against the cached image K/V
            cp = sbp["cross"]
            hn = L.rmsnorm(cp["ln1"], h, cfg.norm_eps)
            bq, sq = hn.shape[0], hn.shape[1]
            nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            q = (hn @ cp["xattn"]["wq"]).reshape(bq, sq, nh, hd)
            q = L.rmsnorm(cp["xattn"]["q_norm"], q, cfg.norm_eps)
            t = k_img.shape[1]
            mask = jnp.ones((bq, 1, 1, sq, t), bool)
            a = L._sdpa(q, k_img, v_img, mask, hd) @ cp["xattn"]["wo"]
            gate = jnp.tanh(cp["xattn"]["gate"].astype(jnp.float32)).astype(h.dtype)
            h = h + gate * a
            g2 = jnp.tanh(cp["mlp_gate"].astype(jnp.float32)).astype(h.dtype)
            h = h + g2 * L.mlp(cp["mlp"], cfg, L.rmsnorm(cp["ln2"], h, cfg.norm_eps))
            return h, cache

        x, kv = jax.lax.scan(
            superblock, x, (params["blocks"], state.kv, state.cross_kv))
        cross_kv = state.cross_kv
    else:
        raise ValueError(fam)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = (x @ unembed.astype(cfg.dtype))[:, 0].astype(jnp.float32)
    return logits, DecodeState(pos=pos + 1, kv=kv, cross_kv=cross_kv,
                               conv=conv, ssm=ssm_states)


# ==========================================================================
# Public façade
# ==========================================================================

class Model:
    """Thin façade bundling config + pure functions (no state)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        return init_params(key, self.cfg)

    def forward(self, params, batch):
        return forward(params, self.cfg, batch)

    def loss(self, params, batch):
        return loss_fn(params, self.cfg, batch)

    def prefill(self, params, batch, cache_len: int):
        return prefill(params, self.cfg, batch, cache_len)

    def decode_step(self, params, state, tokens, moe_serve=None):
        return decode_step(params, self.cfg, state, tokens,
                           moe_serve=moe_serve)

    def init_decode_state(self, batch_size: int, cache_len: int, prefix_len: int = 0):
        return init_decode_state(self.cfg, batch_size, cache_len, prefix_len)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
