"""Mamba2 block: SSD (state-space duality) chunked forward + recurrent decode.

JAX port of the minimal SSD algorithm (Dao & Gu 2024, arXiv:2405.21060):
within-chunk quadratic attention-like term + cross-chunk linear recurrence.
State per layer: (B, H, P, N) with H=ssm heads, P=head dim, N=ssm_state —
O(1) in sequence length, which is what makes long_500k decodable.

Single group (G=1) for B/C projections, as in mamba2-370m.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _dense_init, rmsnorm_init, rmsnorm


def mamba_init(key, cfg: ModelConfig) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 5)
    return {
        # order: [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * n + h), cfg.param_dtype),
        "conv_w": _dense_init(ks[1], (cfg.conv_width, conv_dim), cfg.param_dtype,
                              scale=1.0 / math.sqrt(cfg.conv_width)),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rmsnorm_init(di, cfg.param_dtype),
        "out_proj": _dense_init(ks[2], (di, d), cfg.param_dtype,
                                scale=1.0 / math.sqrt(di * 2 * cfg.num_layers)),
    }


def _segsum(x):
    """x: (..., T) -> (..., T, T) lower-tri cumulative segment sums."""
    t = x.shape[-1]
    csum = jnp.cumsum(x, axis=-1)
    diff = csum[..., :, None] - csum[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int):
    """SSD scan. x: (B,S,H,P), dt: (B,S,H), a_log: (H,), b/c: (B,S,N).

    Returns y: (B,S,H,P) and final state (B,H,P,N).
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, f"seq {s} % chunk {chunk}"
    nc = s // chunk
    a = -jnp.exp(a_log)                       # (H,)
    dta = (dt * a).astype(jnp.float32)        # (B,S,H)

    # chunked views
    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h)
    dtac = dta.reshape(bs, nc, chunk, h).transpose(0, 1, 3, 2)  # (B,nc,H,Q)
    bc = b.reshape(bs, nc, chunk, n)
    cc = c.reshape(bs, nc, chunk, n)

    dtac_cs = jnp.cumsum(dtac, axis=-1)                         # (B,nc,H,Q)

    # 1. within-chunk (diagonal blocks).
    # Contraction order is hand-decomposed: a single 4-operand einsum lets
    # XLA multiply X in BEFORE reducing s, materializing a rank-6
    # (B,nc,H,Q,Q,P) tensor (537 MB/layer-step on the train_4k dry-run).
    # Decomposed: mask M = CB . L . dt stays (B,nc,H,Q,Q); the X product
    # is a batched (Q,Q)x(Q,P) matmul — MXU-shaped, no rank-6 temps.
    l = jnp.exp(_segsum(dtac))                                  # (B,nc,H,Q,Q)
    cb = jnp.einsum("bcln,bcsn->bcls", cc, bc,
                    preferred_element_type=jnp.float32)         # (B,nc,Q,Q)
    m = cb[:, :, None] * l * dtc.astype(jnp.float32).transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_diag = jnp.einsum("bchls,bcshp->bclhp", m.astype(x.dtype), xc,
                        preferred_element_type=jnp.float32)

    # 2. chunk states (B,nc,H,P,N): decay from position s to end of chunk.
    # Same decomposition: scale X by (decay*dt) first, then one matmul.
    decay_out = jnp.exp(dtac_cs[..., -1:] - dtac_cs)            # (B,nc,H,Q)
    w = (decay_out * dtc.astype(jnp.float32).transpose(0, 1, 3, 2))  # (B,nc,H,Q)
    x_scaled = xc * w.transpose(0, 1, 3, 2)[..., None].astype(x.dtype)
    states = jnp.einsum("bcsn,bcshp->bchpn", bc, x_scaled,
                        preferred_element_type=jnp.float32)

    # 3. inter-chunk recurrence: state_{c+1} = state_c * exp(sum dta_c) + states_c
    chunk_decay = jnp.exp(dtac_cs[..., -1])                     # (B,nc,H)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state ENTERING the chunk

    init = jnp.zeros((bs, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)          # (B,nc,H,P,N)

    # 4. off-diagonal contribution via entering state (decay from chunk
    # start through position l inclusive). Decomposed: contract n first
    # ((Q,N)x(N,P) matmul), then the elementwise decay.
    decay_in = jnp.exp(dtac_cs)                                 # (B,nc,H,Q)
    y_off = jnp.einsum("bcln,bchpn->bclhp", cc,
                       prev_states.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    y_off = y_off * decay_in.transpose(0, 1, 3, 2)[..., None]

    y = (y_diag + y_off).astype(x.dtype).reshape(bs, s, h, p)
    y = y + d_skip[None, None, :, None].astype(x.dtype) * x
    return y, final


def _causal_conv(seq, w, bias):
    """seq: (B,S,C), w: (W,C) depthwise causal."""
    width = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + seq.shape[1], :] * w[i][None, None, :]
        for i in range(width)
    )
    return out + bias[None, None, :]


def mamba_apply(p, cfg: ModelConfig, x: jax.Array):
    """Full-sequence forward. x: (B,S,d) -> (B,S,d), final ssm state."""
    bsz, s, _ = x.shape
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xs, b, c, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xs, b, c], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    y, state = ssd_chunked(
        xs.reshape(bsz, s, h, hp), dt, p["A_log"], b, c, p["D"], cfg.ssm_chunk
    )
    y = y.reshape(bsz, s, di) * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return y @ p["out_proj"], state


class MambaCache:
    """Decode-time state: conv tail + ssm state (pytree via NamedTuple-like)."""


def mamba_decode(p, cfg: ModelConfig, x, conv_state, ssm_state):
    """One-token step. x: (B,1,d); conv_state: (B,W-1,conv_dim);
    ssm_state: (B,H,P,N). Returns (y, conv_state', ssm_state')."""
    bsz = x.shape[0]
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x[:, 0] @ p["in_proj"]                              # (B, ...)
    z, xs, b, c, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xs, b, c], axis=-1)               # (B, conv_dim)
    window = jnp.concatenate([conv_state, conv_in[:, None]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    xs, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])                                     # (H,)
    da = jnp.exp(dt * a)                                         # (B,H)
    xh = xs.reshape(bsz, h, hp).astype(jnp.float32)
    dbx = jnp.einsum("bh,bn,bhp->bhpn", dt, b.astype(jnp.float32), xh)
    ssm_state = ssm_state * da[..., None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, c.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh
    y = (y.reshape(bsz, di) * jax.nn.silu(z).astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return (y @ p["out_proj"])[:, None], window[:, 1:], ssm_state
