"""Sharding rules: logical param axes -> mesh PartitionSpecs.

Mesh axes (launch/mesh.py): ('pod', 'data', 'model') multi-pod or
('data', 'model') single pod. Two parameter-placement modes:

* fsdp=True  — params/opt-state sharded over ('pod','data') too (ZeRO-3
               style); required for llama3-405b / dbrx-132b.
* fsdp=False — params replicated over data (pure DP+TP); required by the
               sparcml sync mode (per-rank gradient compression; see
               DESIGN.md §2.2).

Logical axes used by model code:
  'embed_vocab'  vocab dim of embedding/unembedding    -> 'model'
  'tp'           the tensor-parallel dim of a matmul   -> 'model'
  'fsdp'         the dim FSDP shards                   -> ('pod','data') | None
  'experts'      MoE expert dim                        -> 'model' (EP)
  None           replicated
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def rules(fsdp: bool, mesh: Mesh) -> dict:
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp_axes if (fsdp and dp_axes) else None
    return {
        "embed_vocab": "model",
        "tp": "model",
        "fsdp": dp,
        "experts": "model",
        "dp": dp_axes,  # activation batch axes
        None: None,
    }


def spec(mesh: Mesh, fsdp: bool, *logical_axes) -> P:
    r = rules(fsdp, mesh)
    return P(*(r.get(a, None) for a in logical_axes))


def batch_spec(mesh: Mesh, *trailing) -> P:
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(dp_axes, *trailing)


def sharding(mesh: Mesh, s: Optional[P]) -> NamedSharding:
    return NamedSharding(mesh, s if s is not None else P())


def constrain(x, mesh: Mesh, s: P):
    """with_sharding_constraint if x is traced under this mesh, else no-op."""
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))
    except (ValueError, RuntimeError):
        return x
