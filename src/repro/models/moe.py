"""Mixture-of-Experts layer (dbrx 16e/top-4, moonshot 64e/top-6).

Sort-based dispatch (Megablocks-style, no (T,E,C) one-hot): token->expert
assignments are sorted by expert id, packed into (E, C) capacity slots via
cumulative positions, run through a single batched (E,C,d)x(E,d,ff) einsum,
and combined back with router weights. Overflow beyond the capacity factor
is dropped (standard). Expert weights carry an 'experts' logical axis ->
sharded over 'model' (expert parallelism).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _dense_init


def moe_init(key, cfg: ModelConfig) -> dict:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "wi": _dense_init(ks[1], (e, d, ff), cfg.param_dtype),
        "wg": _dense_init(ks[2], (e, d, ff), cfg.param_dtype),
        "wo": _dense_init(ks[3], (e, ff, d), cfg.param_dtype,
                          scale=1.0 / math.sqrt(ff * 2 * cfg.num_layers)),
    }
    if cfg.moe_shared_ff:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks[4], cfg, d_ff=cfg.moe_shared_ff)
    return p


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.experts_per_token / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # align to 8


def _constrain(x, spec):
    """Guarded with_sharding_constraint (no-op outside a mesh context)."""
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError, TypeError):
        return x


def moe_apply(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x: (T, d) flattened tokens -> (T, d).

    Sharding note (found via dry-run HLO): without constraints the
    partitioner reshards the k-times-duplicated (T*k, d) gathered-token
    buffer between the d-sharded stream and the expert-sharded dispatch
    (201 MB all-gather + all-reduce per layer on moonshot). Replicating
    the (T, d) input FIRST moves the reshard to a 6x smaller tensor; the
    combine-side scatter from expert shards then lowers to a partial
    scatter + (T, d) all-reduce.
    """
    t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = capacity(cfg, t)
    if cfg.act_dp_axes is None:
        # shard_map-manual-dp (sparcml) context: replicate the (T,d) input
        # over 'model' once so dispatch gathers are local (see docstring).
        x = _constrain(x, (None, None))
    else:
        # auto-SPMD: keep batch over dp, free d; the slot gather then only
        # reshards (T,d), not the k-duplicated buffer.
        x = _constrain(x, (tuple(cfg.act_dp_axes), None))

    gates = jax.nn.softmax((x @ p["router"].astype(x.dtype)).astype(jnp.float32))
    w, eidx = jax.lax.top_k(gates, k)                      # (T, k)
    w = (w / (w.sum(-1, keepdims=True) + 1e-9)).astype(x.dtype)

    flat_e = eidx.reshape(-1).astype(jnp.int32)            # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=jnp.int32))
    pos_in_seg = jnp.arange(t * k, dtype=jnp.int32) - seg_start[sorted_e]
    keep = pos_in_seg < c
    slot = jnp.where(keep, sorted_e * c + pos_in_seg, e * c)  # OOB sentinel
    token_of = (order // k).astype(jnp.int32)

    # Inverted dispatch: a slot->token map lets us GATHER from the
    # replicated (T,d) x (local, no collective) instead of scattering
    # (T*k,d) into an expert-sharded buffer (which the partitioner lowers
    # to full-buffer all-reduces — found via dry-run HLO). The small i32
    # maps are the only resharded scatters.
    slot_token = jnp.full((e * c,), t, jnp.int32).at[slot].set(
        token_of, mode="drop")                                 # T = empty
    slot_w = jnp.zeros((e * c,), x.dtype).at[slot].set(
        w.reshape(-1)[order], mode="drop")
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)])   # sentinel row
    xin = _constrain(x_pad[slot_token].reshape(e, c, d), ("model", None, None))
    h = jnp.einsum("ecd,edf->ecf", xin, p["wi"].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", xin, p["wg"].astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"].astype(x.dtype))

    # Combine: scatter-add expert outputs back to tokens (partial scatter
    # per expert shard + one (T,d) all-reduce — the cheap direction).
    upd = out.reshape(e * c, d) * slot_w[:, None]
    y = jnp.zeros((t + 1, d), x.dtype).at[slot_token].add(upd, mode="drop")[:t]

    if cfg.moe_shared_ff:
        from repro.models.layers import mlp
        y = y + mlp(p["shared"], cfg, x)
    return y


# --------------------------------------------------------------------------
# Serve-time dispatch (continuous batching, DESIGN.md §8)
# --------------------------------------------------------------------------

class ServeDispatch(NamedTuple):
    """How a decode step's expert dispatch crosses devices at serve time.

    ``exchange`` is the planned combine exchange (built by the serve
    engine from the ServePlan + comm executor — models/ stays ignorant of
    comm/): (p_shards, T, d) stacked per-expert-shard combine partials ->
    the fully-summed (T, d). ``active`` masks the live request slots out
    of routing so retired/empty slots never consume expert capacity or
    touch the wire."""

    active: jax.Array             # (T,) bool — live decode slots
    exchange: Any                 # callable (p_shards, T, d) -> (T, d)
    p_shards: int                 # expert-parallel world size


def moe_apply_serve(p, cfg: ModelConfig, x: jax.Array,
                    dispatch: ServeDispatch) -> jax.Array:
    """Serve-time variant of :func:`moe_apply` for one decode step.

    Differences from the training path, both required for continuous
    batching to reproduce per-request decode token-for-token:

    * drop-free capacity ``c = T``: top-k experts per token are distinct,
      so no expert ever sees more than T rows — an active token's output
      can never depend on which OTHER requests share the batch;
    * inactive slots are routed to a sentinel expert id (dropped before
      packing), so they neither consume capacity nor contribute rows;
    * the combine is materialized as PER-EXPERT-SHARD partials (shard s
      owns the contiguous expert range [s*e/p, (s+1)*e/p)) and summed
      through the planned ``dispatch.exchange`` — the seam where the
      ServePlan chooses dense psum vs the (idx,val) row-stream wire.
    """
    t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = t                                          # drop-free serve capacity
    x = _constrain(x, (None, None))

    gates = jax.nn.softmax((x @ p["router"].astype(x.dtype)).astype(jnp.float32))
    w, eidx = jax.lax.top_k(gates, k)                      # (T, k)
    w = (w / (w.sum(-1, keepdims=True) + 1e-9)).astype(x.dtype)

    flat_e = eidx.reshape(-1).astype(jnp.int32)            # (T*k,)
    # Inactive slots -> sentinel expert e: sorts after every real expert,
    # so it shifts no seg_start and lands outside the (e*c,) buffers.
    flat_e = jnp.where(jnp.repeat(dispatch.active, k), flat_e, e)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=jnp.int32))
    pos_in_seg = jnp.arange(t * k, dtype=jnp.int32) - \
        seg_start[jnp.minimum(sorted_e, e - 1)]
    keep = (pos_in_seg < c) & (sorted_e < e)
    slot = jnp.where(keep, sorted_e * c + pos_in_seg, e * c)  # OOB sentinel
    token_of = (order // k).astype(jnp.int32)

    slot_token = jnp.full((e * c,), t, jnp.int32).at[slot].set(
        token_of, mode="drop")                                 # T = empty
    slot_w = jnp.zeros((e * c,), x.dtype).at[slot].set(
        w.reshape(-1)[order], mode="drop")
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)])   # sentinel row
    xin = _constrain(x_pad[slot_token].reshape(e, c, d), ("model", None, None))
    h = jnp.einsum("ecd,edf->ecf", xin, p["wi"].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", xin, p["wg"].astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"].astype(x.dtype))

    # Per-shard combine partials: shard s scatter-adds ONLY its own
    # experts' rows -> row-sparse (T, d) partial (nonzero rows are the
    # active tokens routed here). The planned exchange owns the sum.
    upd = out.reshape(e * c, d) * slot_w[:, None]
    p_sh = dispatch.p_shards
    assert e % p_sh == 0, (e, p_sh)
    span = (e // p_sh) * c
    parts = []
    for s in range(p_sh):
        st = jax.lax.slice_in_dim(slot_token, s * span, (s + 1) * span)
        su = jax.lax.slice_in_dim(upd, s * span, (s + 1) * span)
        parts.append(jnp.zeros((t + 1, d), x.dtype).at[st].add(
            su, mode="drop")[:t])
    # NO sharding constraint on the stacked partials: a ("model",None,None)
    # constraint here — scatter output, inside the decode layer scan —
    # SILENTLY miscompiles on the pinned XLA-CPU partitioner (active-row
    # values change by O(1); found via the serve parity tests, DESIGN.md
    # §5.4). The exchange owns any resharding it needs.
    y = dispatch.exchange(jnp.stack(parts))

    if cfg.moe_shared_ff:
        from repro.models.layers import mlp
        y = y + mlp(p["shared"], cfg, x)
    return y
