"""Path-based PartitionSpec rules for model parameters.

Leading stack axes (layer / superblock nesting) are always unsharded; the
trailing named dims follow MaxText-style TP/FSDP rules. ``fsdp`` is the
tuple of data axes (('pod','data')) or None for DP-replicated placement
(required by sparcml sync — DESIGN.md §2.2).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


def _leaf_spec(path: tuple, leaf, fsdp, cfg: ModelConfig) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    ndim = len(leaf.shape)

    def with_stack(*trailing) -> P:
        """Pad leading stack axes with None."""
        lead = ndim - len(trailing)
        return P(*([None] * lead + list(trailing)))

    if name == "embed":
        # d_model over TP, vocab REPLICATED: a token gather over a
        # vocab-sharded table forces GSPMD to replicate its output (and
        # with it the whole residual stream) — found via dry-run HLO.
        return P(None, "model")
    if name == "unembed":
        return P(fsdp, "model")
    if name == "vision_proj" or name == "frontend_proj":
        return P(fsdp, "model")
    if name == "pos_embed":
        return P(None, fsdp)

    in_moe = "moe" in names
    if in_moe and name in ("wi", "wg"):
        return with_stack("model", fsdp, None)   # (E,d,ff): EP over experts
    if in_moe and name == "wo":
        return with_stack("model", None, fsdp)
    if name == "router":
        return with_stack(None, None)

    if name in ("wq", "wk", "wv", "wi", "wg", "in_proj"):
        return with_stack(fsdp, "model")
    if name in ("wo", "out_proj"):
        return with_stack("model", fsdp)

    # norms, gates, conv, A_log, D, dt_bias, scale ... replicated
    return P()


def param_specs(params_or_shapes, cfg: ModelConfig, fsdp_axes: Optional[tuple]):
    """Pytree of PartitionSpecs matching the params tree.

    fsdp_axes: e.g. ('pod','data') for ZeRO-3 placement, None for
    DP-replicated (sparcml mode).
    """
    fsdp = fsdp_axes if fsdp_axes else None

    def one(path, leaf):
        spec = _leaf_spec(path, leaf, fsdp, cfg)
        # Never shard a dim that the axis size doesn't divide; XLA would
        # error at lower time. Replace such entries with None.
        return spec

    return jax.tree_util.tree_map_with_path(one, params_or_shapes)


def validate_divisibility(shapes, specs, mesh) -> list[str]:
    """Return a list of leaves whose sharded dims don't divide evenly
    (informational; XLA pads, but uneven shards waste memory)."""
    bad = []

    def check(path, sds, spec):
        for dim, names in zip(sds.shape, tuple(spec) + (None,) * 8):
            if names is None:
                continue
            for nm in (names if isinstance(names, tuple) else (names,)):
                sz = mesh.shape[nm]
                if dim % sz:
                    bad.append(f"{jax.tree_util.keystr(path)}: {dim} % {nm}={sz}")

    jax.tree_util.tree_map_with_path(check, shapes, specs)
    return bad
