"""Train state + top-level training configuration."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional

import jax

from repro.core.compressor import SyncConfig
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig


class TrainState(NamedTuple):
    params: Any
    opt: Any
    residuals: Any       # EF state: bucket-keyed dict {name: (dp, rows,
                         # cols)} from the SyncPlan (sparcml) or None
    step: jax.Array      # i32 scalar
    inflight: Any = None # non-blocking runtime (DESIGN.md §6): bucket-
                         # keyed dict {name: (rows, cols)} of REDUCED
                         # buffers from the previous superstep, applied
                         # this step (staleness>=1); None when synchronous.
                         # Stripped before checkpointing — dropping the
                         # one in-flight gradient on restart is the same
                         # lossy-accumulator deal as the EF reset (§2.3).


@dataclass(frozen=True)
class TrainConfig:
    sync: SyncConfig = field(default_factory=SyncConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    schedule: ScheduleConfig = field(default_factory=ScheduleConfig)
    microbatches: int = 1            # gradient-accumulation steps
    fsdp: bool = False               # ZeRO-3 param placement (dense mode only)
    zero1: bool = True               # shard opt state over dp in sparcml mode
    seed: int = 0

    def __post_init__(self):
        if self.fsdp and self.sync.mode == "sparcml":
            raise ValueError(
                "sparcml sync requires DP-replicated params (fsdp=False): "
                "per-rank error-feedback residuals are O(model) per rank and "
                "cannot compose with ZeRO-3 sharding — see DESIGN.md "
                "§Arch-applicability and the paper's §8.4 ResNet50 discussion."
            )
