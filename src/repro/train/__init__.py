from repro.train.state import TrainState, TrainConfig  # noqa: F401
from repro.train.train_step import build_train_step, init_state  # noqa: F401
