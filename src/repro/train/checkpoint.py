"""Checkpointing: atomic, resumable, mesh-shape-aware.

Layout:  <dir>/step_<N>/
            arrays.npz     flattened leaves by index
            meta.json      step, tree structure token, leaf paths, dp_total

* Atomic + durable: written to step_<N>.tmp, each file fsync'd, then
  os.replace'd and the parent directory fsync'd (the same discipline as
  obs/recorder.py) — a crash mid-save never corrupts the latest
  checkpoint, and a completed save survives power loss.
* Integrity (DESIGN.md §12.4): meta.json records a CRC32 per stored
  array; ``verify_checkpoint`` recomputes them, ``restore(...,
  verify=True)`` refuses a corrupt read (``CheckpointCorrupt``), and
  ``latest_valid_step`` walks newest->oldest to the first checkpoint
  that verifies — keep-N retention doubles as the fallback window.
* Elastic restarts: leaves whose shapes depend on the replica count
  (error-feedback residuals, ZeRO-1 chunks) are re-initialized /
  re-chunked when the mesh changes (`restore(..., remesh=True)`): the EF
  residual is a lossy accumulator, so resetting it on a resize is safe
  (one step of slightly stale compression — documented in DESIGN.md §2.3).
* Multi-host note: this writes the full addressable state from host 0;
  on a real pod each host would write its addressable shards (same API,
  path per host) — the layout keeps leaf paths stable for that extension.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.state import TrainState


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed CRC verification (or could not be read).
    Classified as the 'ckpt_corrupt' fault class by the recovery
    supervisor (runtime/faults.py keys on the class NAME to avoid a
    train<->runtime import cycle — keep it if renaming)."""


def _crc32(arr: np.ndarray) -> int:
    import zlib

    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _fsync_path(path: str) -> None:
    """fsync an already-written file (or directory) by path."""
    flags = os.O_RDONLY | (os.O_DIRECTORY if os.path.isdir(path) else 0)
    fd = os.open(path, flags)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)
    paths = [jax.tree_util.keystr(p) for p, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves


def save(directory: str, state: TrainState, *, dp_total: int,
         keep_last: int = 3, async_save: bool = False,
         extra_meta: Optional[dict] = None,
         opt_layout: Optional[str] = None) -> str:
    """``extra_meta`` is merged into meta.json (JSON-serializable only) —
    the adaptive runtime stores the ACTIVE plan's signature and per-bucket
    algorithm map there, so a restart resumes onto the adapted plan
    (DESIGN.md §7) instead of re-warming from the static one.

    ``opt_layout`` stamps the optimizer-state layout (one of
    ``OPT_LAYOUTS``) into meta so a reader under the OTHER ZeRO layout can
    convert on resume (DESIGN.md §11); omitted = reader assumes its own."""
    step = int(state.step)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    paths, leaves = _flatten_with_paths(state)
    host_leaves = [None if l is None else np.asarray(jax.device_get(l)) for l in leaves]

    def _write():
        arrays = {f"leaf_{i}": a for i, a in enumerate(host_leaves) if a is not None}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        meta = {
            "step": step,
            "dp_total": dp_total,
            "paths": paths,
            "none_leaves": [i for i, a in enumerate(host_leaves) if a is None],
            # integrity record (§12.4): CRC32 per stored array, verified
            # by verify_checkpoint / restore(verify=True)
            "crc32": {k: _crc32(a) for k, a in arrays.items()},
        }
        if opt_layout is not None:
            if opt_layout not in OPT_LAYOUTS:
                raise ValueError(f"unknown opt_layout {opt_layout!r}")
            meta["opt_layout"] = opt_layout
        if extra_meta:
            meta.update(extra_meta)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        # durability: file contents, then the rename, then the dirent
        _fsync_path(os.path.join(tmp, "arrays.npz"))
        _fsync_path(tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _fsync_path(directory)
        _gc(directory, keep_last)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return final
    _write()
    return final


def _gc(directory: str, keep_last: int):
    ckpts = sorted(
        d for d in os.listdir(directory)
        if re.fullmatch(r"step_\d{8}", d)
    )
    for d in ckpts[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def load_meta(directory: str, step: Optional[int] = None) -> dict:
    """The meta.json of one checkpoint (latest by default) — including
    any ``extra_meta`` the writer attached (e.g. the adaptive plan)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    with open(os.path.join(directory, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(
        d for d in os.listdir(directory)
        if re.fullmatch(r"step_\d{8}", d)
    )
    return int(ckpts[-1].split("_")[1]) if ckpts else None


def verify_checkpoint(directory: str, step: int) -> bool:
    """Recompute every stored array's CRC32 against meta.json. True iff
    the checkpoint is readable and every digest matches. A legacy
    checkpoint with no ``crc32`` record verifies by readability alone
    (pre-§12.4 writers — nothing to compare against)."""
    d = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as data:
            crcs = meta.get("crc32")
            if crcs is None:
                _ = [data[k].shape for k in data.files]  # readability only
                return True
            if set(crcs) != set(data.files):
                return False
            return all(_crc32(data[k]) == int(crcs[k]) for k in data.files)
    except Exception:
        return False


def latest_valid_step(directory: str) -> Optional[int]:
    """Newest step whose checkpoint passes :func:`verify_checkpoint` —
    the restore target of the fault-tolerant driver. Keep-N retention
    bounds the walk; None when nothing under ``directory`` verifies."""
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(
        (d for d in os.listdir(directory) if re.fullmatch(r"step_\d{8}", d)),
        reverse=True)
    for d in ckpts:
        step = int(d.split("_")[1])
        if verify_checkpoint(directory, step):
            return step
    return None


def restore(directory: str, like: TrainState, *, dp_total: int,
            step: Optional[int] = None, shardings=None,
            remesh: bool = False, verify: bool = False) -> TrainState:
    """Restore into the structure/shapes of `like` (abstract or concrete).

    remesh=True allows restoring a checkpoint written under a different
    dp_total: replica-dependent leaves (leading axis == old dp_total but
    != new) are reset to zeros of the new shape.

    verify=True recomputes the per-array CRC32s before any value is
    consumed and raises :class:`CheckpointCorrupt` on mismatch — callers
    with a retention window then fall back via :func:`latest_valid_step`.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    if verify and not verify_checkpoint(directory, step):
        raise CheckpointCorrupt(
            f"checkpoint step_{step:08d} under {directory} fails CRC "
            "verification")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    none_set = set(meta["none_leaves"])

    paths, like_leaves = _flatten_with_paths(like)
    assert paths == meta["paths"], "checkpoint/state structure mismatch"
    out = []
    for i, ll in enumerate(like_leaves):
        if ll is None or i in none_set:
            out.append(None)
            continue
        arr = data[f"leaf_{i}"]
        want = tuple(ll.shape)
        if arr.shape != want:
            if remesh and meta["dp_total"] != dp_total:
                arr = _rechunk(arr, want, meta["dp_total"], dp_total)
            else:
                raise ValueError(
                    f"shape mismatch at {paths[i]}: ckpt {arr.shape} vs {want} "
                    f"(use remesh=True for elastic restarts)")
        out.append(jnp.asarray(arr.astype(ll.dtype)))
    treedef = jax.tree_util.tree_structure(like, is_leaf=lambda x: x is None)
    state = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state


# --------------------------------------------------------------------------
# Optimizer-layout interop (DESIGN.md §11)
# --------------------------------------------------------------------------
# Three on-disk optimizer layouts exist:
#   "full"           param-shaped moments (dense mode / zero1=False)
#   "zero1_leaf"     per-LEAF canonical chunks (dp, rows, cols_leaf/dp)
#   "zero_scattered" per-BUCKET owned-range chunks (dp, rows, cols_bkt/dp)
# The two ZeRO layouts are different partitions of the SAME canonical
# coordinates, and the optimizer is elementwise, so conversion through the
# full canonical group buffer is value-exact: a run checkpointed under
# either mode resumes under the other with identical per-coordinate
# moments. Writers stamp meta["opt_layout"]; readers convert when theirs
# differs (Trainer.init_or_resume).

OPT_LAYOUTS = ("full", "zero1_leaf", "zero_scattered")


def opt_layout_of(tcfg) -> str:
    """The optimizer-state layout a TrainConfig trains under — fully
    determined by the config (state_shapes enforces the same mapping)."""
    if tcfg.sync.mode == "sparcml":
        if getattr(tcfg.sync, "output_mode", "replicated") == "scattered":
            return "zero_scattered"
        if tcfg.zero1:
            return "zero1_leaf"
    return "full"


def _moment_scattered_to_leaf(moment: dict, plan, params):
    """{bucket: (dp, rows, w)} -> params-structured tree of per-leaf
    (dp, rows, cols_leaf/dp) chunks, via the full group buffer."""
    p = plan.dp_total
    leaf_chunks: list = [None] * plan.num_leaves
    for g in plan.groups:
        buf = None
        for b in g.buckets:
            ch = np.asarray(moment[b.name])           # (dp, rows, w)
            if buf is None:
                buf = np.zeros((g.rows, g.cols), ch.dtype)
            full = ch.transpose(1, 0, 2).reshape(g.rows, b.cols)
            buf[:, b.col_start:b.col_start + b.cols] = full
        for s in g.slots:
            seg = buf[:, s.offset:s.offset + s.cols]
            w = s.cols // p
            leaf_chunks[s.leaf_id] = jnp.asarray(
                seg.reshape(g.rows, p, w).transpose(1, 0, 2))
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, leaf_chunks)


def _moment_leaf_to_scattered(moment, plan) -> dict:
    """params-structured tree of per-leaf chunks -> {bucket: (dp, rows, w)}
    owned-range chunks. Padding/gap columns zero-fill (they carry no
    parameter and their moments start — and in the leaf layout remain —
    zero)."""
    p = plan.dp_total
    leaves = jax.tree_util.tree_leaves(moment)        # leaf_id order
    out: dict = {}
    for g in plan.groups:
        dtype = np.asarray(leaves[g.slots[0].leaf_id]).dtype
        buf = np.zeros((g.rows, g.cols), dtype)
        for s in g.slots:
            ch = np.asarray(leaves[s.leaf_id])        # (dp, rows, w_leaf)
            buf[:, s.offset:s.offset + s.cols] = \
                ch.transpose(1, 0, 2).reshape(g.rows, s.cols)
        for b in g.buckets:
            seg = buf[:, b.col_start:b.col_start + b.cols]
            w = b.cols // p
            out[b.name] = jnp.asarray(
                seg.reshape(g.rows, p, w).transpose(1, 0, 2))
    return out


def convert_opt_layout(state: TrainState, plan, source: str,
                       target: str) -> TrainState:
    """Convert ``state.opt`` between the two ZeRO layouts (value-exact,
    see module note above). ``plan`` is the SyncPlan whose geometry both
    layouts chunk against. full <-> sharded is not supported: the full
    layout has no canonical chunking to map through."""
    if source == target:
        return state
    pair = {source, target}
    if pair != {"zero1_leaf", "zero_scattered"}:
        raise ValueError(
            f"cannot convert opt layout {source!r} -> {target!r}; only "
            "zero1_leaf <-> zero_scattered interop is supported")
    conv = (_moment_leaf_to_scattered if target == "zero_scattered"
            else lambda m, pl: _moment_scattered_to_leaf(m, pl, state.params))
    opt = dict(state.opt)
    opt["mu"] = conv(state.opt["mu"], plan)
    if "nu" in state.opt:
        opt["nu"] = conv(state.opt["nu"], plan)
    return state._replace(opt=opt)


def _rechunk(arr: np.ndarray, want: tuple, old_dp: int, new_dp: int) -> np.ndarray:
    """Re-partition replica-dependent leaves across a different dp size.

    ZeRO-1 chunks (old_dp, rows, w_old): gather cols -> re-split.
    EF residuals (old_dp, rows, cols): lossy accumulator -> reset.
    """
    if arr.ndim == 3 and arr.shape[0] == old_dp and want[0] == new_dp:
        if arr.shape[1] == want[1] and arr.shape[2] * old_dp == want[2] * new_dp:
            full = np.concatenate([arr[i] for i in range(old_dp)], axis=1)
            return np.stack(np.split(full, new_dp, axis=1))
        return np.zeros(want, arr.dtype)  # residual: reset (documented lossy)
    raise ValueError(f"cannot rechunk {arr.shape} -> {want}")
