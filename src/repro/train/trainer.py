"""Fault-tolerant training driver.

Responsibilities beyond the jitted step:
  * checkpoint every N steps (atomic) + resume-from-latest on start,
  * survive injected/step failures: restore last checkpoint and continue
    (the data pipeline is keyed by step, so replayed batches are identical),
  * straggler watchdog: per-step wall time vs a running median; a step
    exceeding ``straggler_factor`` x median is logged and counted — on a
    real pod this feeds the skip/backup-worker policy; in-process it is
    observability (SPMD has no per-host stragglers to act on),
  * elastic restart: `resume(new_mesh)` re-chunks replica-dependent state
    (see checkpoint.restore(remesh=True)).

Two main loops: :meth:`Trainer.run` (synchronous reference — dispatch one
step, block on its loss) and :meth:`Trainer.run_pipelined` (non-blocking
runtime, DESIGN.md §6 — pipelined stale-gradient supersteps driven by the
double-buffered async driver in ``repro/runtime``). Checkpoints written
by either loop are interchangeable: the pipelined loop strips the
in-flight bucket buffers before saving and re-attaches zeros on resume.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.data.pipeline import DataConfig, synthetic_batch
from repro.obs import resolve as _resolve_obs
from repro.runtime.driver import DriverLog, record_step
from repro.train import checkpoint as ckpt
from repro.train.state import TrainConfig, TrainState
from repro.train.train_step import build_train_step, dp_total_of, init_state

# One log type for both loops (registry-backed, DESIGN.md §10); the name
# survives for PR-2 callers that import TrainerLog.
TrainerLog = DriverLog


class Trainer:
    def __init__(self, model, tcfg: TrainConfig, mesh, data_cfg: DataConfig,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 straggler_factor: float = 3.0, obs=None):
        self.model = model
        self.tcfg = tcfg
        self.mesh = mesh
        self.data_cfg = data_cfg
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.obs = _resolve_obs(obs)
        self.log = TrainerLog(
            registry=self.obs.metrics if self.obs.metrics_on else None)
        self.step_fn, (self.shapes, self.specs) = build_train_step(model, tcfg, mesh)
        self.state: Optional[TrainState] = None
        self._root_key = jax.random.PRNGKey(tcfg.seed)
        # the AdaptiveRuntime of the most recent run_pipelined(adapt=...)
        # call (None otherwise) — exposes the active plan for
        # inspection/tests; the checkpoint meta is the durable record
        self.last_adapt_runtime = None
        # the SyncPlan the most recent run_pipelined compiled against
        # (adaptive runs: the plan active at exit) — what the examples
        # hand to obs.audit_sync_plan after the run
        self.last_plan = None
        # the HealthMonitor of the most recent metrics-on run_pipelined
        # (None otherwise) — its .history holds the ranked verdicts
        self.last_health = None

    # -- lifecycle ---------------------------------------------------------
    def init_or_resume(self):
        self.state, _ = init_state(self.model, self.tcfg, self.mesh)
        if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
            self.state = self._restore_any_layout()
            self.log.restarts += 1
        return int(self.state.step)

    def _restore_any_layout(self):
        """Restore the latest checkpoint, converting the optimizer state
        when it was written under the OTHER ZeRO layout (DESIGN.md §11):
        restore into a template of the checkpoint's own layout, then map
        the moments through the full canonical buffer — value-exact, so a
        scattered run resumes a replicated checkpoint (and vice versa)
        with identical per-coordinate optimizer state."""
        import dataclasses

        from repro.train import train_step as ts

        dp_total = dp_total_of(self.mesh)
        my_layout = ckpt.opt_layout_of(self.tcfg)
        step = self._verified_step()
        meta = ckpt.load_meta(self.ckpt_dir, step)
        ck_layout = meta.get("opt_layout", my_layout)
        if ck_layout == my_layout:
            return ckpt.restore(self.ckpt_dir, self.state, dp_total=dp_total,
                                step=step, verify=True)
        other_mode = {"zero_scattered": "scattered",
                      "zero1_leaf": "replicated"}.get(ck_layout)
        if other_mode is None or my_layout == "full":
            raise ValueError(
                f"checkpoint opt layout {ck_layout!r} is not resumable "
                f"under {my_layout!r} (only zero1_leaf <-> zero_scattered)")
        other_tcfg = dataclasses.replace(
            self.tcfg,
            sync=dataclasses.replace(self.tcfg.sync, output_mode=other_mode))
        other_shapes, _, _ = ts.state_shapes(self.model, other_tcfg,
                                             self.mesh, return_plan=True)
        restored = ckpt.restore(self.ckpt_dir, other_shapes,
                                dp_total=dp_total, step=step, verify=True)
        _, _, plan = ts.state_shapes(self.model, self.tcfg, self.mesh,
                                     return_plan=True)
        return ckpt.convert_opt_layout(restored, plan, source=ck_layout,
                                       target=my_layout)

    def _verified_step(self) -> int:
        """The restore target under the integrity policy (DESIGN.md
        §12.4): the newest checkpoint that passes CRC verification.
        Falling back past a corrupt newest checkpoint is a
        ``recovery/ckpt_fallback`` event; nothing verifying is a clean
        abort (CheckpointCorrupt)."""
        newest = ckpt.latest_step(self.ckpt_dir)
        step = ckpt.latest_valid_step(self.ckpt_dir)
        if step is None:
            raise ckpt.CheckpointCorrupt(
                f"no checkpoint under {self.ckpt_dir} passes CRC "
                "verification (retention window exhausted)")
        if step != newest:
            self.obs.event("recovery/ckpt_fallback", step=step,
                           corrupt_step=newest)
            if self.obs.metrics_on:
                self.obs.metrics.counter("recovery/ckpt_fallbacks").inc()
        return step

    def resume_elastic(self, new_mesh):
        """Elastic restart onto a different mesh (pod count change)."""
        self.mesh = new_mesh
        self.step_fn, (self.shapes, self.specs) = build_train_step(
            self.model, self.tcfg, new_mesh)
        self.state, _ = init_state(self.model, self.tcfg, new_mesh)
        if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
            self.state = ckpt.restore(
                self.ckpt_dir, self.state, dp_total=dp_total_of(new_mesh),
                remesh=True)
        return int(self.state.step)

    # -- main loop ---------------------------------------------------------
    def run(self, num_steps: int, fail_at: Optional[int] = None) -> TrainerLog:
        """Train for num_steps (absolute). fail_at injects a fault for tests."""
        if self.state is None:
            self.init_or_resume()
        if self.state.inflight is not None:
            # hand-off from a pipelined run: drop the in-flight reduction
            # (one step of gradients — the documented lossy-accumulator
            # deal, same as the EF reset on elastic restarts)
            self.state = self.state._replace(inflight=None)
        with self.mesh:
            while int(self.state.step) < num_steps:
                step = int(self.state.step)
                batch = jax.tree.map(
                    jax.numpy.asarray, synthetic_batch(self.data_cfg, step))
                key = jax.random.fold_in(self._root_key, step)
                t0 = time.perf_counter()
                try:
                    if fail_at is not None and step == fail_at:
                        fail_at = None  # fail exactly once
                        raise RuntimeError("injected node failure")
                    new_state, metrics = self.step_fn(self.state, batch, key)
                    jax.block_until_ready(metrics["loss"])
                except Exception:
                    # node-failure path: restore + replay
                    if not self.ckpt_dir:
                        raise
                    self.log.restarts += 1
                    self.state = ckpt.restore(
                        self.ckpt_dir, self._abstract_like(),
                        dp_total=dp_total_of(self.mesh))
                    continue
                dt = time.perf_counter() - t0
                self.state = new_state
                record_step(self.log, step, dt, float(metrics["loss"]),
                            self.straggler_factor)
                if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                    ckpt.save(self.ckpt_dir, self.state,
                              dp_total=dp_total_of(self.mesh),
                              opt_layout=ckpt.opt_layout_of(self.tcfg))
        if self.ckpt_dir:
            ckpt.save(self.ckpt_dir, self.state, dp_total=dp_total_of(self.mesh),
                      opt_layout=ckpt.opt_layout_of(self.tcfg))
        return self.log

    # -- non-blocking runtime (DESIGN.md §6/§7) ----------------------------
    def run_pipelined(self, num_steps: int, *, staleness: int = 1,
                      superstep: int = 4, depth: int = 2,
                      prefetch: int = 2, unroll: bool = False,
                      adapt=False, guard: bool = True, injector=None,
                      recovery=None) -> TrainerLog:
        """Train for num_steps (absolute) with the pipelined runtime:
        K-step scanned supersteps (stale-gradient overlap, ``staleness``
        in {0, 1}) dispatched ``depth`` deep by the async host driver,
        with background data prefetch. Logging and checkpoints sync only
        on retired steps; checkpoints store the synchronous state shape
        (in-flight buffers stripped), so sync and pipelined runs resume
        from each other's checkpoints.

        ``adapt`` (False | True | runtime.adapt.AdaptConfig) turns on
        closed-loop re-planning (DESIGN.md §7): per-bucket measured
        densities feed the calibrated cost model, and accepted replans
        swap the compiled superstep at drain barriers. Checkpoints then
        carry the active plan signature + algorithm map, so a restart
        resumes the ADAPTED plan.

        Fault tolerance (DESIGN.md §12): ``guard=True`` (default) builds
        the GUARDED step — non-finite gradients skip the apply with EF
        residuals and optimizer state preserved exactly, and escalate to
        a checkpoint rewind after N consecutive trips. ``recovery`` (a
        ``runtime.faults.RecoveryConfig``) bounds the driver's restore
        loop with per-fault-class retry budgets + jittered backoff.
        ``injector`` (a ``runtime.faults.FaultInjector``) runs the chaos
        plan against this run: grad-leaf NaN/Inf via the batch-carried
        fault vector, prefetch stalls, collective raises, stragglers,
        post-save checkpoint corruption, SIGTERM."""
        from repro.data.pipeline import synthetic_batch
        from repro.runtime import adapt as rt_adapt
        from repro.runtime import driver as rt_driver
        from repro.runtime import pipeline as rt_pipeline

        if self.state is None:
            self.init_or_resume()
        inject = injector is not None
        if inject:
            # the injector's grad-flag vector is indexed by grad leaf
            # (== param leaf) order — the same flatten the step body uses
            injector.bind(
                n_leaves=len(jax.tree_util.tree_leaves(self.state.params)))

        runtime = None
        plan0 = None
        if adapt:
            from repro.train import train_step as ts

            if staleness < 1:
                raise ValueError("adaptive re-planning rides the pipelined "
                                 "runtime: needs staleness >= 1")
            acfg = (adapt if isinstance(adapt, rt_adapt.AdaptConfig)
                    else rt_adapt.AdaptConfig())
            _, _, base_plan = ts.state_shapes(self.model, self.tcfg,
                                              self.mesh, return_plan=True)
            plan0 = base_plan
            if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
                meta = ckpt.load_meta(self.ckpt_dir)
                algos = meta.get("plan_algorithms")
                if algos:
                    plan0 = base_plan.replan(
                        algorithms=algos,
                        pod_sparse=meta.get("plan_pod_sparse"))
            runtime = rt_adapt.AdaptiveRuntime(
                self.model, self.tcfg, self.mesh, plan=plan0,
                net=self._calibrated_net(acfg), cfg=acfg,
                staleness=staleness, superstep=superstep, unroll=unroll,
                obs=self.obs, guard=guard, inject=inject)
            self.last_adapt_runtime = runtime
            fn, plan = runtime.current_fn(), runtime.current_plan
        else:
            # no controller to consume stats: compile the telemetry in
            # only when a metrics registry will record it (off = the
            # PR-2 step, byte-identical jaxpr)
            telemetry = self.obs.metrics_on
            if superstep > 1:
                fn, _, plan = rt_pipeline.build_superstep(
                    self.model, self.tcfg, self.mesh, staleness=staleness,
                    steps=superstep, unroll=unroll, telemetry=telemetry,
                    guard=guard, inject=inject)
            else:
                fn, _, plan = rt_pipeline.build_pipelined_step(
                    self.model, self.tcfg, self.mesh, staleness=staleness,
                    telemetry=telemetry, guard=guard, inject=inject)
            if telemetry:
                runtime = rt_adapt.TelemetryObserver(self.obs)
        state = self.state
        if staleness:
            state = rt_pipeline.attach_inflight(state, plan, self.mesh)
        elif state.inflight is not None:
            state = state._replace(inflight=None)

        dp_total = dp_total_of(self.mesh)

        def ckpt_fn(s):
            extra = None
            active = getattr(runtime, "current_plan", None)
            if active is not None:
                extra = {"plan_signature": active.signature(),
                         "plan_version": active.version,
                         "plan_algorithms": active.algorithms(),
                         "plan_pod_sparse": active.pod_sparse_flags()}
            ckpt.save(self.ckpt_dir, s._replace(inflight=None),
                      dp_total=dp_total, extra_meta=extra,
                      opt_layout=ckpt.opt_layout_of(self.tcfg))
            if inject:
                # chaos hook: a scheduled ckpt_corrupt spec flips bytes
                # in the save that just landed; the CRC fallback below
                # is what must survive it
                injector.corrupt_checkpoint(self.ckpt_dir, int(s.step))

        def restore_fn():
            restored = ckpt.restore(
                self.ckpt_dir,
                self._abstract_like()._replace(inflight=None),
                dp_total=dp_total, step=self._verified_step(), verify=True)
            if staleness:
                restored = rt_pipeline.attach_inflight(restored, plan,
                                                       self.mesh)
            return restored

        phase_attr = None
        if self.obs.trace_on:
            # Derived device-phase attribution (DESIGN.md §10): lay the
            # cost model's compute / exposed-comm split of the ACTIVE
            # plan into each retire interval. Host arithmetic only.
            from repro.core.cost_model import DEFAULT_NET, plan_bucket_times
            from repro.obs import attribute_step_phases

            attr_net = getattr(self, "_net_cal", None) or DEFAULT_NET

            def phase_attr(dt_unit: float) -> list:
                active = getattr(runtime, "current_plan", None) or plan
                tb = plan_bucket_times(active, net=attr_net)
                names = [b.name for b in active.buckets]
                k = max(1, superstep)
                per = attribute_step_phases(dt_unit / k, tb, names=names,
                                            staleness=staleness)
                out = []
                for i in range(k):
                    base = i * dt_unit / k
                    out.extend({**ph, "offset_s": base + ph["offset_s"]}
                               for ph in per)
                return out

        health = None
        if self.obs.metrics_on:
            # compression-health rules over the run's registry: EF-norm
            # growth / mass-coverage floor on the executor's mass
            # telemetry, step-time p99 regression on the driver series;
            # evaluated at drain barriers + end of run (DESIGN.md §10.5)
            from repro.obs.health import HealthMonitor

            health = HealthMonitor(self.obs.metrics,
                                   audit=getattr(self.obs, "audit", None))
            self.last_health = health

        with self.mesh:
            state, _ = rt_driver.run_pipelined(
                fn, state,
                start_step=int(state.step), num_steps=num_steps,
                batch_fn=lambda step: synthetic_batch(self.data_cfg, step),
                key_fn=lambda step: jax.random.fold_in(self._root_key, step),
                cfg=rt_driver.DriverConfig(depth=depth, prefetch=prefetch,
                                           steps_per_unit=superstep),
                log=self.log, straggler_factor=self.straggler_factor,
                ckpt_every=self.ckpt_every if self.ckpt_dir else None,
                ckpt_fn=ckpt_fn if self.ckpt_dir else None,
                restore_fn=restore_fn if self.ckpt_dir else None,
                adapt=runtime,
                obs=self.obs, phase_attr=phase_attr,
                health=health,
                recovery=recovery, injector=injector,
            )
        self.state = state
        self.last_plan = getattr(runtime, "current_plan", None) or plan
        if self.ckpt_dir:
            ckpt_fn(self.state)
        return self.log

    def _calibrated_net(self, acfg):
        """One-shot alpha-beta calibration, cached per Trainer (the fit is
        cheap but not free; the network does not change mid-process)."""
        from repro.core.cost_model import DEFAULT_NET

        if not acfg.calibrate:
            return DEFAULT_NET
        if getattr(self, "_net_cal", None) is None:
            from repro.utils.calibrate import calibrate

            # the auditor (when attached) receives the post-fit ladder
            # residuals as algorithm "dense_ladder" — the calibrator's
            # own quality signal (DESIGN.md §10)
            self._net_cal = calibrate(self.mesh,
                                      auditor=getattr(self.obs, "audit", None))
        return self._net_cal

    def _abstract_like(self):
        if self.state is not None:
            return self.state
        state, _ = init_state(self.model, self.tcfg, self.mesh)
        return state
