"""Fault-tolerant training driver.

Responsibilities beyond the jitted step:
  * checkpoint every N steps (atomic) + resume-from-latest on start,
  * survive injected/step failures: restore last checkpoint and continue
    (the data pipeline is keyed by step, so replayed batches are identical),
  * straggler watchdog: per-step wall time vs a running median; a step
    exceeding ``straggler_factor`` x median is logged and counted — on a
    real pod this feeds the skip/backup-worker policy; in-process it is
    observability (SPMD has no per-host stragglers to act on),
  * elastic restart: `resume(new_mesh)` re-chunks replica-dependent state
    (see checkpoint.restore(remesh=True)).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from statistics import median
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.data.pipeline import DataConfig, synthetic_batch
from repro.train import checkpoint as ckpt
from repro.train.state import TrainConfig, TrainState
from repro.train.train_step import build_train_step, dp_total_of, init_state


@dataclass
class TrainerLog:
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    straggler_events: list = field(default_factory=list)
    restarts: int = 0


class Trainer:
    def __init__(self, model, tcfg: TrainConfig, mesh, data_cfg: DataConfig,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 straggler_factor: float = 3.0):
        self.model = model
        self.tcfg = tcfg
        self.mesh = mesh
        self.data_cfg = data_cfg
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.log = TrainerLog()
        self.step_fn, (self.shapes, self.specs) = build_train_step(model, tcfg, mesh)
        self.state: Optional[TrainState] = None
        self._root_key = jax.random.PRNGKey(tcfg.seed)

    # -- lifecycle ---------------------------------------------------------
    def init_or_resume(self):
        self.state, _ = init_state(self.model, self.tcfg, self.mesh)
        if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
            self.state = ckpt.restore(
                self.ckpt_dir, self.state, dp_total=dp_total_of(self.mesh))
            self.log.restarts += 1
        return int(self.state.step)

    def resume_elastic(self, new_mesh):
        """Elastic restart onto a different mesh (pod count change)."""
        self.mesh = new_mesh
        self.step_fn, (self.shapes, self.specs) = build_train_step(
            self.model, self.tcfg, new_mesh)
        self.state, _ = init_state(self.model, self.tcfg, new_mesh)
        if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
            self.state = ckpt.restore(
                self.ckpt_dir, self.state, dp_total=dp_total_of(new_mesh),
                remesh=True)
        return int(self.state.step)

    # -- main loop ---------------------------------------------------------
    def run(self, num_steps: int, fail_at: Optional[int] = None) -> TrainerLog:
        """Train for num_steps (absolute). fail_at injects a fault for tests."""
        if self.state is None:
            self.init_or_resume()
        with self.mesh:
            while int(self.state.step) < num_steps:
                step = int(self.state.step)
                batch = jax.tree.map(
                    jax.numpy.asarray, synthetic_batch(self.data_cfg, step))
                key = jax.random.fold_in(self._root_key, step)
                t0 = time.perf_counter()
                try:
                    if fail_at is not None and step == fail_at:
                        fail_at = None  # fail exactly once
                        raise RuntimeError("injected node failure")
                    new_state, metrics = self.step_fn(self.state, batch, key)
                    jax.block_until_ready(metrics["loss"])
                except Exception:
                    # node-failure path: restore + replay
                    if not self.ckpt_dir:
                        raise
                    self.log.restarts += 1
                    self.state = ckpt.restore(
                        self.ckpt_dir, self._abstract_like(),
                        dp_total=dp_total_of(self.mesh))
                    continue
                dt = time.perf_counter() - t0
                self.state = new_state
                self.log.losses.append(float(metrics["loss"]))
                self.log.step_times.append(dt)
                if len(self.log.step_times) >= 5:
                    med = median(self.log.step_times[-50:])
                    if dt > self.straggler_factor * med:
                        self.log.straggler_events.append((step, dt, med))
                if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                    ckpt.save(self.ckpt_dir, self.state,
                              dp_total=dp_total_of(self.mesh))
        if self.ckpt_dir:
            ckpt.save(self.ckpt_dir, self.state, dp_total=dp_total_of(self.mesh))
        return self.log

    def _abstract_like(self):
        if self.state is not None:
            return self.state
        state, _ = init_state(self.model, self.tcfg, self.mesh)
        return state
