"""train_step builder: dense (XLA-auto collectives) and sparcml (paper
Alg. 2) gradient synchronization, microbatch accumulation, ZeRO-1 sharded
optimizer state, all under one jitted function per configuration.

sparcml mode structure (DESIGN.md §2.2):

  shard_map over dp axes ('pod','data'), AUTO over 'model':
    local grads (jax.grad on the rank's batch shard; TP collectives are
    inserted by XLA under the auto axis)
    -> accumulate over microbatches locally (ONE sync per step — the
       paper's non-blocking/fusion insight, free here by construction)
    -> sync_grads_inside: bucket-TopK + error feedback + sparse allreduce
       (+ optional QSGD on the dense phase) over 'data', psum over 'pod'
    -> ZeRO-1 update: each rank updates a 1/dp slice of the canonical
       param layout from its optimizer-state chunk, then all-gathers
       updated slices (composes with DSAR exactly like the paper's dense
       allgather second phase).

dense mode: plain jit; params/opt-state optionally FSDP-sharded (ZeRO-3);
XLA inserts reduce-scatter/all-gather from shardings.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import comm, compat
from repro.core import compressor as comp
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.models.specs import param_specs
from repro.optim.optimizers import clip_by_global_norm, init_opt_state, opt_update
from repro.optim.schedule import make_schedule
from repro.train.state import TrainConfig, TrainState


def dp_axes_of(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_total_of(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes_of(mesh)]))


def _only_dp(s) -> bool:
    names = s if isinstance(s, tuple) else (s,)
    return all(n in ("pod", "data") for n in names if n) and any(names)


def manual_only(spec):
    """shard_map in_specs may reference only MANUAL (dp) axes; the 'model'
    sharding of params/opt rides along under auto."""
    if spec is None:
        return None
    return P(*[(s if _only_dp(s) else None) for s in spec])


def manual_only_tree(specs):
    return jax.tree.map(
        manual_only, specs, is_leaf=lambda x: x is None or isinstance(x, P))


def shardings_tree(mesh: Mesh, specs):
    """PartitionSpec tree (None = replicated) -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()), specs,
        is_leaf=lambda x: x is None or isinstance(x, P))


# --------------------------------------------------------------------------
# ZeRO-1 canonical chunking (sparcml mode)
# --------------------------------------------------------------------------

def _chunk_cols(shape, spec, cfg_sync, dp_total: int) -> tuple[int, int]:
    rows, cols = comp.canonical_shape(shape, spec, cfg_sync.bucket_size)
    assert cols % dp_total == 0, (shape, cols, dp_total)
    return rows, cols // dp_total


def zero1_state_shapes(param_shapes, specs, tcfg: TrainConfig, dp_total: int):
    """Opt-state leaves stored as (dp_total, rows, cols/dp) canonical chunks."""
    n_slots = 2 if tcfg.optimizer.kind == "adamw" else 1

    def one(sd, spec):
        rows, w = _chunk_cols(sd.shape, spec, tcfg.sync, dp_total)
        return jax.ShapeDtypeStruct((dp_total, rows, w), tcfg.optimizer.state_dtype)

    mu = jax.tree.map(one, param_shapes, specs)
    out = {"mu": mu, "count": jax.ShapeDtypeStruct((), jnp.int32)}
    if n_slots == 2:
        out["nu"] = jax.tree.map(one, param_shapes, specs)
    return out


def zero1_state_specs(param_shapes, specs, tcfg: TrainConfig, dp_axes):
    def one(sd, spec):
        ax = comp._model_axis(spec)
        return P(dp_axes, "model" if ax is not None else None, None)

    mu = jax.tree.map(one, param_shapes, specs)
    out = {"mu": mu, "count": P()}
    if tcfg.optimizer.kind == "adamw":
        out["nu"] = mu
    return out


# --------------------------------------------------------------------------
# ZeRO scattered chunking (sparcml + output_mode='scattered', DESIGN.md §11)
# --------------------------------------------------------------------------

def zero_scattered_state_shapes(plan, tcfg: TrainConfig):
    """Optimizer moments partitioned by the plan's OWNED RANGES: one
    (dp_total, rows, cols/dp) chunk per fusion BUCKET (keyed like the
    residuals, by bucket name) instead of per leaf — the same ranges the
    scattered reduce terminates at, so the update never reshuffles the
    exchange output. Every bucket carries moments (raw-dense buckets
    still own their params' update)."""

    def chunks():
        return {
            b.name: jax.ShapeDtypeStruct(
                (plan.dp_total, g.rows, plan.owned_cols(b)),
                tcfg.optimizer.state_dtype)
            for g in plan.groups for b in g.buckets
        }

    out = {"mu": chunks(), "count": jax.ShapeDtypeStruct((), jnp.int32)}
    if tcfg.optimizer.kind == "adamw":
        out["nu"] = chunks()
    return out


def zero_scattered_state_specs(plan, tcfg: TrainConfig, dp_axes):
    sp = plan.scattered_specs(dp_axes)
    out = {"mu": dict(sp), "count": P()}
    if tcfg.optimizer.kind == "adamw":
        out["nu"] = dict(sp)
    return out


# --------------------------------------------------------------------------
# State construction
# --------------------------------------------------------------------------

def state_shapes(model: Model, tcfg: TrainConfig, mesh: Mesh, key=None,
                 return_plan: bool = False):
    """(abstract TrainState, TrainState of PartitionSpecs) without
    allocating. return_plan=True additionally returns the SyncPlan whose
    bucket names key the residual dict (None outside sparcml mode) — the
    ONE plan both the state layout and the step executor must share."""
    if key is None:
        key = jax.random.PRNGKey(tcfg.seed)
    pshapes = jax.eval_shape(model.init, key)
    fsdp_axes = dp_axes_of(mesh) if tcfg.fsdp else None
    pspecs = param_specs(pshapes, model.cfg, fsdp_axes)
    dp_total = dp_total_of(mesh)
    dp_ax = dp_axes_of(mesh)

    plan = None
    if tcfg.sync.mode == "sparcml":
        # Fusion plan (DESIGN.md §3): residual state is keyed BY BUCKET.
        plan = comm.build_sync_plan(pshapes, pspecs, tcfg.sync, dp_total)
        rshapes = plan.residual_shapes()
        rspecs = plan.residual_specs(dp_ax)
    else:
        rshapes = rspecs = None
        if getattr(tcfg.sync, "output_mode", "replicated") == "scattered":
            raise ValueError(
                "output_mode='scattered' requires sync.mode='sparcml' "
                "(dense mode has no plan to scatter; use fsdp for ZeRO-3)")

    if plan is not None and plan.scattered:
        if not tcfg.zero1:
            raise ValueError(
                "output_mode='scattered' IS the sharded-optimizer layout "
                "— it requires zero1=True (DESIGN.md §11)")
        oshapes = zero_scattered_state_shapes(plan, tcfg)
        ospecs = zero_scattered_state_specs(plan, tcfg, dp_ax)
    elif tcfg.sync.mode == "sparcml" and tcfg.zero1:
        oshapes = zero1_state_shapes(pshapes, pspecs, tcfg, dp_total)
        ospecs = zero1_state_specs(pshapes, pspecs, tcfg, dp_ax)
    else:
        oshapes = jax.eval_shape(
            lambda p: init_opt_state(p, tcfg.optimizer), pshapes)
        n_opt = {"adamw": 2, "sgdm": 1}[tcfg.optimizer.kind]
        ospecs = {"mu": pspecs, "count": P()}
        if n_opt == 2:
            ospecs["nu"] = pspecs

    shapes = TrainState(params=pshapes, opt=oshapes, residuals=rshapes,
                        step=jax.ShapeDtypeStruct((), jnp.int32))
    specs = TrainState(params=pspecs, opt=ospecs, residuals=rspecs, step=P())
    if return_plan:
        return shapes, specs, plan
    return shapes, specs


def init_state(model: Model, tcfg: TrainConfig, mesh: Mesh, key=None) -> tuple:
    """Materialize a sharded TrainState (for smoke tests / examples)."""
    if key is None:
        key = jax.random.PRNGKey(tcfg.seed)
    shapes, specs = state_shapes(model, tcfg, mesh, key)

    def make():
        params = model.init(key)
        if tcfg.sync.mode == "sparcml" and tcfg.zero1:
            opt = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), shapes.opt,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        else:
            opt = init_opt_state(params, tcfg.optimizer)
        res = None
        if shapes.residuals is not None:
            res = jax.tree.map(
                lambda s: None if s is None else jnp.zeros(s.shape, s.dtype),
                shapes.residuals, is_leaf=lambda x: x is None or isinstance(x, jax.ShapeDtypeStruct))
        return TrainState(params, opt, res, jnp.zeros((), jnp.int32))

    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()),
        specs, is_leaf=lambda x: x is None or isinstance(x, P))
    with mesh:
        state = jax.jit(make, out_shardings=shardings)()
    return state, specs


def batch_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    dp = dp_axes_of(mesh)
    out = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.family == "vlm":
        out["image_embeds"] = P(dp, None, None)
    if cfg.family == "encoder":
        out["frames"] = P(dp, None, None)
    return out


# --------------------------------------------------------------------------
# Guarded-step helpers (DESIGN.md §12): in-graph all-finite check over the
# raw gradient leaves, plus the chaos harness's in-graph injection. Shared
# by the pipelined step body so the guard's semantics cannot drift between
# lowerings.
# --------------------------------------------------------------------------

def all_finite_leaves(leaves) -> jax.Array:
    """f32 scalar: 1.0 iff every element of every leaf is finite. Checked
    on the RAW grads (before the reduce half) — in a staleness-1 pipeline
    a NaN entering reduce poisons residuals the same step, while the
    grad-norm of the APPLIED (stale, clean) buffers stays finite until
    the next step, so any later check point misses the corruption."""
    fin = jnp.ones((), jnp.float32)
    for g in leaves:
        fin = fin * jnp.all(jnp.isfinite(g)).astype(jnp.float32)
    return fin


def inject_nonfinite_leaves(leaves, fault_vec):
    """Overwrite grad leaf i with NaN (flag 1) or Inf (flag 2) where the
    (n_leaves,) ``fault_vec`` is nonzero. A pure SELECT (``jnp.where``),
    never additive — ``g + flag * nan`` would be NaN even at flag 0. With
    an all-zero vector every where picks the clean branch, so a bound but
    idle injector is bit-exact with no injector at all."""
    out = []
    for i, g in enumerate(leaves):
        flag = fault_vec[i]
        bad = jnp.where(flag > 1.5, jnp.inf, jnp.nan).astype(g.dtype)
        out.append(jnp.where(flag > 0.5, bad, g))
    return out


def guard_select(fin, new_tree, old_tree):
    """Elementwise select between the stepped and the pre-step tree on
    the guard verdict: ``fin`` 1.0 keeps ``new_tree`` bit-exactly (a
    select, so unselected NaNs never propagate), 0.0 rolls every leaf
    back to ``old_tree`` — the EF-preservation invariant: a tripped step
    leaves params, optimizer moments, residuals, and in-flight buffers
    exactly as they were."""
    if fin is None:
        return new_tree
    pred = fin > 0.5
    return jax.tree.map(lambda a, b: jnp.where(pred, a, b),
                        new_tree, old_tree)


# --------------------------------------------------------------------------
# Gradient computation with microbatch accumulation
# --------------------------------------------------------------------------

def _accumulated_grads(model: Model, params, batch, n_micro: int,
                       mesh: Mesh | None = None):
    """Mean loss + mean grads over n_micro microbatches (lax.scan).

    The (B,...) -> (n_micro, B/n_micro, ...) reshape must KEEP the dp
    sharding on the batch dim (axis 1 after reshape) — otherwise XLA puts
    'data' on the microbatch axis and every device materializes the whole
    microbatch (16x activation blowup, found via dry-run memory_analysis).
    """
    if n_micro == 1:
        loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
        return loss, grads

    def micro(batch_i):
        return jax.value_and_grad(lambda p: model.loss(p, batch_i))(params)

    def reshape_keep_dp(x):
        out = x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
        if mesh is not None:
            dp = list(dp_axes_of(mesh))
            # drop leading dp axes until the microbatch rows divide evenly
            while dp and out.shape[1] % int(np.prod([mesh.shape[a] for a in dp])):
                dp.pop(0)
            if dp:
                spec = P(None, tuple(dp), *([None] * (out.ndim - 2)))
                out = jax.lax.with_sharding_constraint(
                    out, NamedSharding(mesh, spec))
        return out

    mb = jax.tree.map(reshape_keep_dp, batch)
    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, batch_i):
        acc_loss, acc_g = carry
        loss, g = micro(batch_i)
        acc_g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc_g, g)
        return (acc_loss + loss, acc_g), None

    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero), mb)
    inv = 1.0 / n_micro
    grads = jax.tree.map(lambda g: (g * inv), grads)
    return loss * inv, grads


# --------------------------------------------------------------------------
# sparcml-mode inner step (manual over dp, auto over 'model')
# --------------------------------------------------------------------------

def _zero1_update(params, grads, opt, lr, tcfg: TrainConfig, pspecs,
                  dp_axes, dp_index, dp_total, gather_ctxs):
    """Each rank updates its canonical column slice, then all-gathers.

    gather_ctxs: one CollectiveContext per dp axis (innermost last) — the
    slice gather uses the same native/emulated collective flavor as the
    sync executor (DESIGN.md §4)."""
    sync = tcfg.sync
    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_s = treedef.flatten_up_to(pspecs)
    leaves_mu = treedef.flatten_up_to(opt["mu"])
    leaves_nu = treedef.flatten_up_to(opt["nu"]) if "nu" in opt else [None] * len(leaves_p)

    count = opt["count"] + 1
    ocfg = tcfg.optimizer
    b1, b2 = ocfg.beta1, ocfg.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    new_p, new_mu, new_nu = [], [], []
    for pl, gl, sl, mul, nul in zip(leaves_p, leaves_g, leaves_s, leaves_mu, leaves_nu):
        pc = comp.to_canonical(pl, sl, sync.bucket_size)        # (c, mB)
        gc = comp.to_canonical(gl, sl, sync.bucket_size)
        w = pc.shape[1] // dp_total
        my_p = jax.lax.dynamic_slice_in_dim(pc, dp_index * w, w, axis=1)
        my_g = jax.lax.dynamic_slice_in_dim(gc, dp_index * w, w, axis=1).astype(jnp.float32)
        m = mul[0].astype(jnp.float32)                          # strip replica axis
        if ocfg.kind == "adamw":
            v = nul[0].astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * my_g
            v2 = b2 * v + (1 - b2) * my_g * my_g
            step = (m2 / c1) / (jnp.sqrt(v2 / c2) + ocfg.eps)
            step = step + ocfg.weight_decay * my_p.astype(jnp.float32)
            new_nu.append(v2.astype(nul.dtype)[None])
        else:
            m2 = ocfg.momentum * m + my_g
            step = m2
            new_nu.append(None)
        upd = (my_p.astype(jnp.float32) - lr * step).astype(pl.dtype)
        new_mu.append(m2.astype(mul.dtype)[None])
        # all-gather updated slices back to the full canonical layout
        full = upd
        for ctx in reversed(gather_ctxs):
            full = ctx.all_gather(full, axis=1)
        new_p.append(comp.from_canonical(full, pl.shape, sl))
    out_opt = {"mu": treedef.unflatten(new_mu), "count": count}
    if "nu" in opt:
        out_opt["nu"] = treedef.unflatten(new_nu)
    return treedef.unflatten(new_p), out_opt


def _zero1_update_spmd(params, grads, opt, lr, tcfg: TrainConfig, pspecs,
                       dp_total):
    """ZeRO-1 chunked update as plain auto-SPMD array ops: all ranks'
    chunks live on the leading (dp_total,) axis of the opt state, so the
    per-chunk math of :func:`_zero1_update` vectorizes over it — bitwise
    the same values, no shard_map (DESIGN.md §4.2)."""
    sync = tcfg.sync
    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_s = treedef.flatten_up_to(pspecs)
    leaves_mu = treedef.flatten_up_to(opt["mu"])
    leaves_nu = treedef.flatten_up_to(opt["nu"]) if "nu" in opt else [None] * len(leaves_p)

    count = opt["count"] + 1
    ocfg = tcfg.optimizer
    b1, b2 = ocfg.beta1, ocfg.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    new_p, new_mu, new_nu = [], [], []
    for pl, gl, sl, mul, nul in zip(leaves_p, leaves_g, leaves_s, leaves_mu, leaves_nu):
        pc = comp.to_canonical(pl, sl, sync.bucket_size)        # (rows, cols)
        gc = comp.to_canonical(gl, sl, sync.bucket_size)
        rows, cols = pc.shape
        w = cols // dp_total
        pch = pc.reshape(rows, dp_total, w).transpose(1, 0, 2)  # (dp, rows, w)
        gch = gc.reshape(rows, dp_total, w).transpose(1, 0, 2).astype(jnp.float32)
        m = mul.astype(jnp.float32)                             # (dp, rows, w)
        if ocfg.kind == "adamw":
            v = nul.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gch
            v2 = b2 * v + (1 - b2) * gch * gch
            step = (m2 / c1) / (jnp.sqrt(v2 / c2) + ocfg.eps)
            step = step + ocfg.weight_decay * pch.astype(jnp.float32)
            new_nu.append(v2.astype(nul.dtype))
        else:
            m2 = ocfg.momentum * m + gch
            step = m2
            new_nu.append(None)
        upd = (pch.astype(jnp.float32) - lr * step).astype(pl.dtype)
        new_mu.append(m2.astype(mul.dtype))
        full = upd.transpose(1, 0, 2).reshape(rows, cols)
        new_p.append(comp.from_canonical(full, pl.shape, sl))
    out_opt = {"mu": treedef.unflatten(new_mu), "count": count}
    if "nu" in opt:
        out_opt["nu"] = treedef.unflatten(new_nu)
    return treedef.unflatten(new_p), out_opt


def _zero_scattered_update(params, reduced, opt, lr, tcfg: TrainConfig,
                           plan, coll):
    """ZeRO scattered update (DESIGN.md §11), manual lowering: consume the
    owner GRAD CHUNKS straight off the scattered reduce (no grad-side
    allgather ever ran), update my param/moment shard, then ONE dense
    param all_gather per BUCKET rebuilds the full params — the per-step
    collective count stays O(num_buckets), not O(num_leaves).

    reduced: bucket-keyed {name: (1, rows, w)} chunks (replica axis of
    size 1 inside shard_map); extra keys (the in-flight validity flag)
    are ignored. Returns (new_params, new_opt, grad_norm). The global
    grad norm is EXACT from the shards: owned ranges are disjoint and
    cover the buffers (padding contributes zero), so one scalar psum of
    the per-shard sums of squares is the global sum — only the summation
    order differs from the replicated path (allclose, not bitwise).
    """
    from repro.comm.buckets import pack_group, unpack_group

    sync = tcfg.sync
    ocfg = tcfg.optimizer
    p = plan.dp_total
    rank = coll.axis_rank()

    gnorm = jnp.sqrt(coll.psum(sum(
        jnp.sum(jnp.square(reduced[b.name][0].astype(jnp.float32)))
        for g in plan.groups for b in g.buckets)))
    factor = (jnp.minimum(1.0, ocfg.grad_clip / (gnorm + 1e-9))
              if ocfg.grad_clip else jnp.float32(1.0))

    count = opt["count"] + 1
    b1, b2 = ocfg.beta1, ocfg.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    leaves_p, ptree = jax.tree.flatten(params)
    new_leaves: list = [None] * plan.num_leaves
    new_mu: dict = {}
    new_nu: dict = {}
    for group in plan.groups:
        pbuf = pack_group(group, leaves_p, sync.bucket_size)  # (rows, cols)
        parts = []
        for b in group.buckets:
            w = plan.owned_cols(b)
            seg = jax.lax.slice_in_dim(pbuf, b.col_start,
                                       b.col_start + b.cols, axis=1)
            my_p = jax.lax.dynamic_slice_in_dim(
                seg.reshape(group.rows, p, w), rank, 1, axis=1
            ).reshape(group.rows, w)
            g = reduced[b.name][0].astype(jnp.float32) * factor
            mul = opt["mu"][b.name]
            m = mul[0].astype(jnp.float32)
            if ocfg.kind == "adamw":
                nul = opt["nu"][b.name]
                v = nul[0].astype(jnp.float32)
                m2 = b1 * m + (1 - b1) * g
                v2 = b2 * v + (1 - b2) * g * g
                step = (m2 / c1) / (jnp.sqrt(v2 / c2) + ocfg.eps)
                step = step + ocfg.weight_decay * my_p
                new_nu[b.name] = v2.astype(nul.dtype)[None]
            else:
                m2 = ocfg.momentum * m + g
                step = m2
            new_mu[b.name] = m2.astype(mul.dtype)[None]
            upd = my_p - lr * step                            # f32 shard
            parts.append(coll.all_gather(upd, axis=1))        # (rows, b.cols)
        out_buf = parts[0] if len(parts) == 1 else jnp.concatenate(parts,
                                                                   axis=1)
        for leaf_id, arr in unpack_group(group, out_buf, leaves_p):
            new_leaves[leaf_id] = arr
    out_opt = {"mu": new_mu, "count": count}
    if ocfg.kind == "adamw":
        out_opt["nu"] = new_nu
    return ptree.unflatten(new_leaves), out_opt, gnorm


def _zero_scattered_update_spmd(params, grads, opt, lr, tcfg: TrainConfig,
                                plan):
    """Auto-SPMD twin of :func:`_zero_scattered_update`: moments live as
    full (dp_total, rows, w) bucket-chunk stacks, the per-chunk math
    vectorizes over the leading axis, and the param 'allgather' is the
    chunk->buffer reshape XLA re-materializes from the sharded stacks.
    ``grads`` are the CLIPPED synced leaves (the caller computes the clip
    exactly as the replicated reference so the factor — and therefore
    every parameter — is bitwise identical to replicated training).

    The params are deliberately NEVER packed into the group buffer here:
    only the moment-derived update direction flows through the bucket
    domain, and the actual parameter step — ``p - lr*(delta + wd*p)`` —
    runs per leaf with exactly the replicated :func:`adamw` fp ops.
    Packing the params alongside the vmapped grad computation trips a
    GSPMD partial-sum mislabel on the XLA-CPU fallback (the packed
    buffer comes back multiplied by dp_total); the delta-only
    formulation both avoids that and keeps per-coordinate bit parity
    with replicated training."""
    from repro.comm.buckets import from_canonical, pack_group

    sync = tcfg.sync
    ocfg = tcfg.optimizer
    p = plan.dp_total
    count = opt["count"] + 1
    b1, b2 = ocfg.beta1, ocfg.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    leaves_p, ptree = jax.tree.flatten(params)
    leaves_g = ptree.flatten_up_to(grads)
    new_leaves: list = [None] * plan.num_leaves
    new_mu: dict = {}
    new_nu: dict = {}
    for group in plan.groups:
        gbuf = pack_group(group, leaves_g, sync.bucket_size)
        parts = []
        for b in group.buckets:
            w = plan.owned_cols(b)
            g = jax.lax.slice_in_dim(
                gbuf, b.col_start, b.col_start + b.cols, axis=1
            ).reshape(group.rows, p, w).transpose(1, 0, 2)  # (p, rows, w)
            mul = opt["mu"][b.name]
            m = mul.astype(jnp.float32)
            if ocfg.kind == "adamw":
                nul = opt["nu"][b.name]
                v = nul.astype(jnp.float32)
                m2 = b1 * m + (1 - b1) * g
                v2 = b2 * v + (1 - b2) * g * g
                delta = (m2 / c1) / (jnp.sqrt(v2 / c2) + ocfg.eps)
                new_nu[b.name] = v2.astype(nul.dtype)
            else:
                m2 = ocfg.momentum * m + g
                delta = m2
            new_mu[b.name] = m2.astype(mul.dtype)
            parts.append(delta.transpose(1, 0, 2).reshape(group.rows,
                                                          b.cols))
        dbuf = parts[0] if len(parts) == 1 else jnp.concatenate(parts,
                                                                axis=1)
        for slot in group.slots:
            seg = jax.lax.slice_in_dim(dbuf, slot.offset,
                                       slot.offset + slot.cols, axis=1)
            delta_leaf = from_canonical(seg, slot.shape, slot.spec)  # f32
            pl = leaves_p[slot.leaf_id]
            pf = pl.astype(jnp.float32)
            step = delta_leaf
            if ocfg.kind == "adamw":
                step = step + ocfg.weight_decay * pf
            new_leaves[slot.leaf_id] = (pf - lr * step).astype(pl.dtype)
    out_opt = {"mu": new_mu, "count": count}
    if ocfg.kind == "adamw":
        out_opt["nu"] = new_nu
    return ptree.unflatten(new_leaves), out_opt


def sparcml_uses_manual_collectives(mesh: Mesh) -> bool:
    """True when the sparcml step lowers through the shard_map manual-dp
    region (native collectives: all-to-all/all-gather appear in HLO);
    False when it falls back to the auto-SPMD formulation (XLA inserts
    all-reduces — DESIGN.md §4.2)."""
    return not compat.partial_manual_collectives_broken(mesh, dp_axes_of(mesh))


def build_train_step(model: Model, tcfg: TrainConfig, mesh: Mesh):
    """Returns (jitted step fn(state, batch, key) -> (state, metrics),
    (state_shapes, state_specs))."""
    cfg = model.cfg
    sched = make_schedule(tcfg.schedule)
    shapes, specs, plan = state_shapes(model, tcfg, mesh, return_plan=True)
    bspecs = batch_specs(cfg, mesh)
    dp_ax = dp_axes_of(mesh)
    dp_total = dp_total_of(mesh)
    n_micro = tcfg.microbatches
    sh = lambda t: shardings_tree(mesh, t)

    if tcfg.sync.mode != "sparcml":
        # ---------------- dense mode: plain auto-SPMD jit ----------------
        import dataclasses
        from repro.models.model import Model as _M
        model = _M(dataclasses.replace(cfg, act_dp_axes=dp_ax))
        cfg_local = model.cfg  # noqa: F841

        def step_fn(state: TrainState, batch, key):
            lr = sched(state.step)
            loss, grads = _accumulated_grads(model, state.params, batch, n_micro,
                                             mesh=mesh)
            grads, gnorm = clip_by_global_norm(grads, tcfg.optimizer.grad_clip)
            new_p, new_opt = opt_update(
                state.params, grads, state.opt, lr, tcfg.optimizer)
            new_state = TrainState(new_p, new_opt, None, state.step + 1)
            return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

        jitted = jax.jit(
            step_fn,
            in_shardings=(sh(specs), sh(bspecs), NamedSharding(mesh, P())),
            out_shardings=(sh(specs), NamedSharding(mesh, P())),
            donate_argnums=(0,),
        )
        return jitted, (shapes, specs)

    # ---------------- sparcml mode: manual dp, auto model ----------------
    pspecs = specs.params
    # `plan` is the one state_shapes keyed the residual dict with.
    # Collective flavor inside the partial-manual region (DESIGN.md §4):
    # native lax collectives, or the psum-emulated fallback on backends
    # whose partitioner cannot lower them there (XLA-CPU container build).
    native = not compat.partial_manual_collectives_broken(mesh, dp_ax)
    data_axis = dp_ax[-1]
    p_data = mesh.shape[data_axis]
    pod_axis = dp_ax[0] if len(dp_ax) > 1 else None
    p_pod = mesh.shape[pod_axis] if pod_axis else 1


    if not native:
        # ------- auto-SPMD fallback: no shard_map (DESIGN.md §4.2) -------
        # The partitioner of this backend cannot lower a partial-manual
        # region (scan bodies / non-psum collectives abort), so the
        # replica axis becomes a real leading axis: vmap computes every
        # rank's grads on its batch slice, the executor's sums over that
        # axis ARE the allreduce (XLA inserts them), numerics unchanged.
        def step_fn(state: TrainState, batch, key):
            lr = sched(state.step)

            def split_ranks(x):
                out = x.reshape((dp_total, x.shape[0] // dp_total)
                                + x.shape[1:])
                spec = P(tuple(dp_ax), *([None] * (out.ndim - 1)))
                return jax.lax.with_sharding_constraint(
                    out, NamedSharding(mesh, spec))

            batch_r = jax.tree.map(split_ranks, batch)
            loss_r, grads_r = jax.vmap(
                lambda b: _accumulated_grads(model, state.params, b,
                                             n_micro))(batch_r)
            loss = jnp.mean(loss_r)
            leaves_r, gtree = jax.tree.flatten(grads_r)
            leaves_spec = gtree.flatten_up_to(pspecs)
            leaves_r = [
                jax.lax.with_sharding_constraint(
                    g, NamedSharding(mesh, P(tuple(dp_ax),
                                             *(s if s is not None else ()))))
                for g, s in zip(leaves_r, leaves_spec)
            ]
            if plan.scattered:
                # Scattered (DESIGN.md §11): owner chunks in, shard
                # update, chunk->buffer rebuild. The clip reuses the
                # replicated code path on the rebuilt leaves so the
                # factor — and therefore training — is BIT-identical.
                reduced, new_res, _ = comm.reduce_buckets_spmd(
                    plan, leaves_r, state.residuals, key,
                    p_data=p_data, p_pod=p_pod)
                synced_leaves = comm.apply_buckets_spmd(
                    plan, comm.unchunk_buckets_spmd(plan, reduced), leaves_r)
                synced = gtree.unflatten(synced_leaves)
                synced, gnorm = clip_by_global_norm(
                    synced, tcfg.optimizer.grad_clip)
                new_p, new_opt = _zero_scattered_update_spmd(
                    state.params, synced, state.opt, lr, tcfg, plan)
                new_state = TrainState(new_p, new_opt, new_res,
                                       state.step + 1)
                return new_state, {"loss": loss, "grad_norm": gnorm,
                                   "lr": lr}
            synced_leaves, new_res = comm.execute_plan_spmd(
                plan, leaves_r, state.residuals, key,
                p_data=p_data, p_pod=p_pod)
            synced = gtree.unflatten(synced_leaves)
            synced, gnorm = clip_by_global_norm(synced, tcfg.optimizer.grad_clip)
            if tcfg.zero1:
                new_p, new_opt = _zero1_update_spmd(
                    state.params, synced, state.opt, lr, tcfg, pspecs,
                    dp_total)
            else:
                new_p, new_opt = opt_update(
                    state.params, synced, state.opt, lr, tcfg.optimizer)
            new_state = TrainState(new_p, new_opt, new_res, state.step + 1)
            return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

        jitted = jax.jit(
            step_fn,
            in_shardings=(sh(specs), sh(bspecs), NamedSharding(mesh, P())),
            out_shardings=(sh(specs), NamedSharding(mesh, P())),
            donate_argnums=(0,),
        )
        return jitted, (shapes, specs)

    def inner(state: TrainState, batch, key, rid):
        # batch arrives as this rank's rows (split over dp by in_specs);
        # rid is this rank's flat dp index fed AS DATA (a (1,) slice of
        # arange(dp_total)) — jax.lax.axis_index does not lower in
        # partial-manual regions on the emulated backends.
        lr = sched(state.step)
        loss, grads = _accumulated_grads(model, state.params, batch, n_micro)
        loss = jax.lax.pmean(loss, dp_ax[-1])
        if len(dp_ax) > 1:
            loss = jax.lax.pmean(loss, dp_ax[0])
        dp_index = rid[0]
        data_rank = dp_index % p_data
        pod_rank = dp_index // p_data if pod_axis else None
        leaves_g, gtree = jax.tree.flatten(grads)
        if plan.scattered:
            # Scattered (DESIGN.md §11): the reduce stops at the owner
            # shard, the update runs there, and the only gather left is
            # the dense param allgather inside the update (one per
            # bucket). Grad norm comes back exactly from the shards.
            reduced, new_res, _ = comm.reduce_buckets(
                plan, leaves_g, state.residuals, key,
                data_axis=data_axis, p_data=p_data,
                pod_axis=pod_axis, p_pod=p_pod,
                native=native, data_rank=data_rank, pod_rank=pod_rank)
            coll = comm.CollectiveContext(data_axis, p_data, native=native,
                                          rank=data_rank)
            new_p, new_opt, gnorm = _zero_scattered_update(
                state.params, reduced, state.opt, lr, tcfg, plan, coll)
            new_state = TrainState(new_p, new_opt, new_res, state.step + 1)
            return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}
        synced_leaves, new_res = comm.execute_plan(
            plan, leaves_g, state.residuals, key,
            data_axis=data_axis, p_data=p_data,
            pod_axis=pod_axis, p_pod=p_pod,
            native=native, data_rank=data_rank, pod_rank=pod_rank,
        )
        synced = gtree.unflatten(synced_leaves)
        synced, gnorm = clip_by_global_norm(synced, tcfg.optimizer.grad_clip)
        if tcfg.zero1:
            gather_ctxs = [
                comm.CollectiveContext(ax, mesh.shape[ax], native=native,
                                       rank=(pod_rank if ax == pod_axis
                                             else data_rank))
                for ax in dp_ax
            ]
            new_p, new_opt = _zero1_update(
                state.params, synced, state.opt, lr, tcfg, pspecs,
                dp_ax, dp_index, dp_total, gather_ctxs)
        else:
            new_p, new_opt = opt_update(
                state.params, synced, state.opt, lr, tcfg.optimizer)
        new_state = TrainState(new_p, new_opt, new_res, state.step + 1)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    in_state_specs = manual_only_tree(specs)
    in_batch_specs = manual_only_tree(bspecs)

    rid_spec = P(tuple(dp_ax))
    mapped = compat.shard_map(
        inner, mesh=mesh,
        in_specs=(in_state_specs, in_batch_specs, P(), rid_spec),
        out_specs=(in_state_specs, P()),
        check_vma=False,
        axis_names=set(dp_ax),
    )

    def stepped(state: TrainState, batch, key):
        # rank-id feed: each rank's slice of arange(dp_total) — see inner.
        rid = jnp.arange(dp_total, dtype=jnp.int32)
        return mapped(state, batch, key, rid)

    jitted = jax.jit(
        stepped,
        in_shardings=(sh(specs), sh(bspecs), NamedSharding(mesh, P())),
        out_shardings=(sh(specs), NamedSharding(mesh, P())),
        donate_argnums=(0,),
    )
    return jitted, (shapes, specs)
