"""Three-term roofline model (TPU v5e targets).

  compute    = HLO_FLOPs / (chips x 197e12 FLOP/s bf16)
  memory     = HLO bytes accessed / (chips x 819e9 B/s HBM)
  collective = collective bytes per chip / (links x 50e9 B/s ICI)

Terms derive from the compiled dry-run artifact (cost_analysis + HLO
parse); there is no wall clock on this CPU-only container. We report the
perfectly-overlapped bound max(terms) and the serial bound sum(terms);
the roofline fraction scores MODEL_FLOPS-time against the overlapped
bound.
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
ICI_LINKS = 2              # bidirectional links engaged per collective on a
                           # 2-D torus axis (conservative; v5e has 4 total)


@dataclass(frozen=True)
class Roofline:
    flops: float                  # total HLO flops across chips
    hbm_bytes: float              # total bytes accessed across chips
    coll_bytes_per_chip: float    # wire bytes per chip
    chips: int
    model_flops: float            # 6*N*D useful flops (per step, all chips)

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / (ICI_LINKS * ICI_BW)

    @property
    def bound(self) -> float:
        """Perfect-overlap step-time lower bound."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def serial_bound(self) -> float:
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Achievable MFU at the overlapped bound."""
        t_model = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_model / self.bound if self.bound else 0.0

    def as_dict(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bound_s": self.bound,
            "serial_bound_s": self.serial_bound,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops": self.flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def model_flops_train(param_count: int, tokens: int) -> float:
    """6*N*D for a training step (fwd+bwd)."""
    return 6.0 * param_count * tokens


def model_flops_infer(param_count: int, tokens: int) -> float:
    """2*N*D for inference."""
    return 2.0 * param_count * tokens
