"""One-shot alpha-beta network calibration (DESIGN.md §7).

The cost model ships TPU-v5e constants (`core.cost_model.DEFAULT_NET`),
but the paper's point (§5.3) is that algorithm selection should use the
*machine's* alpha and beta, fitted from ping-pong/allreduce timings. This
module measures dense allreduce wall times over the mesh's data axis at
a ladder of message sizes and least-squares fits

    T(L) = alpha' + L * beta'   =>   NetworkParams(alpha, link_bytes_per_s)

where the Rabenseifner accounting (2 log2(P) alpha + 2 (P-1)/P N beta_d)
is inverted so the fitted per-hop alpha / per-byte beta plug straight
into the existing ``t_*`` formulas. Measurements are best-of-R jitted
calls (compile excluded), so the fit is one-shot cheap (~a second on the
emulated-CPU host) and cached by the callers that run it per process.

On hosts whose timings are too noisy to fit (negative slope, zero
bandwidth), the fit falls back to DEFAULT_NET rather than returning a
degenerate model — calibration must never make selection worse than the
shipped constants.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import numpy as np

from repro.core.cost_model import DEFAULT_NET, NetworkParams

DEFAULT_SIZES = (1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20)


def fit_network_params(sizes_bytes: Sequence[float],
                       times_s: Sequence[float],
                       p: int = 2,
                       isize: int = 4) -> NetworkParams:
    """Least-squares fit of measured dense-allreduce times to the
    Rabenseifner alpha-beta form; returns calibrated ``NetworkParams``.

    sizes_bytes: payload sizes N*isize of each measurement;
    times_s: matching wall times;
    p: world size the measurements ran at (fixes the latency/bandwidth
    prefactors so alpha/beta come out per-hop / per-byte).
    """
    import math

    sizes = np.asarray(sizes_bytes, dtype=np.float64)
    times = np.asarray(times_s, dtype=np.float64)
    if sizes.size < 2:
        return DEFAULT_NET
    # T = 2 log2(P) * alpha + 2 (P-1)/P * bytes * beta_byte
    lat_pref = 2.0 * math.log2(max(2, p))
    bw_pref = 2.0 * (p - 1) / p
    a = np.stack([np.full_like(sizes, lat_pref), bw_pref * sizes], axis=1)
    coef, *_ = np.linalg.lstsq(a, times, rcond=None)
    alpha, beta_byte = float(coef[0]), float(coef[1])
    if beta_byte <= 0.0 or not np.isfinite(beta_byte):
        return DEFAULT_NET        # too noisy to trust (see module docstring)
    alpha = max(alpha, 1e-9)      # intercepts can fit slightly negative
    return NetworkParams(alpha=alpha, link_bytes_per_s=1.0 / beta_byte,
                         isize=isize)


def measure_allreduce_times(mesh, axis: str = "data",
                            sizes: Sequence[int] = DEFAULT_SIZES,
                            repeats: int = 5) -> list[tuple[int, float]]:
    """Best-of-``repeats`` wall time of a jitted dense psum-allreduce over
    ``axis`` at each element count in ``sizes``. Returns
    [(payload_bytes, seconds), ...] ready for :func:`fit_network_params`.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import compat

    p = mesh.shape[axis]

    out = []
    with mesh:
        for n in sizes:
            n = max(int(n), p)
            n -= n % p

            def allreduce(x):
                # REPLICATED operand: every rank contributes a full
                # n-vector, so the timed psum is an allreduce of N
                # elements — the same N that t_dense_allreduce's
                # Rabenseifner accounting (and the recorded payload
                # n*isize below) refers to. A P(axis)-sharded operand
                # would reduce only n/p elements per rank and overstate
                # the fitted bandwidth by a factor of p.
                f = compat.shard_map(
                    lambda s: jax.lax.psum(s, axis), mesh=mesh,
                    in_specs=P(), out_specs=P(),
                    check_vma=False, axis_names={axis})
                return f(x)

            x = jax.device_put(
                jnp.ones((n,), jnp.float32),
                NamedSharding(mesh, P()))
            fn = jax.jit(allreduce)
            jax.block_until_ready(fn(x))          # compile outside timing
            best = float("inf")
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(x))
                best = min(best, time.perf_counter() - t0)
            out.append((n * 4, best))
    return out


def calibrate(mesh, axis: Optional[str] = None,
              sizes: Sequence[int] = DEFAULT_SIZES,
              repeats: int = 5, isize: int = 4,
              auditor=None) -> NetworkParams:
    """One-shot calibration: measure + fit. ``axis`` defaults to the
    innermost data-parallel axis present on the mesh.

    ``auditor`` (an ``obs.DriftAuditor``) receives the POST-FIT ladder
    residuals — each measured dense-allreduce point joined against the
    fitted model's prediction, recorded as algorithm ``"dense_ladder"``.
    That is the calibrator's own quality signal (DESIGN.md §10): a tight
    fit yields median_ratio ~= 1; a flagged ``dense_ladder`` entry says
    the alpha-beta form itself doesn't describe this machine, so every
    downstream ``select_algorithm`` call inherits that error."""
    from repro.core.cost_model import t_dense_allreduce

    if axis is None:
        axis = next((a for a in ("data", "pod") if a in mesh.axis_names),
                    mesh.axis_names[0])
    meas = measure_allreduce_times(mesh, axis, sizes, repeats)
    p = mesh.shape[axis]
    net = fit_network_params([b for b, _ in meas], [t for _, t in meas],
                             p=p, isize=isize)
    if auditor is not None:
        for payload_bytes, t in meas:
            n_elems = payload_bytes // isize
            auditor.record(
                "dense_ladder", f"calibrate@{payload_bytes}B",
                t_dense_allreduce(p, n_elems, net), t,
                p=p, n=n_elems, kind="calibration")
    return net
