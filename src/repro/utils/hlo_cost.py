"""Trip-count-aware cost extraction from post-optimization HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so a
scan-over-layers model under-reports FLOPs by ~layers x microbatches.
This walker parses the HLO module into computations, builds the call
graph (fusion calls / while bodies / conditionals), extracts loop trip
counts from the condition computations, and accumulates:

  * dot FLOPs (dots dominate transformer FLOPs) via a module-wide symbol
    table (scheduled HLO does not carry operand shapes inline),
  * HBM bytes at fusion boundaries (post-fusion HLO only materializes
    fusion parameters/results, so operand+result bytes of top-level ops
    are exactly XLA's HBM-traffic model),
  * per-chip collective wire bytes (ring formulas).

Validated against cost_analysis() on loop-free modules (tests/test_roofline).
"""
from __future__ import annotations

import functools
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_RESULT_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?)([a-z][a-z0-9]*)\[([\d,]*)\]")
_OP_KIND_RE = re.compile(r"=\s*(?:\([^)]*\)\s*)?(?:[a-z][a-z0-9]*\[[\d,]*\][^\s]*\s+)?([a-z][a-z0-9\-]*)\(")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_COND_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")

_SKIP_HBM = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "after-all", "opt-barrier", "partition-id", "replica-id",
}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _nbytes(dtype: str, dims) -> float:
    isize = _DTYPE_BYTES.get(dtype)
    if isize is None:
        return 0.0
    n = 1
    for d in dims:
        n *= d
    return float(n) * isize


def _dims(s: str) -> tuple:
    return tuple(int(d) for d in s.split(",") if d.strip())


@dataclass
class CompCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    coll_count: dict = field(default_factory=lambda: defaultdict(int))
    children: list = field(default_factory=list)  # (kind, name, cond)
    max_const: int = 0
    # fusion-boundary analysis (fusion bodies only):
    params: dict = field(default_factory=dict)        # pname -> bytes
    param_slice: dict = field(default_factory=dict)   # pname -> slice bytes (if only sliced)
    param_other_use: set = field(default_factory=set) # pname consumed unsliced
    root_bytes: float = 0.0
    root_dus_update: float = 0.0

    def boundary_bytes(self) -> float:
        """HBM traffic at this fusion's boundary: params are charged their
        full size unless they are ONLY dynamic-sliced inside (then the
        slice), the root is charged its size unless it is an in-place
        dynamic-update-slice (then 2x the update)."""
        total = 0.0
        for pname, b in self.params.items():
            if pname in self.param_slice and pname not in self.param_other_use:
                total += 2 * self.param_slice[pname]
            else:
                total += b
        total += (2 * self.root_dus_update) if self.root_dus_update else self.root_bytes
        return total


def parse_module(hlo: str):
    """Returns (comps dict, entry name)."""
    comps: dict[str, CompCost] = {}
    symbols: dict[str, tuple] = {}  # name -> (dtype, dims) result shapes
    lines = hlo.splitlines()
    # pass 1: symbol table (module-wide; HLO names are unique per module)
    for line in lines:
        m = _RESULT_RE.match(line)
        if m and not m.group(2):  # skip tuple-typed results for shapes
            symbols[m.group(1)] = (m.group(3), _dims(m.group(4)))
    # also parameters declared in headers:  %p (x: f32[4,8], ...)
    for m in re.finditer(r"([\w\.\-]+)\s*:\s*([a-z][a-z0-9]*)\[([\d,]*)\]", hlo):
        symbols.setdefault(m.group(1), (m.group(2), _dims(m.group(3))))

    entry = None
    cur: CompCost | None = None
    for line in lines:
        if line and not line[0].isspace():
            h = _COMP_HDR.match(line.rstrip())
            if h:
                cur = CompCost()
                comps[h.group(1)] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = h.group(1)
                # record declared parameters (fusion boundary analysis)
                for pm in re.finditer(
                        r"([\w\.\-]+)\s*:\s*([a-z][a-z0-9]*)\[([\d,]*)\]", line):
                    cur.params[pm.group(1)] = _nbytes(pm.group(2), _dims(pm.group(3)))
            continue
        s = line.strip()
        if not s or cur is None or s == "}":
            if s == "}":
                cur = None
            continue
        _parse_op(s, cur, symbols)
    return comps, entry


def _parse_op(line: str, comp: CompCost, symbols: dict):
    mk = _OP_KIND_RE.search(line)
    kind = mk.group(1) if mk else None
    mres = _RESULT_RE.match(line)

    # constants (loop-bound candidates)
    for c in _CONST_RE.findall(line):
        comp.max_const = max(comp.max_const, int(c))

    # call-graph edges
    mw = _WHILE_RE.search(line)
    if mw and kind == "while":
        comp.children.append(("while", mw.group(2), mw.group(1)))
        return
    mc = _CALL_RE.search(line)
    if mc:
        comp.children.append(
            ("fusion" if kind == "fusion" else "call", mc.group(1), None))
    mb = _COND_BRANCHES_RE.search(line)
    if mb:
        for b in mb.group(1).split(","):
            b = b.strip().lstrip("%")
            if b:
                comp.children.append(("branch", b, None))

    # dot flops
    if kind == "dot" and mres and not mres.group(2):
        out_elems = 1
        for d in _dims(mres.group(4)):
            out_elems *= d
        k = 1
        mlc = _LHS_CONTRACT_RE.search(line)
        if mlc:
            body = line.split("dot(", 1)[1]
            ops = _OPERANDS_RE.findall(body.split(")", 1)[0])
            if ops and ops[0] in symbols:
                lhs_dims = symbols[ops[0]][1]
                for ci in mlc.group(1).split(","):
                    if ci.strip() and int(ci) < len(lhs_dims):
                        k *= lhs_dims[int(ci)]
        comp.flops += 2.0 * out_elems * k

    # collectives
    if kind in _COLLECTIVES or (kind or "").replace("-start", "") in _COLLECTIVES:
        ckind = (kind or "").replace("-start", "")
        r = 0.0
        if mres:
            if mres.group(2):  # tuple result: sum components
                for dt, dd in re.findall(r"([a-z][a-z0-9]*)\[([\d,]*)\]",
                                         line.split("=", 1)[1].split(")")[0]):
                    r += _nbytes(dt, _dims(dd))
                r /= 2 if "-start" in (kind or "") else 1
            else:
                r = _nbytes(mres.group(3), _dims(mres.group(4)))
        g = 1
        mg = _GROUPS_ALT_RE.search(line)
        if mg:
            g = int(mg.group(2))
        else:
            mg = _GROUPS_RE.search(line)
            if mg:
                first = mg.group(1).split("}")[0].lstrip("{")
                g = max(1, len([x for x in first.split(",") if x.strip()]))
        if g > 1 and r:
            if ckind == "all-gather":
                b = (g - 1) / g * r
            elif ckind == "reduce-scatter":
                b = (g - 1) * r
            elif ckind == "all-reduce":
                b = 2 * (g - 1) / g * r
            elif ckind == "all-to-all":
                b = (g - 1) / g * r
            else:
                b = r
            comp.coll_by_kind[ckind] += b
            comp.coll_count[ckind] += 1

    # Track fusion-boundary param usage: params that are ONLY dynamic-sliced
    # inside a body contribute slice-sized traffic, not their full size.
    body = line.split("(", 1)
    ops = (_OPERANDS_RE.findall(body[1].split(")", 1)[0])
           if len(body) > 1 else [])
    res_b = (_nbytes(mres.group(3), _dims(mres.group(4)))
             if (mres and not mres.group(2)) else 0.0)
    if comp.params:
        for i, op in enumerate(ops):
            if op in comp.params:
                if kind in ("dynamic-slice", "slice", "gather") and i == 0:
                    comp.param_slice[op] = max(
                        comp.param_slice.get(op, 0.0), res_b)
                elif kind == "dynamic-update-slice" and i == 0:
                    pass  # in-place destination: charged via root
                else:
                    comp.param_other_use.add(op)
    if line.startswith("ROOT") or " ROOT " in ("  " + line):
        comp.root_bytes = res_b
        if kind == "dynamic-update-slice" and len(ops) > 1:
            upd = symbols.get(ops[1])
            comp.root_dus_update = _nbytes(*upd) if upd else res_b

    # HBM traffic at top-level op boundaries.
    # Slicing/update ops only touch the slice, not the whole operand:
    #   dynamic-slice / gather       -> 2 x result (read slice, write out)
    #   dynamic-update-slice         -> 2 x update operand (in-place)
    #   scatter                      -> 2 x updates operand
    #   fusion                       -> deferred to walk(): boundary_bytes()
    if kind and kind not in _SKIP_HBM and mres and not mres.group(2):
        if kind == "fusion":
            pass  # accounted via the callee's boundary_bytes() in walk()
        elif kind in ("dynamic-slice", "gather", "slice"):
            comp.hbm_bytes += 2 * res_b
        elif kind == "dynamic-update-slice":
            upd = symbols.get(ops[1]) if len(ops) > 1 else None
            comp.hbm_bytes += 2 * (_nbytes(*upd) if upd else res_b)
        elif kind == "scatter":
            upd = symbols.get(ops[2]) if len(ops) > 2 else None
            comp.hbm_bytes += 2 * (_nbytes(*upd) if upd else res_b)
        else:
            comp.hbm_bytes += res_b
            for op in ops:
                if op in symbols:
                    comp.hbm_bytes += _nbytes(*symbols[op])


@dataclass
class ModuleCost:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_by_kind: dict
    coll_count: dict
    trip_counts: list

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "coll_by_kind": dict(self.coll_by_kind),
            "coll_count": dict(self.coll_count),
            "trip_counts": self.trip_counts[:16],
        }


def total_cost(hlo: str) -> ModuleCost:
    comps, entry = parse_module(hlo)
    if not comps or entry is None:
        return ModuleCost(0, 0, 0, {}, {}, [])
    trip_counts: list = []

    @functools.lru_cache(maxsize=None)
    def walk(name: str):
        c = comps.get(name)
        if c is None:
            return (0.0, 0.0, ())
        fl, hb = c.flops, c.hbm_bytes
        agg = defaultdict(lambda: [0.0, 0])
        for k, v in c.coll_by_kind.items():
            agg[k][0] += v
            agg[k][1] += c.coll_count[k]
        for kind, child, cond in c.children:
            cf, ch, ck = walk(child)
            mult = 1.0
            if kind == "while":
                trip = comps.get(cond, CompCost()).max_const or 1
                trip_counts.append((child, trip))
                mult = float(trip)
            fl += cf * mult
            if kind == "fusion":
                # internals never touch HBM; charge the boundary model
                hb += comps.get(child, CompCost()).boundary_bytes()
            elif kind != "call":
                hb += ch * mult
            for k, v, n in ck:
                agg[k][0] += v * mult
                agg[k][1] += int(n * mult)
        return (fl, hb, tuple((k, v[0], v[1]) for k, v in agg.items()))

    fl, hb, ck = walk(entry)
    by_kind, by_count = {}, {}
    for k, v, n in ck:
        by_kind[k] = v
        by_count[k] = n
    return ModuleCost(fl, hb, sum(by_kind.values()), by_kind, by_count,
                      trip_counts)
