"""Parse collective traffic out of post-SPMD HLO text.

cost_analysis() gives FLOPs and memory bytes but NOT collective bytes
(per the roofline spec): we regex every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op, read its result
shape + replica groups, and convert to per-chip wire bytes with the
standard ring/bidirectional formulas.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

# e.g.  %all-gather.5 = bf16[4,1024]{1,0} all-gather(bf16[4,64]{1,0} %x), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclass
class CollectiveStats:
    """Per-chip wire bytes + op counts, by collective kind."""

    bytes_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    count_by_kind: dict = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    def as_dict(self) -> dict:
        return {
            "total_bytes_per_chip": self.total_bytes,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
        }


def _shape_bytes(dtype: str, dims: str) -> float:
    isize = _DTYPE_BYTES.get(dtype)
    if isize is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return float(n * isize)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ALT_RE.search(line)  # replica_groups=[8,64] (iota form)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        body = m.group(1)
        first = body.split("}")[0].lstrip("{")
        ids = [x for x in first.split(",") if x.strip()]
        return max(1, len(ids))
    return default


def parse_collectives(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    """Per-chip wire-byte model (ring algorithms on a bidirectional torus):

      all-gather      result R, groups g: each chip receives (g-1)/g * R
      reduce-scatter  operand O ~ result*g: (g-1)/g * O  (we see result R ->
                      bytes = (g-1) * R)
      all-reduce      result R: 2 (g-1)/g * R   (RS + AG)
      all-to-all      result R: (g-1)/g * R
      collective-permute result R: R
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        r = _shape_bytes(dtype, dims)
        g = _group_size(line, default_group)
        if g <= 1:
            continue
        if kind == "all-gather":
            b = (g - 1) / g * r
        elif kind == "reduce-scatter":
            b = (g - 1) * r
        elif kind == "all-reduce":
            b = 2 * (g - 1) / g * r
        elif kind == "all-to-all":
            b = (g - 1) / g * r
        else:  # collective-permute
            b = r
        stats.bytes_by_kind[kind] += b
        stats.count_by_kind[kind] += 1
    return stats


def remat_duplication(hlo_text: str) -> float:
    """Crude remat-waste signal: ratio of dot ops to distinct dot shapes."""
    dots = re.findall(r"= *[a-z0-9]+\[[\d,]*\][^\s]* dot\(", hlo_text)
    if not dots:
        return 1.0
    return len(dots) / max(1, len(set(dots)))
