"""minicpm-2b [dense]: 40L d_model=2304 36H (GQA kv=36) d_ff=5760
vocab=122753 — WSD schedule, tied embeddings (llama-like) [arXiv:2404.06395]."""
import jax.numpy as jnp
from repro.models.config import ModelConfig
from repro.configs._common import make_train_config


def config(**overrides) -> ModelConfig:
    kw = dict(
        name="minicpm-2b", family="dense",
        num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
        head_dim=64, d_ff=5760, vocab_size=122753,
        tie_embeddings=True, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        max_seq_len=65536,
    )
    kw.update(overrides)
    return ModelConfig(**kw)


def smoke_config() -> ModelConfig:
    return config(num_layers=4, d_model=72, num_heads=6, num_kv_heads=6,
                  head_dim=12, d_ff=144, vocab_size=512, dtype=jnp.float32,
                  param_dtype=jnp.float32, max_seq_len=128)


def train_config(mesh=None, **kw):
    # the arch's signature WSD schedule
    kw.setdefault("microbatches", 16)
    return make_train_config(sync_mode="sparcml", schedule_kind="wsd",
                             peak_lr=1e-3, **kw)
