"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936 — qk_norm + GQA [hf:Qwen/Qwen3-8B family]."""
import jax.numpy as jnp
from repro.models.config import ModelConfig
from repro.configs._common import make_train_config


def config(**overrides) -> ModelConfig:
    kw = dict(
        name="qwen3-4b", family="dense",
        num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=9728, vocab_size=151936, qk_norm=True,
        rope_theta=1000000.0, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        max_seq_len=131072,
    )
    kw.update(overrides)
    return ModelConfig(**kw)


def smoke_config() -> ModelConfig:
    return config(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, d_ff=128, vocab_size=512, dtype=jnp.float32,
                  param_dtype=jnp.float32, max_seq_len=128)


def train_config(mesh=None, **kw):
    kw.setdefault("microbatches", 8)
    return make_train_config(sync_mode="sparcml", peak_lr=3e-4, **kw)
