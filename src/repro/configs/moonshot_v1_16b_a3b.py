"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16)
d_ff=1408(per-expert) vocab=163840, MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B]. Shared-expert path included (2x ff)."""
import jax.numpy as jnp
from repro.models.config import ModelConfig
from repro.configs._common import make_train_config


def config(**overrides) -> ModelConfig:
    kw = dict(
        name="moonshot-v1-16b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=1408, vocab_size=163840,
        num_experts=64, experts_per_token=6, moe_d_ff=1408,
        moe_shared_ff=2816, capacity_factor=1.25,
        rope_theta=50000.0, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        max_seq_len=131072,
    )
    kw.update(overrides)
    return ModelConfig(**kw)


def smoke_config() -> ModelConfig:
    return config(num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
                  head_dim=16, d_ff=32, vocab_size=512, num_experts=8,
                  experts_per_token=2, moe_d_ff=32, moe_shared_ff=64,
                  dtype=jnp.float32, param_dtype=jnp.float32, max_seq_len=128)


def train_config(mesh=None, **kw):
    kw.setdefault("microbatches", 4)
    return make_train_config(sync_mode="sparcml", peak_lr=4e-4, **kw)
