"""Shared helpers for arch config modules."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.compressor import SyncConfig
from repro.optim.optimizers import OptimizerConfig
from repro.optim.schedule import ScheduleConfig
from repro.train.state import TrainConfig


def default_sync(mode: str = "sparcml", k: int = 4, qsgd_bits=4) -> SyncConfig:
    """The paper-faithful Quantized TopK setting: k/512 per bucket (the ASR
    experiment uses 4/512), DSAR with a 4-bit QSGD second phase."""
    return SyncConfig(
        mode=mode, k_per_bucket=k, bucket_size=512,
        algorithm="dsar_split_allgather" if mode == "sparcml" else "dense",
        qsgd_bits=qsgd_bits if mode == "sparcml" else None,
        min_sparse_size=65536, impl="ref",
    )


def make_train_config(*, sync_mode: str, schedule_kind: str = "cosine",
                      peak_lr: float = 3e-4, opt_dtype=jnp.float32,
                      microbatches: int = 1, fsdp: bool = False,
                      k: int = 4, qsgd_bits=4) -> TrainConfig:
    return TrainConfig(
        sync=default_sync(sync_mode, k=k, qsgd_bits=qsgd_bits),
        optimizer=OptimizerConfig(kind="adamw", state_dtype=opt_dtype),
        schedule=ScheduleConfig(kind=schedule_kind, peak_lr=peak_lr,
                                warmup_steps=200, total_steps=20000),
        microbatches=microbatches,
        fsdp=fsdp,
        zero1=(sync_mode == "sparcml"),
    )
