"""Architecture registry: the 10 assigned configs + input-shape sets.

Every arch is selectable via ``--arch <id>``; every cell of the
(arch x input-shape) grid is defined here, including applicability rules
(DESIGN.md §4): long_500k only for sub-quadratic families, decode shapes
only for decoders.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

ARCH_IDS = [
    "llama32_vision_11b",
    "mamba2_370m",
    "minicpm_2b",
    "qwen3_4b",
    "llama3_405b",
    "internlm2_20b",
    "dbrx_132b",
    "moonshot_v1_16b_a3b",
    "zamba2_2p7b",
    "hubert_xlarge",
]

# canonical external names (hyphenated, as assigned)
EXTERNAL_NAMES = {
    "llama32_vision_11b": "llama-3.2-vision-11b",
    "mamba2_370m": "mamba2-370m",
    "minicpm_2b": "minicpm-2b",
    "qwen3_4b": "qwen3-4b",
    "llama3_405b": "llama3-405b",
    "internlm2_20b": "internlm2-20b",
    "dbrx_132b": "dbrx-132b",
    "moonshot_v1_16b_a3b": "moonshot-v1-16b-a3b",
    "zamba2_2p7b": "zamba2-2.7b",
    "hubert_xlarge": "hubert-xlarge",
}
_BY_EXTERNAL = {v: k for k, v in EXTERNAL_NAMES.items()}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_module(arch: str):
    arch = _BY_EXTERNAL.get(arch, arch).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str, **overrides):
    mod = get_module(arch)
    return mod.config(**overrides)


def get_train_config(arch: str, mesh=None, **overrides):
    return get_module(arch).train_config(mesh=mesh, **overrides)


def smoke_config(arch: str):
    return get_module(arch).smoke_config()


def applicable_shapes(arch: str) -> dict:
    """shape -> (applicable: bool, reason-if-skipped)."""
    cfg = get_config(arch)
    out = {}
    for name, sh in SHAPES.items():
        if sh.kind == "decode" and not cfg.is_decoder:
            out[name] = (False, "encoder-only: no autoregressive decode")
        elif name == "long_500k" and not cfg.subquadratic:
            out[name] = (False, "pure full-attention: no sub-quadratic path")
        else:
            out[name] = (True, "")
    return out
