"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 [arXiv:2403.17297]."""
import jax.numpy as jnp
from repro.models.config import ModelConfig
from repro.configs._common import make_train_config


def config(**overrides) -> ModelConfig:
    kw = dict(
        name="internlm2-20b", family="dense",
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=16384, vocab_size=92544,
        rope_theta=1000000.0, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        max_seq_len=131072,
    )
    kw.update(overrides)
    return ModelConfig(**kw)


def smoke_config() -> ModelConfig:
    return config(num_layers=4, d_model=96, num_heads=6, num_kv_heads=2,
                  head_dim=16, d_ff=192, vocab_size=512, dtype=jnp.float32,
                  param_dtype=jnp.float32, max_seq_len=128)


def train_config(mesh=None, **kw):
    kw.setdefault("microbatches", 4)
    return make_train_config(sync_mode="sparcml", peak_lr=2e-4, **kw)
