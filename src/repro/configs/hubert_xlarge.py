"""hubert-xlarge [audio]: 48L d_model=1280 16H d_ff=5120 vocab=504 —
encoder-only (wav2vec2-style backbone) [arXiv:2106.07447].

The conv waveform frontend is a STUB: input_specs() provides precomputed
frame embeddings (B, S, 512). No decode shapes (encoder-only)."""
import jax.numpy as jnp
from repro.models.config import ModelConfig
from repro.configs._common import make_train_config


def config(**overrides) -> ModelConfig:
    kw = dict(
        name="hubert-xlarge", family="encoder",
        num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
        head_dim=80, d_ff=5120, vocab_size=504, causal=False,
        frontend_dim=512, act_fn="gelu",
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, max_seq_len=32768,
    )
    kw.update(overrides)
    return ModelConfig(**kw)


def smoke_config() -> ModelConfig:
    return config(num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
                  head_dim=16, d_ff=128, vocab_size=96, frontend_dim=32,
                  dtype=jnp.float32, param_dtype=jnp.float32, max_seq_len=128)


def train_config(mesh=None, **kw):
    kw.setdefault("microbatches", 8)
    return make_train_config(sync_mode="sparcml", peak_lr=5e-4, **kw)
