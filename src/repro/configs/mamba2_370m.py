"""mamba2-370m [ssm]: 48L d_model=1024 (attn-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060]."""
import jax.numpy as jnp
from repro.models.config import ModelConfig
from repro.configs._common import make_train_config


def config(**overrides) -> ModelConfig:
    kw = dict(
        name="mamba2-370m", family="ssm",
        num_layers=48, d_model=1024, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=50280, ssm_state=128, ssm_expand=2,
        ssm_head_dim=64, ssm_chunk=256, conv_width=4,
        tie_embeddings=True, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        max_seq_len=1 << 20,
    )
    kw.update(overrides)
    return ModelConfig(**kw)


def smoke_config() -> ModelConfig:
    return config(num_layers=4, d_model=64, ssm_state=16, ssm_head_dim=16,
                  ssm_chunk=8, vocab_size=512, dtype=jnp.float32,
                  param_dtype=jnp.float32, max_seq_len=128)


def train_config(mesh=None, **kw):
    kw.setdefault("microbatches", 16)
    return make_train_config(sync_mode="sparcml", peak_lr=6e-4, **kw)
