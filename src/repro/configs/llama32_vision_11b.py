"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].

Text backbone only; the vision frontend is a STUB: input_specs() provides
precomputed patch embeddings (B, 1600, 1280). Cross-attention blocks every
5 layers (8 total, matching the 11B release).
"""
import jax.numpy as jnp
from repro.models.config import ModelConfig
from repro.configs._common import make_train_config


def config(**overrides) -> ModelConfig:
    kw = dict(
        name="llama-3.2-vision-11b", family="vlm",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=128256,
        cross_attn_every=5, num_image_tokens=1600, vision_dim=1280,
        rope_theta=500000.0, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        max_seq_len=131072,
    )
    kw.update(overrides)
    return ModelConfig(**kw)


def smoke_config() -> ModelConfig:
    return config(num_layers=10, d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, d_ff=128, vocab_size=512, cross_attn_every=5,
                  num_image_tokens=16, vision_dim=48, dtype=jnp.float32,
                  param_dtype=jnp.float32, max_seq_len=128)


def train_config(mesh=None, **kw):
    kw.setdefault("microbatches", 8)
    return make_train_config(sync_mode="sparcml", peak_lr=1e-4, **kw)
