"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 [arXiv:2407.21783].

Arch-applicability note (DESIGN.md §3/§4): at 405B the per-rank
error-feedback residual of TopK SGD is O(model size) per data rank, which
is incompatible with the ZeRO-3 placement this model needs to fit a 256-chip
pod — so the full-scale train cell uses dense sync (FSDP) with bf16
optimizer state; sparcml is exercised on the reduced smoke config.
"""
import jax.numpy as jnp
from repro.models.config import ModelConfig
from repro.configs._common import make_train_config


def config(**overrides) -> ModelConfig:
    kw = dict(
        name="llama3-405b", family="dense",
        num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
        head_dim=128, d_ff=53248, vocab_size=128256,
        rope_theta=500000.0, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        max_seq_len=131072,
    )
    kw.update(overrides)
    return ModelConfig(**kw)


def smoke_config() -> ModelConfig:
    return config(num_layers=6, d_model=128, num_heads=8, num_kv_heads=2,
                  head_dim=16, d_ff=256, vocab_size=512, dtype=jnp.float32,
                  param_dtype=jnp.float32, max_seq_len=128)


def train_config(mesh=None, **kw):
    kw.setdefault("opt_dtype", jnp.bfloat16)   # fits 16 GB HBM (DESIGN §2.3)
    kw.setdefault("microbatches", 16)
    return make_train_config(sync_mode="dense", fsdp=True, peak_lr=8e-5, **kw)
