"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752(per-expert)
vocab=100352, MoE 16 experts top-4 (fine-grained) [hf:databricks/dbrx-base].

Arch-applicability note: like llama3-405b, per-rank EF residuals don't
compose with the FSDP placement this model needs at 256 chips -> dense sync
at full scale, sparcml on the smoke config (DESIGN.md §3/§4)."""
import jax.numpy as jnp
from repro.models.config import ModelConfig
from repro.configs._common import make_train_config


def config(**overrides) -> ModelConfig:
    kw = dict(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=10752, vocab_size=100352,
        num_experts=16, experts_per_token=4, moe_d_ff=10752,
        capacity_factor=1.25, rope_theta=500000.0,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, max_seq_len=32768,
    )
    kw.update(overrides)
    return ModelConfig(**kw)


def smoke_config() -> ModelConfig:
    return config(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, d_ff=128, vocab_size=512, num_experts=4,
                  experts_per_token=2, moe_d_ff=128, dtype=jnp.float32,
                  param_dtype=jnp.float32, max_seq_len=128)


def train_config(mesh=None, **kw):
    kw.setdefault("opt_dtype", jnp.bfloat16)
    kw.setdefault("microbatches", 8)
    return make_train_config(sync_mode="dense", fsdp=True, peak_lr=1e-4, **kw)
