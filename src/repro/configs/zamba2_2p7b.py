"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + SHARED attention block every
6 layers [arXiv:2411.15242].

long_500k: the shared attention runs a 4096-token sliding window (ring KV
cache) so decode state stays bounded — noted TPU/long-context adaptation."""
import jax.numpy as jnp
from repro.models.config import ModelConfig
from repro.configs._common import make_train_config


def config(long_context: bool = False, **overrides) -> ModelConfig:
    kw = dict(
        name="zamba2-2.7b", family="hybrid",
        num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
        head_dim=80, d_ff=10240, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
        attn_every=6, sliding_window=4096 if long_context else 0,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, max_seq_len=1 << 20,
    )
    kw.update(overrides)
    return ModelConfig(**kw)


def smoke_config() -> ModelConfig:
    return config(num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
                  head_dim=16, d_ff=128, ssm_state=16, ssm_head_dim=16,
                  ssm_chunk=8, attn_every=2, vocab_size=512,
                  dtype=jnp.float32, param_dtype=jnp.float32, max_seq_len=128)


def train_config(mesh=None, **kw):
    kw.setdefault("microbatches", 8)
    return make_train_config(sync_mode="sparcml", peak_lr=3e-4, **kw)
