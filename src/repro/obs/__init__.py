"""repro.obs — unified observability: tracing, metrics, drift audit,
health rules, flight recorder.

One facade object (:class:`Observability`) bundles the concerns so
every layer threads a single handle:

    obs = configure(trace=True, metrics=True, recorder="blackbox.json")
    with obs.span("driver/dispatch", step=i): ...
    obs.event("adapt/replan_accepted", signature=sig)
    obs.export(trace_path="trace.json", metrics_path="metrics.jsonl")

The second tier (DESIGN.md §10.5–§10.7) layers on the same registry:
:class:`~repro.obs.health.HealthMonitor` runs windowed compression-
health rules over it, :class:`~repro.obs.recorder.FlightRecorder`
(``obs.recorder``) dumps a bounded ring to ``blackbox.json`` on
crashes, and ``python -m repro.obs.report`` renders the exported
artifacts into a terminal summary.

The module-level default is OFF (``obs.OFF``): every span is a shared
no-op context manager, every event a single attribute check — the
pipelined driver's retire stays the only sync point and the hot path is
unchanged (tests/test_obs.py pins both). ``resolve`` maps the ubiquitous
``obs=None`` parameter onto the current default so call sites stay
one-liners.
"""
from __future__ import annotations

from repro.obs.audit import (
    DriftAuditor,
    attribute_step_phases,
    audit_serve_plan,
    audit_sync_plan,
    time_phases,
)
from repro.obs.health import (
    HealthConfig,
    HealthEvent,
    HealthMonitor,
    rank_events,
)
from repro.obs.metrics import (
    SCHEMA_VERSION,
    JsonlSink,
    MetricsRegistry,
    record_bucket_telemetry,
)
from repro.obs.recorder import FlightRecorder
from repro.obs.trace import NULL_TRACER, Tracer, validate_span_tree


class Observability:
    """Tracer + metrics registry + drift auditor (+ optional flight
    recorder) behind one handle."""

    def __init__(self, tracer: Tracer | None = None,
                 metrics: MetricsRegistry | None = None,
                 audit: DriftAuditor | None = None,
                 recorder=None):
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None \
            else MetricsRegistry(enabled=False)
        self.audit = audit
        # FlightRecorder (repro.obs.recorder) or None; runtime loops
        # check the attribute and dump on exception/watchdog/signal.
        self.recorder = recorder

    @property
    def trace_on(self) -> bool:
        return self.tracer.enabled

    @property
    def metrics_on(self) -> bool:
        return self.metrics.enabled

    @property
    def enabled(self) -> bool:
        return self.trace_on or self.metrics_on

    # -- delegation shorthands --------------------------------------------
    def span(self, name: str, /, cat: str = "host", **args):
        return self.tracer.span(name, cat, **args)

    def instant(self, name: str, /, cat: str = "host", **args) -> None:
        self.tracer.instant(name, cat, **args)

    def event(self, name: str, /, **fields) -> None:
        """A structured event lands in BOTH sinks: the metrics event log
        and (as an instant marker) the trace timeline. ``name`` is
        positional-only so fields may themselves be named ``name``."""
        self.metrics.event(name, **fields)
        self.tracer.instant(name, cat="event")

    def export(self, trace_path: str | None = None,
               metrics_path: str | None = None,
               meta: dict | None = None) -> dict:
        """Flush whichever sinks have destinations; returns written paths.
        The audit report (when an auditor is attached) rides the metrics
        JSONL as ``audit/*`` events, emitted here."""
        out = {}
        if (self.audit is not None and len(self.audit) and self.metrics_on
                and not self.metrics.events_named("audit/algorithm_residual")):
            # the audit probes emit() themselves when handed the registry;
            # don't double the residual events here
            self.audit.emit(self.metrics)
        if trace_path and self.trace_on:
            out["trace"] = self.tracer.export(trace_path, meta=meta)
        if metrics_path and self.metrics_on:
            out["metrics"] = self.metrics.dump_jsonl(metrics_path, meta=meta)
        return out


OFF = Observability()

_default = OFF


def configure(trace: bool = False, metrics: bool = False,
              audit: bool = False, *, set_as_default: bool = True,
              flag_ratio: float = 3.0,
              recorder: str | bool = False,
              recorder_capacity: int = 256) -> Observability:
    """Build (and by default install) an Observability handle.

    ``recorder`` attaches a :class:`~repro.obs.recorder.FlightRecorder`:
    pass a path for its ``blackbox.json`` (or ``True`` for the default
    name in the CWD). The runtime driver and serve engine dump it on
    exception and watchdog fire; call
    ``obs.recorder.install_signal_handlers()`` from the main thread to
    add the signal trigger."""
    ob = Observability(
        tracer=Tracer(enabled=True) if trace else NULL_TRACER,
        metrics=MetricsRegistry(enabled=metrics),
        audit=DriftAuditor(flag_ratio=flag_ratio) if audit else None,
    )
    if recorder:
        path = recorder if isinstance(recorder, str) else "blackbox.json"
        ob.recorder = FlightRecorder(path, capacity=recorder_capacity,
                                     obs=ob)
    if set_as_default:
        set_default(ob)
    return ob


def get_default() -> Observability:
    return _default


def set_default(ob: Observability) -> None:
    global _default
    _default = ob


def resolve(ob: Observability | None) -> Observability:
    """Map the ``obs=None`` call-site convention onto the default."""
    return ob if ob is not None else _default


__all__ = [
    "SCHEMA_VERSION",
    "DriftAuditor",
    "FlightRecorder",
    "HealthConfig",
    "HealthEvent",
    "HealthMonitor",
    "JsonlSink",
    "MetricsRegistry",
    "NULL_TRACER",
    "Observability",
    "OFF",
    "Tracer",
    "attribute_step_phases",
    "audit_serve_plan",
    "audit_sync_plan",
    "configure",
    "get_default",
    "rank_events",
    "record_bucket_telemetry",
    "resolve",
    "set_default",
    "time_phases",
    "validate_span_tree",
]
