"""Structured span tracer with Chrome-trace export (DESIGN.md §10).

One process-wide clock (``perf_counter`` relative to tracer birth), one
append-only event list, Chrome Trace Event JSON out — the file loads
directly in ``chrome://tracing`` / Perfetto. Three event kinds:

  span(name)       a host-side complete event ("ph": "X"), recorded by a
                   context manager; spans opened on the same thread nest
                   by construction (enter/exit is LIFO per thread), so
                   the exported tree is always well-formed
  complete(...)    an explicitly-timed complete event — how DERIVED
                   device-phase spans (compute vs exposed comm, per
                   bucket) are laid into a measured retire interval by
                   the runtime (see obs/audit.attribute_step_phases)
  instant(name)    a zero-duration marker ("ph": "i") — plan swaps,
                   forced switches, checkpoint boundaries

The tracer NEVER touches the device: no ``block_until_ready``, no array
reads. Everything it records is host wall time, so tracing adds no sync
points — the pipelined driver's retire remains the only one (the
invariant tests/test_obs.py pins). A disabled tracer returns a shared
null context manager from :func:`Tracer.span`; the hot-path cost of
tracing-off is one attribute check.
"""
from __future__ import annotations

import json
import os
import threading
import time


class _NullSpan:
    """Shared no-op context manager (tracer disabled)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One open host span; records a complete ("X") event on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self._tracer
        tr._append({
            "name": self._name, "cat": self._cat, "ph": "X",
            "ts": (self._t0 - tr._born) * 1e6,
            "dur": (t1 - self._t0) * 1e6,
            "pid": tr.pid, "tid": threading.get_ident(),
            **({"args": self._args} if self._args else {}),
        })
        return False


class Tracer:
    """Append-only Chrome-trace event recorder.

    ``enabled=False`` builds a permanently-off tracer (``NULL_TRACER`` is
    the shared instance): every record call is a no-op and ``span``
    returns the shared null context manager.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: list[dict] = []
        self.pid = os.getpid()
        self._born = time.perf_counter()
        self._lock = threading.Lock()

    # -- clock -------------------------------------------------------------
    def now_us(self) -> float:
        """Current trace-relative timestamp (microseconds)."""
        return (time.perf_counter() - self._born) * 1e6

    def to_us(self, t_perf_counter: float) -> float:
        """Map an absolute ``perf_counter`` reading onto the trace clock."""
        return (t_perf_counter - self._born) * 1e6

    # -- recording ---------------------------------------------------------
    def _append(self, ev: dict) -> None:
        with self._lock:
            self.events.append(ev)

    def span(self, name: str, /, cat: str = "host", **args):
        """Context manager recording one host span."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    def complete(self, name: str, cat: str, /, ts_us: float, dur_us: float,
                 tid: int | str = "derived", **args) -> None:
        """Record an explicitly-timed complete event (derived spans)."""
        if not self.enabled:
            return
        self._append({
            "name": name, "cat": cat, "ph": "X",
            "ts": float(ts_us), "dur": float(max(dur_us, 0.0)),
            "pid": self.pid, "tid": tid,
            **({"args": args} if args else {}),
        })

    def instant(self, name: str, /, cat: str = "host", **args) -> None:
        if not self.enabled:
            return
        self._append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self.now_us(), "pid": self.pid,
            "tid": threading.get_ident(),
            **({"args": args} if args else {}),
        })

    def counter(self, name: str, **series) -> None:
        """Chrome counter event ("C"): a stacked timeline in the viewer."""
        if not self.enabled:
            return
        self._append({
            "name": name, "cat": "metric", "ph": "C",
            "ts": self.now_us(), "pid": self.pid, "tid": 0,
            "args": {k: float(v) for k, v in series.items()},
        })

    # -- export ------------------------------------------------------------
    def export(self, path: str, meta: dict | None = None) -> str:
        """Write Chrome Trace Event JSON; returns the path."""
        with self._lock:
            events = list(self.events)
        doc = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": dict(meta or {}),
        }
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path

    def clear(self) -> None:
        with self._lock:
            self.events = []


NULL_TRACER = Tracer(enabled=False)


def validate_span_tree(events: list[dict], tol_us: float = 1.0) -> list[str]:
    """Check that complete events nest properly per (pid, tid): no span
    partially overlaps another on its own track. Returns a list of
    violation descriptions (empty = well-formed). Used by tests and by
    ``benchmarks/run.py --trace`` as a cheap artifact sanity check."""
    by_track: dict = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        by_track.setdefault((ev.get("pid"), ev.get("tid")), []).append(ev)
    bad = []
    for track, evs in by_track.items():
        evs = sorted(evs, key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        stack: list[dict] = []
        for ev in evs:
            t0, t1 = ev["ts"], ev["ts"] + ev.get("dur", 0.0)
            while stack and t0 >= stack[-1]["ts"] + stack[-1]["dur"] - tol_us:
                stack.pop()
            if stack:
                p0 = stack[-1]["ts"]
                p1 = p0 + stack[-1]["dur"]
                if t1 > p1 + tol_us or t0 < p0 - tol_us:
                    bad.append(
                        f"track {track}: span {ev['name']!r} "
                        f"[{t0:.1f},{t1:.1f}]us partially overlaps "
                        f"{stack[-1]['name']!r} [{p0:.1f},{p1:.1f}]us")
            stack.append(ev)
    return bad
