"""``python -m repro.obs.report`` — render exported obs artifacts.

Reads the metrics JSONL a run dumped (``MetricsRegistry.dump_jsonl`` /
``JsonlSink``) plus, optionally, its Chrome trace, and prints the
terminal summary a human wants after (or instead of) opening Perfetto:

  * run header metadata
  * per-bucket density/mass spectra: nnz, wire bytes, mass coverage and
    EF-residual norm percentiles per fusion bucket (DESIGN.md §10.5)
  * the health timeline: every ``health/*`` event in time order with
    severity markers
  * the recovery timeline: injected faults, guard trips, retries,
    checkpoint fallbacks, demotions and serve sheds (DESIGN.md §12)
  * the serve SLO attainment table: declared ServeConfig targets vs the
    measured p99s (``serve/slo_targets`` event + ``serve/*_steps``
    histograms)
  * a trace digest: span-tree validation + the heaviest span names

Pure stdlib + the repro.obs readers; no jax import, so it runs anywhere
the artifacts land (CI included: examples-smoke invokes it on the
train/serve artifacts it just produced).

Usage:
    python -m repro.obs.report RUN.jsonl [--trace TRACE.json] [--blackbox BB.json]
"""
from __future__ import annotations

import argparse
import json
import sys


def load_metrics_jsonl(path: str) -> dict:
    """Parse a dump into {header, metrics: {name: row}, events: [...]}.
    Tolerates trailing garbage lines (a crashed writer mid-line) —
    parseable prefix wins."""
    header = None
    metrics: dict = {}
    events: list = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError:
                break
            kind = row.get("kind")
            if kind == "header":
                header = row
            elif kind == "event":
                events.append(row)
            elif kind is not None:
                metrics[row.get("name", "?")] = row
    if header is None:
        raise ValueError(f"{path}: no JSONL header line "
                         "(not a metrics dump?)")
    return {"header": header, "metrics": metrics, "events": events}


def _fmt(v, width: int = 9) -> str:
    if v is None:
        return "-".rjust(width)
    try:
        return f"{float(v):.4g}".rjust(width)
    except (TypeError, ValueError):
        return str(v).rjust(width)


def _bucket_spectra(metrics: dict) -> list[str]:
    """Per-bucket table from the bucket/<name>/<col> histogram rows."""
    cols = ("nnz", "wire_bytes", "mass_coverage", "ef_norm")
    buckets: dict[str, dict] = {}
    for name, row in metrics.items():
        parts = name.split("/")
        if len(parts) == 3 and parts[0] == "bucket" and parts[2] in cols:
            buckets.setdefault(parts[1], {})[parts[2]] = row
    if not buckets:
        return ["  (no per-bucket telemetry in this run)"]
    w = max(len(b) for b in buckets)
    head = (f"  {'bucket':<{w}} {'nnz p50':>9} {'nnz p99':>9} "
            f"{'wire p50':>9} {'cov p50':>9} {'cov min':>9} "
            f"{'ef p50':>9} {'ef max':>9}")
    lines = [head, "  " + "-" * (len(head) - 2)]
    for b in sorted(buckets):
        r = buckets[b]

        def g(col, stat):
            return (r.get(col) or {}).get(stat)

        lines.append(
            f"  {b:<{w}} {_fmt(g('nnz', 'p50'))} {_fmt(g('nnz', 'p99'))} "
            f"{_fmt(g('wire_bytes', 'p50'))} "
            f"{_fmt(g('mass_coverage', 'p50'))} "
            f"{_fmt(g('mass_coverage', 'min'))} "
            f"{_fmt(g('ef_norm', 'p50'))} {_fmt(g('ef_norm', 'max'))}")
    return lines


def _health_timeline(events: list) -> list[str]:
    rows = [e for e in events
            if str(e.get("event", "")).startswith("health/")]
    if not rows:
        return ["  (no health events — clean run or health engine off)"]
    mark = {"critical": "!!", "warn": " !", "info": "  "}
    lines = []
    for e in sorted(rows, key=lambda e: e.get("t", 0.0)):
        sev = e.get("severity", "info")
        lines.append(
            f"  t+{float(e.get('t', 0.0)):7.2f}s {mark.get(sev, '  ')} "
            f"[{sev:<8}] {e['event'][len('health/'):]:<15} "
            f"{e.get('subject', '?'):<20} {e.get('message', '')}")
    return lines


def _recovery_timeline(metrics: dict, events: list) -> list[str]:
    """Fault/recovery story of the run (DESIGN.md §12): injected faults,
    guard trips, retries/aborts, checkpoint fallbacks, demotions and
    serve sheds in time order, closed by the recovery counters. Works on
    torn tails too — load_metrics_jsonl already dropped them."""
    prefixes = ("faults/", "recovery/", "serve/shed", "adapt/fault_")
    rows = [e for e in events
            if str(e.get("event", "")).startswith(prefixes)
            or e.get("event") in ("driver/restart", "health/nonfinite")]
    counters = {n: r.get("value") for n, r in sorted(metrics.items())
                if r.get("kind") == "counter"
                and n.startswith(("faults/", "recovery/", "guard/",
                                  "serve/shed", "serve/retries"))}
    if not rows and not counters:
        return ["  (no fault/recovery activity in this run)"]
    lines = []
    for e in sorted(rows, key=lambda e: e.get("t", 0.0)):
        detail = " ".join(
            f"{k}={e[k]}" for k in sorted(e)
            if k not in ("event", "t", "kind", "message"))
        msg = e.get("message", "")
        lines.append(f"  t+{float(e.get('t', 0.0)):7.2f}s "
                     f"{e['event']:<24} {detail}"
                     + (f"  {msg}" if msg else ""))
    if counters:
        lines.append("  counters: " + " ".join(
            f"{n}={v}" for n, v in counters.items()))
    return lines


def _slo_table(metrics: dict, events: list) -> list[str]:
    targets: dict = {}
    for e in events:
        if e.get("event") == "serve/slo_targets":
            # keep only the numeric target fields; the JSONL record also
            # carries bookkeeping keys (kind, event, t)
            targets.update({k: v for k, v in e.items()
                            if k not in ("event", "t", "kind")
                            and isinstance(v, (int, float))})
    if not targets:
        return ["  (no SLO targets declared — pass a ServeConfig with "
                "slo_* set)"]
    head = (f"  {'slo':<14} {'target':>9} {'p99':>9} {'p50':>9} "
            f"{'attained':>9}")
    lines = [head, "  " + "-" * (len(head) - 2)]
    for key in sorted(targets):
        t = targets[key]
        row = metrics.get(f"serve/{key}_steps") or {}
        p99 = row.get("p99")
        ok = ("-" if p99 is None or t is None
              else ("yes" if float(p99) <= float(t) else "NO"))
        lines.append(f"  {key:<14} {_fmt(t)} {_fmt(p99)} "
                     f"{_fmt(row.get('p50'))} {ok:>9}")
    return lines


def _trace_digest(path: str) -> list[str]:
    from repro.obs.trace import validate_span_tree

    doc = json.load(open(path))
    evs = doc.get("traceEvents", [])
    spans = [e for e in evs if e.get("ph") == "X"]
    problems = validate_span_tree(evs)
    by_name: dict[str, float] = {}
    for s in spans:
        by_name[s["name"]] = by_name.get(s["name"], 0.0) + s.get("dur", 0.0)
    lines = [f"  {len(evs)} events, {len(spans)} spans; span tree "
             + ("OK" if not problems else f"{len(problems)} problem(s)")]
    for p in problems[:5]:
        lines.append(f"    problem: {p}")
    for name, us in sorted(by_name.items(), key=lambda kv: -kv[1])[:8]:
        lines.append(f"  {name:<32} {us / 1e3:10.3f} ms total")
    return lines


def _blackbox_digest(path: str) -> list[str]:
    doc = json.load(open(path))
    notes = doc.get("notes", [])
    return [
        f"  reason={doc.get('reason')!r} uptime={doc.get('uptime_s', 0):.1f}s "
        f"notes={len(notes)} trace_tail={len(doc.get('trace_tail', []))} "
        f"event_tail={len(doc.get('event_tail', []))}",
        *(f"    last note: {json.dumps(notes[-1])}" if notes else ()),
    ]


def render(metrics_path: str, trace_path: str | None = None,
           blackbox_path: str | None = None) -> str:
    doc = load_metrics_jsonl(metrics_path)
    meta = doc["header"].get("meta") or {}
    out = [f"== obs report: {metrics_path} "
           f"(schema v{doc['header'].get('schema_version')}) =="]
    if meta:
        out.append("  " + " ".join(f"{k}={v}" for k, v in
                                   sorted(meta.items())[:8]))
    out.append("")
    out.append("-- per-bucket density/mass spectra --")
    out.extend(_bucket_spectra(doc["metrics"]))
    out.append("")
    out.append("-- health timeline --")
    out.extend(_health_timeline(doc["events"]))
    out.append("")
    out.append("-- recovery timeline --")
    out.extend(_recovery_timeline(doc["metrics"], doc["events"]))
    out.append("")
    out.append("-- serve SLO attainment --")
    out.extend(_slo_table(doc["metrics"], doc["events"]))
    if trace_path:
        out.append("")
        out.append(f"-- trace digest: {trace_path} --")
        out.extend(_trace_digest(trace_path))
    if blackbox_path:
        out.append("")
        out.append(f"-- flight recorder: {blackbox_path} --")
        out.extend(_blackbox_digest(blackbox_path))
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a run's obs artifacts as a terminal summary")
    ap.add_argument("metrics", help="metrics JSONL path (dump_jsonl output)")
    ap.add_argument("--trace", default=None, help="Chrome trace JSON path")
    ap.add_argument("--blackbox", default=None,
                    help="flight-recorder blackbox.json path")
    args = ap.parse_args(argv)
    print(render(args.metrics, args.trace, args.blackbox))
    return 0


if __name__ == "__main__":
    sys.exit(main())
