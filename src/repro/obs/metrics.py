"""Metrics registry: counters, gauges, histograms, series + sinks.

Single process-wide registry shape (DESIGN.md §10):

  Counter    monotonically increasing int (plan swaps, stragglers,
             restarts, clamp-fold drops)
  Gauge      last-written float (current density, straggler median)
  Histogram  full sample list with count/sum/mean/min/max/percentiles
             (per-bucket nnz and wire bytes, serve TTFT/TPOT, step wall)
  Series     append-only typed list whose ``.data`` IS a plain python
             list — DriverLog's public fields (losses, step_times, ...)
             are views of Series data, so PR-2 consumers keep indexing
             real lists while the registry owns storage
  Event      a timestamped dict (controller decisions with the
             densities/costs that justified them, audit residuals)

Two sinks: ``dump_jsonl`` (header line with ``schema_version`` + run
metadata, then one line per metric and per event) and ``summary()``
(aligned terminal table). No dependencies beyond numpy; everything is
host-side only — recording a metric never touches a device value that
isn't already a host scalar.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

SCHEMA_VERSION = 2


def _jsonable(v):
    """Best-effort conversion of numpy/jax scalars and containers."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if hasattr(v, "item") and getattr(v, "ndim", None) in (0, None):
        try:
            return v.item()
        except Exception:
            pass
    if hasattr(v, "tolist"):
        try:
            return v.tolist()
        except Exception:
            pass
    return str(v)


class Counter:
    kind = "counter"

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += int(n)

    def snapshot(self) -> dict:
        return {"value": self.value}

    def brief(self) -> str:
        return str(self.value)


class Gauge:
    kind = "gauge"

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = None

    def set(self, v) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        return {"value": self.value}

    def brief(self) -> str:
        return "-" if self.value is None else f"{self.value:.6g}"


class Histogram:
    """Keeps every sample (runs here are short); percentiles on demand."""

    kind = "histogram"

    __slots__ = ("name", "values")

    def __init__(self, name: str):
        self.name = name
        self.values: list[float] = []

    def observe(self, v) -> None:
        self.values.append(float(v))

    def observe_many(self, vs) -> None:
        self.values.extend(float(v) for v in np.asarray(vs).ravel())

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.values, q)) if self.values else float("nan")

    def snapshot(self) -> dict:
        if not self.values:
            return {"count": 0}
        a = np.asarray(self.values, dtype=np.float64)
        p50, p90, p99 = np.percentile(a, [50, 90, 99])
        return {
            "count": int(a.size), "sum": float(a.sum()),
            "mean": float(a.mean()), "min": float(a.min()),
            "max": float(a.max()), "p50": float(p50),
            "p90": float(p90), "p99": float(p99),
        }

    def brief(self) -> str:
        s = self.snapshot()
        if not s["count"]:
            return "empty"
        return (f"n={s['count']} mean={s['mean']:.4g} p50={s['p50']:.4g} "
                f"p90={s['p90']:.4g} p99={s['p99']:.4g}")


class Series:
    """Append-only list metric. ``.data`` is the underlying plain list —
    hand it out as a public field and callers index it like any list."""

    kind = "series"

    __slots__ = ("name", "data")

    def __init__(self, name: str):
        self.name = name
        self.data: list = []

    def append(self, v) -> None:
        self.data.append(v)

    def snapshot(self) -> dict:
        return {"count": len(self.data), "values": _jsonable(self.data)}

    def brief(self) -> str:
        return f"n={len(self.data)}"


class MetricsRegistry:
    """Get-or-create metric store plus a structured event log."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.metrics: dict[str, object] = {}
        self.events: list[dict] = []
        self._born = time.time()

    def _get(self, name: str, cls):
        m = self.metrics.get(name)
        if m is None:
            m = cls(name)
            self.metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {type(m).__name__}, "
                f"requested {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def series(self, name: str) -> Series:
        return self._get(name, Series)

    def event(self, name: str, /, **fields) -> None:
        """Record a structured event (no-op when the registry is off).
        ``name`` is positional-only so fields may themselves be named
        ``name`` (e.g. a bench row's name)."""
        if not self.enabled:
            return
        self.events.append({
            "event": name, "t": time.time() - self._born,
            **{k: _jsonable(v) for k, v in fields.items()},
        })

    def events_named(self, name: str) -> list[dict]:
        return [e for e in self.events if e["event"] == name]

    # -- sinks -------------------------------------------------------------
    def dump_jsonl(self, path: str, meta: dict | None = None) -> str:
        """JSONL sink: header line, then one line per metric, then one per
        event. The header carries ``schema_version`` and run metadata so
        files are joinable across PRs. The write is atomic (tmp file +
        flush + fsync + rename) so an abnormal exit mid-dump can never
        leave a truncated file — readers see the previous complete dump
        or the new one, nothing in between."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = os.path.join(d, f".{os.path.basename(path)}.tmp.{os.getpid()}")
        with open(tmp, "w") as f:
            f.write(json.dumps({
                "kind": "header", "schema_version": SCHEMA_VERSION,
                "meta": _jsonable(meta or {}),
            }) + "\n")
            for name in sorted(self.metrics):
                m = self.metrics[name]
                f.write(json.dumps({
                    "kind": m.kind, "name": name, **_jsonable(m.snapshot()),
                }) + "\n")
            for ev in self.events:
                f.write(json.dumps({"kind": "event", **ev}) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        return path

    def jsonl_sink(self, path: str, meta: dict | None = None) -> "JsonlSink":
        """Open a context-managed JSONL sink bound to this registry: a
        handle that re-dumps the registry on ``flush()``, on context
        exit (including exceptions), and — as a last resort — at
        interpreter exit via ``atexit``, so a run killed halfway still
        leaves a valid, parseable JSONL behind instead of nothing."""
        return JsonlSink(self, path, meta)

    def summary(self) -> str:
        """Aligned terminal table of every metric plus event counts."""
        lines = []
        if self.metrics:
            w = max(len(n) for n in self.metrics)
            for name in sorted(self.metrics):
                m = self.metrics[name]
                lines.append(f"  {name:<{w}}  {m.kind:<9}  {m.brief()}")
        by_name: dict[str, int] = {}
        for ev in self.events:
            by_name[ev["event"]] = by_name.get(ev["event"], 0) + 1
        for name in sorted(by_name):
            lines.append(f"  {name:<{max(len(n) for n in by_name)}}  "
                         f"event     x{by_name[name]}")
        return "\n".join(lines) if lines else "  (no metrics recorded)"


class JsonlSink:
    """Crash-safe handle on a metrics JSONL file (DESIGN.md §10.2).

    ``MetricsRegistry.dump_jsonl`` alone only writes when the program
    reaches the final export call — a run that dies early leaves no
    metrics at all. The sink closes that gap: open it at run START, and
    every exit path (normal return, exception via the ``with`` block,
    SIGTERM-free interpreter shutdown via ``atexit``) re-dumps whatever
    the registry holds at that moment. Each dump is the atomic
    whole-file write of ``dump_jsonl``, so the file on disk is always a
    complete, parseable JSONL — partial runs included. ``meta`` may be
    mutated (or replaced via the attribute) before the final flush."""

    def __init__(self, registry: MetricsRegistry, path: str,
                 meta: dict | None = None):
        import atexit

        self.registry = registry
        self.path = path
        self.meta = dict(meta or {})
        self._closed = False
        self._atexit = atexit
        atexit.register(self._atexit_flush)

    def flush(self) -> str:
        return self.registry.dump_jsonl(self.path, self.meta)

    def _atexit_flush(self) -> None:
        if not self._closed:
            try:
                self.flush()
            except Exception:
                pass  # interpreter teardown — never raise from atexit

    def close(self) -> str:
        """Final flush + atexit deregistration (idempotent)."""
        if not self._closed:
            self._closed = True
            try:
                self._atexit.unregister(self._atexit_flush)
            except Exception:
                pass
            return self.flush()
        return self.path

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


NULL_REGISTRY = MetricsRegistry(enabled=False)


def record_bucket_telemetry(registry: MetricsRegistry, telemetry: dict,
                            *, prefix: str = "bucket") -> None:
    """Fold one step's in-graph telemetry into per-bucket histograms.

    Accepts both wire widths: (k, 2) [nnz, wire] (the PR-3 format the
    serve activation exchange still emits) and (k, 4) [nnz, wire, mass
    coverage, EF-residual norm] (the training executor, DESIGN.md
    §10.5). The extra columns land in ``<prefix>/<name>/mass_coverage``
    and ``.../ef_norm`` histograms the health engine windows over."""
    if not registry.enabled:
        return
    for name, arr in telemetry.items():
        # a single step's (2,)/(4,) row is one-row 2-D
        a = np.atleast_2d(np.asarray(arr))
        if a.ndim != 2 or a.shape[-1] not in (2, 4):
            continue
        registry.histogram(f"{prefix}/{name}/nnz").observe_many(a[:, 0])
        registry.histogram(f"{prefix}/{name}/wire_bytes").observe_many(a[:, 1])
        if a.shape[-1] == 4:
            registry.histogram(
                f"{prefix}/{name}/mass_coverage").observe_many(a[:, 2])
            registry.histogram(
                f"{prefix}/{name}/ef_norm").observe_many(a[:, 3])
