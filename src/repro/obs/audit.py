"""Cost-model drift auditor: predicted vs measured, per algorithm.

The adaptive controller picks bucket algorithms from
``cost_model.bucket_time`` and plans steps with ``t_step_overlapped``;
nobody checks those numbers against reality. This module closes the loop
(DESIGN.md §10):

  DriftAuditor            joins (algorithm, predicted_s, measured_s)
                          samples and reports per-algorithm residual
                          stats — median measured/predicted ratio, mean
                          relative error, a ``flagged`` bit when the
                          ratio leaves the trust band — i.e. when
                          ``select_algorithm`` is being lied to
  audit_sync_plan         probes each distinct bucket signature of a
                          training SyncPlan with the standalone
                          ``make_sparse_allreduce`` collective and joins
                          against ``bucket_time``
  audit_serve_plan        same join for a ServePlan's activation
                          exchange (``exchange_activation_spmd`` vs the
                          stream/dense cost entries)
  attribute_step_phases   lays the overlap model's compute / exposed-
                          comm split into ONE measured step interval —
                          the derived device-phase spans the tracer
                          draws (solves the model for t_compute, then
                          normalizes so the spans tile the measurement)

Probes run the real executor halves but OUTSIDE the training loop (at
drain barriers or run end), so the audit adds no sync points to the
pipelined hot path. The per-algorithm median ratio doubles as the
calibrator's quality signal: ``utils.calibrate`` records its post-fit
ladder residuals here, and ``net_scale_hint`` says how far the fitted
alpha-beta model sits from what the probes actually measured.
"""
from __future__ import annotations

import time

import numpy as np


class DriftAuditor:
    """Accumulates predicted-vs-measured samples; reports per algorithm.

    ``flag_ratio`` bounds the trust band: an algorithm whose median
    measured/predicted ratio falls outside [1/flag_ratio, flag_ratio]
    is flagged as drifted.
    """

    def __init__(self, flag_ratio: float = 3.0):
        if flag_ratio <= 1.0:
            raise ValueError("flag_ratio must be > 1")
        self.flag_ratio = float(flag_ratio)
        self.samples: list[dict] = []

    def record(self, algorithm: str, name: str, predicted_s: float,
               measured_s: float, **extra) -> None:
        self.samples.append({
            "algorithm": algorithm, "name": name,
            "predicted_s": float(predicted_s),
            "measured_s": float(measured_s), **extra,
        })

    def __len__(self) -> int:
        return len(self.samples)

    # -- joins -------------------------------------------------------------
    def per_algorithm(self) -> dict[str, dict]:
        by: dict[str, list[dict]] = {}
        for s in self.samples:
            by.setdefault(s["algorithm"], []).append(s)
        out = {}
        for alg, rows in sorted(by.items()):
            pred = np.asarray([r["predicted_s"] for r in rows])
            meas = np.asarray([r["measured_s"] for r in rows])
            ok = pred > 0
            ratio = np.where(ok, meas / np.where(ok, pred, 1.0), np.nan)
            med = float(np.nanmedian(ratio)) if ok.any() else float("nan")
            rel = np.abs(meas - pred) / np.where(ok, pred, 1.0)
            out[alg] = {
                "count": int(len(rows)),
                "predicted_total_s": float(pred.sum()),
                "measured_total_s": float(meas.sum()),
                "median_ratio": med,
                "mean_rel_err": float(np.nanmean(np.where(ok, rel, np.nan)))
                if ok.any() else float("nan"),
                "flagged": bool(np.isfinite(med) and not
                                (1.0 / self.flag_ratio <= med
                                 <= self.flag_ratio)),
            }
        return out

    def net_scale_hint(self) -> float | None:
        """Overall median measured/predicted ratio — the single scalar a
        calibrator can fold back into its fitted params (``None`` until
        at least one positive-prediction sample exists)."""
        r = [s["measured_s"] / s["predicted_s"] for s in self.samples
             if s["predicted_s"] > 0]
        return float(np.median(r)) if r else None

    def flagged_algorithms(self) -> list[str]:
        return [a for a, st in self.per_algorithm().items() if st["flagged"]]

    def report(self) -> dict:
        return {
            "kind": "drift_audit",
            "flag_ratio": self.flag_ratio,
            "samples": int(len(self.samples)),
            "net_scale_hint": self.net_scale_hint(),
            "per_algorithm": self.per_algorithm(),
            "flagged": self.flagged_algorithms(),
        }

    def emit(self, registry) -> None:
        """Mirror the per-algorithm join into the metrics registry as
        ``audit/algorithm_residual`` events (one per algorithm)."""
        for alg, st in self.per_algorithm().items():
            registry.event("audit/algorithm_residual", algorithm=alg, **st)
        hint = self.net_scale_hint()
        if hint is not None:
            registry.gauge("audit/net_scale_hint").set(hint)

    def summary(self) -> str:
        stats = self.per_algorithm()
        if not stats:
            return "  (no audit samples)"
        w = max(len(a) for a in stats)
        lines = [f"  {'algorithm':<{w}}  {'n':>3}  {'pred_ms':>9}  "
                 f"{'meas_ms':>9}  {'med_ratio':>9}  flag"]
        for alg, st in stats.items():
            lines.append(
                f"  {alg:<{w}}  {st['count']:>3}  "
                f"{st['predicted_total_s'] * 1e3:>9.3f}  "
                f"{st['measured_total_s'] * 1e3:>9.3f}  "
                f"{st['median_ratio']:>9.3f}  "
                f"{'DRIFT' if st['flagged'] else 'ok'}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Plan probes: time the real collectives, join against the cost model.
# ---------------------------------------------------------------------------

def _time_fn(fn, args, reps: int) -> float:
    """Best-of-reps wall time of a jitted call (one warmup)."""
    import jax

    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def audit_sync_plan(plan, mesh, axis_name: str = "data", *, net=None,
                    reps: int = 3, auditor: DriftAuditor | None = None,
                    registry=None, max_n: int = 1 << 22) -> DriftAuditor:
    """Probe each DISTINCT (algorithm, n, k) bucket signature of a
    training ``SyncPlan`` with the standalone sparse allreduce and record
    predicted (``bucket_time``) vs measured into ``auditor``.

    One probe per signature, not per bucket — same compiled collective,
    same cost entry. Buckets with n > ``max_n`` are skipped (probing them
    would dominate the run being audited)."""
    import jax
    import jax.numpy as jnp

    from repro.core.allreduce import make_sparse_allreduce
    from repro.core.cost_model import DEFAULT_NET, bucket_time

    net = net or DEFAULT_NET
    auditor = auditor if auditor is not None else DriftAuditor()
    p = mesh.shape[axis_name]
    cfg = plan.cfg
    vb = cfg.qsgd_bits if cfg.qsgd_bits is not None else 32
    impl = getattr(cfg, "impl", "auto")

    seen: set[tuple] = set()
    for g in plan.groups:
        for b in g.buckets:
            k = plan.bucket_k(g, b)
            sig = (b.algorithm, b.n, k)
            if sig in seen:
                continue
            seen.add(sig)
            if b.n > max_n:
                if registry is not None:
                    registry.event("audit/bucket_skipped", name=b.name,
                                   n=b.n, reason=f"n > max_n={max_n}")
                continue
            predicted = bucket_time(b.algorithm, p, k, b.n, net, vb)
            try:
                fn = make_sparse_allreduce(
                    mesh, axis_name, n=b.n,
                    k_per_bucket=cfg.k_per_bucket,
                    bucket_size=cfg.bucket_size,
                    algorithm=b.algorithm, impl=impl)
                key = jax.random.PRNGKey(hash(sig) & 0x7FFFFFFF)
                x = jax.random.normal(key, (p, b.n), jnp.float32)
                measured = _time_fn(fn, (x, None), reps)
            except Exception as e:  # pragma: no cover - probe robustness
                if registry is not None:
                    registry.event("audit/bucket_probe_failed", name=b.name,
                                   algorithm=b.algorithm, error=str(e))
                continue
            auditor.record(b.algorithm, b.name, predicted, measured,
                           n=b.n, k=k, p=p, kind="train_bucket")
    if registry is not None:
        auditor.emit(registry)
    return auditor


def audit_serve_plan(plan, mesh, axis_name: str = "model", *, net=None,
                     reps: int = 3, auditor: DriftAuditor | None = None,
                     registry=None) -> DriftAuditor:
    """Probe a ``ServePlan``'s activation exchange: time
    ``exchange_activation_spmd`` on a model-axis-sharded (p, T, d)
    partials stack and join against the stream/dense cost entries."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.comm.executor import exchange_activation_spmd
    from repro.core.cost_model import DEFAULT_NET, bucket_time

    net = net or DEFAULT_NET
    auditor = auditor if auditor is not None else DriftAuditor()
    p = mesh.shape[axis_name]

    for b in plan.buckets:
        predicted = bucket_time(b.algorithm, p, b.d, b.n, net)
        try:
            fn = jax.jit(lambda x, alg=b.algorithm:
                         exchange_activation_spmd(x, alg))
            key = jax.random.PRNGKey(hash((b.name, b.algorithm))
                                     & 0x7FFFFFFF)
            x = jax.random.normal(key, (p, b.tokens, b.d), jnp.float32)
            x = jax.device_put(x, NamedSharding(mesh, P(axis_name)))
            measured = _time_fn(fn, (x,), reps)
        except Exception as e:  # pragma: no cover - probe robustness
            if registry is not None:
                registry.event("audit/bucket_probe_failed", name=b.name,
                               algorithm=b.algorithm, error=str(e))
            continue
        auditor.record(b.algorithm, b.name, predicted, measured,
                       n=b.n, k=b.d, p=p, kind="serve_bucket")
    if registry is not None:
        auditor.emit(registry)
    return auditor


# ---------------------------------------------------------------------------
# Derived device-phase attribution.
# ---------------------------------------------------------------------------

def attribute_step_phases(dt_s: float, t_buckets, names=None,
                          staleness: int = 1) -> list[dict]:
    """Split one MEASURED step interval into compute + exposed per-bucket
    comm phases consistent with the overlap model (DESIGN.md §6).

    Solves ``t_c + sum(exposed_bucket_times(t_buckets, t_c)) == dt_s``
    for the compute share ``t_c`` (the RHS is monotone in ``t_c``, so a
    bisection converges); if the modeled full drain already exceeds the
    measurement, the whole interval is attributed to comm, scaled to
    fit. Returns phase dicts ``{name, cat, offset_s, dur_s, args}`` that
    tile ``[0, dt_s]`` exactly — ready for ``Tracer.complete`` at
    ``retire_end - dt_s``. These spans are DERIVED (model laid into a
    measurement), which their ``cat`` says out loud; the honest
    per-algorithm ground truth is the audit probes above."""
    from repro.core.cost_model import exposed_bucket_times

    t_buckets = [float(t) for t in t_buckets]
    names = list(names) if names is not None else [
        f"bucket{i}" for i in range(len(t_buckets))]
    dt_s = float(dt_s)
    if dt_s <= 0.0:
        return []

    if staleness == 0:
        total = sum(t_buckets)
        t_c = max(0.0, dt_s - total)
        exposed = list(t_buckets)
    else:
        lo, hi = 0.0, dt_s
        for _ in range(50):
            mid = 0.5 * (lo + hi)
            if mid + sum(exposed_bucket_times(t_buckets, mid)) < dt_s:
                lo = mid
            else:
                hi = mid
        t_c = 0.5 * (lo + hi)
        exposed = exposed_bucket_times(t_buckets, t_c)

    # Normalize so the phases tile the measured interval exactly.
    total = t_c + sum(exposed)
    scale = dt_s / total if total > 0 else 0.0
    phases = []
    off = 0.0
    if t_c > 0:
        dur = t_c * scale
        phases.append({"name": "compute", "cat": "device.derived",
                       "offset_s": off, "dur_s": dur,
                       "args": {"modeled_s": t_c}})
        off += dur
    for name, exp, full in zip(names, exposed, t_buckets):
        if exp <= 0:
            continue
        dur = exp * scale
        phases.append({"name": f"comm/{name}", "cat": "device.derived",
                       "offset_s": off, "dur_s": dur,
                       "args": {"exposed_s": exp, "bucket_s": full,
                                "hidden_s": full - exp}})
        off += dur
    return phases


def time_phases(phases: dict) -> dict[str, float]:
    """Time a dict of named thunks (the compose-able executor halves —
    e.g. ``{"reduce": ..., "apply": ...}``), blocking each: the direct
    measurement path for tests and offline audits. NOT for the pipelined
    hot loop (it syncs per phase by construction)."""
    import jax

    out = {}
    for name, fn in phases.items():
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        out[name] = time.perf_counter() - t0
    return out
